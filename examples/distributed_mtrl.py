"""The paper's algorithm on the production mesh: Dif-AltGDmin with nodes
= devices and AGREE = collective-permute ring gossip (shard_map), checked
against the single-host simulator.

With the declarative API this is ONE spec run on TWO substrates — the
``substrate`` field is the only difference between the simulator call and
the mesh call; min-B/gradient route through the same AltgdminEngine on
both, so the comparison isolates the gossip lowering (dense W product vs
collective-permute).

Needs multiple devices, so it re-executes itself with 8 fake CPU devices
if started with only one.

  PYTHONPATH=src python examples/distributed_mtrl.py
"""
import os
import subprocess
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    raise SystemExit(subprocess.run([sys.executable] + sys.argv).returncode)

import dataclasses

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp                                       # noqa: E402
from repro.api import (                                       # noqa: E402
    ExperimentSpec, ProblemSpec, TopologySpec, InitSpec, SolverSpec,
    run_experiment,
)


def main():
    L = 8
    print(f"devices: {len(jax.devices())} (one Dec-MTRL node per device)")
    spec = ExperimentSpec(
        name="mesh_vs_simulator",
        problem=ProblemSpec(d=100, T=64, r=4, n=30, L=L, kappa=2.0),
        topology=TopologySpec(family="ring", weights="circulant",
                              shifts=(-1, 1)),     # ring = ICI-native
        init=InitSpec(T_pm=25, T_con=8),
        solver=SolverSpec(name="dif_altgdmin", T_GD=200, T_con=2),
    )

    sim = run_experiment(spec, key=0)
    hw = run_experiment(dataclasses.replace(spec, substrate="mesh"), key=0)

    drift = float(jnp.max(jnp.abs(hw.U_nodes - sim.U_nodes)))
    print(f"mesh runtime   : SD₂ = {hw.final_sd_max:.2e}  (ring gossip, "
          f"T_con=2, 200 iters)")
    print(f"simulator (W)  : SD₂ = {sim.final_sd_max:.2e}")
    print(f"max |U_hw − U_sim| = {drift:.2e}  (identical algorithm, "
          f"collective-permute vs matmul gossip)")
    assert drift < 1e-7
    print("\nOnly the d×r iterate crossed the wire — X, y, B stayed "
          "node-local (federated).")


if __name__ == "__main__":
    main()
