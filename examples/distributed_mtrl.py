"""The paper's algorithm on the production mesh: Dif-AltGDmin with nodes
= devices and AGREE = collective-permute ring gossip (shard_map), checked
against the single-host simulator.

Needs multiple devices, so it re-executes itself with 8 fake CPU devices
if started with only one.

  PYTHONPATH=src python examples/distributed_mtrl.py
"""
import os
import subprocess
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    raise SystemExit(subprocess.run([sys.executable] + sys.argv).returncode)

import jax
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from repro.core import (                                      # noqa: E402
    generate_problem, node_view, decentralized_spectral_init,
    dif_altgdmin, dif_altgdmin_mesh, subspace_distance,
)
from repro.core.altgdmin import resolve_eta                   # noqa: E402
from repro.distributed import circulant_weights               # noqa: E402


def main():
    L = 8
    print(f"devices: {len(jax.devices())} (one Dec-MTRL node per device)")
    prob = generate_problem(jax.random.PRNGKey(0), d=100, T=64, r=4, n=30,
                            L=L, kappa=2.0)
    Xg, yg = node_view(prob)
    W = jnp.asarray(circulant_weights(L, (-1, 1)))    # ring = ICI-native
    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=prob.r, T_pm=25, T_con=8)
    eta = resolve_eta(None, prob.n, R_diag=init.R_diag, L=L)

    from repro.utils.compat import make_mesh
    mesh = make_mesh((L,), ("nodes",))
    U_hw, _ = dif_altgdmin_mesh(init.U0, Xg, yg, mesh, "nodes", eta=eta,
                                T_GD=200, T_con=2)
    sim = dif_altgdmin(init.U0, Xg, yg, W, eta=eta, T_GD=200, T_con=2,
                       U_star=prob.U_star)

    sd_hw = max(float(subspace_distance(U, prob.U_star)) for U in U_hw)
    sd_sim = float(sim.sd_max[-1])
    drift = float(jnp.max(jnp.abs(U_hw - sim.U_nodes)))
    print(f"mesh runtime   : SD₂ = {sd_hw:.2e}  (ring gossip, T_con=2, "
          f"200 iters)")
    print(f"simulator (W)  : SD₂ = {sd_sim:.2e}")
    print(f"max |U_hw − U_sim| = {drift:.2e}  (identical algorithm, "
          f"collective-permute vs matmul gossip)")
    assert drift < 1e-7
    print("\nOnly the d×r iterate crossed the wire — X, y, B stayed "
          "node-local (federated).")


if __name__ == "__main__":
    main()
