"""Federated multi-task representation learning with per-node task heads —
the paper's shared-U / local-B structure mapped onto a deep net.

L nodes train a SHARED transformer backbone on node-local data with
node-specific lm_heads (the federated carve-out: heads never leave their
node, exactly like the paper's B_g).  The backbone is synchronized by the
paper's diffusion strategy; we compare against the fusion-center
allreduce and against no communication at all.

The closing section runs the paper's *linear* shared-U/local-B object on
the same topology via the declarative API (``ExperimentSpec`` →
``run_experiment``) — the exact setting Theorem 1 covers — so the deep
and linear variants of the same federated structure sit side by side.

  PYTHONPATH=src python examples/federated_multitask.py
"""
import jax
import jax.numpy as jnp

from repro.api import (ExperimentSpec, InitSpec, ProblemSpec, SolverSpec,
                       TopologySpec, run_experiment)
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.distributed.aggregation import AggregationConfig
from repro.launch import steps as steps_lib
from repro.models import init_params
from repro.optim import adamw, constant

N_NODES, SEQ, PER_NODE_B, STEPS = 4, 64, 4, 120


def node_batches(cfg, step):
    """Each node draws from a DIFFERENT synthetic task distribution (its
    own seed ⇒ its own Markov stream) — multi-task, data-scarce."""
    batches = []
    for g in range(N_NODES):
        ds = SyntheticLM(cfg.vocab_size, SEQ, PER_NODE_B, seed=1000 + g)
        b = ds.batch(step)
        batches.append(b["tokens"])
    toks = jnp.stack(batches)                    # (L, B, S)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}


def run(strategy: str, t_con: int = 1, steps: int = STEPS):
    cfg = get_config("qwen3-1.7b").smoke()
    params = steps_lib.replicate_for_nodes(
        init_params(jax.random.PRNGKey(0), cfg), N_NODES)
    opt = adamw(constant(1e-3))
    state = steps_lib.TrainState(params, opt.init(params),
                                 jnp.zeros((), jnp.int32))
    agg = AggregationConfig(strategy=strategy, t_con=t_con,
                            local_patterns=("embed", "lm_head"))
    step_fn = jax.jit(steps_lib.make_train_step_fused(cfg, opt, agg,
                                                      N_NODES))
    losses = []
    for i in range(steps):
        state, m = step_fn(state, node_batches(cfg, i))
        losses.append(float(m["loss"]))
    # backbone spread: how far apart are the nodes' backbones?
    spreads = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            state.params)[0]:
        p = "/".join(str(getattr(k, "key", k)) for k in path)
        if "seg" in p:
            spreads.append(float(jnp.max(jnp.abs(leaf - leaf.mean(0)))))
    return losses, max(spreads)


def main():
    print(f"{N_NODES} nodes, node-local task heads (federated), "
          f"{STEPS} steps\n")
    print(f"{'strategy':<22}{'loss@0':>9}{'loss@end':>10}"
          f"{'backbone spread':>18}")
    for strategy, t_con in [("diffusion", 1), ("allreduce", 0),
                            ("local", 0)]:
        losses, spread = run(strategy, t_con)
        print(f"{strategy + (f' (T_con={t_con})' if t_con else ''):<22}"
              f"{losses[0]:>9.4f}{losses[-1]:>10.4f}{spread:>18.2e}")
    print("\nTakeaways:")
    print(" * diffusion tracks the fusion-center loss with 1 gossip round")
    print("   per step (params only, heads stay local — federated);")
    print(" * allreduce keeps replicas exactly equal (spread 0);")
    print(" * no communication ('local') lets node backbones drift apart.")

    # The linear-MTRL counterpart (the object Theorem 1 actually covers):
    # same shared-representation/local-head structure, same ring, driven
    # declaratively through the experiment API.
    spec = ExperimentSpec(
        name="linear_counterpart",
        problem=ProblemSpec(d=80, T=32, r=4, n=30, L=N_NODES, kappa=2.0,
                            dtype="float32"),
        topology=TopologySpec(family="ring", weights="circulant"),
        init=InitSpec(T_pm=20, T_con=6),
        solver=SolverSpec(name="dif_altgdmin", T_GD=150, T_con=1),
    )
    trace = run_experiment(spec, key=0)
    print(f"\nlinear MTRL counterpart (Dif-AltGDmin, T_con=1, same ring): "
          f"SD₂ {trace.sd_max[0]:.2e} → {trace.final_sd_max:.2e} "
          f"in {spec.solver.T_GD} iters — the shared-U/local-B structure "
          f"the deep variant above inherits.")


if __name__ == "__main__":
    main()
