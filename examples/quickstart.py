"""Quickstart — the paper in 60 seconds.

Reproduces (at reduced scale) the paper's Experiment 1 comparison: the
proposed Dif-AltGDmin vs centralized AltGDmin, Dec-AltGDmin, and the
DGD-variant, on synthetic multi-task linear regression over an
Erdős–Rényi network.  Prints the subspace-distance trajectory of each.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402

from repro.core import (                                    # noqa: E402
    generate_problem, node_view, decentralized_spectral_init,
    dif_altgdmin, dec_altgdmin, centralized_altgdmin, dgd_altgdmin,
)
from repro.core.altgdmin import resolve_eta                 # noqa: E402
from repro.distributed import (                             # noqa: E402
    erdos_renyi, metropolis_weights, gamma,
)


def main():
    # scaled-down Experiment 1: L=10 nodes, d=T=150, r=4, n=30, p=0.5
    L, d, T, r, n = 10, 150, 150, 4, 30
    prob = generate_problem(jax.random.PRNGKey(0), d=d, T=T, r=r, n=n,
                            L=L, kappa=2.0)
    Xg, yg = node_view(prob)
    graph = erdos_renyi(L, 0.5, seed=1)
    W = jnp.asarray(metropolis_weights(graph))
    print(f"Dec-MTRL: L={L} nodes, d={d}, T={T} tasks, r={r}, n={n} "
          f"samples/task (data-scarce: n < d)")
    print(f"network: Erdős–Rényi p=0.5, γ(W)={gamma(np.asarray(W)):.3f}")

    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=r, T_pm=30, T_con=10)
    eta = resolve_eta(None, n, R_diag=init.R_diag, L=L)
    kw = dict(eta=eta, T_GD=250, U_star=prob.U_star)

    runs = {
        "Dif-AltGDmin (paper, T_con=3)":
            dif_altgdmin(init.U0, Xg, yg, W, T_con=3, **kw),
        "Dec-AltGDmin [9]  (T_con=3)":
            dec_altgdmin(init.U0, Xg, yg, W, T_con=3, **kw),
        "AltGDmin [10] (centralized)":
            centralized_altgdmin(init.U0[0], Xg, yg, **kw),
        "DGD-variant (baseline)":
            dgd_altgdmin(init.U0, Xg, yg,
                         jnp.asarray(graph.adj, jnp.float64), **kw),
    }

    print(f"\n{'algorithm':<32}" + "".join(f"τ={t:<9}" for t in
                                           (0, 50, 100, 150, 200, 249)))
    for name, res in runs.items():
        sd = np.asarray(res.sd_max)
        row = "".join(f"{sd[t]:<10.2e}" for t in (0, 50, 100, 150, 200, 249))
        print(f"{name:<32}{row}")

    print("\nTakeaways (= the paper's Fig. 1):")
    print(" * Dif-AltGDmin converges linearly, at the same order as the")
    print("   centralized algorithm, with only 3 gossip rounds/iteration;")
    print(" * Dec-AltGDmin plateaus at a T_con-dependent error floor;")
    print(" * the DGD-variant fails to converge for this non-convex "
          "problem.")


if __name__ == "__main__":
    main()
