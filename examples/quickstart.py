"""Quickstart — the paper in 60 seconds, via the declarative API.

Reproduces (at reduced scale) the paper's Experiment 1 comparison: the
proposed Dif-AltGDmin vs centralized AltGDmin, Dec-AltGDmin, and the
DGD-variant, on synthetic multi-task linear regression over an
Erdős–Rényi network.  One :class:`ExperimentSpec` describes the cell;
``dataclasses.replace`` swaps the solver; ``run_experiment`` does the
rest (problem → topology → spectral init → η → algorithm → metrics).

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np                                          # noqa: E402

from repro.api import (                                     # noqa: E402
    ExperimentSpec, ProblemSpec, TopologySpec, InitSpec, SolverSpec,
    materialize, run_experiment,
)
from repro.distributed import gamma                         # noqa: E402


def main():
    # scaled-down Experiment 1: L=10 nodes, d=T=150, r=4, n=30, p=0.5
    spec = ExperimentSpec(
        name="quickstart_exp1",
        problem=ProblemSpec(d=150, T=150, r=4, n=30, L=10, kappa=2.0),
        topology=TopologySpec(family="erdos_renyi", p=0.5, seed=1,
                              weights="metropolis"),
        init=InitSpec(T_pm=30, T_con=10),
        solver=SolverSpec(name="dif_altgdmin", T_GD=250, T_con=3),
    )
    p = spec.problem
    print(f"Dec-MTRL: L={p.L} nodes, d={p.d}, T={p.T} tasks, r={p.r}, "
          f"n={p.n} samples/task (data-scarce: n < d)")

    mat = materialize(spec, key=0)     # shared by all four algorithms
    print(f"network: Erdős–Rényi p={spec.topology.p}, "
          f"γ(W)={gamma(np.asarray(mat.W)):.3f}")

    runs = {}
    for label, solver in [
            ("Dif-AltGDmin (paper, T_con=3)", "dif_altgdmin"),
            ("Dec-AltGDmin [9]  (T_con=3)", "dec_altgdmin"),
            ("AltGDmin [10] (centralized)", "centralized_altgdmin"),
            ("DGD-variant (baseline)", "dgd_altgdmin")]:
        sp = dataclasses.replace(
            spec, solver=dataclasses.replace(spec.solver, name=solver))
        runs[label] = run_experiment(sp, key=0, materialized=mat)

    print(f"\n{'algorithm':<32}" + "".join(f"τ={t:<9}" for t in
                                           (0, 50, 100, 150, 200, 249)))
    for name, trace in runs.items():
        row = "".join(f"{trace.sd_max[t]:<10.2e}"
                      for t in (0, 50, 100, 150, 200, 249))
        print(f"{name:<32}{row}")

    print("\nTakeaways (= the paper's Fig. 1):")
    print(" * Dif-AltGDmin converges linearly, at the same order as the")
    print("   centralized algorithm, with only 3 gossip rounds/iteration;")
    print(" * Dec-AltGDmin plateaus at a T_con-dependent error floor;")
    print(" * the DGD-variant fails to converge for this non-convex "
          "problem.")


if __name__ == "__main__":
    main()
