"""Serving example: batched generation across architecture families —
attention (GQA ring-buffer KV cache), SSM (O(1) recurrent state), and the
sliding-window long-context variant.  (The MTRL counterpart — batched
min-B personalization over a checkpointed U — is
``examples/serve_personalize.py``.)

  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import generate


def main():
    print("batched decode across architecture families (smoke configs):\n")
    for arch, kwargs in [
        ("qwen3-1.7b", {}),                       # GQA + qk-norm
        ("mamba2-130m", {}),                      # attention-free SSD
        ("zamba2-7b", {}),                        # hybrid + shared attn
        ("llava-next-mistral-7b", {}),            # sliding-window ring KV
    ]:
        tokens, stats = generate(arch, batch=2, prompt_len=12, gen=6,
                                 **kwargs)
        print(f"{arch:<24} first row: {tokens[0].tolist()}  "
              f"({stats['tok_per_s']:.1f} tok/s/seq)")
    print("\nAll four families share one serve_step API: "
          "decode_step(params, cache, token, cfg).")


if __name__ == "__main__":
    main()
