"""End-to-end driver: train an LM for a few hundred steps with the
paper's diffusion aggregation, demonstrating loss decrease (the synthetic
Markov stream has ~ln 17 ≈ 2.8 nats of irreducible entropy, so learning is
visible) and a checkpoint save/restore round-trip.

Default model is CPU-sized (~15M params; one core drives this whole run);
``--hundred-m`` selects the ~100M-parameter variant for real hardware.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.distributed.aggregation import AggregationConfig
from repro.launch import steps as steps_lib
from repro.models import init_params, count_params
from repro.optim import adamw, warmup_cosine
from repro.checkpoint import save_checkpoint, restore_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--aggregation", default="diffusion")
    ap.add_argument("--hundred-m", action="store_true",
                    help="~100M-param variant (needs real hardware)")
    args = ap.parse_args()

    if args.hundred_m:
        size = dict(n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
                    d_head=64, d_ff=3072, vocab_size=32768)
    else:       # ~15M params, trains visibly in minutes on one CPU core
        size = dict(n_layers=4, d_model=320, n_heads=4, n_kv_heads=2,
                    d_head=80, d_ff=1024, vocab_size=4096)
    cfg = dataclasses.replace(
        get_config("qwen3-1.7b"), name="qwen3-mini", remat=False,
        dtype="float32", **size)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = count_params(params)
    print(f"model: {cfg.name}  ({n_params/1e6:.1f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model})")

    n_nodes, per_node, seq = args.nodes, 4, 128
    params = steps_lib.replicate_for_nodes(params, n_nodes)
    opt = adamw(warmup_cosine(3e-3, 30, args.steps), weight_decay=0.01)
    state = steps_lib.TrainState(params, opt.init(params),
                                 jnp.zeros((), jnp.int32))
    agg = AggregationConfig(strategy=args.aggregation, t_con=1,
                            local_patterns=("embed", "lm_head"))
    step_fn = jax.jit(steps_lib.make_train_step_fused(cfg, opt, agg,
                                                      n_nodes))
    ds = SyntheticLM(cfg.vocab_size, seq, n_nodes * per_node, seed=0)
    # fixed 10-batch pool (epochs over a small dataset ⇒ visible learning
    # dynamics within a few hundred steps on one CPU core)
    pool = [ds.batch(i)["tokens"].reshape(n_nodes, per_node, seq)
            for i in range(10)]

    t0, first = time.time(), None
    for i in range(args.steps):
        toks = pool[i % len(pool)]
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}
        state, m = step_fn(state, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {loss:.4f}  "
                  f"({time.time()-t0:.1f}s)")
    assert loss < first, "loss did not decrease"
    print(f"\nloss {first:.4f} → {loss:.4f} over {args.steps} steps "
          f"({args.aggregation} aggregation, {n_nodes} nodes)")

    # checkpoint round-trip
    path = "/tmp/repro_train_lm_ckpt"
    save_checkpoint(path, args.steps, state.params)
    restored = restore_checkpoint(path, args.steps, state.params)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored)):
        assert (jnp.asarray(a) == jnp.asarray(b)).all()
    print(f"checkpoint round-trip OK ({path})")


if __name__ == "__main__":
    main()
