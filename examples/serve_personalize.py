"""Few-shot personalization serving — the paper's product story, end to
end (the MTRL counterpart of the LM ``serve_decode.py`` example).

1. Train Dif-AltGDmin while PUBLISHING the representation: the runner's
   ``checkpoint_every`` hook writes crash-safe U snapshots (spectral
   init at step 0, then every k outer iterations).
2. Serve a fixed cohort of brand-new users (each arriving with few-shot
   data (X_new, y_new)) from every published checkpoint in order — the
   drifting-U continual mode, where the batched min-B engine hot-swaps
   to fresher U's and the personalized-regressor error θ̂ = U b_new vs
   θ* = U* b* falls checkpoint over checkpoint.
3. Run the closed-loop deadline batcher on the final U for the serving
   telemetry (batch sizes, p50/p99 latency, shed count).

  PYTHONPATH=src python examples/serve_personalize.py
"""
import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402

from repro.api import (                                     # noqa: E402
    ExperimentSpec, InitSpec, ProblemSpec, SolverSpec, TopologySpec,
    run_experiment,
)
from repro.serving import (                                 # noqa: E402
    RequestGenerator, ServingEngine, load_representation, run_closed_loop,
)

T_GD, EVERY = 100, 25


def main():
    spec = ExperimentSpec(
        name="serve_personalize",
        problem=ProblemSpec(d=80, T=64, r=4, n=24, L=8, kappa=2.0),
        topology=TopologySpec(family="erdos_renyi", p=0.5, seed=1),
        init=InitSpec(T_pm=25, T_con=10),
        solver=SolverSpec(name="dif_altgdmin", T_GD=T_GD, T_con=3))
    p = spec.problem
    print(f"training Dif-AltGDmin (d={p.d}, T={p.T}, r={p.r}, L={p.L}), "
          f"publishing U every {EVERY} iters...")
    with tempfile.TemporaryDirectory() as ckdir:
        trace = run_experiment(spec, key=0, checkpoint_every=EVERY,
                               checkpoint_dir=ckdir)
        steps = sorted(int(s.split("_")[1]) for s in os.listdir(ckdir))
        print(f"published checkpoints: {steps}  "
              f"(final sd_max {trace.final_sd_max:.2e})\n")

        # a fixed cohort of new users: few-shot data from the true model
        U_star = np.asarray(trace.materialized.problem.U_star)
        gen = RequestGenerator(U_star, t_new=16, seed=5)
        cohort = gen.generate(48)
        X_list = [q.X for q in cohort]
        y_list = [q.y for q in cohort]
        theta_star = np.stack([q.theta_star for q in cohort])

        # drifting-U mode: hot-swap to each checkpoint in publish order
        # (a live server would HotSwapSource.poll() between batches —
        # here all steps already exist on disk, so we replay them)
        engine = None
        print(f"{'checkpoint':>10} {'train sd_max':>14} "
              f"{'cohort mean err':>16}")
        prev_err = None
        for step in steps:
            U = load_representation(ckdir, step, d=p.d, r=p.r,
                                    dtype=jnp.float64)
            if engine is None:
                engine = ServingEngine(U, max_batch=48, version=step)
            else:
                engine.update_representation(U, version=step)
            _, theta, _ = engine.solve(X_list, y_list)
            err = float(np.mean(np.linalg.norm(np.asarray(theta)
                                               - theta_star, axis=1)
                                / np.linalg.norm(theta_star, axis=1)))
            sd = float(trace.sd_max[step - 1]) if step else float("nan")
            trend = "" if prev_err is None else \
                ("  ↓" if err < prev_err else "  ↑")
            print(f"{step:>10} {sd:>14.2e} {err:>16.2e}{trend}")
            prev_err = err

        # closed-loop telemetry on the final representation
        load = RequestGenerator(U_star, t_new=(8, 16, 24), rate_hz=150,
                                seed=9).generate(200)
        server = ServingEngine(engine.U, max_batch=16,
                               version=engine.version)
        warm_rng = np.random.default_rng(0)
        for t in (8, 16, 24):      # warm the jit per sample bucket
            server.solve([warm_rng.standard_normal((t, p.d))],
                         [np.zeros(t)])
        report = run_closed_loop(server, load, max_wait_s=5e-3,
                                 queue_capacity=64)
    pct = report.latency_percentiles((50, 99))
    print(f"\nclosed loop (final U, ragged T_new, Poisson 150 req/s): "
          f"{len(report.records)} served in "
          f"{len(report.batch_sizes)} batches "
          f"(mean size {np.mean(report.batch_sizes):.1f}), "
          f"{report.n_shed} shed")
    print(f"latency p50 {1e3 * pct['p50']:.2f} ms, "
          f"p99 {1e3 * pct['p99']:.2f} ms; "
          f"cohort-level mean err {report.mean_err:.2e}")


if __name__ == "__main__":
    main()
