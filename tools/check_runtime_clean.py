#!/usr/bin/env python
"""Guard: repro.core.runtime holds ONLY the substrate skeletons.

This check is now reprolint rule RL006 — this script remains as a thin
delegate for callers of the historical entry point (CI used to run it
standalone; tests/test_programs.py still subprocess-calls it).  The one
canonical analysis entry point is ``python -m tools.reprolint --all``.

Run from the repo root: ``python tools/check_runtime_clean.py``.
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
RUNTIME = ROOT / "src/repro/core/runtime.py"


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    from repro.analysis.astlint import RUNTIME_ALLOWED, check_source

    findings = check_source(RUNTIME.read_text(),
                            RUNTIME.relative_to(ROOT).as_posix(),
                            rules=("RL006",))
    if findings:
        for f in findings:
            print(f"FAIL: {f.render()}")
        print("Register a SolverProgram in repro.core.program instead — "
              "the lowerings derive every substrate.")
        return 1
    print(f"OK: {RUNTIME.name} holds only the substrate skeletons "
          f"{sorted(RUNTIME_ALLOWED)} (reprolint RL006)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
