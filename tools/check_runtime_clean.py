#!/usr/bin/env python
"""Guard: repro.core.runtime holds ONLY the substrate skeletons.

The PR-9 refactor collapsed the per-solver ``*_mesh`` closures into
:class:`repro.core.program.SolverProgram` lowerings; the runtime module
keeps just the two shard_map iteration skeletons.  This check fails CI
if a hand-written solver function grows back there — new solvers
register a program (see README "Solver programs") and get all three
substrates derived.

Run from the repo root: ``python tools/check_runtime_clean.py``.
"""
from __future__ import annotations

import ast
import pathlib
import sys

RUNTIME = pathlib.Path(__file__).resolve().parent.parent / (
    "src/repro/core/runtime.py")

# the substrate skeletons — the ONLY top-level functions allowed
ALLOWED = {"_altgdmin_mesh", "_altgdmin_virtual_mesh"}


def main() -> int:
    tree = ast.parse(RUNTIME.read_text(), filename=str(RUNTIME))
    top_level = [n.name for n in tree.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    rogue = [n for n in top_level if n not in ALLOWED]
    missing = ALLOWED - set(top_level)
    if rogue:
        print(f"FAIL: solver-specific functions in {RUNTIME.name}: "
              f"{rogue}\nRegister a SolverProgram in repro.core.program "
              f"instead — the lowerings derive every substrate.")
        return 1
    if missing:
        print(f"FAIL: expected skeleton(s) missing from {RUNTIME.name}: "
              f"{sorted(missing)}")
        return 1
    print(f"OK: {RUNTIME.name} holds only the substrate skeletons "
          f"{sorted(ALLOWED)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
