"""``python -m tools.reprolint`` — the ONE static-analysis entry point.

Prepares the environment the jaxpr level needs BEFORE jax loads — 8
fake host devices for the mesh/virtual traces, x64 for the precision
rule — then hands off to :func:`repro.analysis.driver.main`.  Run from
the repo root::

    python -m tools.reprolint --all          # CI: every rule
    python -m tools.reprolint --ast          # source rules only (fast)
    python -m tools.reprolint --jaxpr --program dif_altgdmin
"""
import os
import pathlib
import sys

# must precede the first jax import: device count is fixed at init
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

_ROOT = pathlib.Path(__file__).resolve().parent.parent.parent
if str(_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(_ROOT / "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)   # the JX003 f64 traces

from repro.analysis.driver import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
