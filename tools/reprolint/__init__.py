"""CLI front-end for :mod:`repro.analysis` — see ``__main__.py``."""
