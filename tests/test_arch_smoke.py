"""Per-architecture smoke tests: instantiate the REDUCED variant of each
assigned config (≤2 layers — 4 for the hybrid so the shared-attn period is
exercised — d_model ≤ 256, ≤4 experts) and run one forward and one train
step on CPU, asserting output shapes and the absence of NaNs.  Decode
paths get one cached step each."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config
from repro.models import init_params, forward, init_cache, decode_step
from repro.models.frontends import vlm_batch_stub

BATCH, SEQ = 2, 32


def make_batch(cfg, key, batch=BATCH, seq=SEQ):
    if cfg.modality == "vlm":
        return vlm_batch_stub(key, batch, seq, cfg)
    return {"tokens": jax.random.randint(key, (batch, seq), 0,
                                         cfg.vocab_size, dtype=jnp.int32)}


@pytest.fixture(scope="module")
def smoke_setups():
    return {}


def _setup(name):
    cfg = get_config(name).smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    return cfg, params, batch


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_forward_shapes_and_finiteness(name):
    cfg, params, batch = _setup(name)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{name}: NaN/Inf in logits"
    assert bool(jnp.isfinite(aux)), f"{name}: NaN aux loss"
    if cfg.n_experts:
        assert float(aux) > 0.0       # load-balance loss active


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_train_step_updates_params(name):
    cfg, params, batch = _setup(name)
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, aux = forward(p, batch, cfg)
        lt = logits[:, -labels.shape[1]:]        # align (vlm prepends vis)
        ll = jax.nn.log_softmax(lt, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
        return nll + aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"{name}: NaN loss"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{name}: NaN grad"
    # gradient reaches the embedding and at least one block
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert gnorm > 0.0


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_decode_step(name):
    cfg = get_config(name).smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_cache(cfg, batch=BATCH, capacity=16)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    step = jax.jit(lambda p, s, t: decode_step(p, s, t, cfg))
    logits, state = step(params, state, tok)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name}: NaN decode logits"
    assert int(state.pos) == 1
    logits2, state = step(params, state, tok)
    assert int(state.pos) == 2
    assert bool(jnp.isfinite(logits2).all())


def test_prefill_matches_decode_gqa():
    """Teacher-forcing equivalence: running tokens one-by-one through the
    decode path must match the full-sequence forward (qwen3 = GQA+qknorm)."""
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    S = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    full, _ = forward(params, {"tokens": toks}, cfg)
    state = init_cache(cfg, batch=1, capacity=S)
    outs = []
    for i in range(S):
        lg, state = decode_step(params, state, toks[:, i:i + 1], cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_prefill_matches_decode_ssm():
    """Same equivalence for the SSD recurrence (mamba2)."""
    cfg = get_config("mamba2-130m").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    S = 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    full, _ = forward(params, {"tokens": toks}, cfg)
    state = init_cache(cfg, batch=1, capacity=S)
    outs = []
    for i in range(S):
        lg, state = decode_step(params, state, toks[:, i:i + 1], cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_param_count_formula_matches():
    """Analytic n_params() agrees with the actual initialized tree."""
    for name in ("qwen3-1.7b", "mamba2-130m", "arctic-480b", "zamba2-7b",
                 "deepseek-v3-671b"):
        cfg = get_config(name).smoke()
        params = init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
        assert actual == cfg.n_params(), (
            f"{name}: analytic {cfg.n_params()} vs actual {actual}")
