"""Paper-validation tests: the simulator must reproduce the qualitative
claims of Sec. V (Experiments 1–2) and the structure of Theorem 1.

A single module-scoped problem instance (scaled-down Experiment 1:
L=10, d=T=120, r=4, n=30) keeps runtime tractable on 1 CPU core while
preserving every regime the paper demonstrates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    generate_problem, split_samples, node_view, decentralized_spectral_init,
    dif_altgdmin, dec_altgdmin, centralized_altgdmin, dgd_altgdmin,
    subspace_distance, task_error, theory,
)
from repro.core.altgdmin import resolve_eta, theta_nodes
from repro.distributed import erdos_renyi, metropolis_weights


@pytest.fixture(scope="module")
def setting():
    key = jax.random.PRNGKey(0)
    prob = generate_problem(key, d=120, T=120, r=4, n=30, L=10, kappa=2.0)
    Xg, yg = node_view(prob)
    g = erdos_renyi(10, 0.5, seed=1)
    W = jnp.asarray(metropolis_weights(g))
    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=prob.r, T_pm=30, T_con=10)
    eta = resolve_eta(None, prob.n, R_diag=init.R_diag, L=prob.L)
    return dict(prob=prob, Xg=Xg, yg=yg, graph=g, W=W, init=init, eta=eta)


@pytest.fixture(scope="module")
def runs(setting):
    s = setting
    kw = dict(eta=s["eta"], T_GD=200, U_star=s["prob"].U_star)
    return dict(
        dif=dif_altgdmin(s["init"].U0, s["Xg"], s["yg"], s["W"], T_con=5, **kw),
        dec=dec_altgdmin(s["init"].U0, s["Xg"], s["yg"], s["W"], T_con=5, **kw),
        cen=centralized_altgdmin(s["init"].U0[0], s["Xg"], s["yg"], **kw),
        dgd=dgd_altgdmin(s["init"].U0, s["Xg"], s["yg"],
                         jnp.asarray(s["graph"].adj, jnp.float64), **kw),
    )


# ------------------------------------------------------- problem generator

def test_problem_generator_consistency(setting):
    p = setting["prob"]
    assert p.d == 120 and p.T == 120 and p.r == 4 and p.n == 30 and p.L == 10
    # exact low-rank model: y_t = X_t θ*_t
    y_check = jnp.einsum("tnd,dt->tn", p.X, p.Theta_star)
    np.testing.assert_allclose(np.asarray(p.y), np.asarray(y_check), rtol=1e-9)
    # U* orthonormal, Θ* rank r, condition number as requested
    np.testing.assert_allclose(np.asarray(p.U_star.T @ p.U_star), np.eye(4),
                               atol=1e-10)
    sv = np.linalg.svd(np.asarray(p.Theta_star), compute_uv=False)
    assert sv[3] > 1e-8 and sv[4] < 1e-8 if len(sv) > 4 else True
    assert np.isclose(p.kappa, 2.0, rtol=1e-6)
    # Assumption 1 incoherence: μ is a small constant for Haar V*
    assert 1.0 <= p.mu < 4.0


def test_sample_splitting_folds(setting):
    p = setting["prob"]
    sp = split_samples(p, 6)                     # 30 = 6 folds × 5
    assert sp.X.shape == (6, 120, 5, 120) and sp.y.shape == (6, 120, 5)
    # folds are disjoint partitions of the original samples
    np.testing.assert_allclose(
        np.asarray(sp.X.transpose(1, 0, 2, 3).reshape(p.X.shape)),
        np.asarray(p.X))


# ------------------------------------------------------- spectral init

def test_spectral_init_accuracy_and_consistency(setting):
    init, prob = setting["init"], setting["prob"]
    sd = [float(subspace_distance(U, prob.U_star)) for U in init.U0]
    assert max(sd) < 0.9            # δ(0) < 1: non-trivial initial estimate
    spread = np.max([np.linalg.norm(np.asarray(a - b))
                     for a in init.U0 for b in init.U0])
    assert spread < 1e-2            # ρ(0): broadcast pins node consistency
    # orthonormality of every node's basis
    for U in init.U0:
        np.testing.assert_allclose(np.asarray(U.T @ U), np.eye(prob.r),
                                   atol=1e-8)


def test_spectral_init_improves_with_T_pm(setting):
    s = setting
    prob = s["prob"]
    short = decentralized_spectral_init(
        jax.random.PRNGKey(1), s["Xg"], s["yg"], s["W"], kappa=prob.kappa,
        mu=prob.mu, r=prob.r, T_pm=2, T_con=10)
    sd_short = max(float(subspace_distance(U, prob.U_star)) for U in short.U0)
    sd_long = max(float(subspace_distance(U, prob.U_star))
                  for U in s["init"].U0)
    assert sd_long <= sd_short + 1e-6


# ------------------------------------------------------- Experiment 1 claims

def test_dif_converges_linearly(runs):
    """Theorem 1: SD decays geometrically to ε."""
    sd = np.asarray(runs["dif"].sd_max)
    assert sd[-1] < 2e-3
    # monotone-ish geometric decay: each 50-iter block shrinks substantially
    assert sd[50] < 0.5 * sd[0] and sd[100] < 0.5 * sd[50]


def test_dif_matches_centralized_order(runs):
    """Fig. 1: Dif-AltGDmin converges at the same order as AltGDmin."""
    sd_dif = float(runs["dif"].sd_max[-1])
    sd_cen = float(runs["cen"].sd_max[-1])
    assert sd_dif < 10 * sd_cen          # same order of magnitude


def test_dec_plateaus_above_dif(runs):
    """Fig. 1: Dec-AltGDmin cannot reach below a T_con-dependent floor."""
    sd_dec = float(runs["dec"].sd_max[-1])
    sd_dif = float(runs["dif"].sd_max[-1])
    assert sd_dec > 10 * sd_dif
    # and the floor is a plateau, not slow convergence:
    sd = np.asarray(runs["dec"].sd_max)
    assert sd[-1] > 0.5 * sd[150]


def test_dgd_fails_to_converge(runs):
    """Fig. 1: the DGD-variant fails for MTRL."""
    assert float(runs["dgd"].sd_max[-1]) > 10 * float(runs["dif"].sd_max[-1])


def test_task_parameter_recovery(runs, setting):
    """Theorem 1 part 1: ||θ_t − θ*_t|| ≤ ε||θ*_t|| for the node's tasks."""
    prob = setting["prob"]
    theta = theta_nodes(runs["dif"].U_nodes, runs["dif"].B_nodes)  # (L,tpn,d)
    theta = np.asarray(theta).reshape(prob.T, prob.d).T            # (d, T)
    err = task_error(jnp.asarray(theta), prob.Theta_star)
    assert float(err) < 5e-3


def test_dec_floor_depends_on_T_con(setting):
    """Fig. 1a-1c: Dec-AltGDmin's floor drops as T_con grows."""
    s = setting
    kw = dict(eta=s["eta"], T_GD=120, U_star=s["prob"].U_star)
    lo = dec_altgdmin(s["init"].U0, s["Xg"], s["yg"], s["W"], T_con=2, **kw)
    hi = dec_altgdmin(s["init"].U0, s["Xg"], s["yg"], s["W"], T_con=20, **kw)
    assert float(hi.sd_max[-1]) < 0.5 * float(lo.sd_max[-1])


def test_dif_works_with_single_aggregation_step(setting):
    """Paper contribution 3: 'effective even with a single aggregation
    step' — T_con = 1 still converges."""
    s = setting
    res = dif_altgdmin(s["init"].U0, s["Xg"], s["yg"], s["W"], T_con=1,
                       eta=s["eta"], T_GD=300, U_star=s["prob"].U_star)
    assert float(res.sd_max[-1]) < 1e-2


def test_dif_sample_split_path(setting):
    """Algorithm 3 line 4 (sample splitting) — the theory path runs and
    converges (uses fresh disjoint folds per iteration).  Needs per-fold
    n ≳ max(log T, log d, r) (Prop. 3), so use a dedicated instance with
    n = 120 split into 4 folds of 30."""
    s = setting
    prob = generate_problem(jax.random.PRNGKey(9), d=120, T=120, r=4,
                            n=120, L=10, kappa=2.0)
    folded = split_samples(prob, 4)
    Xg, yg = node_view(folded)
    init = decentralized_spectral_init(
        jax.random.PRNGKey(10), Xg[0], yg[0], s["W"], kappa=prob.kappa,
        mu=prob.mu, r=prob.r, T_pm=30, T_con=10)     # init on fold 00
    eta = theory.eta_star(30, prob.sigma_max)        # per-fold n = 30
    res = dif_altgdmin(init.U0, Xg, yg, s["W"], T_con=5,
                       eta=eta, T_GD=150, U_star=prob.U_star)
    assert float(res.sd_max[-1]) < 0.05


# ------------------------------------------------------- Experiment 2 claim

def test_dif_robust_to_sparse_connectivity():
    """Fig. 2: Dif-AltGDmin tolerates sparse graphs where Dec-AltGDmin
    degrades. Compare final SD on p=0.2 vs p=0.8 graphs."""
    key = jax.random.PRNGKey(4)
    prob = generate_problem(key, d=80, T=80, r=4, n=40, L=8, kappa=1.5)
    Xg, yg = node_view(prob)
    finals = {}
    for p in (0.3, 0.9):
        g = erdos_renyi(8, p, seed=11)
        W = jnp.asarray(metropolis_weights(g))
        init = decentralized_spectral_init(
            jax.random.PRNGKey(5), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
            r=prob.r, T_pm=30, T_con=10)
        eta = resolve_eta(None, prob.n, R_diag=init.R_diag, L=prob.L)
        res = dif_altgdmin(init.U0, Xg, yg, W, T_con=5, eta=eta, T_GD=150,
                           U_star=prob.U_star)
        finals[p] = float(res.sd_max[-1])
    # both converge to small error; sparse within 100× of dense
    assert finals[0.3] < 1e-2 and finals[0.9] < 1e-2


# ------------------------------------------------------- theory formulas

def test_Tcon_GD_independent_of_eps():
    a = theory.T_con_GD(L=20, r=4, kappa=2.0, gamma_W=0.8)
    for eps in (1e-2, 1e-6, 1e-12):
        # Dif's T_con,GD has no ε argument at all — API-level independence —
        # while Dec's grows with log(1/ε):
        dec = theory.T_con_GD_dec(L=20, d=600, kappa=2.0, eps=eps,
                                  gamma_W=0.8)
        assert dec > a
    d1 = theory.T_con_GD_dec(L=20, d=600, kappa=2.0, eps=1e-2, gamma_W=0.8)
    d2 = theory.T_con_GD_dec(L=20, d=600, kappa=2.0, eps=1e-8, gamma_W=0.8)
    assert d2 > d1


def test_complexity_improvement_over_dec():
    """Sec. III claims: Dif's time & comm complexities beat Dec's,
    increasingly so for small ε and large κ."""
    kw = dict(n=30, d=600, T=600, r=4, L=20, gamma_W=0.8, max_deg=10)
    for eps, kappa in [(1e-4, 2.0), (1e-8, 4.0)]:
        dif = theory.dif_complexity(eps=eps, kappa=kappa, **kw)
        dec = theory.dec_complexity(eps=eps, kappa=kappa, **kw)
        assert dif.tau_time < dec.tau_time
        assert dif.tau_comm < dec.tau_comm
        assert dif.T_con_GD < dec.T_con_GD


def test_contraction_factor_matches_empirical(runs, setting):
    """Lemma 1: empirical per-iteration decay rate ≤ theoretical
    (1 − 0.3 c_η κ⁻²) bound is conservative — check empirical rate < 1 and
    bounded by theory's prediction in the right direction."""
    sd = np.asarray(runs["dif"].sd_max)
    # fit decay rate over the clean mid-section
    rate = (sd[100] / sd[20]) ** (1 / 80)
    bound = theory.contraction_factor(setting["prob"].kappa)
    assert rate < 1.0
    assert rate <= bound + 0.05       # empirical at least as fast (whp)


def test_eta_resolution(setting):
    prob, init = setting["prob"], setting["init"]
    eta_t = theory.eta_star(prob.n, prob.sigma_max)
    eta_e = resolve_eta(None, prob.n, R_diag=init.R_diag, L=prob.L)
    assert 0.3 * eta_t < eta_e < 3 * eta_t     # estimate near ground truth
    assert resolve_eta(1e-3, prob.n) == 1e-3   # explicit passthrough
