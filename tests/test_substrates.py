"""optim / data / checkpoint substrate tests (incl. hypothesis properties)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import (adamw, sgd, adam, clip_by_global_norm,
                         apply_updates, warmup_cosine, cosine_decay,
                         linear_warmup, constant)
from repro.data import SyntheticLM, make_batch_for
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.configs import get_config


# ----------------------------------------------------------------- optim

def _quadratic_params():
    return {"a": jnp.array([3.0, -2.0], jnp.float32),
            "b": {"c": jnp.array([[1.5]], jnp.float32)}}


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
    lambda: sgd(0.05, momentum=0.9, nesterov=True),
    lambda: adam(0.1), lambda: adamw(0.1, weight_decay=0.01)])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return (jnp.sum(p["a"] ** 2) + jnp.sum(p["b"]["c"] ** 2))

    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"x": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(gn), 20.0)
    total = float(jnp.linalg.norm(clipped["x"]))
    assert np.isclose(total, 1.0, rtol=1e-5)
    # below threshold: unchanged
    unchanged, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(unchanged["x"]),
                               np.asarray(g["x"]))


@settings(max_examples=25, deadline=None)
@given(step=st.integers(min_value=0, max_value=10_000))
def test_schedules_bounded(step):
    s = jnp.array(step, jnp.int32)
    for sched in (constant(1e-3), linear_warmup(1e-3, 100),
                  cosine_decay(1e-3, 5000, floor=1e-5),
                  warmup_cosine(1e-3, 100, 5000, floor=1e-5)):
        v = float(sched(s))
        assert 0.0 <= v <= 1e-3 + 1e-9


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.array(0))) == 0.0
    assert np.isclose(float(sched(jnp.array(10))), 1.0)
    assert float(sched(jnp.array(100))) < 1e-6
    # monotone rise through warmup
    vals = [float(sched(jnp.array(i))) for i in range(11)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))


# ----------------------------------------------------------------- data

def test_synthetic_lm_deterministic_and_disjoint():
    ds = SyntheticLM(vocab_size=100, seq_len=16, batch_size=4, seed=1)
    a = ds.batch(step=3, node=0)
    b = ds.batch(step=3, node=0)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = ds.batch(step=3, node=1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert a["tokens"].shape == (4, 16)
    assert int(a["tokens"].min()) >= 0 and int(a["tokens"].max()) < 100


def test_make_batch_for_vlm():
    cfg = get_config("llava-next-mistral-7b").smoke()
    b = make_batch_for(cfg, batch=2, seq=32)
    assert b["tokens"].shape == (2, 32 - cfg.vis_tokens)
    assert b["vis_embed"].shape == (2, cfg.vis_tokens, cfg.d_model)
    assert b["labels"].shape == b["tokens"].shape


# ----------------------------------------------------------------- ckpt

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen3-1.7b").smoke()
    from repro.models import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 100, params)
    assert latest_step(d) == 100
    like = init_params(jax.random.PRNGKey(1), cfg)      # different values
    restored = restore_checkpoint(d, 100, like)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_multiple_steps(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(10.0)}
    save_checkpoint(d, 1, tree)
    save_checkpoint(d, 20, jax.tree.map(lambda x: x * 2, tree))
    assert latest_step(d) == 20
    r = restore_checkpoint(d, 20, tree)
    np.testing.assert_allclose(np.asarray(r["w"]), np.arange(10.0) * 2)


def test_checkpoint_missing_leaf_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 0, {"w": jnp.ones(3)})
    with pytest.raises(KeyError):
        restore_checkpoint(d, 0, {"w": jnp.ones(3), "extra": jnp.ones(2)})
