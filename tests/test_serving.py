"""Serving subsystem: packed batched min-B parity with the training
engine (bitwise on xla-ref, tolerance on pallas-interpret), exactness of
the two padding axes (batch slots and sample rows), the deadline
batcher's launch/shed semantics under a deterministic service model, the
publisher / hot-swap lifecycle, and the runner's ``checkpoint_every``
segmented mode (bit-identical trajectory + drifting-U error decrease)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ExperimentSpec, InitSpec, ProblemSpec, SolverSpec,
                       TopologySpec, run_experiment)
from repro.checkpoint import latest_step
from repro.core.engine import AltgdminEngine
from repro.serving import (HotSwapSource, RepresentationPublisher,
                           RequestGenerator, ServeRequest, ServingEngine,
                           deployable_basis, load_representation,
                           pack_requests, publish_representation,
                           run_closed_loop)

D, R_RANK = 40, 3


def _basis(key, d=D, r=R_RANK, dtype=jnp.float64):
    return jnp.linalg.qr(jax.random.normal(key, (d, r), dtype))[0]


def _requests(key, d=D, t_news=(16, 16, 16), dtype=jnp.float64):
    X_list, y_list = [], []
    for i, t in enumerate(t_news):
        kx, ky = jax.random.split(jax.random.fold_in(key, i))
        X_list.append(jax.random.normal(kx, (t, d), dtype))
        y_list.append(jax.random.normal(ky, (t,), dtype))
    return X_list, y_list


# ================================================================ parity

def test_packed_solve_is_the_training_minb_path():
    """solve_packed ≡ AltgdminEngine.minimize_B on the same packed
    layout, bit for bit — serving IS the training fold solve."""
    key = jax.random.PRNGKey(0)
    U = _basis(key)
    X_list, y_list = _requests(jax.random.fold_in(key, 1))
    X, y, R = pack_requests(X_list, y_list, max_batch=4)
    eng = ServingEngine(U, max_batch=4, backend="xla-ref")
    B_serve, _ = eng.solve_packed(X, y)
    B_train = AltgdminEngine("xla-ref").minimize_B(U[None], X[None],
                                                   y[None])[0]
    assert jnp.array_equal(B_serve, B_train)
    assert R == 3 and B_serve.shape == (4, R_RANK)


def test_ragged_batch_matches_per_request_training_solve():
    """Heterogeneous T_new, one packed dispatch vs one training-engine
    solve per request (each at its TRUE sample count — so this also
    covers the zero-row padding): vmap batching is the only difference,
    so agreement is ~1e-10, not bitwise."""
    key = jax.random.PRNGKey(1)
    U = _basis(key)
    X_list, y_list = _requests(jax.random.fold_in(key, 1),
                               t_news=(5, 9, 16, 12))
    eng = ServingEngine(U, max_batch=4, backend="xla-ref")
    B, theta, _ = eng.solve(X_list, y_list)
    train = AltgdminEngine("xla-ref")
    for i, (Xi, yi) in enumerate(zip(X_list, y_list)):
        b_ref = train.minimize_B(U[None], Xi[None, None],
                                 yi[None, None])[0, 0]
        np.testing.assert_allclose(np.asarray(B[i]), np.asarray(b_ref),
                                   rtol=0, atol=1e-10)
    np.testing.assert_allclose(np.asarray(theta),
                               np.asarray(B @ U.T), rtol=0, atol=0)


def test_batch_slot_padding_is_bitwise_exact():
    """R=3 real requests served from a max_batch=3 engine vs a
    max_batch=8 engine (5 dummy slots): real solutions identical bit
    for bit — dummy slots never perturb real lanes."""
    key = jax.random.PRNGKey(2)
    U = _basis(key)
    X_list, y_list = _requests(jax.random.fold_in(key, 1))
    B_tight, _, _ = ServingEngine(U, max_batch=3,
                                  backend="xla-ref").solve(X_list, y_list)
    B_slack, _, _ = ServingEngine(U, max_batch=8,
                                  backend="xla-ref").solve(X_list, y_list)
    assert jnp.array_equal(B_tight, B_slack)


def test_sample_bucket_padding_is_bitwise_exact():
    """The same requests solved in a pad_n_to=8 bucket and a pad_n_to=32
    bucket (3x the zero rows) give bit-identical b — zero rows add
    exact zeros to the Gram and to Aᵀy."""
    key = jax.random.PRNGKey(3)
    U = _basis(key)
    X_list, y_list = _requests(jax.random.fold_in(key, 1),
                               t_news=(7, 11, 13))
    B8, _, _ = ServingEngine(U, max_batch=4, pad_n_to=8,
                             backend="xla-ref").solve(X_list, y_list)
    B32, _, _ = ServingEngine(U, max_batch=4, pad_n_to=32,
                              backend="xla-ref").solve(X_list, y_list)
    assert jnp.array_equal(B8, B32)


def test_pallas_interpret_matches_ref():
    key = jax.random.PRNGKey(4)
    U = _basis(key)
    X_list, y_list = _requests(jax.random.fold_in(key, 1),
                               t_news=(8, 16, 12))
    B_ref, _, _ = ServingEngine(U, max_batch=4,
                                backend="xla-ref").solve(X_list, y_list)
    U32 = U.astype(jnp.float32)
    B_pl, _, _ = ServingEngine(U32, max_batch=4,
                               backend="pallas-interpret").solve(
        [x.astype(jnp.float32) for x in X_list],
        [v.astype(jnp.float32) for v in y_list])
    np.testing.assert_allclose(np.asarray(B_pl), np.asarray(B_ref),
                               rtol=0, atol=1e-6)


def test_noiseless_request_recovers_truth():
    """With U = U* and noiseless y, the served θ̂ is the user's true
    regressor to solver precision — the few-shot personalization
    promise."""
    key = jax.random.PRNGKey(5)
    U_star = _basis(key)
    gen = RequestGenerator(np.asarray(U_star), t_new=16, seed=0)
    reqs = gen.generate(6)
    eng = ServingEngine(U_star, max_batch=8, backend="xla-ref")
    _, theta, _ = eng.solve([q.X for q in reqs], [q.y for q in reqs])
    for i, q in enumerate(reqs):
        err = np.linalg.norm(np.asarray(theta[i]) - q.theta_star) \
            / np.linalg.norm(q.theta_star)
        assert err < 1e-9


# ============================================================ validation

def test_underdetermined_request_raises():
    U = _basis(jax.random.PRNGKey(6))
    eng = ServingEngine(U, max_batch=4, backend="xla-ref")
    X = np.zeros((R_RANK - 1, D))          # T_new < r
    with pytest.raises(ValueError, match="underdetermined"):
        eng.solve([X], [np.zeros(R_RANK - 1)])


def test_pack_requests_validation():
    X = np.zeros((4, D))
    with pytest.raises(ValueError, match="at least one"):
        pack_requests([], [], max_batch=4)
    with pytest.raises(ValueError, match="max_batch"):
        pack_requests([X] * 5, [np.zeros(4)] * 5, max_batch=4)
    with pytest.raises(ValueError, match="rows"):
        pack_requests([X], [np.zeros(3)], max_batch=4)


def test_update_representation_rejects_stacks():
    eng = ServingEngine(_basis(jax.random.PRNGKey(7)), backend="xla-ref")
    with pytest.raises(ValueError, match="single"):
        eng.update_representation(jnp.zeros((4, D, R_RANK)))
    eng.update_representation(_basis(jax.random.PRNGKey(8)), version=9)
    assert eng.version == 9


# ======================================================= deadline batcher

def _tiny_engine(max_batch=4):
    return ServingEngine(_basis(jax.random.PRNGKey(9), d=8, r=2),
                         max_batch=max_batch, backend="xla-ref")


def _burst(n, dt, t0=0.0, d=8, t_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=i, X=rng.standard_normal((t_new, d)),
                         y=rng.standard_normal(t_new),
                         t_arrival=t0 + i * dt) for i in range(n)]


def _const_service(_batch_size):
    return 5e-3


def test_batcher_full_batch_launches_at_fill_time():
    """A dense burst fills max_batch-sized batches, each launched the
    moment its last member arrived (not at the deadline)."""
    reqs = _burst(8, dt=1e-4)
    report = run_closed_loop(_tiny_engine(max_batch=4), reqs,
                             max_wait_s=1.0, service_time=_const_service)
    assert report.batch_sizes == [4, 4]
    assert report.n_shed == 0
    first = [r for r in report.records if r.rid < 4]
    assert all(r.t_launch == pytest.approx(reqs[3].t_arrival)
               for r in first)
    assert sorted(r.rid for r in report.records) == list(range(8))


def test_batcher_deadline_launches_short_batch():
    """Sparse arrivals never fill a batch: each request rides alone and
    waits exactly max_wait_s."""
    reqs = _burst(3, dt=1.0)
    report = run_closed_loop(_tiny_engine(max_batch=4), reqs,
                             max_wait_s=2e-3, service_time=_const_service)
    assert report.batch_sizes == [1, 1, 1]
    for rec in report.records:
        assert rec.queue_wait == pytest.approx(2e-3)
        assert rec.latency == pytest.approx(2e-3 + 5e-3)


def test_batcher_sheds_on_full_queue_and_serves_rest_exactly_once():
    """A burst far beyond queue capacity during a slow solve: overflow
    arrivals are shed and counted; every admitted request is served
    exactly once; served + shed == offered."""

    def slow(_batch_size):
        return 1.0

    reqs = _burst(20, dt=1e-4)
    report = run_closed_loop(_tiny_engine(max_batch=4), reqs,
                             max_wait_s=1e-3, queue_capacity=4,
                             service_time=slow)
    assert report.n_shed > 0
    rids = [r.rid for r in report.records]
    assert len(rids) == len(set(rids))
    assert len(rids) + report.n_shed == 20
    assert all(s <= 4 for s in report.batch_sizes)


def test_batcher_rejects_inconsistent_limits():
    eng = _tiny_engine(max_batch=4)
    with pytest.raises(ValueError, match="packed capacity"):
        run_closed_loop(eng, _burst(2, dt=1e-3), max_batch=8)
    with pytest.raises(ValueError, match="cannot hold"):
        run_closed_loop(eng, _burst(2, dt=1e-3), queue_capacity=2)


def test_closed_loop_is_deterministic():
    reqs = _burst(12, dt=2e-3)
    kw = dict(max_wait_s=3e-3, service_time=_const_service)
    r1 = run_closed_loop(_tiny_engine(), _burst(12, dt=2e-3), **kw)
    r2 = run_closed_loop(_tiny_engine(), reqs, **kw)
    assert r1.batch_sizes == r2.batch_sizes
    assert [rec.latency for rec in r1.records] \
        == [rec.latency for rec in r2.records]


# ==================================================== publisher / hot swap

def test_deployable_basis_is_orthonormal():
    stack = jax.random.normal(jax.random.PRNGKey(10), (5, D, R_RANK))
    U = deployable_basis(stack)
    assert U.shape == (D, R_RANK)
    np.testing.assert_allclose(np.asarray(U.T @ U), np.eye(R_RANK),
                               atol=1e-10)


def test_publisher_cadence(tmp_path):
    pub = RepresentationPublisher(str(tmp_path), every=3)
    stack = jax.random.normal(jax.random.PRNGKey(11), (2, 6, 2))
    hits = [s for s in range(7) if pub.maybe(s, stack)]
    assert hits == [0, 3, 6]
    assert pub.published == [0, 3, 6]
    assert latest_step(str(tmp_path)) == 6
    with pytest.raises(ValueError):
        RepresentationPublisher(str(tmp_path), every=0)


def test_hot_swap_source_only_reports_newer(tmp_path):
    d, r = 6, 2
    U0 = _basis(jax.random.PRNGKey(12), d=d, r=r)
    publish_representation(str(tmp_path), 0, U0)
    src = HotSwapSource(str(tmp_path), d=d, r=r, dtype=jnp.float64)
    step, U = src.poll()
    assert step == 0
    np.testing.assert_allclose(np.asarray(U),
                               np.asarray(deployable_basis(U0)),
                               atol=1e-12)
    assert src.poll() is None                  # nothing newer
    publish_representation(str(tmp_path), 5,
                           _basis(jax.random.PRNGKey(13), d=d, r=r))
    assert src.poll()[0] == 5
    # an incomplete (manifest-less) newer dir stays invisible
    os.mkdir(tmp_path / "step_000000009")
    assert src.poll() is None


# ==================================================== checkpointed training

def _spec(T_GD=16):
    return ExperimentSpec(
        name="serving_test",
        problem=ProblemSpec(d=30, T=24, r=2, n=24, L=4, kappa=2.0),
        topology=TopologySpec(family="erdos_renyi", p=0.6, seed=1),
        init=InitSpec(T_pm=10, T_con=5),
        solver=SolverSpec(name="dif_altgdmin", T_GD=T_GD, T_con=3))


@pytest.fixture(scope="module")
def segmented_run(tmp_path_factory):
    ckdir = str(tmp_path_factory.mktemp("serving_ck"))
    spec = _spec()
    seg = run_experiment(spec, key=0, checkpoint_every=4,
                         checkpoint_dir=ckdir)
    plain = run_experiment(spec, key=0)
    return spec, ckdir, seg, plain


def test_segmented_run_is_bit_identical(segmented_run):
    _, _, seg, plain = segmented_run
    assert np.array_equal(seg.sd_max, plain.sd_max)
    assert np.array_equal(seg.sd_mean, plain.sd_mean)
    assert jnp.array_equal(seg.U_nodes, plain.U_nodes)
    assert np.array_equal(seg.time_axis, plain.time_axis)


def test_segmented_run_publishes_schedule(segmented_run):
    spec, ckdir, _, _ = segmented_run
    steps = sorted(int(s.split("_")[1]) for s in os.listdir(ckdir))
    assert steps == [0, 4, 8, 12, 16]
    assert latest_step(ckdir) == spec.solver.T_GD
    U = load_representation(ckdir, 16, d=spec.problem.d,
                            r=spec.problem.r, dtype=jnp.float64)
    assert U.shape == (spec.problem.d, spec.problem.r)


def test_drifting_checkpoints_reduce_serving_error(segmented_run):
    """The acceptance criterion of the continual mode: a fixed cohort's
    θ̂ error falls MONOTONICALLY across the published checkpoints, from
    the step-0 (spectral init) U to the final U."""
    spec, ckdir, seg, _ = segmented_run
    p = spec.problem
    gen = RequestGenerator(np.asarray(seg.materialized.problem.U_star),
                           t_new=12, seed=3)
    reqs = gen.generate(24)
    errs = []
    eng = None
    for step in (0, 4, 8, 12, 16):
        U = load_representation(ckdir, step, d=p.d, r=p.r,
                                dtype=jnp.float64)
        if eng is None:
            eng = ServingEngine(U, max_batch=24, backend="xla-ref",
                                version=step)
        else:
            eng.update_representation(U, version=step)
        _, theta, version = eng.solve([q.X for q in reqs],
                                      [q.y for q in reqs])
        assert version == step
        theta = np.asarray(theta)
        errs.append(float(np.mean(
            [np.linalg.norm(theta[i] - q.theta_star)
             / np.linalg.norm(q.theta_star)
             for i, q in enumerate(reqs)])))
    assert all(b < a for a, b in zip(errs, errs[1:])), errs
    assert errs[-1] < 0.75 * errs[0], errs


def test_checkpoint_kwargs_guards(tmp_path):
    spec = _spec(T_GD=2)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        run_experiment(spec, key=0, checkpoint_every=1)
    with pytest.raises(ValueError, match=">= 1"):
        run_experiment(spec, key=0, checkpoint_every=0,
                       checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="simulator"):
        run_experiment(dataclasses.replace(spec, substrate="mesh"), key=0,
                       checkpoint_every=1, checkpoint_dir=str(tmp_path))
    folds = dataclasses.replace(
        spec, problem=dataclasses.replace(spec.problem, n_folds=2))
    with pytest.raises(ValueError, match="n_folds"):
        run_experiment(folds, key=0, checkpoint_every=1,
                       checkpoint_dir=str(tmp_path))
