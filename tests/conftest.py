"""Shared test config.

x64 is enabled globally: the paper's linear-MTRL path needs double
precision to exhibit the theoretical contraction cleanly.  Model/NN code
always passes explicit float32/bfloat16 dtypes, so it is unaffected.

NOTE: XLA_FLAGS device-count faking is deliberately NOT set here — smoke
tests run on the 1 real CPU device; only launch/dryrun.py (a separate
process) fakes 512 devices.
"""
import jax

jax.config.update("jax_enable_x64", True)
