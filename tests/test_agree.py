"""AGREE protocol (Algorithm 1) — Proposition 1 contraction, weight
matrices, and equivalence of formulations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.agree import agree, agree_power
from repro.core import theory
from repro.distributed import (
    erdos_renyi, ring, torus2d, hypercube, complete, star, path_graph,
    metropolis_weights, equal_neighbor_weights, lazy_weights,
    circulant_weights, gamma,
)
from repro.distributed.mixing import is_doubly_stochastic


# ---------------------------------------------------------------- graphs

@pytest.mark.parametrize("make,args", [
    (ring, (8,)), (torus2d, (4, 4)), (hypercube, (4,)), (complete, (7,)),
    (star, (9,)), (path_graph, (6,)), (erdos_renyi, (12, 0.4)),
])
def test_graph_families_connected_symmetric(make, args):
    g = make(*args)
    assert g.is_connected()
    assert np.array_equal(g.adj, g.adj.T)
    assert np.all(np.diag(g.adj) == 0)


def test_erdos_renyi_edge_density():
    # the old triu bug made every graph complete; check density ≈ p
    g = erdos_renyi(60, 0.3, seed=5, ensure_connected=False)
    density = g.n_edges / (60 * 59 / 2)
    assert 0.2 < density < 0.4


# ---------------------------------------------------------------- weights

@pytest.mark.parametrize("weights", [metropolis_weights, lazy_weights])
@pytest.mark.parametrize("graph", [ring(8), erdos_renyi(10, 0.5, seed=2),
                                   star(6), torus2d(3, 3)])
def test_weights_doubly_stochastic_contractive(weights, graph):
    w = weights(graph)
    assert is_doubly_stochastic(w)
    assert gamma(w) < 1.0


def test_equal_neighbor_doubly_stochastic_iff_regular():
    w_ring = equal_neighbor_weights(ring(8))        # regular
    assert is_doubly_stochastic(w_ring)
    w_star = equal_neighbor_weights(star(6))        # irregular
    assert np.allclose(w_star.sum(axis=1), 1.0)     # always row-stochastic


def test_circulant_matches_metropolis_on_ring():
    # the TPU-runtime circulant W with shifts (±1) is a valid ring mixer
    w = circulant_weights(8, (-1, 1))
    assert is_doubly_stochastic(w)
    assert gamma(w) < 1.0


# ---------------------------------------------------------------- AGREE

def test_agree_preserves_average_and_contracts():
    g = erdos_renyi(12, 0.5, seed=3)
    w = jnp.asarray(metropolis_weights(g))
    z = jax.random.normal(jax.random.PRNGKey(0), (12, 5, 3), dtype=jnp.float64)
    z_bar = jnp.mean(z, axis=0)
    out = agree(z, w, 40)
    # doubly stochastic ⇒ average preserved exactly
    np.testing.assert_allclose(np.mean(np.asarray(out), axis=0),
                               np.asarray(z_bar), rtol=1e-10)
    # contraction toward consensus
    dev0 = float(jnp.max(jnp.abs(z - z_bar)))
    dev = float(jnp.max(jnp.abs(out - z_bar)))
    assert dev < 1e-3 * dev0


def test_agree_equals_power_form():
    g = ring(10)
    w = jnp.asarray(metropolis_weights(g))
    z = jax.random.normal(jax.random.PRNGKey(1), (10, 4), dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(agree(z, w, 7)),
                               np.asarray(agree_power(z, w, 7)), rtol=1e-9)


def test_agree_zero_rounds_identity():
    z = jnp.ones((4, 2))
    w = jnp.asarray(metropolis_weights(ring(4)))
    assert agree(z, w, 0) is z


@settings(max_examples=20, deadline=None)
@given(t_con=st.integers(min_value=1, max_value=30))
def test_prop1_contraction_rate(t_con):
    """Proposition 1: max_g |z_g − z̄| ≤ γ^T_con · max_g |z_g^in − z̄|
    (for symmetric doubly-stochastic W the bound holds in ℓ₂ per column)."""
    g = erdos_renyi(9, 0.6, seed=7)
    w = metropolis_weights(g)
    gm = gamma(w)
    z = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (9,),
                                     dtype=jnp.float64))
    z_bar = z.mean()
    out = np.asarray(agree(jnp.asarray(z), jnp.asarray(w), t_con))
    lhs = np.linalg.norm(out - z_bar)
    rhs = gm ** t_con * np.linalg.norm(z - z_bar)
    assert lhs <= rhs * (1 + 1e-9)


def test_prop1_round_bound_sufficient():
    """theory.prop1_consensus_rounds gives enough rounds for ε_con accuracy."""
    g = erdos_renyi(9, 0.6, seed=7)
    w = metropolis_weights(g)
    eps_con = 1e-3
    t_con = theory.prop1_consensus_rounds(9, eps_con, gamma(w))
    z = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (9,),
                                     dtype=jnp.float64))
    out = np.asarray(agree(jnp.asarray(z), jnp.asarray(w), t_con))
    z_bar = z.mean()
    assert np.max(np.abs(out - z_bar)) <= eps_con * np.max(np.abs(z - z_bar))
