"""Sparse consensus path: SparseGraph families, SparseWeights mixing
parity, the segment-sum CombineRule lowerings vs the dense stacked
product, padding-row neutrality, RCM shift pruning, degree-weighted comm
pricing, and sparse-vs-dense trajectory parity for every registered
solver (plus the virtual-node mesh tier in a subprocess with 8 fake
devices)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import graphs, mixing
from repro.distributed.graphs import SparseGraph
from repro.distributed.mixing import SparseWeights
from repro.distributed import consensus

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------- graph families

FAMILIES = {
    "erdos_renyi": lambda L: graphs.erdos_renyi(L, p=0.15, seed=3),
    "ring": lambda L: graphs.ring(L),
    "barabasi_albert": lambda L: graphs.barabasi_albert(L, m=2, seed=0),
    "hierarchical": lambda L: graphs.hierarchical(L, branching=4),
    "cluster_cliques": lambda L: graphs.cluster_of_cliques(L, clique=8,
                                                           seed=2),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_families_sparse_born_and_connected(family):
    g = FAMILIES[family](48)
    assert isinstance(g, SparseGraph)
    assert g.is_connected()
    a = np.asarray(g.to_dense().adj)
    assert np.array_equal(a, a.T)
    assert np.all(np.diag(a) == 0)
    assert g.n_edges * 2 == int(a.sum())
    u, v = g.edges()
    assert np.all(u < v)                      # canonical undirected form
    assert g.max_degree == int(a.sum(axis=1).max())
    assert np.array_equal(g.degrees, a.sum(axis=1))


def test_large_graph_never_densifies():
    L = 20_000
    g = graphs.barabasi_albert(L, m=3, seed=1)
    assert g.n_nodes == L and g.is_connected()
    with pytest.raises(ValueError):
        _ = g.adj
    with pytest.raises(ValueError):
        g.to_dense()
    # ER above its dense cutoff takes the G(L, M) sampler
    p = 2 * np.log(L) / L                     # safely connected regime
    ge = graphs.erdos_renyi(L, p=p, seed=0)
    assert ge.is_connected()
    mean = p * L * (L - 1) / 2
    assert abs(ge.n_edges - mean) < 6 * np.sqrt(mean)
    # sub-threshold p: the ring-overlay fallback still connects
    gf = graphs.erdos_renyi(L, p=0.5 / L, seed=0, max_tries=2)
    assert gf.is_connected()


def test_er_small_L_dense_draw_unchanged():
    # below the cutoff the historical dense-matrix draw is kept so seeds
    # reproduce pre-sparse graphs bit for bit
    g = graphs.erdos_renyi(24, p=0.3, seed=7)
    rng = np.random.default_rng(7)
    upper = np.triu(rng.random((24, 24)) < 0.3, k=1)
    legacy = upper | upper.T
    assert np.array_equal(np.asarray(g.to_dense().adj).astype(bool), legacy)


# ------------------------------------------------- mixing weight parity

WEIGHT_PAIRS = {
    "metropolis": (mixing.metropolis_weights,
                   mixing.metropolis_weights_sparse),
    "equal_neighbor": (mixing.equal_neighbor_weights,
                       mixing.equal_neighbor_weights_sparse),
    "lazy": (lambda g: mixing.lazy_weights(g, 0.5),
             lambda g: mixing.lazy_weights_sparse(g, 0.5)),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("scheme", sorted(WEIGHT_PAIRS))
def test_sparse_weights_match_dense_builders(family, scheme):
    g = FAMILIES[family](40)
    dense_fn, sparse_fn = WEIGHT_PAIRS[scheme]
    Wd = np.asarray(dense_fn(g.to_dense()))
    sw = sparse_fn(g)
    np.testing.assert_allclose(sw.to_dense(), Wd, atol=1e-14)


def test_circulant_sparse_weights_fold_collisions():
    sw = mixing.circulant_weights_sparse(6, (-1, 1, 3, -3), None)
    Wd = np.asarray(mixing.circulant_weights(6, (-1, 1, 3, -3), None))
    np.testing.assert_allclose(sw.to_dense(), Wd, atol=1e-15)


def test_sparse_power_budget_degrade():
    g = graphs.erdos_renyi(60, p=0.12, seed=1)
    sw = mixing.metropolis_weights_sparse(g)
    p2 = sw.power(2)
    assert p2 is not None
    np.testing.assert_allclose(
        p2.to_dense(), np.linalg.matrix_power(sw.to_dense(), 2),
        atol=1e-12)
    # a tiny fill budget forces the per-round fallback
    assert sw.power(4, max_fill_factor=1.01) is None


# ------------------------------------------------- combine-rule parity

def _parity_setup(L=24, k=5, seed=0):
    g = graphs.erdos_renyi(L, p=0.3, seed=seed)
    sw = mixing.metropolis_weights_sparse(g)
    Wd = jnp.asarray(sw.to_dense())
    Z = jax.random.normal(jax.random.PRNGKey(seed), (L, 7, k))
    return sw, Wd, Z


@pytest.mark.parametrize("rule", ["gossip", "exact_diffusion",
                                  "beyond_central"])
def test_gossip_family_sparse_parity(rule):
    sw, Wd, Z = _parity_setup()
    r = consensus.get_rule(rule)
    dense = r.make_sim_mixer(Wd, 3, backend="xla-ref")
    sparse = r.make_sim_mixer(sw, 3, backend="xla-ref")
    np.testing.assert_allclose(np.asarray(sparse(Z)),
                               np.asarray(dense(Z)), atol=1e-12)


def test_neighbor_sparse_parity():
    g = graphs.erdos_renyi(24, p=0.3, seed=0)
    Md = consensus.neighbor_average_matrix(
        jnp.asarray(g.to_dense().adj, jnp.float64))
    Ms = consensus.neighbor_average_matrix(g)
    assert isinstance(Ms, SparseWeights)
    Z = jax.random.normal(jax.random.PRNGKey(1), (24, 5))
    r = consensus.get_rule("neighbor")
    np.testing.assert_allclose(
        np.asarray(r.make_sim_mixer(Ms, 1, backend="xla-ref")(Z)),
        np.asarray(r.make_sim_mixer(Md, 1, backend="xla-ref")(Z)),
        atol=1e-12)


@pytest.mark.parametrize("rule,kw", [
    ("topk_gossip", dict(compression_k=3)),
    ("quantized_gossip", dict(compression="int8")),
    ("event_gossip", dict(event_threshold=0.05)),
])
def test_compressed_rules_sparse_parity(rule, kw):
    sw, Wd, Z = _parity_setup()
    r = consensus.get_rule(rule)
    state0 = r.init_state(Z, **kw)
    md = r.make_sim_state_mixer(Wd, 3, backend="xla-ref", **kw)
    ms = r.make_sim_state_mixer(sw, 3, backend="xla-ref", **kw)
    zd, _ = md(Z, state0)
    zs, _ = ms(Z, state0)
    np.testing.assert_allclose(np.asarray(zs), np.asarray(zd), atol=1e-12)


def test_partial_and_pushsum_sparse_parity():
    sw, Wd, Z = _parity_setup()
    m = jnp.asarray(np.random.default_rng(3).random(24) > 0.3)
    for rule in ("partial_gossip", "push_sum_gossip"):
        r = consensus.get_rule(rule)
        dense = r.make_sim_masked_mixer(Wd, 3, backend="xla-ref")
        sparse = r.make_sim_masked_mixer(sw, 3, backend="xla-ref")
        np.testing.assert_allclose(np.asarray(sparse(Z, m)),
                                   np.asarray(dense(Z, m)), atol=1e-12,
                                   err_msg=rule)


def test_stale_sparse_parity():
    sw, Wd, Z = _parity_setup()
    m = jnp.asarray(np.random.default_rng(5).random(24) > 0.3)
    r = consensus.get_rule("stale_gossip")
    state0 = r.init_state(Z)
    md = r.make_sim_masked_state_mixer(Wd, 3, backend="xla-ref")
    ms = r.make_sim_masked_state_mixer(sw, 3, backend="xla-ref")
    zd, std = md(Z, state0, m)
    zs, sts = ms(Z, state0, m)
    np.testing.assert_allclose(np.asarray(zs), np.asarray(zd), atol=1e-12)
    np.testing.assert_allclose(np.asarray(sts), np.asarray(std), atol=1e-12)


def test_padding_row_neutrality():
    # extra padding entries (row=L, weight 0.0) must be BITWISE invisible
    sw, _, Z = _parity_setup()
    rows, cols, vals, diag = consensus._sparse_arrays(sw)
    zf = Z.reshape(Z.shape[0], -1)
    base = consensus.sparse_round(zf, rows, cols, vals, diag, sw.n)
    pad = consensus._SPARSE_PAD
    rows2 = jnp.concatenate([rows, jnp.full((pad,), sw.n, rows.dtype)])
    cols2 = jnp.concatenate([cols, jnp.zeros((pad,), cols.dtype)])
    vals2 = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    more = consensus.sparse_round(zf, rows2, cols2, vals2, diag, sw.n)
    assert np.array_equal(np.asarray(base), np.asarray(more))


def test_consensus_spread_large_L_is_radius():
    # the exact pairwise diameter fuses down to an (L, L) norm buffer —
    # 40 GB at L=100k — so above SPREAD_EXACT_MAX the metric switches to
    # the O(L·d·r) consensus radius; below it, exact and unchanged
    from repro.core.metrics import SPREAD_EXACT_MAX, consensus_spread
    rng = np.random.default_rng(0)
    U = jnp.asarray(rng.normal(size=(SPREAD_EXACT_MAX + 1, 3, 2)))
    dev = U - jnp.mean(U, axis=0, keepdims=True)
    radius = jnp.max(jnp.sqrt(jnp.sum(dev ** 2, axis=(-2, -1))))
    assert np.isclose(float(consensus_spread(U)), float(radius))
    small = U[:8]
    diff = small[:, None] - small[None, :]
    exact = jnp.max(jnp.sqrt(jnp.sum(diff ** 2, axis=(-2, -1))))
    assert float(consensus_spread(small)) == float(exact)


def test_maybe_sparsify_policy():
    small = np.asarray(mixing.metropolis_weights(
        graphs.erdos_renyi(32, p=0.2, seed=0).to_dense()))
    assert consensus.maybe_sparsify(jnp.asarray(small)) is not None
    assert not isinstance(consensus.maybe_sparsify(jnp.asarray(small)),
                          SparseWeights)          # below node cutoff
    g = graphs.erdos_renyi(consensus.SPARSE_MIN_NODES, p=0.01, seed=0)
    big = mixing.metropolis_weights_sparse(g).to_dense()
    assert isinstance(consensus.maybe_sparsify(big), SparseWeights)
    sw = mixing.metropolis_weights_sparse(graphs.ring(16))
    assert consensus.maybe_sparsify(sw) is sw     # explicit passes through


def test_spectral_init_sparse_equals_dense():
    """PR-9 satellite: decentralized_spectral_init routes every AGREE
    through maybe_sparsify, so at L ≥ 512 on a sparse graph the init's
    consensus rounds run on the padded-COO segment-sum path.  Pinned
    against the dense (L, L) product ≤ 1e-12 — same arithmetic per
    round, different lowering."""
    from repro.core import spectral
    from repro.core.problem import generate_problem, node_view

    L = 1024
    g = graphs.erdos_renyi(L, p=6.0 / L, seed=5)
    W = mixing.metropolis_weights_sparse(g).to_dense()
    assert isinstance(consensus.maybe_sparsify(W), SparseWeights)

    prob = generate_problem(jax.random.PRNGKey(0), d=8, T=L, r=2, n=10,
                            L=L, kappa=1.2)
    Xg, yg = node_view(prob)
    kw = dict(kappa=prob.kappa, mu=prob.mu, r=2, T_pm=3, T_con=2)
    sp = spectral.decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, **kw)

    orig = spectral.maybe_sparsify
    spectral.maybe_sparsify = lambda w: w         # force the dense path
    try:
        dn = spectral.decentralized_spectral_init(
            jax.random.PRNGKey(1), Xg, yg, W, **kw)
    finally:
        spectral.maybe_sparsify = orig

    for a, b, what in ((sp.U0, dn.U0, "U0"),
                       (sp.R_diag, dn.R_diag, "R_diag"),
                       (sp.alpha, dn.alpha, "alpha")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-12, err_msg=what)


def test_power_hoist_matches_per_round():
    sw, Wd, Z = _parity_setup()
    r = consensus.get_rule("gossip")
    # pallas-backend lowering may hoist W^T; xla-ref never does — both
    # must agree with the exact dense product
    exact = np.asarray(consensus.stacked_product(Z, Wd, 5))
    hoisted = r.make_sim_mixer(sw, 5, backend="jax_pallas")
    np.testing.assert_allclose(np.asarray(hoisted(Z)), exact, atol=1e-12)


# ------------------------------------------------- RCM shift pruning

def test_rcm_prunes_scrambled_structured_graph():
    L = 96
    Wc = np.asarray(mixing.metropolis_weights(
        graphs.cluster_of_cliques(L, clique=8, seed=2).to_dense()))
    p = np.random.default_rng(0).permutation(L)
    rw = consensus.mesh_weights_relabeled(Wc[np.ix_(p, p)])  # verify=True
    assert rw.shifts_after < rw.shifts_before / 2
    # relabeled mixing is the same arithmetic: permute, mix, un-permute
    Z = np.random.default_rng(1).normal(size=(L, 5))
    W = Wc[np.ix_(p, p)]
    Wp = W[np.ix_(rw.perm, rw.perm)]
    inv = np.empty(L, dtype=np.int64)
    inv[rw.perm] = np.arange(L)
    np.testing.assert_allclose((Wp @ Z[rw.perm])[inv], W @ Z, atol=1e-12)


def test_rcm_identity_fallback_on_circulant():
    rw = consensus.mesh_weights_relabeled(
        np.asarray(mixing.circulant_weights(32, (-1, 1), None)))
    assert np.array_equal(rw.perm, np.arange(32))
    assert rw.shifts_after == rw.shifts_before == 2


def test_rcm_round_trip_verifies_on_er():
    W = np.asarray(mixing.metropolis_weights(
        graphs.erdos_renyi(64, p=0.1, seed=5).to_dense()))
    rw = consensus.mesh_weights_relabeled(W, verify=True)
    assert rw.shifts_before >= rw.shifts_after >= 1


# ------------------------------------------------- comm pricing parity

def test_network_bytes_from_edges():
    sig = consensus.get_rule("gossip").signature(3)
    g = graphs.erdos_renyi(64, p=0.1, seed=2)
    dense_edges = int(np.asarray(g.to_dense().adj).sum()) // 2
    assert g.n_edges == dense_edges
    b = sig.network_bytes_per_iter(40, 8, n_nodes=64, n_edges=g.n_edges)
    assert b == 3 * 2 * dense_edges * 40 * 8


def test_time_axis_degree_weighted_dense_equals_sparse():
    from repro.core.comm_model import time_axis_from_signature
    g = graphs.erdos_renyi(32, p=0.2, seed=4)
    sig = consensus.get_rule("gossip").signature(2)
    deg_sparse = g.degrees
    deg_dense = np.asarray(g.to_dense().adj).sum(axis=1).astype(int)
    ax_s = time_axis_from_signature(sig, 5, 16, 2, 32, int(g.max_degree),
                                    1e-3, seed=0, degrees=deg_sparse)
    ax_d = time_axis_from_signature(sig, 5, 16, 2, 32, int(g.max_degree),
                                    1e-3, seed=0, degrees=deg_dense)
    np.testing.assert_array_equal(ax_s, ax_d)
    # and the degree-weighted axis is >= the uniform max_deg axis is NOT
    # guaranteed (max over more draws) — but both must be monotone
    assert np.all(np.diff(ax_s) > 0)


# ------------------------------------------------- solver trajectories

def _small_spec(name, representation):
    from repro.api.spec import (ExperimentSpec, InitSpec, ProblemSpec,
                                SolverSpec, TopologySpec)
    return ExperimentSpec(
        problem=ProblemSpec(d=16, T=48, r=2, n=12, L=24, kappa=1.2),
        topology=TopologySpec(family="erdos_renyi", p=0.3, seed=3,
                              weights="metropolis",
                              representation=representation),
        init=InitSpec(T_pm=4, T_con=2),
        solver=SolverSpec(name=name, T_GD=3, T_con=2),
    )


@pytest.mark.parametrize("name", sorted(
    __import__("repro.api.registry", fromlist=["solver_names"])
    .solver_names()))
def test_every_solver_sparse_equals_dense(name):
    from repro.api.runner import run_experiment
    td = run_experiment(_small_spec(name, "dense"))
    ts = run_experiment(_small_spec(name, "sparse"))
    np.testing.assert_allclose(np.asarray(ts.U_nodes),
                               np.asarray(td.U_nodes),
                               rtol=1e-7, atol=1e-9)
    np.testing.assert_allclose(ts.sd_max, td.sd_max, rtol=1e-7, atol=1e-9)


def test_topology_spec_representation_validation():
    from repro.api.spec import TopologySpec
    with pytest.raises(ValueError):
        TopologySpec(representation="csr")
    t = TopologySpec(family="barabasi_albert", ba_m=2,
                     representation="sparse")
    assert t.use_sparse(24)
    assert not TopologySpec(representation="dense").use_sparse(10_000)


# ------------------------------------------------- virtual-node mesh

VIRTUAL_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax
    jax.config.update("jax_enable_x64", True)
    import dataclasses
    import numpy as np
    from repro.api.spec import (ExperimentSpec, InitSpec, ProblemSpec,
                                SolverSpec, TopologySpec)
    from repro.api.runner import run_experiment

    base = ExperimentSpec(
        problem=ProblemSpec(d=16, T=96, r=2, n=12, L=48, kappa=1.2),
        topology=TopologySpec(family="erdos_renyi", p=0.15, seed=3,
                              weights="metropolis"),
        init=InitSpec(T_pm=4, T_con=2),
        solver=SolverSpec(name="dif_altgdmin", T_GD=4, T_con=3),
    )
    sim = run_experiment(base)
    # L=48 on 8 devices -> the virtual-node tier (block of 6 per device)
    vm = run_experiment(dataclasses.replace(base, substrate="mesh"))
    np.testing.assert_allclose(np.asarray(vm.U_nodes),
                               np.asarray(sim.U_nodes),
                               rtol=1e-8, atol=1e-9)
    np.testing.assert_allclose(vm.sd_max, sim.sd_max,
                               rtol=1e-8, atol=1e-10)
    # sparse representation decomposes identically
    vs = run_experiment(dataclasses.replace(
        base, substrate="mesh",
        topology=dataclasses.replace(base.topology,
                                     representation="sparse")))
    np.testing.assert_allclose(np.asarray(vs.U_nodes),
                               np.asarray(sim.U_nodes),
                               rtol=1e-8, atol=1e-9)
    print("OK")
""")


def test_virtual_mesh_matches_simulator():
    r = subprocess.run([sys.executable, "-c", VIRTUAL_SCRIPT],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK" in r.stdout


def test_virtual_topology_decomposition_reconstructs_W():
    g = graphs.erdos_renyi(48, p=0.15, seed=3)
    sw = mixing.metropolis_weights_sparse(g)
    vt = consensus.VirtualTopology.from_weights(sw, 8)
    assert vt.n_nodes == 48 and vt.block == 6
    assert vt.n_local_entries + vt.n_cross_entries == sw.nnz
    # rebuild the dense W from the class decomposition
    W = np.zeros((48, 48))
    V, D = vt.block, vt.n_dev
    for dev in range(D):
        lr = np.asarray(vt.local_rows[dev])
        lc = np.asarray(vt.local_cols[dev])
        lv = np.asarray(vt.local_vals[dev])
        keep = lr < V
        W[dev * V + lr[keep], dev * V + lc[keep]] += lv[keep]
        for k, s in enumerate(vt.dev_shifts):
            src = (dev + s) % D
            cr = np.asarray(vt.cross_rows[k, dev])
            cc = np.asarray(vt.cross_cols[k, dev])
            cv = np.asarray(vt.cross_vals[k, dev])
            keep = cr < V
            W[dev * V + cr[keep], src * V + cc[keep]] += cv[keep]
    W[np.arange(48), np.arange(48)] = np.asarray(vt.diag).ravel()
    np.testing.assert_allclose(W, sw.to_dense(), atol=1e-15)
