"""Unified consensus layer (CombineRule): lowering equivalences, the
per-solver bit-identical-trajectory acceptance (the refactor must not
change any existing solver's arithmetic), the comm signatures, and the
two new combine-rule solvers (exact_diffusion / beyond_central)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.api import (ExperimentSpec, InitSpec, ProblemSpec, SolverSpec,
                       TopologySpec, get_solver, run_experiment)
from repro.core.agree import agree
from repro.core.engine import AltgdminEngine, ref_grad_U, ref_minimize_B
from repro.core.spectral import _qr_pos
from repro.distributed import (CombineRule, CommSignature, circulant_weights,
                               combine_blocks, get_rule, metropolis_weights,
                               register_rule, ring)
from repro.distributed.consensus import (BeyondCentralCombine,
                                         ExactDiffusionCombine,
                                         GossipCombine, stacked_product)
from repro.kernels import ops, ref


# ------------------------------------------------------- combine_blocks

def test_combine_blocks_matches_ref_and_fused():
    k = jax.random.PRNGKey(0)
    z = jax.random.normal(k, (16, 8), jnp.float32)
    nbrs = [jax.random.normal(jax.random.fold_in(k, i), (16, 8), jnp.float32)
            for i in range(3)]
    weights = (0.25, 0.25, 0.25, 0.25)
    want = ref.ref_gossip_combine(z, jnp.stack(nbrs), weights)
    unfused = combine_blocks(z, nbrs, weights, backend="xla-ref")
    fused = combine_blocks(z, nbrs, weights, backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(unfused), np.asarray(want),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_combine_blocks_per_shift_weights():
    """The generalized primitive: a non-uniform weight vector (a W row)
    combines every neighbour with its own weight on both paths."""
    k = jax.random.PRNGKey(7)
    z = jax.random.normal(k, (12, 4), jnp.float32)
    nbrs = [jax.random.normal(jax.random.fold_in(k, i), (12, 4), jnp.float32)
            for i in range(3)]
    weights = jnp.asarray([0.4, 0.3, 0.2, 0.1], jnp.float32)
    want = ref.ref_gossip_combine(z, jnp.stack(nbrs), weights)
    unfused = combine_blocks(z, nbrs, weights, backend="xla-ref")
    fused = combine_blocks(z, nbrs, weights, backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(unfused), np.asarray(want),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_combine_blocks_f64_stays_exact():
    """x64 policy: float64 operands never take the f32-accumulating
    fused kernel, even on pallas backends."""
    z = jax.random.normal(jax.random.PRNGKey(1), (8, 4), jnp.float64)
    nbrs = [jnp.roll(z, s, axis=0) for s in (-1, 1)]
    sw, wn = 1 / 3, 1 / 3
    exact = sw * z + wn * nbrs[0] + wn * nbrs[1]
    out = combine_blocks(z, nbrs, (sw, wn, wn), backend="pallas-interpret")
    assert out.dtype == jnp.float64
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exact))


# ------------------------------------------- mesh weight decomposition

def test_mesh_weights_from_matrix_circulant_collapses_uniform():
    """A circulant W decomposes to the historical signed shifts with one
    shared weight row (the scalar fast path — no per-device gather)."""
    from repro.distributed import mesh_weights_from_matrix
    W = circulant_weights(8, (-1, 1))
    shifts, table = mesh_weights_from_matrix(W)
    assert shifts == (-1, 1)
    np.testing.assert_array_equal(table, np.broadcast_to(table[0],
                                                         table.shape))
    np.testing.assert_allclose(table[0], [1 / 3, 1 / 3, 1 / 3], rtol=1e-12)


def test_mesh_weights_from_matrix_reconstructs_any_W():
    """Every entry of an irregular Metropolis W lands on exactly one
    cyclic shift: reassembling the table reproduces W exactly."""
    from repro.distributed import erdos_renyi, mesh_weights_from_matrix
    g = erdos_renyi(8, 0.4, seed=3)
    W = metropolis_weights(g)
    shifts, table = mesh_weights_from_matrix(W)
    L = W.shape[0]
    idx = np.arange(L)
    rebuilt = np.zeros_like(W)
    rebuilt[idx, idx] = table[:, 0]
    for k, s in enumerate(shifts):
        rebuilt[idx, (idx + s) % L] += table[:, k + 1]
    np.testing.assert_array_equal(rebuilt, W)
    # signed representatives, sorted
    assert all(-L // 2 < s <= L // 2 for s in shifts)
    assert list(shifts) == sorted(shifts)


def test_mesh_weights_from_matrix_rejects_nonsquare():
    from repro.distributed import mesh_weights_from_matrix
    with pytest.raises(ValueError, match="square"):
        mesh_weights_from_matrix(np.ones((3, 4)))


# ------------------------------------------------- simulator lowerings

def _ring_setup(L=8, dtype=jnp.float64):
    W = jnp.asarray(circulant_weights(L, (-1, 1)), dtype)
    Z = jax.random.normal(jax.random.PRNGKey(2), (L, 6, 3), dtype)
    return W, Z


def test_gossip_sim_lowering_bit_identical_to_agree():
    W, Z = _ring_setup()
    for t_con in (0, 1, 4):
        mix = get_rule("gossip").make_sim_mixer(W, t_con, backend="xla-ref")
        np.testing.assert_array_equal(np.asarray(mix(Z)),
                                      np.asarray(agree(Z, W, t_con)))


def test_gossip_sim_fused_is_power_combine():
    """Fused sim lowering ≡ the precomputed W^{T_con} mix_nodes combine
    (the engine's PR-1 hoist), bit-for-bit."""
    W, Z = _ring_setup(dtype=jnp.float32)
    t_con = 3
    mix = get_rule("gossip").make_sim_mixer(W, t_con,
                                            backend="pallas-interpret")
    Wp = jnp.linalg.matrix_power(W.astype(jnp.float32), t_con)
    want = ops.mix_nodes(Z, Wp, backend="pallas-interpret").astype(Z.dtype)
    np.testing.assert_array_equal(np.asarray(mix(Z)), np.asarray(want))
    # and it is genuinely close to the exact sequential product
    np.testing.assert_allclose(np.asarray(mix(Z)),
                               np.asarray(agree(Z, W, t_con)),
                               rtol=2e-5, atol=1e-6)


def test_gossip_sim_fused_f64_falls_back_exact():
    W, Z = _ring_setup(dtype=jnp.float64)
    mix = get_rule("gossip").make_sim_mixer(W, 4, backend="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(mix(Z)),
                                  np.asarray(agree(Z, W, 4)))


def test_neighbor_sim_lowering_matches_dense_product():
    g = ring(8)
    adj = jnp.asarray(g.adj, jnp.float64)
    M = adj / jnp.maximum(jnp.sum(adj, axis=1), 1.0)[:, None]
    Z = jax.random.normal(jax.random.PRNGKey(3), (8, 5, 2), jnp.float64)
    mix = get_rule("neighbor").make_sim_mixer(M, backend="xla-ref")
    want = jnp.einsum("gh,h...->g...", M, Z)
    np.testing.assert_array_equal(np.asarray(mix(Z)), np.asarray(want))


def test_central_and_none_rules():
    Z = jax.random.normal(jax.random.PRNGKey(4), (6, 4), jnp.float64)
    mean = get_rule("central").make_sim_mixer()(Z)
    np.testing.assert_allclose(np.asarray(mean),
                               np.broadcast_to(np.asarray(Z).mean(0),
                                               Z.shape), rtol=1e-12)
    assert get_rule("none").make_sim_mixer()(Z) is Z


# ----------------------------------------- engine mixers route through

def test_engine_mixers_are_rule_lowerings():
    W, Z = _ring_setup()
    eng = AltgdminEngine("xla-ref")
    np.testing.assert_array_equal(
        np.asarray(eng.make_mixer(W, 3)(Z)), np.asarray(agree(Z, W, 3)))
    M = W  # any dense mixer
    np.testing.assert_array_equal(
        np.asarray(eng.make_neighbor_mixer(M)(Z)),
        np.asarray(jnp.einsum("gh,h...->g...", M, Z)))


# ------------------------------- per-solver bit-identical trajectories

TINY = ExperimentSpec(
    problem=ProblemSpec(d=36, T=24, r=3, n=22, L=8, kappa=1.5),
    topology=TopologySpec(family="ring", weights="metropolis"),
    init=InitSpec(T_pm=12, T_con=5),
    solver=SolverSpec(name="dif_altgdmin", T_GD=40, T_con=2))


def _pr2_reference_trajectory(name, mat, eta, T_GD, T_con):
    """The pre-refactor (PR-2) per-iteration arithmetic, written out
    inline: ref min-B/grad + sequential AGREE + QR.  The refactored
    solvers must reproduce these trajectories bit-for-bit on xla-ref."""
    U0, Xg, yg, W, adj = mat.init.U0, mat.Xg, mat.yg, mat.W, mat.adj
    L = U0.shape[0]

    def min_grad(U):
        B = ref_minimize_B(U, Xg, yg)
        return B, ref_grad_U(U, B, Xg, yg)

    if name == "dif_altgdmin":
        def step(U, _):
            _, G = min_grad(U)
            U_new, _ = _qr_pos(agree(U - (eta * L) * G, W, T_con))
            return U_new, None
    elif name == "dec_altgdmin":
        def step(U, _):
            _, G = min_grad(U)
            U_new, _ = _qr_pos(U - (eta * L) * agree(G, W, T_con))
            return U_new, None
    elif name == "dgd_altgdmin":
        deg = jnp.maximum(jnp.sum(adj, axis=1), 1.0)
        M = adj / deg[:, None]

        def step(U, _):
            _, G = min_grad(U)
            nbr = jnp.einsum("gh,h...->g...", M.astype(U.dtype), U)
            U_new, _ = _qr_pos(nbr - eta * G)
            return U_new, None
    else:                                   # centralized
        def step(U, _):
            Ub = jnp.broadcast_to(U[None], (Xg.shape[0],) + U.shape)
            B = ref_minimize_B(Ub, Xg, yg)
            G = jnp.sum(ref_grad_U(Ub, B, Xg, yg), axis=0)
            U_new, _ = _qr_pos(U - eta * G)
            return U_new, None

    U_init = U0[0] if name == "centralized_altgdmin" else U0
    U_fin, _ = jax.lax.scan(step, U_init, None, length=T_GD)
    return U_fin if name != "centralized_altgdmin" else U_fin[None]


@pytest.mark.parametrize("name", ["dif_altgdmin", "dec_altgdmin",
                                  "dgd_altgdmin", "centralized_altgdmin"])
def test_solver_trajectories_bit_identical_through_combine_rule(name):
    """Acceptance: every legacy solver routes its combines through
    CombineRule with NO behavior change — trajectories equal the inline
    PR-2 arithmetic exactly (no tolerance) on xla-ref."""
    from repro.api.runner import materialize
    spec = dataclasses.replace(TINY, solver=dataclasses.replace(
        TINY.solver, name=name))
    mat = materialize(spec, key=0)
    solver = get_solver(name)
    eng = AltgdminEngine("xla-ref")
    got = solver.call(mat.init.U0, mat.Xg, mat.yg, mat.W, mat.adj,
                      eta=mat.eta, T_GD=spec.solver.T_GD,
                      T_con=spec.solver.T_con,
                      U_star=mat.problem.U_star, engine=eng)
    want = _pr2_reference_trajectory(name, mat, mat.eta, spec.solver.T_GD,
                                     spec.solver.T_con)
    np.testing.assert_array_equal(np.asarray(got.U_nodes), np.asarray(want))


# --------------------------------------------------- new solver rules

@pytest.mark.parametrize("name,solver_kw", [
    ("exact_diffusion", {}),
    ("beyond_central", {"local_steps": 2}),
])
def test_new_solvers_converge(name, solver_kw):
    """Acceptance: exact_diffusion and beyond_central are registered
    solvers runnable via run_experiment with decreasing sd_max."""
    spec = dataclasses.replace(TINY, solver=SolverSpec(
        name=name, T_GD=60, T_con=3, **solver_kw))
    trace = run_experiment(spec, key=0)
    assert np.all(np.isfinite(trace.sd_max))
    assert trace.sd_max[-1] < 0.25 * trace.sd_max[0], (
        name, trace.sd_max[0], trace.sd_max[-1])
    # the tail of the trajectory keeps improving (not a one-step fluke)
    assert trace.sd_max[-1] <= np.min(trace.sd_max) * 1.05


def test_exact_diffusion_first_step_matches_dif():
    """With ψ_prev initialized to U0 the τ=0 correction vanishes (up to
    the one-ULP ``(ψ + U0) − U0`` round trip), so the first
    exact-diffusion iterate matches Dif-AltGDmin's."""
    from repro.api.runner import materialize
    mat = materialize(TINY, key=0)
    eng = AltgdminEngine("xla-ref")
    kw = dict(eta=mat.eta, T_GD=1, T_con=2, U_star=mat.problem.U_star,
              engine=eng)
    from repro.core import dif_altgdmin, exact_diffusion_altgdmin
    a = dif_altgdmin(mat.init.U0, mat.Xg, mat.yg, mat.W, **kw)
    b = exact_diffusion_altgdmin(mat.init.U0, mat.Xg, mat.yg, mat.W, **kw)
    np.testing.assert_allclose(np.asarray(a.U_nodes),
                               np.asarray(b.U_nodes),
                               rtol=1e-12, atol=1e-13)


def test_exact_diffusion_correction_formula():
    psi = jnp.ones((4, 2, 2)) * 3.0
    psi_prev = jnp.ones((4, 2, 2))
    U_prev = jnp.ones((4, 2, 2)) * 2.0
    np.testing.assert_array_equal(
        np.asarray(ExactDiffusionCombine.correct(psi, psi_prev, U_prev)),
        np.asarray(psi + U_prev - psi_prev))


def test_beyond_central_single_round_combine():
    """The beyond_central rule combines with ONE mixing round no matter
    what T_con says — that is the communication efficiency."""
    W, Z = _ring_setup()
    rule = BeyondCentralCombine()
    for t_con in (1, 5, 10):
        np.testing.assert_array_equal(
            np.asarray(rule.make_sim_mixer(W, t_con, backend="xla-ref")(Z)),
            np.asarray(agree(Z, W, 1)))
        assert rule.signature(t_con).rounds_per_iter == 1


# ------------------------------------------------------ comm signatures

def test_comm_signatures():
    assert get_rule("gossip").signature(7) == CommSignature("gossip", 7)
    assert get_rule("neighbor").signature(7) == CommSignature("neighbor", 1)
    assert get_rule("central").signature(3) == CommSignature("central", 1)
    assert get_rule("none").signature(3) == CommSignature("none", 0)
    assert get_rule("exact_diffusion").signature(4) == CommSignature(
        "gossip", 4)


def test_beyond_central_prices_cheaper_wall_clock():
    """The signature reaches the API's time axis: beyond_central's
    single-round exchange is cheaper per iteration than dif's T_con
    AGREE rounds."""
    dif = run_experiment(dataclasses.replace(
        TINY, solver=SolverSpec(name="dif_altgdmin", T_GD=10, T_con=5)),
        key=0)
    bc = run_experiment(dataclasses.replace(
        TINY, solver=SolverSpec(name="beyond_central", T_GD=10, T_con=5)),
        key=0)
    assert bc.time_axis[-1] < 0.5 * dif.time_axis[-1]
    # ...but its local work is not free: local_steps scales the compute
    # term of the axis
    bc4 = run_experiment(dataclasses.replace(
        TINY, solver=SolverSpec(name="beyond_central", T_GD=10, T_con=5,
                                local_steps=4)), key=0)
    assert bc4.time_axis[-1] > bc.time_axis[-1]


def test_unconsumed_local_steps_rejected():
    """A non-default local_steps on a solver that ignores the field must
    raise instead of silently running without it."""
    spec = dataclasses.replace(TINY, solver=SolverSpec(
        name="dif_altgdmin", T_GD=5, local_steps=3))
    with pytest.raises(ValueError, match="does not consume local_steps"):
        run_experiment(spec, key=0)


def test_registry_rejects_unknown_rule():
    from repro.api import SolverDef, register_solver
    with pytest.raises(ValueError, match="unknown combine rule"):
        register_solver(SolverDef(name="bogus", fn=lambda: None,
                                  combine="telepathy"))


def test_rule_registry_open_and_duplicate_guard():
    class Custom(GossipCombine):
        name = "test_custom_rule"
    try:
        register_rule(Custom())
    except ValueError:
        pass                     # registered by an earlier in-process run
    assert isinstance(get_rule("test_custom_rule"), CombineRule)
    with pytest.raises(ValueError, match="already registered"):
        register_rule(Custom())
    with pytest.raises(ValueError, match="unknown combine rule"):
        get_rule("no_such_rule")


# ------------------------------------------------- env var validation

def test_bad_backend_env_raises_with_var_name(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "palas")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        ops.default_backend()
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    monkeypatch.setenv("REPRO_ENGINE_BACKEND", "xla_ref")
    from repro.core.engine import default_engine_backend
    with pytest.raises(ValueError, match="REPRO_ENGINE_BACKEND"):
        default_engine_backend()


def test_stacked_product_zero_rounds_identity():
    Z = jnp.ones((4, 2))
    W = jnp.asarray(metropolis_weights(ring(4)))
    assert stacked_product(Z, W, 0) is Z
