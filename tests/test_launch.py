"""Launch-layer unit tests (no fake-device mesh needed): sharding rules,
shape admissibility, input-spec assembly, HLO collective parser."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.sharding import param_spec_for, param_specs, cache_specs
from repro.launch.shapes import get_shape, long_ctx_variant, cache_capacity
from repro.launch.specs import abstract_params, batch_struct
from repro.utils.hlo import collective_stats, dominant_collective


# ------------------------------------------------------------- sharding

def test_col_row_rules():
    assert param_spec_for("seg0/mixer/wq/w", (52, 6144, 6144), 16) == \
        P(None, None, "model")
    assert param_spec_for("seg0/mixer/wo/w", (52, 6144, 6144), 16) == \
        P(None, "model", None)
    assert param_spec_for("seg0/ffn/down/w", (40, 22528, 8192), 16) == \
        P(None, "model", None)


def test_expert_and_embed_rules():
    assert param_spec_for("seg1/ffn/experts/gate", (58, 256, 7168, 2048),
                          16) == P(None, "model", None, None)
    assert param_spec_for("embed/table", (129280, 7168), 16) == \
        P("model", None)
    # mamba2 vocab 50280 % 16 ≠ 0 → falls back to sharding d_model
    assert param_spec_for("embed/table", (50280, 768), 16) == \
        P(None, "model")


def test_indivisible_col_falls_back_to_row():
    # mamba2-130m in_proj output 2·1536+2·128+24 = 3352, 3352 % 16 ≠ 0;
    # input 768 % 16 = 0 → row-parallel fallback
    assert param_spec_for("seg0/mixer/in_proj/w", (24, 768, 3352), 16) \
        == P(None, "model", None)
    # zamba's in_proj output 14576 = 16·911 IS divisible → col-parallel
    assert param_spec_for("seg0/mixer/in_proj/w", (78, 3584, 14576), 16) \
        == P(None, None, "model")


def test_norms_replicated():
    spec = param_spec_for("seg0/norm1/scale", (52, 6144), 16)
    assert all(e is None for e in spec)        # fully replicated


def test_node_axis_lead():
    s = param_spec_for("seg0/mixer/wq/w", (16, 52, 6144, 6144), 16,
                       lead=("pod", "data"))
    assert s == P(("pod", "data"), None, None, "model")


def test_fsdp_serving_layout():
    cfg = get_config("deepseek-v3-671b")
    params = abstract_params(cfg)
    specs = param_specs(params, lead=None, model_size=16,
                        fsdp_axes=("data",), fsdp_size=16)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    big_2d = [
        (p, s) for (p, s) in flat
        if "experts" in "/".join(str(getattr(k, "key", k)) for k in p)]
    # expert weights must be sharded over BOTH model and data
    for path, spec in big_2d:
        names = [a for e in spec if e for a in
                 (e if isinstance(e, tuple) else (e,))]
        assert "model" in names and "data" in names, (path, spec)


def test_cache_specs_structural():
    cfg = get_config("zamba2-7b").smoke()
    from repro.models import init_cache
    state = jax.eval_shape(lambda: init_cache(cfg, batch=4, capacity=8))
    specs = cache_specs(state, ("data",), cfg)
    # zamba: grouped ssm caches + shared attn kv caches exist
    assert specs.shared_caches is not None
    assert specs.pos == P()
    # batch dims carry the data axes
    assert specs.shared_caches.k[2] is None or True  # structural smoke


# ------------------------------------------------------------- shapes

def test_shapes_table():
    assert get_shape("train_4k").global_batch == 256
    assert get_shape("long_500k").seq_len == 524_288
    with pytest.raises(KeyError):
        get_shape("train_1m")


def test_long_ctx_variant():
    ssm = get_config("mamba2-130m")
    v, note = long_ctx_variant(ssm)
    assert v is ssm and note == ""          # sub-quadratic: unchanged
    dense = get_config("granite-20b")
    v, note = long_ctx_variant(dense)
    assert v.sliding_window == 8192 and "swa" in v.name
    zamba = get_config("zamba2-7b")
    v, _ = long_ctx_variant(zamba)
    assert v is zamba                       # hybrid already windowed


def test_cache_capacity_windowing():
    assert cache_capacity(get_config("granite-20b"),
                          get_shape("decode_32k")) == 32_768
    v, _ = long_ctx_variant(get_config("granite-20b"))
    assert cache_capacity(v, get_shape("long_500k")) == 8_192


# ------------------------------------------------------------- specs

def test_batch_struct_vlm_splits_seq():
    cfg = get_config("llava-next-mistral-7b")
    b = batch_struct(cfg, 4, 4096)
    assert b["tokens"].shape == (4, 4096 - cfg.vis_tokens)
    assert b["vis_embed"].shape == (4, cfg.vis_tokens, cfg.d_model)


def test_abstract_params_no_allocation():
    cfg = get_config("deepseek-v3-671b")        # 671B params — shapes only
    p = abstract_params(cfg, n_nodes=16)
    leaves = jax.tree_util.tree_leaves(p)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
    assert all(x.shape[0] == 16 for x in leaves)
    total = sum(x.size for x in leaves) / 16
    assert 6e11 < total < 8e11                  # ≈ 671B per node replica


# ------------------------------------------------------------- hlo parser

HLO_SAMPLE = """
  %ag = bf16[16,2048,512]{2,1,0} all-gather(bf16[1,2048,512] %x), dims={0}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024] %y), to_apply=%add
  %cp = f32[8,64]{1,0} collective-permute(f32[8,64] %z), source_target_pairs={{0,1}}
  %a2a = (bf16[4,32]{1,0}, bf16[4,32]{1,0}) all-to-all(bf16[4,32] %p, bf16[4,32] %q)
  %rs = f32[128]{0} reduce-scatter(f32[1024] %w), dimensions={0}
  %notcoll = f32[2]{0} add(f32[2] %a, f32[2] %b)
"""


def test_collective_stats_parser():
    st = collective_stats(HLO_SAMPLE)
    per = st["per_op"]
    assert per["all-gather"]["bytes"] == 16 * 2048 * 512 * 2
    assert per["all-reduce"]["bytes"] == 1024 * 4
    assert per["collective-permute"]["bytes"] == 8 * 64 * 4
    assert per["all-to-all"]["bytes"] == 2 * 4 * 32 * 2
    assert per["reduce-scatter"]["bytes"] == 128 * 4
    assert st["total_count"] == 5
    assert dominant_collective(st) == "all-gather"


def test_collective_stats_skips_async_done():
    txt = ("%s = f32[64]{0} all-gather-start(f32[4] %x)\n"
           "%d = f32[64]{0} all-gather-done(f32[64] %s)\n")
    st = collective_stats(txt)
    assert st["per_op"]["all-gather"]["count"] == 1
