"""Mesh runtime ≡ simulator: Dif-AltGDmin with shard_map/ppermute gossip
must match the simulator run with the circulant ring W bit-for-bit-ish
(subprocess: 8 fake devices, one node per device), on every engine
backend — the mesh runtime routes its min-B/gradient phases through the
same AltgdminEngine as the simulator."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    import jax.numpy as jnp, numpy as np
    from repro.core import (generate_problem, node_view,
                            decentralized_spectral_init, dif_altgdmin,
                            subspace_distance)
    from repro.core import dif_altgdmin_mesh
    from repro.core.altgdmin import resolve_eta
    from repro.distributed import circulant_weights

    L = 8
    prob = generate_problem(jax.random.PRNGKey(0), d=60, T=32, r=3, n=25,
                            L=L, kappa=1.5)
    Xg, yg = node_view(prob)
    W = jnp.asarray(circulant_weights(L, (-1, 1)))
    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=prob.r, T_pm=20, T_con=8)
    eta = resolve_eta(None, prob.n, R_diag=init.R_diag, L=L)

    sim = dif_altgdmin(init.U0, Xg, yg, W, eta=eta, T_GD=150, T_con=2,
                       U_star=prob.U_star)

    from repro.utils.compat import make_mesh
    mesh = make_mesh((L,), ("nodes",))
    U_hw, B_hw = dif_altgdmin_mesh(init.U0, Xg, yg, mesh, "nodes",
                                   eta=eta, T_GD=150, T_con=2)

    # identical trajectories (same arithmetic, different lowering)
    np.testing.assert_allclose(np.asarray(U_hw), np.asarray(sim.U_nodes),
                               rtol=1e-8, atol=1e-9)
    np.testing.assert_allclose(np.asarray(B_hw), np.asarray(sim.B_nodes),
                               rtol=1e-7, atol=1e-8)
    # and it actually converged
    sd = max(float(subspace_distance(U, prob.U_star)) for U in U_hw)
    assert sd < 5e-2, sd  # 150 iters suffice here
    # the lowering uses collective-permutes (the ICI gossip)
    spec = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("nodes"))
    lowered = jax.jit(
        lambda u, x, y: dif_altgdmin_mesh(u, x, y, mesh, "nodes", eta=eta,
                                          T_GD=2, T_con=2),
        in_shardings=(spec, spec, spec)).lower(init.U0, Xg, yg)
    assert "collective-permute" in lowered.compile().as_text()
    print("OK", sd)
""")


def test_mesh_runtime_matches_simulator():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK" in r.stdout


# ------------------------------------------------- mesh through engine

ENGINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import numpy as np
    from repro.api import (ExperimentSpec, ProblemSpec, TopologySpec,
                           InitSpec, SolverSpec, EngineSpec,
                           run_experiment)
    import repro.core.engine as engine_mod

    backend = sys.argv[1]

    # count engine phase calls so "routes through AltgdminEngine" is
    # asserted structurally, not just numerically
    calls = {"min_grad": 0}
    orig = engine_mod.AltgdminEngine.min_grad
    def counting(self, *a, **kw):
        calls["min_grad"] += 1
        return orig(self, *a, **kw)
    engine_mod.AltgdminEngine.min_grad = counting

    spec = ExperimentSpec(
        problem=ProblemSpec(d=48, T=32, r=3, n=25, L=8, kappa=1.5),
        topology=TopologySpec(family="ring", weights="circulant"),
        init=InitSpec(T_pm=15, T_con=6),
        solver=SolverSpec(name="dif_altgdmin", T_GD=60, T_con=2),
        engine=EngineSpec(backend=backend))

    sim = run_experiment(spec, key=0)
    calls_sim = calls["min_grad"]
    hw = run_experiment(dataclasses.replace(spec, substrate="mesh"),
                        key=0)
    assert calls["min_grad"] > calls_sim, "mesh run bypassed the engine"

    # acceptance: mesh matches the simulator to <= 1e-7 on this backend
    drift = float(np.max(np.abs(np.asarray(hw.U_nodes)
                                - np.asarray(sim.U_nodes))))
    assert drift <= 1e-7, f"U drift {drift} on {backend}"
    np.testing.assert_allclose(hw.sd_max, sim.sd_max,
                               rtol=1e-7, atol=1e-9)
    np.testing.assert_allclose(hw.spread, sim.spread,
                               rtol=1e-6, atol=1e-9)
    # B is emitted by the engine in f32 on fused backends, so allow one
    # f32 ULP there; xla-ref keeps the f64 tolerance
    b_tol = (dict(rtol=1e-7, atol=1e-8) if backend == "xla-ref"
             else dict(rtol=1e-5, atol=1e-6))
    np.testing.assert_allclose(np.asarray(hw.B_nodes),
                               np.asarray(sim.B_nodes), **b_tol)
    # the mesh Trace carries the full metric set, same shapes
    assert hw.sd_max.shape == sim.sd_max.shape
    assert hw.time_axis.shape == sim.time_axis.shape
    print("OK", backend, drift)
""")


@pytest.mark.parametrize("backend", ["xla-ref", "pallas-interpret"])
def test_mesh_through_engine_matches_simulator(backend):
    """The same ExperimentSpec run on substrate='mesh' must match the
    simulator to <= 1e-7 while routing min-B/grad through the engine —
    on the seed-numerics backend AND the fused kernel backend."""
    r = subprocess.run([sys.executable, "-c", ENGINE_SCRIPT, backend],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert f"OK {backend}" in r.stdout


# ------------------------------------------- dec/dgd mesh runtimes

DEC_DGD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import numpy as np
    from repro.api import (ExperimentSpec, ProblemSpec, TopologySpec,
                           InitSpec, SolverSpec, EngineSpec,
                           run_experiment)

    solver, backend = sys.argv[1], sys.argv[2]
    spec = ExperimentSpec(
        problem=ProblemSpec(d=48, T=32, r=3, n=25, L=8, kappa=1.5),
        topology=TopologySpec(family="ring", weights="circulant"),
        init=InitSpec(T_pm=15, T_con=6),
        solver=SolverSpec(name=solver, T_GD=60, T_con=2),
        engine=EngineSpec(backend=backend))

    sim = run_experiment(spec, key=0)
    hw = run_experiment(dataclasses.replace(spec, substrate="mesh"),
                        key=0)
    drift = float(np.max(np.abs(np.asarray(hw.U_nodes)
                                - np.asarray(sim.U_nodes))))
    assert drift <= 1e-7, f"U drift {drift} for {solver} on {backend}"
    np.testing.assert_allclose(hw.sd_max, sim.sd_max,
                               rtol=1e-7, atol=1e-9)
    print("OK", solver, backend, drift)
""")


@pytest.mark.parametrize("backend", ["xla-ref", "pallas-interpret"])
@pytest.mark.parametrize("solver", ["dec_altgdmin", "dgd_altgdmin"])
def test_dec_dgd_mesh_matches_simulator(solver, backend):
    """Acceptance: the newly mesh-capable solvers (combine-then-adjust
    and the DGD variation) match their simulator trajectories to <= 1e-7
    on both the seed-numerics and the fused kernel backend."""
    r = subprocess.run([sys.executable, "-c", DEC_DGD_SCRIPT, solver,
                        backend],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert f"OK {solver} {backend}" in r.stdout


# ------------------------------- fused combine dispatch per gossip round

FUSED_COMBINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import sys
    sys.path.insert(0, "src")
    import jax.numpy as jnp, numpy as np
    from repro.core import generate_problem, node_view, \\
        decentralized_spectral_init
    from repro.core import dif_altgdmin_mesh
    from repro.distributed import circulant_weights
    from repro.utils.compat import make_mesh
    from repro.kernels import ops

    # count trace-time gossip_combine dispatches: the round body of the
    # mesh mixer must contain exactly ONE fused K+1-way combine (not K
    # separate weighted-sum sweeps); lax.scan then runs it T_con times.
    calls = {"n": 0}
    orig = ops.gossip_combine
    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)
    ops.gossip_combine = counting

    L, T_con = 8, 3
    prob = generate_problem(jax.random.PRNGKey(0), d=32, T=16, r=3, n=20,
                            L=L, kappa=1.5, dtype=jnp.float32)
    Xg, yg = node_view(prob)
    W = jnp.asarray(circulant_weights(L, (-1, 1)), jnp.float32)
    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=prob.r, T_pm=10, T_con=4)
    mesh = make_mesh((L,), ("nodes",))
    U, B = dif_altgdmin_mesh(init.U0, Xg, yg, mesh, "nodes", eta=1e-4,
                             T_GD=4, T_con=T_con,
                             backend="pallas-interpret")
    jax.block_until_ready(U)
    assert calls["n"] == 1, \\
        f"expected ONE fused combine in the gossip round body, " \\
        f"got {calls['n']}"
    assert np.all(np.isfinite(np.asarray(U)))

    # xla-ref keeps the exact unfused chain: no fused dispatch at all
    calls["n"] = 0
    U2, _ = dif_altgdmin_mesh(init.U0, Xg, yg, mesh, "nodes", eta=1e-4,
                              T_GD=4, T_con=T_con, backend="xla-ref")
    jax.block_until_ready(U2)
    assert calls["n"] == 0, calls["n"]
    # and the fused rounds agree with the exact chain (f32 tolerance)
    np.testing.assert_allclose(np.asarray(U), np.asarray(U2),
                               rtol=2e-4, atol=2e-5)
    print("OK fused-combine")
""")


def test_runtime_single_fused_combine_dispatch_per_round():
    """Acceptance: on pallas backends the mesh runtime issues ONE fused
    gossip_combine per gossip round (the K+1-way kernel) instead of the
    T_con x K weighted-sum chain; xla-ref keeps the exact chain."""
    r = subprocess.run([sys.executable, "-c", FUSED_COMBINE_SCRIPT],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK fused-combine" in r.stdout


# ------------------------------- arbitrary weighted topologies (PR 4)

WEIGHTED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import numpy as np
    from repro.api import (ExperimentSpec, ProblemSpec, TopologySpec,
                           InitSpec, SolverSpec, EngineSpec,
                           run_experiment)
    from repro.distributed import erdos_renyi

    solver, backend = sys.argv[1], sys.argv[2]
    # the graph must be genuinely irregular so the per-device weight
    # table (not the uniform scalar fast path) is what runs
    g = erdos_renyi(8, 0.45, seed=2)
    assert len({int(d) for d in g.degrees}) > 1, list(g.degrees)

    kw = {"local_steps": 2} if solver == "beyond_central" else {}
    spec = ExperimentSpec(
        problem=ProblemSpec(d=48, T=32, r=3, n=25, L=8, kappa=1.5),
        topology=TopologySpec(family="erdos_renyi", p=0.45, seed=2,
                              weights="metropolis"),
        init=InitSpec(T_pm=15, T_con=6),
        solver=SolverSpec(name=solver, T_GD=40, T_con=2, **kw),
        engine=EngineSpec(backend=backend))

    sim = run_experiment(spec, key=0)
    hw = run_experiment(dataclasses.replace(spec, substrate="mesh"),
                        key=0)
    U_sim = np.asarray(sim.U_nodes)
    U_hw = np.asarray(hw.U_nodes)
    if U_sim.shape[0] == 1:     # centralized: one U vs L identical rows
        U_sim = np.broadcast_to(U_sim, U_hw.shape)
    drift = float(np.max(np.abs(U_hw - U_sim)))
    assert drift <= 1e-7, f"U drift {drift} for {solver} on {backend}"
    np.testing.assert_allclose(hw.sd_max, sim.sd_max,
                               rtol=1e-7, atol=1e-9)
    print("OK", solver, backend, drift)
""")

ALL_SOLVERS = ["dif_altgdmin", "dec_altgdmin", "dgd_altgdmin",
               "centralized_altgdmin", "exact_diffusion", "beyond_central"]


@pytest.mark.parametrize("backend", ["xla-ref", "pallas-interpret"])
@pytest.mark.parametrize("solver", ALL_SOLVERS)
def test_weighted_topology_mesh_matches_simulator(solver, backend):
    """Acceptance (PR 4): every registered solver runs a
    Metropolis-weighted irregular-ER spec on the mesh substrate with
    <= 1e-7 parity to the simulator, on the seed-numerics backend AND
    the fused kernel backend — the consensus layer decomposes the
    arbitrary W into per-shift, per-device weights."""
    r = subprocess.run([sys.executable, "-c", WEIGHTED_SCRIPT, solver,
                        backend],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert f"OK {solver} {backend}" in r.stdout


WEIGHTED_COMBINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import sys
    sys.path.insert(0, "src")
    import jax.numpy as jnp, numpy as np
    from repro.core import generate_problem, node_view, \\
        decentralized_spectral_init
    from repro.core import dif_altgdmin_mesh
    from repro.distributed import erdos_renyi, metropolis_weights
    from repro.utils.compat import make_mesh
    from repro.kernels import ops

    # weighted combines must stay ONE fused dispatch per gossip round:
    # the per-shift weight vector rides the kernel as an operand, not as
    # K separate axpy sweeps
    calls = {"n": 0}
    orig = ops.gossip_combine
    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)
    ops.gossip_combine = counting

    L, T_con = 8, 3
    g = erdos_renyi(L, 0.45, seed=2)
    assert len({int(d) for d in g.degrees}) > 1      # irregular
    W = jnp.asarray(metropolis_weights(g), jnp.float32)
    prob = generate_problem(jax.random.PRNGKey(0), d=32, T=16, r=3, n=20,
                            L=L, kappa=1.5, dtype=jnp.float32)
    Xg, yg = node_view(prob)
    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=prob.r, T_pm=10, T_con=4)
    mesh = make_mesh((L,), ("nodes",))
    U, B = dif_altgdmin_mesh(init.U0, Xg, yg, mesh, "nodes", eta=1e-4,
                             T_GD=4, T_con=T_con, W=np.asarray(W),
                             backend="pallas-interpret")
    jax.block_until_ready(U)
    assert calls["n"] == 1, \\
        f"expected ONE fused weighted combine per gossip round, " \\
        f"got {calls['n']}"
    assert np.all(np.isfinite(np.asarray(U)))

    # xla-ref keeps the exact unfused chain: no fused dispatch at all
    calls["n"] = 0
    U2, _ = dif_altgdmin_mesh(init.U0, Xg, yg, mesh, "nodes", eta=1e-4,
                              T_GD=4, T_con=T_con, W=np.asarray(W),
                              backend="xla-ref")
    jax.block_until_ready(U2)
    assert calls["n"] == 0, calls["n"]
    # and the fused weighted rounds agree with the exact chain
    np.testing.assert_allclose(np.asarray(U), np.asarray(U2),
                               rtol=2e-4, atol=2e-5)
    print("OK weighted-combine")
""")


def test_weighted_combine_single_dispatch_per_round():
    """Acceptance (PR 4): the generalized per-shift-weight combine on an
    irregular Metropolis graph still lowers to ONE fused gossip_combine
    dispatch per gossip round on the pallas backends."""
    r = subprocess.run([sys.executable, "-c", WEIGHTED_COMBINE_SCRIPT],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK weighted-combine" in r.stdout


# --------------------------- compressed consensus rules (PR 5)

COMPRESSED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import numpy as np
    from repro.api import (ExperimentSpec, ProblemSpec, TopologySpec,
                           InitSpec, SolverSpec, EngineSpec,
                           run_experiment)

    solver, backend = sys.argv[1], sys.argv[2]
    kw = {"dif_topk": {"compression_k": 12},
          "dif_quantized": {"compression": "int8_stochastic"},
          "dif_event": {"event_threshold": 0.05}}[solver]
    # irregular weighted graph: the per-device weight table path AND the
    # compact-payload ppermute path run together
    spec = ExperimentSpec(
        problem=ProblemSpec(d=48, T=32, r=3, n=25, L=8, kappa=1.5),
        topology=TopologySpec(family="erdos_renyi", p=0.45, seed=2,
                              weights="metropolis"),
        init=InitSpec(T_pm=15, T_con=6),
        solver=SolverSpec(name=solver, T_GD=40, T_con=2, **kw),
        engine=EngineSpec(backend=backend))

    sim = run_experiment(spec, key=0)
    hw = run_experiment(dataclasses.replace(spec, substrate="mesh"),
                        key=0)
    drift = float(np.max(np.abs(np.asarray(hw.U_nodes)
                                - np.asarray(sim.U_nodes))))
    assert drift <= 1e-7, f"U drift {drift} for {solver} on {backend}"
    np.testing.assert_allclose(hw.sd_max, sim.sd_max,
                               rtol=1e-7, atol=1e-9)
    print("OK", solver, backend, drift)
""")

COMPRESSED_SOLVERS = ["dif_topk", "dif_quantized", "dif_event"]


@pytest.mark.parametrize("backend", ["xla-ref", "pallas-interpret"])
@pytest.mark.parametrize("solver", COMPRESSED_SOLVERS)
def test_compressed_mesh_matches_simulator(solver, backend):
    """Acceptance (PR 5): the compressed solvers — whose reference-copy
    error-feedback state rides the aux scan carry and whose COMPACT
    payloads (top-k rows + indices / int8 + scale / triggered resends)
    are what crosses the collective-permutes — match their simulator
    trajectories to <= 1e-7 on a Metropolis-weighted irregular-ER spec,
    on the seed-numerics backend AND the fused kernel backend."""
    r = subprocess.run([sys.executable, "-c", COMPRESSED_SCRIPT, solver,
                        backend],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert f"OK {solver} {backend}" in r.stdout


COMPRESSED_COMBINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import sys
    sys.path.insert(0, "src")
    import jax.numpy as jnp, numpy as np
    from repro.core import generate_problem, node_view, \\
        decentralized_spectral_init
    from repro.core import dif_topk_mesh
    from repro.distributed import circulant_weights
    from repro.utils.compat import make_mesh
    from repro.kernels import ops
    from repro.kernels import compress as cpk

    # the compressed round must stay ONE fused gossip_combine dispatch
    # per round (after the compact-payload permutes + copy refresh), and
    # the compress_topk kernel is what encodes the payload
    calls = {"combine": 0, "topk": 0}
    orig_combine = ops.gossip_combine
    def counting_combine(*a, **kw):
        calls["combine"] += 1
        return orig_combine(*a, **kw)
    ops.gossip_combine = counting_combine
    orig_topk = cpk.compress_topk
    def counting_topk(*a, **kw):
        calls["topk"] += 1
        return orig_topk(*a, **kw)
    cpk.compress_topk = counting_topk

    L, T_con = 8, 3
    prob = generate_problem(jax.random.PRNGKey(0), d=32, T=16, r=3, n=20,
                            L=L, kappa=1.5, dtype=jnp.float32)
    Xg, yg = node_view(prob)
    W = jnp.asarray(circulant_weights(L, (-1, 1)), jnp.float32)
    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=prob.r, T_pm=10, T_con=4)
    mesh = make_mesh((L,), ("nodes",))
    U, B = dif_topk_mesh(init.U0, Xg, yg, mesh, "nodes", eta=1e-4,
                         T_GD=4, T_con=T_con, compression_k=8,
                         backend="pallas-interpret")
    jax.block_until_ready(U)
    assert calls["combine"] == 1, \\
        f"expected ONE fused combine per compressed round, " \\
        f"got {calls['combine']}"
    assert calls["topk"] == 1, calls["topk"]
    assert np.all(np.isfinite(np.asarray(U)))

    # xla-ref keeps the exact unfused chain + reference encoder: no
    # fused kernel dispatches at all
    calls["combine"] = calls["topk"] = 0
    U2, _ = dif_topk_mesh(init.U0, Xg, yg, mesh, "nodes", eta=1e-4,
                          T_GD=4, T_con=T_con, compression_k=8,
                          backend="xla-ref")
    jax.block_until_ready(U2)
    assert calls["combine"] == 0 and calls["topk"] == 0, calls
    np.testing.assert_allclose(np.asarray(U), np.asarray(U2),
                               rtol=2e-4, atol=2e-5)
    print("OK compressed-combine")
""")


def test_compressed_combine_single_dispatch_per_round():
    """Acceptance (PR 5): compression does not unfuse the combine — on
    pallas backends each compressed gossip round is still ONE fused
    gossip_combine dispatch (plus the compress_topk payload encode);
    xla-ref keeps the exact chain with zero fused dispatches."""
    r = subprocess.run([sys.executable, "-c", COMPRESSED_COMBINE_SCRIPT],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK compressed-combine" in r.stdout


# ------------------------- dropout-tolerant consensus rules (PR 6)

SYSTEM_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import numpy as np
    from repro.api import (ExperimentSpec, ProblemSpec, TopologySpec,
                           InitSpec, SolverSpec, SystemSpec,
                           run_experiment)

    solver = sys.argv[1]
    spec = ExperimentSpec(
        problem=ProblemSpec(d=48, T=32, r=3, n=25, L=8, kappa=1.5),
        topology=TopologySpec(family="erdos_renyi", p=0.45, seed=2,
                              weights="metropolis"),
        init=InitSpec(T_pm=15, T_con=6),
        solver=SolverSpec(name=solver, T_GD=25, T_con=2),
        system=SystemSpec(availability="bernoulli", p_on=0.7, seed=7))

    # degenerate anchor: an always-on SystemSpec on the MESH substrate
    # reproduces the dense mesh run bit-for-bit (partial/stale)
    dense = run_experiment(dataclasses.replace(
        spec, solver=dataclasses.replace(spec.solver,
                                         name="dif_altgdmin"),
        system=None, substrate="mesh"), key=0)
    anchor = run_experiment(dataclasses.replace(
        spec, system=SystemSpec(), substrate="mesh"), key=0,
        materialized=dense.materialized)
    if solver in ("dif_partial", "dif_stale"):
        assert np.array_equal(np.asarray(anchor.U_nodes),
                              np.asarray(dense.U_nodes)), "anchor drift"
        np.testing.assert_array_equal(anchor.sd_max, dense.sd_max)
    else:
        np.testing.assert_allclose(anchor.sd_max, dense.sd_max,
                                   rtol=1e-8, atol=1e-10)

    # faulted run: one seeded 30%-dropout schedule, both substrates
    sim = run_experiment(spec, key=0, materialized=dense.materialized)
    hw = run_experiment(dataclasses.replace(spec, substrate="mesh"),
                        key=0, materialized=dense.materialized)
    drift = float(np.max(np.abs(np.asarray(hw.U_nodes)
                                - np.asarray(sim.U_nodes))))
    assert drift <= 2e-6, f"U drift {drift} for {solver}"
    np.testing.assert_allclose(hw.sd_max, sim.sd_max, atol=2e-6)
    for t in (sim, hw):
        assert np.all(np.isfinite(t.sd_max))
        assert np.all(np.diff(t.time_axis) > 0)
        assert t.time_axis_source == "simulated"
    np.testing.assert_array_equal(sim.time_axis, hw.time_axis)
    print("OK", solver, drift)
""")

SYSTEM_SOLVERS = ["dif_partial", "dif_stale", "dif_pushsum"]


@pytest.mark.parametrize("solver", SYSTEM_SOLVERS)
def test_dropout_mesh_matches_simulator(solver):
    """Acceptance (PR 6): the dropout-tolerant solvers — whose seeded
    availability mask rides the scan's xs on both substrates — (a)
    reduce to the dense mesh run bit-for-bit under an always-on
    SystemSpec (push-sum to float round-off: its ratio correction is
    different arithmetic), and (b) under seeded 30% Bernoulli dropout
    match the simulator trajectory to <= 2e-6 with a finite, strictly
    monotone, substrate-independent simulated time axis."""
    r = subprocess.run([sys.executable, "-c", SYSTEM_SCRIPT, solver],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert f"OK {solver}" in r.stdout
