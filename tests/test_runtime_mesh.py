"""Mesh runtime ≡ simulator: Dif-AltGDmin with shard_map/ppermute gossip
must match the simulator run with the circulant ring W bit-for-bit-ish
(subprocess: 8 fake devices, one node per device)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    import jax.numpy as jnp, numpy as np
    from repro.core import (generate_problem, node_view,
                            decentralized_spectral_init, dif_altgdmin,
                            subspace_distance)
    from repro.core.runtime import dif_altgdmin_mesh
    from repro.core.altgdmin import resolve_eta
    from repro.distributed import circulant_weights

    L = 8
    prob = generate_problem(jax.random.PRNGKey(0), d=60, T=32, r=3, n=25,
                            L=L, kappa=1.5)
    Xg, yg = node_view(prob)
    W = jnp.asarray(circulant_weights(L, (-1, 1)))
    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=prob.r, T_pm=20, T_con=8)
    eta = resolve_eta(None, prob.n, R_diag=init.R_diag, L=L)

    sim = dif_altgdmin(init.U0, Xg, yg, W, eta=eta, T_GD=150, T_con=2,
                       U_star=prob.U_star)

    from repro.utils.compat import make_mesh
    mesh = make_mesh((L,), ("nodes",))
    U_hw, B_hw = dif_altgdmin_mesh(init.U0, Xg, yg, mesh, "nodes",
                                   eta=eta, T_GD=150, T_con=2)

    # identical trajectories (same arithmetic, different lowering)
    np.testing.assert_allclose(np.asarray(U_hw), np.asarray(sim.U_nodes),
                               rtol=1e-8, atol=1e-9)
    np.testing.assert_allclose(np.asarray(B_hw), np.asarray(sim.B_nodes),
                               rtol=1e-7, atol=1e-8)
    # and it actually converged
    sd = max(float(subspace_distance(U, prob.U_star)) for U in U_hw)
    assert sd < 5e-2, sd  # 150 iters suffice here
    # the lowering uses collective-permutes (the ICI gossip)
    spec = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("nodes"))
    lowered = jax.jit(
        lambda u, x, y: dif_altgdmin_mesh(u, x, y, mesh, "nodes", eta=eta,
                                          T_GD=2, T_con=2),
        in_shardings=(spec, spec, spec)).lower(init.U0, Xg, yg)
    assert "collective-permute" in lowered.compile().as_text()
    print("OK", sd)
""")


def test_mesh_runtime_matches_simulator():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK" in r.stdout
