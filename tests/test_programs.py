"""The solver-program IR and its three lowerings (PR-9 tentpole).

Every registered solver is a :class:`repro.core.program.SolverProgram`;
the registry derives its simulator / mesh / virtual-mesh entry points
from the program's lowerings.  These tests pin the refactor's
contract:

  * the simulator lowering is BITWISE identical to the legacy
    hand-written drivers in :mod:`repro.core.altgdmin`, for all 12
    solvers, on both the ``xla-ref`` and ``pallas-interpret`` backends
    (the legacy drivers stay in-tree as the oracle);
  * the mesh lowering (one node per device) and the virtual-node mesh
    lowering (L = devices × block) agree with the simulator ≤ 1e-8 for
    all 12 solvers — run in a subprocess with 8 fake host devices,
    like tests/test_runtime_mesh.py;
  * the registry metadata round-trips the program (topology / combine /
    spec_kwargs / takes_avail), and repro.core.runtime holds only the
    two substrate skeletons (tools/check_runtime_clean.py's invariant).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from repro.api.registry import get_solver, solver_names
from repro.core import altgdmin as alg
from repro.core import (decentralized_spectral_init, generate_problem,
                        node_view)
from repro.core.program import get_program, program_names
from repro.distributed import graphs, mixing

ALL_SOLVERS = ("dif_altgdmin", "dec_altgdmin", "centralized_altgdmin",
               "dgd_altgdmin", "exact_diffusion", "beyond_central",
               "dif_topk", "dif_quantized", "dif_event",
               "dif_partial", "dif_stale", "dif_pushsum")

# the extra SolverSpec knobs each program consumes, with the values the
# parity runs use (chosen to exercise the non-default paths)
SPEC_KW = {
    "beyond_central": dict(local_steps=2),
    "dif_topk": dict(compression_k=3),
    "dif_quantized": dict(compression="int8_stochastic"),
    "dif_event": dict(event_threshold=0.05),
}


def test_every_solver_is_a_program():
    assert program_names() == tuple(sorted(ALL_SOLVERS))
    # subset, not equality: other test modules may register ad-hoc
    # solver defs into the shared registry within the same process
    assert set(program_names()) <= set(solver_names())
    assert set(ALL_SOLVERS) <= set(solver_names())
    for name in ALL_SOLVERS:
        s = get_solver(name)
        p = get_program(name)
        assert s.program is p
        assert s.mesh_fn is not None and s.virtual_mesh_fn is not None
        assert (s.topology, s.combine) == (p.topology, p.combine)
        assert s.spec_kwargs == p.spec_kwargs
        assert s.takes_avail == p.takes_avail
        assert set(SPEC_KW.get(name, {})) <= set(p.spec_kwargs)


def test_runtime_module_is_solver_free():
    """The historical per-solver *_mesh closures must not grow back in
    repro.core.runtime (same check tools/check_runtime_clean.py runs in
    CI): only the two substrate skeletons live there."""
    r = subprocess.run(
        [sys.executable, "tools/check_runtime_clean.py"],
        capture_output=True, text=True, cwd=REPO_ROOT, timeout=60)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"


# ------------------------------------------------ shared tiny problem

@pytest.fixture(scope="module")
def prob8():
    L, d, r, T, n = 8, 16, 2, 24, 20
    prob = generate_problem(jax.random.PRNGKey(0), d=d, T=T, r=r, n=n,
                            L=L, kappa=1.2)
    Xg, yg = node_view(prob)
    g = graphs.erdos_renyi(L, 0.6, seed=2)
    adj = jnp.asarray(np.asarray(g.adj, dtype=float))
    W = jnp.asarray(mixing.metropolis_weights(g))
    init = decentralized_spectral_init(
        jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa, mu=prob.mu,
        r=r, T_pm=8, T_con=4)
    eta = alg.resolve_eta(None, prob.n, R_diag=init.R_diag, L=L)
    avail = jnp.asarray(np.random.default_rng(0).random((3, L)) > 0.3)
    return dict(prob=prob, Xg=Xg, yg=yg, adj=adj, W=W, U0=init.U0,
                eta=eta, T_GD=3, avail=avail)


def _legacy(name, pb, backend):
    """The hand-written driver in repro.core.altgdmin — the oracle."""
    kw = dict(eta=pb["eta"], T_GD=pb["T_GD"], U_star=pb["prob"].U_star,
              backend=backend)
    U0, Xg, yg, W = pb["U0"], pb["Xg"], pb["yg"], pb["W"]
    fns = {
        "dif_altgdmin": lambda: alg.dif_altgdmin(U0, Xg, yg, W, T_con=2,
                                                 **kw),
        "dec_altgdmin": lambda: alg.dec_altgdmin(U0, Xg, yg, W, T_con=2,
                                                 **kw),
        "centralized_altgdmin": lambda: alg.centralized_altgdmin(
            U0[0], Xg, yg, **kw),
        "dgd_altgdmin": lambda: alg.dgd_altgdmin(U0, Xg, yg, pb["adj"],
                                                 **kw),
        "exact_diffusion": lambda: alg.exact_diffusion_altgdmin(
            U0, Xg, yg, W, T_con=2, **kw),
        "beyond_central": lambda: alg.beyond_central_altgdmin(
            U0, Xg, yg, W, T_con=2, local_steps=2, **kw),
        "dif_topk": lambda: alg.dif_topk_altgdmin(
            U0, Xg, yg, W, T_con=2, compression_k=3, **kw),
        "dif_quantized": lambda: alg.dif_quantized_altgdmin(
            U0, Xg, yg, W, T_con=2, compression="int8_stochastic", **kw),
        "dif_event": lambda: alg.dif_event_altgdmin(
            U0, Xg, yg, W, T_con=2, event_threshold=0.05, **kw),
        "dif_partial": lambda: alg.dif_partial_altgdmin(
            U0, Xg, yg, W, T_con=2, avail=pb["avail"], **kw),
        "dif_stale": lambda: alg.dif_stale_altgdmin(
            U0, Xg, yg, W, T_con=2, avail=pb["avail"], **kw),
        "dif_pushsum": lambda: alg.dif_pushsum_altgdmin(
            U0, Xg, yg, W, T_con=2, avail=pb["avail"], **kw),
    }
    return fns[name]()


def _lowered(name, pb, backend):
    """The same run through the program's simulator lowering."""
    s = get_solver(name)
    kw = dict(eta=pb["eta"], T_GD=pb["T_GD"], U_star=pb["prob"].U_star,
              backend=backend, **SPEC_KW.get(name, {}))
    if s.takes_avail:
        kw["avail"] = pb["avail"]
    if s.topology == "none":
        return s.fn(pb["U0"][0], pb["Xg"], pb["yg"], **kw)
    if s.topology == "adj":
        return s.fn(pb["U0"], pb["Xg"], pb["yg"], pb["adj"], **kw)
    return s.fn(pb["U0"], pb["Xg"], pb["yg"], pb["W"], T_con=2, **kw)


@pytest.mark.parametrize("backend", ["xla-ref", "pallas-interpret"])
@pytest.mark.parametrize("name", ALL_SOLVERS)
def test_simulator_lowering_bitwise_vs_legacy(name, backend, prob8):
    """The simulator lowering is the SAME program as the legacy driver —
    bit-for-bit, metrics included, on both the reference and the
    interpreted-kernel backends."""
    ref = _legacy(name, prob8, backend)
    new = _lowered(name, prob8, backend)
    for field in ("U_nodes", "B_nodes", "sd_max", "sd_mean", "spread"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, field)),
                                      np.asarray(getattr(new, field)),
                                      err_msg=f"{name}/{backend}: {field}")
    if ref.send_frac is None:
        assert new.send_frac is None
    else:
        np.testing.assert_array_equal(np.asarray(ref.send_frac),
                                      np.asarray(new.send_frac))


# --------------------------------------- mesh / virtual-mesh parity
# Subprocess with 8 fake host devices (device count is fixed at process
# start).  One process per substrate covers all 12 solvers to amortize
# the spectral init; the scripts print per-solver deltas on failure.

_PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    import numpy as np, jax.numpy as jnp
    from repro.core import (generate_problem, node_view,
                            decentralized_spectral_init)
    from repro.core import altgdmin as alg
    from repro.api.registry import get_solver
    from repro.distributed import graphs, mixing
    from repro.distributed import consensus as cons
    from repro.utils.compat import make_mesh

    SPEC_KW = {
        "beyond_central": dict(local_steps=2),
        "dif_topk": dict(compression_k=3),
        "dif_quantized": dict(compression="int8_stochastic"),
        "dif_event": dict(event_threshold=0.05),
    }
    NAMES = %r

    def setup(L, p, seed):
        prob = generate_problem(jax.random.PRNGKey(0), d=16, T=3 * L,
                                r=2, n=20, L=L, kappa=1.2)
        Xg, yg = node_view(prob)
        g = graphs.erdos_renyi(L, p, seed=seed)
        adj = jnp.asarray(np.asarray(g.adj, dtype=float))
        W = jnp.asarray(mixing.metropolis_weights(g))
        init = decentralized_spectral_init(
            jax.random.PRNGKey(1), Xg, yg, W, kappa=prob.kappa,
            mu=prob.mu, r=2, T_pm=8, T_con=4)
        eta = alg.resolve_eta(None, prob.n, R_diag=init.R_diag, L=L)
        avail = jnp.asarray(np.random.default_rng(0).random((3, L)) > 0.3)
        return prob, Xg, yg, adj, W, init.U0, eta, avail

    def simulate(s, name, U0, Xg, yg, adj, W, eta, U_star, avail):
        kw = dict(eta=eta, T_GD=3, U_star=U_star, backend="xla-ref",
                  **SPEC_KW.get(name, {}))
        if s.takes_avail:
            kw["avail"] = avail
        if s.topology == "none":
            return s.fn(U0[0], Xg, yg, **kw)
        if s.topology == "adj":
            return s.fn(U0, Xg, yg, adj, **kw)
        return s.fn(U0, Xg, yg, W, T_con=2, **kw)
""" % (ALL_SOLVERS,)

MESH_SCRIPT = textwrap.dedent(_PRELUDE + """
    prob, Xg, yg, adj, W, U0, eta, avail = setup(8, 0.6, 2)
    mesh = make_mesh((8,), ("nodes",))
    Madj = np.asarray(cons.neighbor_average_matrix(adj))
    fails = []
    for name in NAMES:
        s = get_solver(name)
        sim = simulate(s, name, U0, Xg, yg, adj, W, eta, prob.U_star,
                       avail)
        kw = dict(eta=eta, T_GD=3, T_con=2, backend="xla-ref",
                  U_star=prob.U_star, **SPEC_KW.get(name, {}))
        kw["W"] = Madj if s.topology == "adj" else np.asarray(W)
        if s.takes_avail:
            kw["avail"] = avail
        hw = s.mesh_fn(U0, Xg, yg, mesh, "nodes", **kw)
        dU = float(np.max(np.abs(np.asarray(hw.U_nodes)
                                 - np.asarray(sim.U_nodes))))
        dsd = float(np.max(np.abs(np.asarray(hw.sd_max)
                                  - np.asarray(sim.sd_max))))
        print(f"mesh {name:22s} dU={dU:.2e} dsd={dsd:.2e}")
        if not (dU <= 1e-8 and dsd <= 1e-8):
            fails.append((name, dU, dsd))
    assert not fails, fails
    print("OK")
""")

VIRTUAL_SCRIPT = textwrap.dedent(_PRELUDE + """
    from repro.distributed.mixing import SparseWeights
    prob, Xg, yg, adj, W, U0, eta, avail = setup(16, 0.4, 3)
    mesh = make_mesh((8,), ("nodes",))
    vtW = cons.VirtualTopology.from_weights(
        SparseWeights.from_dense(np.asarray(W)), 8)
    Madj = np.asarray(cons.neighbor_average_matrix(adj))
    vtA = cons.VirtualTopology.from_weights(
        SparseWeights.from_dense(Madj), 8)
    fails = []
    for name in NAMES:
        s = get_solver(name)
        sim = simulate(s, name, U0, Xg, yg, adj, W, eta, prob.U_star,
                       avail)
        kw = dict(eta=eta, T_GD=3, T_con=2, backend="xla-ref",
                  U_star=prob.U_star, **SPEC_KW.get(name, {}))
        kw["vt"] = vtA if s.topology == "adj" else vtW
        if s.takes_avail:
            kw["avail"] = avail
        hw = s.virtual_mesh_fn(U0, Xg, yg, mesh, "nodes", **kw)
        U_sim = np.asarray(sim.U_nodes)
        if s.topology == "none":
            U_sim = np.broadcast_to(U_sim[0],
                                    np.asarray(hw.U_nodes).shape)
        dU = float(np.max(np.abs(np.asarray(hw.U_nodes) - U_sim)))
        dsd = float(np.max(np.abs(np.asarray(hw.sd_max)
                                  - np.asarray(sim.sd_max))))
        print(f"virt {name:22s} dU={dU:.2e} dsd={dsd:.2e}")
        if not (dU <= 1e-8 and dsd <= 1e-8):
            fails.append((name, dU, dsd))
    assert not fails, fails
    print("OK")
""")


def _run_sub(script):
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1800)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-4000:]}"
    assert "OK" in r.stdout


def test_mesh_lowering_matches_simulator_subprocess():
    """All 12 programs, mesh-lowered (one node per device, the weighted
    W path), agree with the simulator lowering ≤ 1e-8."""
    _run_sub(MESH_SCRIPT)


def test_virtual_mesh_lowering_matches_simulator_subprocess():
    """All 12 programs, virtual-mesh-lowered (L=16 on 8 devices, block
    of 2), agree with the simulator lowering ≤ 1e-8."""
    _run_sub(VIRTUAL_SCRIPT)
