"""Checkpoint store: pytree round-trips (dict / list / NamedTuple paths,
dtype preservation, missing-leaf KeyError), shard chunking, and the
crash-safety contract — saves stage into a ``step_*.tmp`` directory and
rename atomically, and ``latest_step`` never reports a directory whose
manifest is missing, so a killed save can't be hot-swapped in."""
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


class Carry(NamedTuple):
    U: jnp.ndarray
    step: jnp.ndarray


def _tree():
    return {
        "params": [jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   jnp.ones((4,), jnp.float64) * np.pi],
        "carry": Carry(U=jnp.eye(3, dtype=jnp.float64),
                       step=jnp.asarray(7, jnp.int32)),
        "scalar": jnp.asarray(2.5, jnp.float16),
    }


def _like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


# ------------------------------------------------------------ round trip

def test_roundtrip_nested_pytree(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree)
    out = restore_checkpoint(str(tmp_path), 3, _like(tree))
    flat_in = jax.tree_util.tree_leaves(tree)
    flat_out = jax.tree_util.tree_leaves(out)
    assert len(flat_in) == len(flat_out)
    for a, b in zip(flat_in, flat_out):
        assert a.dtype == b.dtype, "dtype must survive the round trip"
        assert a.shape == b.shape
        assert bool(jnp.all(a == b))
    # structure (incl. the NamedTuple node) survives
    assert isinstance(out["carry"], Carry)
    assert isinstance(out["params"], list)


def test_roundtrip_many_shards(tmp_path):
    tree = {"a": jnp.arange(1000, dtype=jnp.float32),
            "b": jnp.arange(1000, dtype=jnp.float64),
            "c": jnp.arange(10, dtype=jnp.int32)}
    path = save_checkpoint(str(tmp_path), 0, tree, shard_bytes=4096)
    shards = [f for f in os.listdir(path) if f.startswith("shard_")]
    assert len(shards) > 1, "shard_bytes must chunk the leaves"
    out = restore_checkpoint(str(tmp_path), 0, _like(tree))
    for k in tree:
        assert bool(jnp.all(out[k] == tree[k]))
        assert out[k].dtype == tree[k].dtype


def test_missing_leaf_raises_keyerror(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones(3)})
    with pytest.raises(KeyError, match="missing leaf"):
        restore_checkpoint(str(tmp_path), 1,
                           {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_extra_manifest_leaves_are_ignored(tmp_path):
    # the serving reader restores only {"U"} out of {"U", "U_nodes"}
    save_checkpoint(str(tmp_path), 2, {"U": jnp.ones((3, 2)),
                                       "U_nodes": jnp.ones((4, 3, 2))})
    out = restore_checkpoint(str(tmp_path), 2, {"U": jnp.zeros((3, 2))})
    assert bool(jnp.all(out["U"] == 1))


# ------------------------------------------------------------ latest_step

def test_latest_step_empty_and_missing(tmp_path):
    assert latest_step(str(tmp_path)) is None
    assert latest_step(str(tmp_path / "nope")) is None


def test_latest_step_orders_numerically(tmp_path):
    for s in (3, 10, 7):
        save_checkpoint(str(tmp_path), s, {"x": jnp.zeros(2)})
    assert latest_step(str(tmp_path)) == 10


# ------------------------------------------------------------ crash safety

def test_save_stages_then_renames(tmp_path):
    path = save_checkpoint(str(tmp_path), 5, {"x": jnp.ones(4)})
    assert os.path.isdir(path)
    assert not os.path.isdir(path + ".tmp"), \
        "the staging dir must be renamed away on completion"
    assert os.path.isfile(os.path.join(path, "manifest.msgpack"))


def test_latest_step_skips_manifestless_dir(tmp_path):
    # simulate a save killed after shard writes but before the manifest
    save_checkpoint(str(tmp_path), 2, {"x": jnp.zeros(2)})
    dead = tmp_path / "step_000000009"
    dead.mkdir()
    (dead / "shard_00000.npz").write_bytes(b"partial")
    assert latest_step(str(tmp_path)) == 2


def test_latest_step_ignores_tmp_staging_dir(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
    staging = tmp_path / "step_000000008.tmp"
    staging.mkdir()
    (staging / "manifest.msgpack").write_bytes(b"in flight")
    assert latest_step(str(tmp_path)) == 1


def test_save_clears_stale_staging_and_overwrites(tmp_path):
    # a stale .tmp from a killed save must not break the next save,
    # and re-saving a step replaces the old content
    stale = tmp_path / "step_000000004.tmp"
    stale.mkdir()
    (stale / "junk").write_bytes(b"x")
    save_checkpoint(str(tmp_path), 4, {"x": jnp.ones(3)})
    assert not stale.exists()
    save_checkpoint(str(tmp_path), 4, {"x": jnp.full((3,), 9.0)})
    out = restore_checkpoint(str(tmp_path), 4, {"x": jnp.zeros(3)})
    assert bool(jnp.all(out["x"] == 9.0))
