"""SPMD lowering semantics on a small fake mesh (subprocess, 8 devices):
the paper's diffusion aggregation must lower to collective-permute
(neighbour gossip), the fusion-center baseline to all-reduce — the
communication patterns of Alg. 3 vs AltGDmin, visible in the HLO."""
import os
import subprocess
import sys
import textwrap

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import sys
    sys.path.insert(0, "src")
    from repro.configs import get_config
    from repro.launch.specs import input_specs
    from repro.utils.hlo import collective_stats

    from repro.utils.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_config("mamba2-130m")

    def lower(agg):
        spec = input_specs(cfg, "train_4k", mesh, aggregation=agg)
        with mesh:
            c = jax.jit(spec.step_fn,
                        in_shardings=spec.in_shardings).lower(
                            *spec.args).compile()
        return collective_stats(c.as_text())

    dif = lower("diffusion")
    ar = lower("allreduce")
    cp_dif = dif["per_op"].get("collective-permute", {}).get("count", 0)
    cp_ar = ar["per_op"].get("collective-permute", {}).get("count", 0)
    ar_count = ar["per_op"].get("all-reduce", {}).get("count", 0)
    assert cp_dif > cp_ar, (dif["per_op"], ar["per_op"])
    assert ar_count > 0, ar["per_op"]
    print("OK", cp_dif, cp_ar, ar_count)
""")


def test_diffusion_lowers_to_permutes_allreduce_to_allreduce():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=1800)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK" in r.stdout
