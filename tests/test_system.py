"""System-realism layer (PR 6): SystemSpec validation and seeded fault
schedules, the dropout-tolerant consensus rules (partial / stale /
push-sum) and their degenerate bit-identity with dense gossip, the
event-driven simulated clock vs the closed-form pricing, the CHOCO
consensus step size at aggressive sparsification, deterministic time
axes, and the sweep driver."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.api import (ExperimentSpec, InitSpec, ProblemSpec, SolverSpec,
                       SystemSpec, TopologySpec, get_solver,
                       run_experiment, materialize, system_time_axis)
from repro.core import comm_model as cm
from repro.distributed import get_rule
from repro.distributed.consensus import (masked_mixing_matrix,
                                         push_sum_matrix)
from repro.distributed.graphs import erdos_renyi
from repro.distributed.mixing import metropolis_weights

TINY = ExperimentSpec(
    problem=ProblemSpec(d=36, T=24, r=3, n=22, L=8, kappa=1.5),
    topology=TopologySpec(family="erdos_renyi", p=0.5, seed=3,
                          weights="metropolis"),
    init=InitSpec(T_pm=12, T_con=5),
    solver=SolverSpec(name="dif_altgdmin", T_GD=30, T_con=3))

DROP30 = SystemSpec(availability="bernoulli", p_on=0.7, seed=7)


def _with(spec: ExperimentSpec, **kw) -> ExperimentSpec:
    solver_kw = {k: kw.pop(k) for k in list(kw)
                 if k in ("name", "T_GD", "T_con", "compression",
                          "compression_k", "consensus_gamma")}
    if solver_kw:
        kw["solver"] = dataclasses.replace(spec.solver, **solver_kw)
    return dataclasses.replace(spec, **kw)


def _mixing(L: int = 8, seed: int = 3, p: float = 0.5):
    g = erdos_renyi(L, p, seed=seed)
    return g, jnp.asarray(metropolis_weights(g))


# ----------------------------------------------------- SystemSpec schema

@pytest.mark.parametrize("bad", [
    dict(availability="sometimes"),
    dict(availability="bernoulli", p_on=1.3),
    dict(availability="bernoulli", p_on=-0.1),
    dict(availability="markov", p_drop=2.0),
    dict(availability="markov", p_return=-0.5),
    dict(straggler_prob=1.5),
    dict(straggler_factor=0.5),
    dict(speed_spread=-1.0),
    dict(latency_s=-1e-3),
    dict(jitter_std_s=-1e-6),
])
def test_systemspec_rejects_bad_fields_at_construction(bad):
    with pytest.raises(ValueError):
        SystemSpec(**bad)


def test_systemspec_json_roundtrip():
    spec = _with(TINY, name="dif_partial")
    spec = dataclasses.replace(spec, system=DROP30)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert back.system == DROP30
    # a spec WITHOUT a system layer round-trips to None, not a default
    assert ExperimentSpec.from_json(TINY.to_json()).system is None


def test_availability_mask_semantics_and_determinism():
    T_GD, L = 400, 10
    always = SystemSpec().availability_mask(T_GD, L)
    assert always.all() and always.shape == (T_GD, L)
    # bernoulli: seeded, reproducible, empirical rate near p_on
    m1 = DROP30.availability_mask(T_GD, L)
    m2 = DROP30.availability_mask(T_GD, L)
    np.testing.assert_array_equal(m1, m2)
    assert 0.6 < m1.mean() < 0.8
    # a different seed gives a different schedule
    m3 = dataclasses.replace(DROP30, seed=8).availability_mask(T_GD, L)
    assert not np.array_equal(m1, m3)
    # markov: p_drop=0 never leaves the on state; symmetric rates hover
    # near the stationary p_return / (p_drop + p_return)
    on = SystemSpec(availability="markov", p_drop=0.0)
    assert on.is_always_on and on.availability_mask(50, L).all()
    mk = SystemSpec(availability="markov", p_drop=0.3, p_return=0.3,
                    seed=5).availability_mask(2000, L)
    assert 0.4 < mk.mean() < 0.6


def test_node_speeds_seeded_and_bounded():
    s = SystemSpec(speed_spread=0.5, seed=3)
    v1, v2 = s.node_speeds(12), s.node_speeds(12)
    np.testing.assert_array_equal(v1, v2)
    assert np.all(v1 >= 1.0) and np.all(v1 <= 1.5)
    np.testing.assert_array_equal(SystemSpec().node_speeds(12), np.ones(12))


# ----------------------------------------- masked mixing-matrix algebra

def test_masked_matrix_full_mask_is_bitwise_identity():
    _, W = _mixing()
    m = jnp.ones(W.shape[0], W.dtype)
    np.testing.assert_array_equal(np.asarray(masked_mixing_matrix(W, m)),
                                  np.asarray(W))


def test_masked_matrix_rows_stochastic_under_dropout():
    _, W = _mixing()
    m = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], W.dtype)
    Wm = masked_mixing_matrix(W, m)
    np.testing.assert_allclose(np.asarray(Wm.sum(1)), 1.0, atol=1e-12)
    # no weight crosses a dead endpoint (off-diagonal rows/cols zero)
    dead = np.asarray(m) == 0
    off = np.asarray(Wm) - np.diag(np.diag(np.asarray(Wm)))
    assert np.all(off[dead] == 0) and np.all(off[:, dead] == 0)


def test_push_sum_matrix_column_stochastic():
    _, W = _mixing()
    m = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], W.dtype)
    C = push_sum_matrix(W, m)
    np.testing.assert_allclose(np.asarray(C.sum(0)), 1.0, atol=1e-12)


def test_push_sum_weight_invariant_and_full_mask_matches_dense():
    """The companion weights stay a probability vector up to scale
    (Σ_g w_g = L through any masked-round product), and at full mask
    the bias-corrected readout agrees with plain dense gossip."""
    L = 8
    _, W = _mixing(L)
    rng = np.random.default_rng(0)
    w = jnp.ones((L, 1), jnp.float64)
    for _ in range(6):
        m = jnp.asarray((rng.random(L) < 0.7).astype(np.float64))
        m = m.at[int(rng.integers(L))].set(1.0)   # keep >= 1 node live
        w = push_sum_matrix(W, m) @ w
        np.testing.assert_allclose(float(w.sum()), L, rtol=1e-12)
        assert np.all(np.asarray(w) >= 0)
    Z = jnp.asarray(rng.standard_normal((L, 6, 2)))
    ones = jnp.ones(L, jnp.float64)
    dense = get_rule("gossip").make_sim_mixer(W, 4, backend="xla-ref")(Z)
    push = get_rule("push_sum_gossip").make_sim_masked_mixer(
        W, 4, backend="xla-ref")(Z, ones)
    np.testing.assert_allclose(np.asarray(push), np.asarray(dense),
                               rtol=1e-10, atol=1e-12)


# ------------------------------------------- degenerate anchor (runner)

def test_degenerate_system_spec_is_bit_identical_to_dense():
    """An always-on SystemSpec must not perturb the trajectory: the
    partial and stale solvers reduce to dense dif_altgdmin bit-for-bit,
    push-sum to float round-off (its ratio correction is genuinely
    different arithmetic)."""
    dense = run_experiment(TINY, key=0)
    mat = dense.materialized
    for name in ("dif_partial", "dif_stale"):
        spec = dataclasses.replace(_with(TINY, name=name),
                                   system=SystemSpec())
        t = run_experiment(spec, key=0, materialized=mat)
        np.testing.assert_array_equal(np.asarray(t.U_nodes),
                                      np.asarray(dense.U_nodes))
        np.testing.assert_array_equal(t.sd_max, dense.sd_max)
        assert t.time_axis_source == "simulated"
    spec = dataclasses.replace(_with(TINY, name="dif_pushsum"),
                               system=SystemSpec())
    t = run_experiment(spec, key=0, materialized=mat)
    np.testing.assert_allclose(t.sd_max, dense.sd_max,
                               rtol=1e-8, atol=1e-10)


def test_dropout_on_unaware_solver_raises():
    spec = dataclasses.replace(TINY, system=DROP30)
    with pytest.raises(ValueError, match="dropout"):
        run_experiment(spec, key=0)
    # but an always-on system layer is fine on any solver (it only
    # changes the clock)
    t = run_experiment(dataclasses.replace(TINY, system=SystemSpec()),
                      key=0)
    assert t.time_axis_source == "simulated"


def test_dropout_solvers_converge_under_30pct_bernoulli():
    """Seeded 30% dropout: all three dropout-tolerant rules keep
    contracting (finite everywhere, big net decrease) and the simulated
    clock stays strictly monotone."""
    base = _with(TINY, T_GD=70)
    mat = materialize(base, key=1)
    for name in ("dif_partial", "dif_stale", "dif_pushsum"):
        spec = dataclasses.replace(_with(base, name=name), system=DROP30)
        t = run_experiment(spec, key=1, materialized=mat)
        assert np.all(np.isfinite(t.sd_max)), name
        assert t.sd_max[-1] < 0.2 * t.sd_max[0], name
        assert np.all(np.diff(t.time_axis) > 0), name
        assert t.time_axis_source == "simulated"


def test_masked_trajectory_deterministic_across_substrates():
    """One seeded fault schedule, two substrates: the simulator scan and
    the SPMD mesh runtime see the identical mask and agree on the
    trajectory to float tolerance."""
    from repro.core.altgdmin import dif_partial_altgdmin
    from repro.core import dif_partial_mesh
    n_dev = jax.device_count()
    if n_dev < 2:
        pytest.skip("needs >= 2 devices (run under "
                    "xla_force_host_platform_device_count)")
    L = n_dev
    spec = dataclasses.replace(
        TINY, problem=dataclasses.replace(TINY.problem, L=L, T=3 * L))
    mat = materialize(spec, key=2)
    avail = jnp.asarray(DROP30.availability_mask(12, L).astype(np.float64))
    sim = dif_partial_altgdmin(mat.init.U0, mat.Xg, mat.yg, mat.W,
                               eta=mat.eta, T_GD=12, T_con=3,
                               U_star=mat.problem.U_star, avail=avail)
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("nodes",))
    msh = dif_partial_mesh(mat.init.U0, mat.Xg, mat.yg, mesh, "nodes",
                           eta=mat.eta, T_GD=12, T_con=3,
                           U_star=mat.problem.U_star, avail=avail,
                           shifts=tuple(range(1, L)), self_weight=None,
                           W=mat.W)
    np.testing.assert_allclose(np.asarray(sim.U_nodes),
                               np.asarray(msh.U_nodes), atol=2e-6)
    np.testing.assert_allclose(np.asarray(sim.sd_max),
                               np.asarray(msh.sd_max), atol=2e-6)


# --------------------------------------------------- event-driven clock

def test_zero_jitter_simulated_axis_matches_closed_form():
    """With every node live, no jitter, no stragglers and uniform
    speeds, the event-driven clock must collapse to the closed-form
    decentralized pricing exactly."""
    spec = dataclasses.replace(
        TINY, system=SystemSpec(jitter_std_s=0.0))
    solver = get_solver("dif_altgdmin")
    g = spec.topology.build_graph(spec.problem.L)
    sim_axis = system_time_axis(spec, solver, g)
    model = dataclasses.replace(cm.ETHERNET_1GBPS, jitter_std_s=0.0)
    closed = cm.decentralized_time_axis(
        spec.solver.T_GD, spec.solver.T_con, spec.problem.d,
        spec.problem.r, g.max_degree, spec.comm.compute_s_per_iter,
        model=model)
    np.testing.assert_allclose(sim_axis, closed, rtol=1e-12, atol=1e-12)


def test_jittered_simulated_axis_stays_near_closed_form():
    spec = dataclasses.replace(TINY, system=SystemSpec())
    solver = get_solver("dif_altgdmin")
    g = spec.topology.build_graph(spec.problem.L)
    sim_axis = system_time_axis(spec, solver, g)
    model = dataclasses.replace(cm.ETHERNET_1GBPS, jitter_std_s=0.0)
    closed = cm.decentralized_time_axis(
        spec.solver.T_GD, spec.solver.T_con, spec.problem.d,
        spec.problem.r, g.max_degree, spec.comm.compute_s_per_iter,
        model=model)
    assert np.all(np.diff(sim_axis) > 0)
    # max-over-neighbours jitter biases each round slightly ABOVE the
    # jitter-free closed form; 10% bounds it without pinning the rng
    np.testing.assert_allclose(sim_axis, closed, rtol=0.10)


def test_dropout_saves_simulated_time():
    """Down nodes send nothing, so the 30%-dropout axis must run faster
    than the always-on axis under the same spec."""
    solver = get_solver("dif_partial")
    g = TINY.topology.build_graph(TINY.problem.L)
    on = system_time_axis(dataclasses.replace(
        _with(TINY, name="dif_partial"), system=SystemSpec()), solver, g)
    off = system_time_axis(dataclasses.replace(
        _with(TINY, name="dif_partial"), system=DROP30), solver, g)
    assert off[-1] < on[-1]


def test_straggler_and_speed_spread_slow_the_clock():
    solver = get_solver("dif_altgdmin")
    g = TINY.topology.build_graph(TINY.problem.L)
    base = system_time_axis(dataclasses.replace(
        TINY, system=SystemSpec()), solver, g)
    slow = system_time_axis(dataclasses.replace(
        TINY, system=SystemSpec(speed_spread=1.0, straggler_prob=0.2,
                                straggler_factor=5.0)), solver, g)
    assert slow[-1] > base[-1]


def test_time_axes_deterministic_across_runs():
    """Two identical invocations — closed-form AND simulated — produce
    the identical axis: every jitter draw threads a spec-seeded rng."""
    t1 = run_experiment(TINY, key=0)
    t2 = run_experiment(TINY, key=0, materialized=t1.materialized)
    np.testing.assert_array_equal(t1.time_axis, t2.time_axis)
    assert t1.time_axis_source == "closed_form"
    spec = dataclasses.replace(_with(TINY, name="dif_partial"),
                               system=DROP30)
    s1 = run_experiment(spec, key=0, materialized=t1.materialized)
    s2 = run_experiment(spec, key=0, materialized=t1.materialized)
    np.testing.assert_array_equal(s1.time_axis, s2.time_axis)


# ------------------------------------------------ CHOCO consensus gamma

def test_choco_gamma_stabilizes_aggressive_sparsification():
    """dif_topk at k = d/16 (94% of entries dropped): the undamped rule
    stagnates — each round moves a node's copy by a full compressed
    correction, too coarse at this sparsity — while the CHOCO step size
    γ < 1 damps the correction and restores convergence.  The default
    γ=1 stays bitwise the historical code path."""
    d = TINY.problem.d  # 36 -> k = 2 ~ d/16
    k = max(2, d // 16)
    base = _with(TINY, name="dif_topk", T_GD=200, compression_k=k)
    t = run_experiment(base, key=0)
    assert np.all(np.isfinite(t.sd_max))
    damped = run_experiment(_with(base, consensus_gamma=0.2), key=0,
                            materialized=t.materialized)
    assert np.all(np.isfinite(damped.sd_max))
    assert damped.sd_max[-1] < 0.06                 # converged
    assert damped.sd_max[-1] < 0.1 * t.sd_max[-1]   # gamma=1 stagnates
    # explicit gamma=1.0 is the same code path bit-for-bit
    short = _with(base, T_GD=30)
    t0 = run_experiment(short, key=0, materialized=t.materialized)
    t1 = run_experiment(_with(short, consensus_gamma=1.0), key=0,
                        materialized=t.materialized)
    np.testing.assert_array_equal(np.asarray(t0.U_nodes),
                                  np.asarray(t1.U_nodes))


def test_consensus_gamma_rejected_on_dense_solver():
    with pytest.raises(ValueError, match="consensus_gamma"):
        run_experiment(_with(TINY, consensus_gamma=0.5), key=0)


# ------------------------------------------------------------ sweep CLI

def test_sweep_cell_and_grid_in_process(tmp_path):
    from benchmarks import sweep
    spec = _with(TINY, T_GD=10)
    cells = [
        {"key": 0, "spec": json.loads(spec.to_json())},
        {"key": 1, "spec": json.loads(dataclasses.replace(
            _with(spec, name="dif_partial"),
            system=DROP30).to_json())},
    ]
    grid = tmp_path / "grid.json"
    out = tmp_path / "rows.csv"
    grid.write_text(json.dumps(cells))
    sweep.main(["run", "--specs", str(grid), "--out", str(out),
                "--in-process"])
    lines = out.read_text().strip().splitlines()
    assert len(lines) == 1 + 2 * len(sweep.CHECKPOINTS)
    assert lines[0].split(",")[:2] == ["config", "solver"]
    # the dropout cell priced its axis with the simulated clock
    assert any("simulated" in ln for ln in lines[1:])
    assert any("closed_form" in ln for ln in lines[1:])
