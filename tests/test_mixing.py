"""Mixing-matrix invariants (distributed/mixing.py) and the measured
Proposition-1 contraction: the spread actually observed after AGREE must
sit under the gamma(W)^T_con bound, graph by graph."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property test falls back to a fixed grid
    st = None

from repro.core.agree import agree
from repro.distributed import (circulant_weights, equal_neighbor_weights,
                               erdos_renyi, gamma, lazy_weights,
                               metropolis_weights, path_graph, ring, star)
from repro.distributed.mixing import is_doubly_stochastic


# ------------------------------------------------------------ invariants

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 7])
def test_metropolis_doubly_stochastic_on_irregular_er(seed):
    """Metropolis–Hastings weights stay symmetric + doubly stochastic on
    irregular Erdős–Rényi graphs (where the paper's equal-neighbor rule
    loses double stochasticity)."""
    g = erdos_renyi(14, 0.35, seed=seed)
    degs = g.adj.sum(axis=1)
    assert degs.min() != degs.max(), "want an irregular instance"
    w = metropolis_weights(g)
    assert is_doubly_stochastic(w)
    assert np.allclose(w, w.T)
    assert gamma(w) < 1.0


@pytest.mark.parametrize("make,args", [
    (erdos_renyi, (12, 0.4, 5)), (star, (9,)), (path_graph, (7,)),
    (ring, (8,)),
])
def test_equal_neighbor_row_stochastic_everywhere(make, args):
    """The equal-neighbor rule is row-stochastic and nonnegative on ANY
    graph (that is all AGREE needs to be an average of neighbours);
    double stochasticity is a bonus that requires regularity."""
    w = equal_neighbor_weights(make(*args))
    assert np.all(w >= -1e-12)
    assert np.allclose(w.sum(axis=1), 1.0)


def test_lazy_weights_beat_bipartite_periodicity():
    """On a bipartite regular graph the zero-self-weight equal-neighbor
    matrix has λ_min = −1 (γ = 1: values oscillate forever between the
    two sides); the lazy mix always contracts."""
    g = ring(4)                             # bipartite, 2-regular
    assert np.isclose(gamma(equal_neighbor_weights(g)), 1.0)
    assert gamma(lazy_weights(g, 0.5)) < 1.0


@pytest.mark.parametrize("shifts", [(-1, 1), (-2, 2), (-1, 1, -3, 3)])
def test_circulant_weights_doubly_stochastic(shifts):
    w = circulant_weights(12, shifts)
    assert is_doubly_stochastic(w)


# ------------------------------------------------- measured Prop-1 bound

def _check_prop1(t_con, seed):
    """Proposition 1, measured: after T_con AGREE rounds with a symmetric
    doubly-stochastic W the node spread (Frobenius deviation from the
    preserved average) is ≤ γ(W)^T_con × the initial spread."""
    L = 10
    g = erdos_renyi(L, 0.45, seed=seed)
    w = metropolis_weights(g)
    gm = gamma(w)
    z = jax.random.normal(jax.random.PRNGKey(seed), (L, 6), jnp.float64)
    z_bar = np.asarray(z).mean(axis=0)
    out = np.asarray(agree(z, jnp.asarray(w), t_con))
    # average preserved (double stochasticity), spread contracted
    np.testing.assert_allclose(out.mean(axis=0), z_bar, rtol=1e-9,
                               atol=1e-12)
    spread_in = np.linalg.norm(np.asarray(z) - z_bar)
    spread_out = np.linalg.norm(out - z_bar)
    assert spread_out <= gm ** t_con * spread_in * (1 + 1e-9), (
        spread_out, gm ** t_con * spread_in)


if st is not None:
    @settings(max_examples=25, deadline=None)
    @given(t_con=st.integers(min_value=1, max_value=25),
           seed=st.integers(min_value=0, max_value=50))
    def test_prop1_measured_spread_under_gamma_bound(t_con, seed):
        _check_prop1(t_con, seed)
else:
    @pytest.mark.parametrize("t_con,seed", [(1, 0), (3, 5), (10, 7),
                                            (25, 11), (7, 42)])
    def test_prop1_measured_spread_under_gamma_bound(t_con, seed):
        _check_prop1(t_con, seed)
