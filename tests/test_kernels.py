"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps per kernel + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------- flash

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,D", [
    (1, 64, 64, 2, 2, 32),       # MHA square
    (2, 32, 32, 4, 1, 16),       # MQA
    (1, 64, 64, 4, 2, 32),       # GQA group 2
    (1, 16, 48, 2, 2, 32),       # cross lengths (decode-ish, aligned ends)
    (1, 40, 40, 2, 2, 32),       # non-multiple of block → padding path
])
def test_flash_attention_matches_ref(B, Sq, Skv, H, Hkv, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, blk_q=16, blk_k=16)
    want = ref.ref_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                             jnp.swapaxes(v, 1, 2))
    want = jnp.swapaxes(want, 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [8, 16, 33])
def test_flash_attention_sliding_window(window):
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    out = ops.flash_attention(q, k, v, window=window, blk_q=16, blk_k=16)
    want = ref.ref_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                             jnp.swapaxes(v, 1, 2), window=window)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(want, 1, 2)),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked_attention():
    """The Pallas kernel, the chunked-jnp production path, and the naive
    core must all agree (same math, three implementations)."""
    from repro.models.attention import chunked_attention, attention_core
    B, S, H, D = 2, 64, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    a = attention_core(q, k, v, pos, pos)
    b = chunked_attention(q, k, v, pos, pos, chunk=16)
    c = ops.flash_attention(q, k, v, blk_q=16, blk_k=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------- SSD

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 16, 8, 8),
    (2, 48, 3, 8, 16, 16),
    (1, 20, 2, 16, 8, 8),        # padding path (20 % 8 ≠ 0)
    (1, 64, 1, 32, 32, 64),      # single chunk
])
def test_ssd_scan_matches_sequential_ref(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H),
                                           jnp.float32)) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, S, N), dtype)
    D = jnp.ones((H,), jnp.float32) * 0.5
    y, h = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk)
    y_ref, h_ref = ref.ref_ssd(x, dt, A, Bm, Cm, D)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=tol, atol=tol)


def test_ssd_chunked_model_path_matches_ref():
    """models.ssm.ssd_chunked (the jnp production path) vs sequential."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 40, 3, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(jax.random.fold_in(ks[3], 7), (B, S, N),
                           jnp.float32)
    D = jnp.full((H,), 0.5, jnp.float32)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    y_ref, h_ref = ref.ref_ssd(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref, np.float32),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------- MTRL LS

@pytest.mark.parametrize("T,n,d,r,blk_d", [
    (6, 30, 64, 4, 16),
    (3, 20, 100, 8, 32),         # d not a multiple of blk_d → padding
    (1, 50, 256, 2, 256),        # single tile
])
def test_task_gram_and_minimize_B(T, n, d, r, blk_d):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    X = jax.random.normal(ks[0], (T, n, d), jnp.float32)
    U = jnp.linalg.qr(jax.random.normal(ks[1], (d, r), jnp.float32))[0]
    y = jax.random.normal(ks[2], (T, n), jnp.float32)
    B = ops.altgdmin_minimize_B(X, U, y, blk_d=blk_d)
    # oracle: direct lstsq per task
    A = jnp.einsum("tnd,dr->tnr", X, U)
    B_ref = jnp.stack([jnp.linalg.lstsq(A[t], y[t])[0] for t in range(T)])
    np.testing.assert_allclose(np.asarray(B), np.asarray(B_ref), rtol=1e-3,
                               atol=1e-4)
    # Gram pieces vs oracle
    from repro.kernels.altgdmin_ls import task_gram
    dpad = (-d) % blk_d
    Xp = jnp.pad(X, ((0, 0), (0, 0), (0, dpad)))
    Up = jnp.pad(U, ((0, dpad), (0, 0)))
    G, c = task_gram(Xp, Up, y, blk_d=min(blk_d, d + dpad))
    G_ref, c_ref = ref.ref_task_gram(X, U, y)
    np.testing.assert_allclose(np.asarray(G), np.asarray(G_ref), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("T,n,d,r", [(5, 25, 64, 4), (2, 30, 80, 6)])
def test_altgdmin_gradient_kernel(T, n, d, r):
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    X = jax.random.normal(ks[0], (T, n, d), jnp.float32)
    U = jnp.linalg.qr(jax.random.normal(ks[1], (d, r), jnp.float32))[0]
    B = jax.random.normal(ks[2], (T, r), jnp.float32)
    y = jax.random.normal(ks[3], (T, n), jnp.float32)
    g = ops.altgdmin_gradient(X, U, B, y, blk_d=32)
    g_ref = ref.ref_altgdmin_grad(X, U, B, y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4,
                               atol=1e-4)


def test_kernel_LS_matches_simulator_minimize_B():
    """The Pallas LS path must agree with the simulator's minimize_B on a
    real MTRL instance (same Cholesky route)."""
    from repro.core import generate_problem, node_view
    from repro.core.altgdmin import minimize_B
    prob = generate_problem(jax.random.PRNGKey(7), d=60, T=24, r=3, n=20,
                            L=4, kappa=1.5, dtype=jnp.float32)
    Xg, yg = node_view(prob)
    B_sim = minimize_B(jnp.broadcast_to(prob.U_star, (4,) + prob.U_star.shape),
                       Xg, yg)
    B_ker = jnp.stack([
        ops.altgdmin_minimize_B(Xg[g], prob.U_star, yg[g], blk_d=32)
        for g in range(4)])
    np.testing.assert_allclose(np.asarray(B_ker), np.asarray(B_sim),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------- gossip

@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=3, max_value=5000),
       k=st.integers(min_value=1, max_value=4))
def test_gossip_combine_matches_ref(n, k):
    key = jax.random.PRNGKey(n)
    z = jax.random.normal(key, (n,), jnp.float32)
    nbrs = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    w_self = 1.0 / (k + 1)
    w_nbr = (1.0 - w_self) / k
    weights = (w_self,) + (w_nbr,) * k
    out = ops.gossip_combine(z, nbrs, weights)
    want = ref.ref_gossip_combine(z, nbrs, weights)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6,
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=3, max_value=2000),
       k=st.integers(min_value=1, max_value=5))
def test_gossip_combine_per_shift_weights(n, k):
    """Non-uniform per-shift weights (an irregular-graph W row) through
    the fused kernel match the weighted reference."""
    key = jax.random.PRNGKey(n + 7)
    z = jax.random.normal(key, (n,), jnp.float32)
    nbrs = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    weights = jax.nn.softmax(jax.random.normal(
        jax.random.fold_in(key, 2), (k + 1,)))
    out = ops.gossip_combine(z, nbrs, weights)
    want = ref.ref_gossip_combine(z, nbrs, weights)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6,
                               atol=1e-6)


def test_gossip_combine_kernel_odd_rows():
    """Regression (PR 4): the raw kernel pads row counts not divisible
    by blk_rows instead of tripping a bare assert — M=300 with the
    default blk_rows=256 crashed before."""
    from repro.kernels import gossip_axpy
    key = jax.random.PRNGKey(3)
    z = jax.random.normal(key, (300, 8), jnp.float32)
    nbrs = jax.random.normal(jax.random.fold_in(key, 1), (2, 300, 8),
                             jnp.float32)
    weights = jnp.asarray([0.5, 0.3, 0.2], jnp.float32)
    out = gossip_axpy.gossip_combine(z, nbrs, weights, interpret=True)
    want = ref.ref_gossip_combine(z, nbrs, weights)
    assert out.shape == z.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_mix_rows_preserves_dtype():
    """Regression (PR 4): mix_rows' out_shape followed a hard-coded f32,
    silently upcasting bf16 operands in the hoisted AGREE path; the
    output dtype must follow Z."""
    key = jax.random.PRNGKey(5)
    W = jax.nn.softmax(jax.random.normal(key, (4, 4)), axis=1)
    for dtype in (jnp.bfloat16, jnp.float32):
        Z = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 37, 3)).astype(dtype)
        out = ops.mix_nodes(Z, W.astype(jnp.float32),
                            backend="pallas-interpret")
        assert out.dtype == dtype, (dtype, out.dtype)
        assert out.shape == Z.shape
        want = jnp.einsum("gh,h...->g...", W.astype(jnp.float32),
                          Z.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want.astype(dtype), np.float32),
                                   rtol=1e-2 if dtype == jnp.bfloat16
                                   else 1e-6, atol=1e-2)
