"""Gossip runtime tests: roll_gossip ≡ simulator AGREE with circulant W;
shard_map ppermute gossip ≡ roll_gossip (run in a subprocess with 8 fake
devices, since device count is fixed at process start); aggregation
strategy semantics."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from repro.core.agree import agree
from repro.distributed import (
    roll_gossip, circulant_weights, AggregationConfig, aggregate_gradients,
    aggregate_params, comm_bytes_per_step,
)


def test_roll_gossip_matches_circulant_agree():
    """One roll-gossip round over the leading axis must equal Z ← W Z with
    the circulant ring W — the simulator and the runtime are numerically
    the same algorithm."""
    L = 8
    key = jax.random.PRNGKey(0)
    Z = jax.random.normal(key, (L, 5, 3), dtype=jnp.float64)
    for t_con in (1, 3, 7):
        W = jnp.asarray(circulant_weights(L, (-1, 1)))
        expected = agree(Z, W, t_con)
        got = roll_gossip(Z, t_con, shifts=(-1, 1))
        np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                                   rtol=1e-6)


def test_roll_gossip_pytree_and_mean_preservation():
    tree = {"a": jax.random.normal(jax.random.PRNGKey(1), (6, 4),
                                   dtype=jnp.float64),
            "b": {"c": jax.random.normal(jax.random.PRNGKey(2), (6, 2, 2),
                                         dtype=jnp.float64)}}
    out = roll_gossip(tree, 50)
    for k, x in (("a", tree["a"]), ("c", tree["b"]["c"])):
        y = out[k] if k == "a" else out["b"]["c"]
        # mean over nodes preserved; near-consensus after 50 rounds
        np.testing.assert_allclose(np.asarray(y.mean(0)),
                                   np.asarray(x.mean(0)), rtol=1e-9)
        spread = float(jnp.max(jnp.abs(y - y.mean(0))))
        assert spread < 1e-3 * float(jnp.max(jnp.abs(x - x.mean(0))))


SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    import sys
    sys.path.insert(0, "src")
    from repro.distributed import shard_map_gossip, roll_gossip
    mesh = jax.make_mesh((8,), ("nodes",))
    Z = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 3), dtype=jnp.float64)
    for t in (1, 4):
        want = roll_gossip(Z, t)
        got = shard_map_gossip(Z, mesh, "nodes", t)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9)
    # the lowering really contains collective-permutes
    sharded = jax.device_put(
        Z, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("nodes")))
    txt = jax.jit(lambda z: shard_map_gossip(z, mesh, "nodes", 2)).lower(
        sharded).compile().as_text()
    assert "collective-permute" in txt, "expected collective-permute in HLO"
    print("OK")
""")


def test_shard_map_gossip_equivalence_subprocess():
    r = subprocess.run([sys.executable, "-c", SHARD_MAP_SCRIPT],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       timeout=300)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "OK" in r.stdout


# ------------------------------------------------------- aggregation

def _node_tree(L=8):
    k = jax.random.PRNGKey(3)
    return {"backbone": jax.random.normal(k, (L, 4, 2), dtype=jnp.float64),
            "lm_head": jax.random.normal(jax.random.fold_in(k, 1), (L, 3),
                                         dtype=jnp.float64)}


def test_allreduce_is_exact_mean():
    g = _node_tree()
    agg = AggregationConfig(strategy="allreduce")
    out = aggregate_gradients(g, agg)
    for name in ("backbone", "lm_head"):
        want = np.broadcast_to(np.asarray(g[name]).mean(0, keepdims=True),
                               g[name].shape)
        np.testing.assert_allclose(np.asarray(out[name]), want, rtol=1e-9)
    # params untouched by allreduce
    p = _node_tree()
    assert aggregate_params(p, agg) is p


def test_diffusion_touches_params_not_grads():
    agg = AggregationConfig(strategy="diffusion", t_con=2)
    g = _node_tree()
    assert aggregate_gradients(g, agg) is g
    p = _node_tree()
    out = aggregate_params(p, agg)
    assert not np.allclose(np.asarray(out["backbone"]),
                           np.asarray(p["backbone"]))


def test_federated_local_patterns_respected():
    """The paper's federated carve-out: local groups are NEVER mixed."""
    agg = AggregationConfig(strategy="diffusion", t_con=3,
                            local_patterns=("lm_head",))
    p = _node_tree()
    out = aggregate_params(p, agg)
    np.testing.assert_array_equal(np.asarray(out["lm_head"]),
                                  np.asarray(p["lm_head"]))
    assert not np.allclose(np.asarray(out["backbone"]),
                           np.asarray(p["backbone"]))


def test_dgd_excludes_self():
    """DGD neighbour average excludes the node's own params."""
    agg = AggregationConfig(strategy="dgd")
    L = 4
    p = {"w": jnp.eye(L, dtype=jnp.float64)}    # node g holds e_g
    out = aggregate_params(p, agg)
    # node 0's new value = avg of nodes 1 and 3 = (e_1+e_3)/2 → own entry 0
    assert float(out["w"][0, 0]) == 0.0
    assert np.isclose(float(out["w"][0, 1]), 0.5)
    assert np.isclose(float(out["w"][0, 3]), 0.5)


def test_comm_bytes_ordering():
    """The paper's headline: diffusion (small constant T_con) communicates
    less than consensus tuned for the same accuracy (ε-dependent T_con)."""
    n, itemsize, L = 1_000_000, 2, 16
    dif = comm_bytes_per_step(n, itemsize,
                              AggregationConfig("diffusion", t_con=1), L)
    dec = comm_bytes_per_step(n, itemsize,
                              AggregationConfig("consensus", t_con=30), L)
    ar = comm_bytes_per_step(n, itemsize,
                             AggregationConfig("allreduce"), L)
    assert dif < dec
    assert dif > 0 and ar > 0
    assert comm_bytes_per_step(n, itemsize,
                               AggregationConfig("local"), L) == 0


def test_invalid_strategy_raises():
    with pytest.raises(ValueError):
        AggregationConfig(strategy="telepathy")


# ----------------- compressed strategies (PR-9 satellite bugfix)

def test_compressed_strategies_priced_from_rule_signature():
    """Regression: comm_bytes_per_step used to call the rule signature
    without payload context, so every strategy priced the dense
    n_params × itemsize product (a ``wire_dtype`` scalar at best).  The
    compressed strategies must price their actual wire format — well
    under the dense diffusion volume."""
    n, itemsize, L = 4096, 4, 16
    dense = comm_bytes_per_step(n, itemsize,
                                AggregationConfig("diffusion", t_con=1), L)
    topk = comm_bytes_per_step(
        n, itemsize, AggregationConfig("topk", t_con=1,
                                       compression_k=256), L)
    assert dense >= 4 * topk, (dense, topk)
    # k values (4 B) + k indices (4 B) per message, deg 2, one round
    assert topk == 2 * (256 * 2) * 4
    bf16 = comm_bytes_per_step(n, itemsize,
                               AggregationConfig("quantized", t_con=1), L)
    int8 = comm_bytes_per_step(
        n, itemsize, AggregationConfig("quantized", t_con=1,
                                       compression="int8"), L)
    assert bf16 == dense // 2
    assert int8 == 2 * (n + 4)       # int8 payload + one f32 scale, deg 2


def test_compressed_strategies_exchange_params():
    """topk / quantized are parameter-gossip strategies: grads untouched,
    params mixed.  topk's memoryless compressor zeroes all but the k
    largest-magnitude entries of the sent copy; quantized defaults to a
    bfloat16 wire cast (output restored to the param dtype)."""
    for agg in (AggregationConfig("topk", compression_k=4),
                AggregationConfig("quantized")):
        g = _node_tree()
        assert aggregate_gradients(g, agg) is g
        p = _node_tree()
        out = aggregate_params(p, agg)
        assert not np.allclose(np.asarray(out["backbone"]),
                               np.asarray(p["backbone"]))
        assert out["backbone"].dtype == p["backbone"].dtype
    # knobs are rejected on strategies that don't consume them
    with pytest.raises(ValueError, match="compression_k"):
        AggregationConfig("diffusion", compression_k=4)
    with pytest.raises(ValueError, match="compression "):
        AggregationConfig("topk", compression="int8")


# ------------------------- weighted roll_gossip (PR-5 satellite bugfix)

def test_roll_gossip_weighted_matrix_matches_agree():
    """Regression: roll_gossip used to be uniform-ring only and would
    silently mix with wrong weights on any other topology.  With ``W=``
    it must reproduce the exact mixing product for an irregular
    Metropolis matrix (per-node weight-table path)."""
    from repro.distributed import erdos_renyi, metropolis_weights
    g = erdos_renyi(8, 0.45, seed=2)
    assert len({int(d) for d in g.degrees}) > 1        # genuinely irregular
    W = jnp.asarray(metropolis_weights(g))
    Z = jax.random.normal(jax.random.PRNGKey(3), (8, 5, 3), jnp.float64)
    for t_con in (1, 4):
        got = roll_gossip(Z, t_con, W=np.asarray(W))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(agree(Z, W, t_con)),
                                   rtol=1e-12, atol=1e-13)


def test_roll_gossip_circulant_matrix_collapses_to_legacy_path():
    """A circulant W hands roll_gossip the same shared scalar weights as
    the historical shifts/self_weight form — bit-identical rounds."""
    Z = jax.random.normal(jax.random.PRNGKey(4), (8, 4, 2), jnp.float64)
    W = circulant_weights(8, (-1, 1))
    got = roll_gossip(Z, 3, W=W)
    legacy = roll_gossip(Z, 3, shifts=(-1, 1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


def test_roll_gossip_weighted_pytree_and_leaf_validation():
    """The table path applies per-node rows to every leaf; a leaf whose
    leading axis disagrees with W raises a clear error instead of
    silently mixing with wrong weights."""
    from repro.distributed import erdos_renyi, metropolis_weights
    W = metropolis_weights(erdos_renyi(8, 0.45, seed=2))
    tree = {"a": jax.random.normal(jax.random.PRNGKey(5), (8, 3),
                                   jnp.float64),
            "b": jax.random.normal(jax.random.PRNGKey(6), (8, 2, 2),
                                   jnp.float64)}
    out = roll_gossip(tree, 2, W=W)
    Wj = jnp.asarray(W)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(agree(tree["a"], Wj, 2)),
                               rtol=1e-12, atol=1e-13)
    with pytest.raises(ValueError, match="leading"):
        roll_gossip({"bad": jnp.ones((4, 3))}, 1, W=W)
