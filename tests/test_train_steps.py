"""Trainer integration tests: aggregation-strategy semantics on a real
(smoke) model, fused/unfused step equivalence, unroll-vs-scan
equivalence (the dry-run's cost-calibration correctness)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.aggregation import AggregationConfig
from repro.launch import steps as steps_lib
from repro.models import init_params, forward
from repro.optim import adamw, constant


N_NODES = 2


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b").smoke()
    params = steps_lib.replicate_for_nodes(
        init_params(jax.random.PRNGKey(0), cfg), N_NODES)
    toks = jax.random.randint(jax.random.PRNGKey(1), (N_NODES, 2, 16), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}
    return cfg, params, batch


def _run(cfg, params, batch, strategy, t_con=1, steps=3, fused=True,
         wire_dtype=None):
    opt = adamw(constant(1e-3))
    state = steps_lib.TrainState(params, opt.init(params),
                                 jnp.zeros((), jnp.int32))
    agg = AggregationConfig(strategy=strategy, t_con=t_con,
                            wire_dtype=wire_dtype)
    make = (steps_lib.make_train_step_fused if fused
            else steps_lib.make_train_step)
    step = jax.jit(make(cfg, opt, agg, N_NODES))
    for _ in range(steps):
        state, metrics = step(state, batch)
    return state, metrics


def test_allreduce_keeps_replicas_identical(setup):
    cfg, params, batch = setup
    state, _ = _run(cfg, params, batch, "allreduce")
    for leaf in jax.tree_util.tree_leaves(state.params):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   rtol=1e-6, atol=1e-7)


def test_two_node_diffusion_equals_allreduce(setup):
    """With 2 nodes and shifts (−1, 1), one diffusion round averages both
    replicas exactly (both shifts hit the other node: W = [[⅓,⅔],[⅔,⅓]]
    …  with self_weight=0.5 and a single shift it IS the exact mean).
    Verify the exact-mean configuration matches allreduce-of-params after
    identical gradients."""
    cfg, params, batch = setup
    agg_exact = AggregationConfig(strategy="diffusion", t_con=1,
                                  shifts=(1,), self_weight=0.5)
    opt = adamw(constant(1e-3))
    state = steps_lib.TrainState(params, opt.init(params),
                                 jnp.zeros((), jnp.int32))
    step = jax.jit(steps_lib.make_train_step_fused(cfg, opt, agg_exact,
                                                   N_NODES))
    state, _ = step(state, batch)
    # after one exact-mean diffusion round the replicas coincide
    for leaf in jax.tree_util.tree_leaves(state.params):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   rtol=1e-5, atol=1e-6)


def test_local_lets_replicas_diverge(setup):
    cfg, params, batch = setup
    state, _ = _run(cfg, params, batch, "local")
    diverged = any(
        not np.allclose(np.asarray(l[0]), np.asarray(l[1]), atol=1e-7)
        for l in jax.tree_util.tree_leaves(state.params))
    assert diverged


def test_fused_matches_unfused(setup):
    cfg, params, batch = setup
    s1, m1 = _run(cfg, params, batch, "diffusion", fused=True)
    s2, m2 = _run(cfg, params, batch, "diffusion", fused=False)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)


def test_wire_dtype_close_to_full_precision(setup):
    cfg, params, batch = setup
    s1, _ = _run(cfg, params, batch, "diffusion", steps=2)
    s2, _ = _run(cfg, params, batch, "diffusion", steps=2,
                 wire_dtype="bfloat16")
    # bf16 wire ⇒ small quantization error, same trajectory
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0.05,
                                   atol=1e-2)


def test_unroll_matches_scan_forward():
    """cfg.unroll=True (the dry-run's cost-calibration mode) must be
    numerically identical to the scan path — for a hybrid arch too."""
    for arch in ("qwen3-1.7b", "zamba2-7b"):
        cfg = get_config(arch).smoke()
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, 24), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
        a, _ = forward(params, {"tokens": toks}, cfg)
        cfg_u = dataclasses.replace(cfg, unroll=True)
        b, _ = forward(params, {"tokens": toks}, cfg_u)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-5)


def test_unroll_matches_scan_decode():
    from repro.models import init_cache, decode_step
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cfg_u = dataclasses.replace(cfg, unroll=True)
    tok = jnp.array([[3]], jnp.int32)
    s1 = init_cache(cfg, batch=1, capacity=8)
    s2 = init_cache(cfg_u, batch=1, capacity=8)
    l1, s1 = decode_step(params, s1, tok, cfg)
    l2, s2 = decode_step(params, s2, tok, cfg_u)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-5,
                               atol=2e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1.caches),
                    jax.tree_util.tree_leaves(s2.caches)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-5)


def test_remat_policy_dots_same_values():
    cfg = get_config("qwen3-1.7b").smoke()
    cfg_r = dataclasses.replace(cfg, remat=True, remat_policy="dots")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    g1 = jax.grad(lambda p: steps_lib.loss_fn(p, batch, cfg))(params)
    g2 = jax.grad(lambda p: steps_lib.loss_fn(p, batch, cfg_r))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)
