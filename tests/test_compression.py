"""Compressed & event-triggered consensus rules (PR 5): the compress
kernels vs their oracles, the reference-copy error-feedback state, the
lossless-recovery bit-identities, the shared f64 precision gate, the
payload-aware comm pricing (dense vs compressed axes), and the paper-shape
acceptance (top-k at k = d/4 within 2x of the dense floor while the wire
carries >= 4x fewer bytes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.api import (ExperimentSpec, InitSpec, ProblemSpec, SolverSpec,
                       TopologySpec, get_solver, run_experiment)
from repro.api.runner import materialize
from repro.core import comm_model as cm
from repro.distributed import CommSignature, get_rule
from repro.distributed.mixing import metropolis_weights
from repro.distributed.graphs import ring
from repro.kernels import compress as cpk
from repro.kernels import gossip_axpy as ga
from repro.kernels import ops, ref


TINY = ExperimentSpec(
    problem=ProblemSpec(d=36, T=24, r=3, n=22, L=8, kappa=1.5),
    topology=TopologySpec(family="ring", weights="metropolis"),
    init=InitSpec(T_pm=12, T_con=5),
    solver=SolverSpec(name="dif_altgdmin", T_GD=30, T_con=2))


def _tiny_with(solver: SolverSpec) -> ExperimentSpec:
    return dataclasses.replace(TINY, solver=solver)


# ------------------------------------------------------------- kernels

def test_compress_topk_kernel_matches_ref():
    """Selection AND gathered rows of the pallas kernel equal the
    lax.top_k oracle bit-for-bit on f32 blocks."""
    M = jax.random.normal(jax.random.PRNGKey(3), (5, 32, 3), jnp.float32)
    v_k, i_k = ops.compress_topk(M, 8, backend="pallas-interpret")
    v_r, i_r = ref.ref_compress_topk(M, 8)
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    assert i_k.dtype == jnp.int32 and v_k.dtype == M.dtype


def test_compress_topk_full_k_covers_all_rows():
    M = jax.random.normal(jax.random.PRNGKey(4), (3, 12, 2), jnp.float32)
    vals, idx = ops.compress_topk(M, 12, backend="pallas-interpret")
    for n in range(3):
        assert sorted(np.asarray(idx[n])) == list(range(12))
    # scatter-replace over the full index set reproduces M exactly
    out = jax.vmap(lambda x, v, i: x.at[i].set(v))(
        jnp.zeros_like(M), vals, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(M))


def test_compress_topk_validates_k():
    M = jnp.ones((2, 8, 2), jnp.float32)
    with pytest.raises(ValueError, match="1 <= k <= d"):
        ops.compress_topk(M, 0, backend="xla-ref")
    with pytest.raises(ValueError, match="1 <= k <= d"):
        ops.compress_topk(M, 9, backend="pallas-interpret")


def test_dequant_kernel_matches_ref():
    q = jax.random.randint(jax.random.PRNGKey(5), (4, 20, 3), -127,
                           128).astype(jnp.int8)
    scale = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (4, 1, 1),
                                      jnp.float32)) + 1e-3
    got = ops.dequant(q, scale, backend="pallas-interpret")
    want = ref.ref_dequant(q, scale)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == scale.dtype


# ------------------------------------------- lossless-recovery anchors

def test_topk_full_k_recovers_dense_gossip_bit_identically():
    """k = d refreshes every row of the public copy with the exact
    iterate, so compressed Dif-AltGDmin IS Dif-AltGDmin bit-for-bit."""
    mat = materialize(TINY, key=0)
    dense = run_experiment(TINY, key=0, materialized=mat)
    full = run_experiment(_tiny_with(SolverSpec(
        name="dif_topk", T_GD=30, T_con=2,
        compression_k=TINY.problem.d)), key=0, materialized=mat)
    np.testing.assert_array_equal(np.asarray(full.U_nodes),
                                  np.asarray(dense.U_nodes))
    np.testing.assert_array_equal(full.sd_max, dense.sd_max)
    np.testing.assert_array_equal(np.asarray(full.B_nodes),
                                  np.asarray(dense.B_nodes))


def test_event_zero_threshold_recovers_dense_gossip_bit_identically():
    """theta = 0 always triggers the re-broadcast, so every public copy
    equals the iterate and the round is the dense product."""
    mat = materialize(TINY, key=0)
    dense = run_experiment(TINY, key=0, materialized=mat)
    ev = run_experiment(_tiny_with(SolverSpec(
        name="dif_event", T_GD=30, T_con=2)), key=0, materialized=mat)
    np.testing.assert_array_equal(np.asarray(ev.U_nodes),
                                  np.asarray(dense.U_nodes))
    np.testing.assert_array_equal(ev.sd_max, dense.sd_max)


# --------------------------------------- error-feedback state plumbing

def test_error_feedback_state_round_trips_through_scan():
    """The driver's lax.scan carry must thread the reference-copy state
    across rounds AND outer iterations: a hand-rolled python loop over
    the same stateful mixer reproduces the scanned run exactly."""
    from repro.core.engine import AltgdminEngine
    from repro.core.spectral import _qr_pos
    mat = materialize(TINY, key=0)
    spec = _tiny_with(SolverSpec(name="dif_topk", T_GD=6, T_con=2,
                                 compression_k=9))
    got = run_experiment(spec, key=0, materialized=mat)

    rule = get_rule("topk_gossip")
    eng = AltgdminEngine("xla-ref")
    mix = rule.make_sim_state_mixer(mat.W, 2, backend="xla-ref",
                                    compression_k=9)
    L = TINY.problem.L
    U = mat.init.U0
    state = rule.init_state(U, compression_k=9)
    for _ in range(6):
        B, G = eng.min_grad(U, mat.Xg, mat.yg, mat.Xg, mat.yg,
                            same_data=True)
        U_tilde, state = mix(U - mat.eta * L * G, state)
        U = _qr_pos(U_tilde)[0]
    # scan-traced vs eager arithmetic: machine-eps only
    np.testing.assert_allclose(np.asarray(got.U_nodes), np.asarray(U),
                               rtol=0, atol=1e-12)
    # the state genuinely evolved (it is not a dead carry slot): a run
    # whose copies are frozen at init diverges at O(1)
    U_frozen = mat.init.U0
    state0 = rule.init_state(U_frozen, compression_k=9)
    for _ in range(6):
        B, G = eng.min_grad(U_frozen, mat.Xg, mat.yg, mat.Xg, mat.yg,
                            same_data=True)
        U_t, _ = mix(U_frozen - mat.eta * L * G, state0)
        U_frozen = _qr_pos(U_t)[0]
    assert float(jnp.max(jnp.abs(np.asarray(got.U_nodes)
                                 - np.asarray(U_frozen)))) > 1e-3


def test_compressed_state_not_shared_across_runs():
    """Two runs from the same spec start from fresh zero copies: results
    are reproducible (no hidden module-level state)."""
    spec = _tiny_with(SolverSpec(name="dif_quantized", T_GD=8, T_con=2,
                                 compression="int8_stochastic"))
    mat = materialize(spec, key=0)
    a = run_experiment(spec, key=0, materialized=mat)
    b = run_experiment(spec, key=0, materialized=mat)
    np.testing.assert_array_equal(np.asarray(a.U_nodes),
                                  np.asarray(b.U_nodes))


# ------------------------------------------------- f64 precision gate

def test_f64_operands_take_exact_unfused_path(monkeypatch):
    """x64 policy (the shared _fused_wanted gate): on the pallas
    backends float64 operands never reach the f32-accumulating kernels —
    neither the combine/mix kernels nor the new compress/dequant pair;
    the exact reference encoder + unfused chain run instead."""
    calls = {"n": 0}

    def count(orig):
        def wrapped(*a, **kw):
            calls["n"] += 1
            return orig(*a, **kw)
        return wrapped

    monkeypatch.setattr(cpk, "compress_topk", count(cpk.compress_topk))
    monkeypatch.setattr(cpk, "dequant", count(cpk.dequant))
    monkeypatch.setattr(ga, "gossip_combine", count(ga.gossip_combine))
    monkeypatch.setattr(ga, "mix_rows", count(ga.mix_rows))

    for name, kw in (("dif_topk", {"compression_k": 9}),
                     ("dif_quantized", {"compression": "int8"})):
        spec = dataclasses.replace(
            _tiny_with(SolverSpec(name=name, T_GD=4, T_con=2, **kw)),
            engine=dataclasses.replace(TINY.engine,
                                       backend="pallas-interpret"))
        trace = run_experiment(spec, key=0)   # f64 problem dtype
        assert np.all(np.isfinite(trace.sd_max))
    assert calls["n"] == 0, f"{calls['n']} fused kernel dispatches on f64"


# ------------------------------------------------- convergence checks

@pytest.mark.parametrize("name,kw,shrink", [
    # top-k and event-triggered trade convergence speed for wire volume,
    # so their short-horizon bounds are looser than the quantized wire's
    ("dif_topk", {"compression_k": 9}, 0.65),
    ("dif_quantized", {}, 0.5),
    ("dif_quantized", {"compression": "int8"}, 0.5),
    ("dif_quantized", {"compression": "int8_stochastic"}, 0.5),
    ("dif_event", {"event_threshold": 0.02}, 0.6),
])
def test_compressed_solvers_converge(name, kw, shrink):
    """Every compressed solver is registered, runnable via
    run_experiment, and decreases sd_max."""
    spec = _tiny_with(SolverSpec(name=name, T_GD=60, T_con=3, **kw))
    trace = run_experiment(spec, key=0)
    assert np.all(np.isfinite(trace.sd_max))
    assert trace.sd_max[-1] < shrink * trace.sd_max[0], (
        name, kw, trace.sd_max[0], trace.sd_max[-1])


def test_quantized_bf16_tracks_dense_floor():
    """Difference quantization contracts with consensus: the bf16 wire
    reaches the dense trajectory's neighbourhood (not a bf16-resolution
    floor on the iterate)."""
    mat = materialize(TINY, key=0)
    dense = run_experiment(_tiny_with(SolverSpec(
        name="dif_altgdmin", T_GD=80, T_con=3)), key=0, materialized=mat)
    q = run_experiment(_tiny_with(SolverSpec(
        name="dif_quantized", T_GD=80, T_con=3)), key=0, materialized=mat)
    assert q.sd_max[-1] <= 3 * dense.sd_max[-1] + 1e-6, (
        q.sd_max[-1], dense.sd_max[-1])


def test_unconsumed_compression_knobs_rejected():
    """Non-default compression knobs on solvers that ignore them raise
    before materialization (same policy as local_steps)."""
    for field, kw in (("compression", {"compression": "bf16"}),
                      ("compression_k", {"compression_k": 5}),
                      ("event_threshold", {"event_threshold": 0.1})):
        spec = _tiny_with(SolverSpec(name="dif_altgdmin", T_GD=5, **kw))
        with pytest.raises(ValueError, match=f"does not consume {field}"):
            run_experiment(spec, key=0)
    # and the knobs ARE consumed by their own solvers
    with pytest.raises(ValueError, match="does not consume compression_k"):
        run_experiment(_tiny_with(SolverSpec(
            name="dif_quantized", T_GD=5, compression_k=3)), key=0)


def test_bad_quantized_wire_format_rejected():
    spec = _tiny_with(SolverSpec(name="dif_quantized", T_GD=5,
                                 compression="fp4"))
    with pytest.raises(ValueError, match="wire format"):
        run_experiment(spec, key=0)


def test_event_send_fraction_drops_as_consensus_tightens():
    """The event trigger actually suppresses re-broadcasts once nodes
    agree: with a converged iterate and warm copies the measured send
    fraction is far below the theta=0 worst case the signature prices."""
    rule = get_rule("event_gossip")
    Z = jax.random.normal(jax.random.PRNGKey(0), (8, 12, 3))
    frac_cold = float(rule.send_fraction(Z, jnp.zeros_like(Z), 0.05))
    frac_warm = float(rule.send_fraction(Z, Z * (1 + 1e-4), 0.05))
    assert frac_cold == 1.0 and frac_warm == 0.0


# ------------------------------------------------- comm pricing (bugfix)

def test_signature_payload_fields_route_into_pricing():
    """Regression (PR-5 satellite): time_axis_from_signature used to
    hardwire a dense d x r exchange at the model's bytes_per_entry, so a
    CommSignature could not express a smaller payload.  The signature's
    entries/bytes now reach the per-message cost."""
    d, r, L, deg, T = 100, 4, 16, 2, 20
    flat = cm.NetworkModel(bandwidth_bytes=1e9 / 8, latency_s=0.0,
                           jitter_std_s=0.0, bytes_per_entry=8)
    dense_sig = CommSignature("gossip", 3)
    topk_sig = get_rule("topk_gossip").signature(3, d=d, r=r)
    dense_axis = cm.time_axis_from_signature(dense_sig, T, d, r, L, deg,
                                             0.0, model=flat)
    topk_axis = cm.time_axis_from_signature(topk_sig, T, d, r, L, deg,
                                            0.0, model=flat)
    # defaults reproduce the historical dense pricing exactly
    np.testing.assert_array_equal(
        dense_axis, cm.decentralized_time_axis(T, 3, d, r, deg, 0.0,
                                               model=flat))
    # f32 values + int32 indices for d/4 rows: 500 B vs 3200 B per
    # message.  The 6.4x wire factor decomposes as 3.2x fewer entries
    # x 2x f32-instead-of-f64 wire (see TopkGossipCombine docstring).
    assert topk_sig.entries_per_round == (d // 4) * (r + 1)
    assert topk_sig.bytes_per_entry == 4
    ratio = dense_axis[-1] / topk_axis[-1]
    assert ratio >= 4.0, ratio
    # the entry-count factor alone (model-native precision both sides)
    assert (d * r) / topk_sig.entries_per_round == pytest.approx(3.2)


def test_bytes_per_iter_honors_signature_payload():
    d, r = 100, 4
    dense = CommSignature("gossip", 3).bytes_per_iter(d * r, 8, 16, 2)
    topk = get_rule("topk_gossip").signature(3, d=d, r=r).bytes_per_iter(
        d * r, 8, 16, 2)
    quant = get_rule("quantized_gossip").signature(
        3, d=d, r=r).bytes_per_iter(d * r, 8, 16, 2)
    assert dense / topk >= 4.0
    assert dense / quant == 4.0              # bf16 wire: 2 B vs 8 B
    int8 = get_rule("quantized_gossip").signature(
        3, d=d, r=r, compression="int8").bytes_per_iter(d * r, 8, 16, 2)
    assert dense / int8 > 7.5                # 1 B + scale vs 8 B


def test_signature_without_dims_falls_back_dense():
    sig = get_rule("topk_gossip").signature(4)
    assert sig == CommSignature("gossip", 4)
    assert get_rule("event_gossip").signature(4).entries_per_round is None


def test_trace_time_axis_prices_compression():
    """End to end through run_experiment: the tpu-ici model's axis is
    cheaper for the compressed solver than the dense one (same spec
    otherwise)."""
    base = dataclasses.replace(
        TINY, comm=dataclasses.replace(TINY.comm, model="tpu-ici",
                                       compute_s_per_iter=0.0))
    mat = materialize(base, key=0)
    dense = run_experiment(base, key=0, materialized=mat)
    tk = run_experiment(dataclasses.replace(base, solver=SolverSpec(
        name="dif_topk", T_GD=30, T_con=2)), key=0, materialized=mat)
    assert tk.time_axis[-1] < dense.time_axis[-1]


# --------------------------------------------- paper-shape acceptance

def test_acceptance_topk_quarter_d_paper_shape():
    """PR-5 acceptance: dif_altgdmin with topk_gossip at k = d/4 on the
    paper's (d=100, r=4, L=16) shape reaches sd_max within 2x of the
    dense-gossip floor at equal T_GD, while the priced time axis and the
    CommSignature bytes/iter both show >= 4x reduction."""
    spec = ExperimentSpec(
        problem=ProblemSpec(d=100, T=64, r=4, n=60, L=16, kappa=1.5,
                            noise_std=3e-2),
        topology=TopologySpec(family="ring", weights="metropolis"),
        init=InitSpec(T_pm=30, T_con=10),
        solver=SolverSpec(name="dif_altgdmin", T_GD=400, T_con=3))
    mat = materialize(spec, key=0)
    dense = run_experiment(spec, key=0, materialized=mat)
    tk = run_experiment(dataclasses.replace(spec, solver=SolverSpec(
        name="dif_topk", T_GD=400, T_con=3, compression_k=25)), key=0,
        materialized=mat)
    assert tk.sd_max[-1] <= 2.0 * dense.sd_max[-1], (
        float(tk.sd_max[-1]), float(dense.sd_max[-1]))

    # >= 4x wire reduction, priced and declared
    d, r = 100, 4
    solver = get_solver("dif_topk")
    sig = solver.signature(3, d=d, r=r, compression_k=25)
    dense_bytes = CommSignature("gossip", 3).bytes_per_iter(d * r, 8, 16, 2)
    assert dense_bytes / sig.bytes_per_iter(d * r, 8, 16, 2) >= 4.0
    flat = cm.NetworkModel(bandwidth_bytes=1e9 / 8, latency_s=0.0,
                           jitter_std_s=0.0, bytes_per_entry=8)
    dense_axis = cm.time_axis_from_signature(CommSignature("gossip", 3),
                                             400, d, r, 16, 2, 0.0,
                                             model=flat)
    topk_axis = cm.time_axis_from_signature(sig, 400, d, r, 16, 2, 0.0,
                                            model=flat)
    assert dense_axis[-1] / topk_axis[-1] >= 4.0


# ------------------------------------------ fold-schedule pin (bugfix)

def _folded_setup(T_GD):
    from repro.core import generate_problem, node_view, split_samples
    prob = generate_problem(jax.random.PRNGKey(9), d=24, T=16, r=3, n=40,
                            L=8, kappa=1.5)
    folded = split_samples(prob, 4)
    Xg, yg = node_view(folded)
    W = jnp.asarray(metropolis_weights(ring(8)))
    U0 = jnp.stack([jnp.linalg.qr(jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(10), g), (24, 3)))[0]
        for g in range(8)])
    return prob, Xg, yg, W, U0


def test_fold_schedule_is_2tau_2tau_plus_1():
    """Pin the sample-split schedule: 0-based iteration tau consumes
    fold (2*tau mod F) for the min step and (2*tau + 1 mod F) for the
    gradient step — exactly what a hand-rolled loop with that selection
    produces."""
    from repro.core import dif_altgdmin
    from repro.core.engine import (AltgdminEngine, ref_grad_U,
                                   ref_minimize_B)
    from repro.core.spectral import _qr_pos
    from repro.core.agree import agree
    T_GD, T_con, F = 5, 2, 4
    prob, Xg, yg, W, U0 = _folded_setup(T_GD)
    eng = AltgdminEngine("xla-ref")
    got = dif_altgdmin(U0, Xg, yg, W, eta=1e-3, T_GD=T_GD, T_con=T_con,
                       engine=eng)

    U = U0
    for tau in range(T_GD):
        Xb, yb = Xg[(2 * tau) % F], yg[(2 * tau) % F]
        Xc, yc = Xg[(2 * tau + 1) % F], yg[(2 * tau + 1) % F]
        B = ref_minimize_B(U, Xb, yb)
        G = ref_grad_U(U, B, Xc, yc)
        U = _qr_pos(agree(U - (1e-3 * 8) * G, W, T_con))[0]
    # machine-eps only (scan-traced vs eager loop); the off-by-one
    # schedule of the old docstring, (2*tau - 1, 2*tau), diverges at
    # O(0.1) on this instance
    np.testing.assert_allclose(np.asarray(got.U_nodes), np.asarray(U),
                               rtol=0, atol=1e-12)


def test_final_B_refits_on_last_min_fold():
    """Regression (PR-5 satellite): B_fin used to refit on fold 0
    regardless of where the trajectory ended; it must use the LAST min
    fold, 2*(T_GD - 1) mod F — the data that produced the final U."""
    from repro.core import dif_altgdmin, beyond_central_altgdmin
    from repro.core.engine import AltgdminEngine
    T_GD, F = 5, 4
    prob, Xg, yg, W, U0 = _folded_setup(T_GD)
    eng = AltgdminEngine("xla-ref")
    res = dif_altgdmin(U0, Xg, yg, W, eta=1e-3, T_GD=T_GD, T_con=2,
                       engine=eng)
    last_min = (2 * (T_GD - 1)) % F
    want = eng.minimize_B(res.U_nodes, Xg[last_min], yg[last_min])
    np.testing.assert_array_equal(np.asarray(res.B_nodes),
                                  np.asarray(want))
    # beyond_central interleaves local_steps folds: its last min fold is
    # 2*(T_GD*local_steps - 1) mod F
    res_bc = beyond_central_altgdmin(U0, Xg, yg, W, eta=1e-3, T_GD=T_GD,
                                     T_con=1, local_steps=2, engine=eng)
    last_min_bc = (2 * (T_GD * 2 - 1)) % F
    want_bc = eng.minimize_B(res_bc.U_nodes, Xg[last_min_bc],
                             yg[last_min_bc])
    np.testing.assert_array_equal(np.asarray(res_bc.B_nodes),
                                  np.asarray(want_bc))
