"""Property tests on model invariants (hypothesis + targeted checks):
causality, sliding-window locality, RoPE relativity, MoE dispatch
correctness vs the dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import init_params, forward
from repro.models import moe as moe_mod
from repro.models.attention import attention_core, chunked_attention


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-1.7b").smoke()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@settings(max_examples=8, deadline=None)
@given(j=st.integers(min_value=1, max_value=15))
def test_causality(j):
    """Perturbing token j must not change logits at positions < j."""
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 16), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    l1, _ = forward(params, {"tokens": toks}, cfg)
    toks2 = toks.at[0, j].set((toks[0, j] + 7) % cfg.vocab_size)
    l2, _ = forward(params, {"tokens": toks2}, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :j]),
                               np.asarray(l2[:, :j]), rtol=1e-5, atol=1e-5)
    # and the perturbed position itself must change
    assert not np.allclose(np.asarray(l1[:, j]), np.asarray(l2[:, j]))


def test_ssm_causality():
    cfg = get_config("mamba2-130m").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 20), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    l1, _ = forward(params, {"tokens": toks}, cfg)
    toks2 = toks.at[0, 10].set((toks[0, 10] + 3) % cfg.vocab_size)
    l2, _ = forward(params, {"tokens": toks2}, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, :10]),
                               np.asarray(l2[:, :10]), rtol=1e-4, atol=1e-4)


def test_sliding_window_locality():
    """With window w, a token ≥ w positions in the past cannot influence
    the current logit."""
    base = get_config("qwen3-1.7b").smoke()
    cfg = dataclasses.replace(base, sliding_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 24), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    l1, _ = forward(params, {"tokens": toks}, cfg)
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab_size)
    l2, _ = forward(params, {"tokens": toks2}, cfg)
    # last position (23) is ≥ 8 away from position 2 → unchanged
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)
    # position 3 IS within the window of position 2 → changed
    assert not np.allclose(np.asarray(l1[:, 3]), np.asarray(l2[:, 3]))


def test_rope_is_relative():
    """Attention with RoPE depends only on relative positions: shifting
    all positions by a constant leaves the output unchanged."""
    B, S, H, D = 1, 12, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    from repro.models.layers import apply_rope
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    for shift in (0, 5, 100):
        pos = jnp.arange(S, dtype=jnp.int32)[None] + shift
        qr = apply_rope(q, pos, 10_000.0)
        kr = apply_rope(k, pos, 10_000.0)
        out = attention_core(qr, kr, v, pos, pos)
        if shift == 0:
            ref = out
        else:
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 32]))
def test_chunked_attention_chunk_invariance(chunk):
    """The online-softmax result must not depend on the chunk size."""
    B, S, H, D = 1, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ref = attention_core(q, k, v, pos, pos)
    out = chunked_attention(q, k, v, pos, pos, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------- MoE

def _moe_cfg(**kw):
    base = get_config("arctic-480b").smoke()
    return dataclasses.replace(base, **kw)


def test_moe_dispatch_matches_dense_oracle():
    """With capacity ample enough that nothing drops, sort-based dispatch
    must equal the dense evaluate-all-experts oracle."""
    cfg = _moe_cfg(capacity_factor=8.0)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.3
    y, aux = moe_mod.moe_forward(p, x, cfg)
    y_ref = moe_mod.moe_forward_dense_fallback(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_gracefully():
    """With capacity 0-ish, output degrades to the shared/dense branches
    (no NaNs, no crash) — token dropping is well-defined."""
    cfg = _moe_cfg(capacity_factor=1e-6)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    y, _ = moe_mod.moe_forward(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_moe_sigmoid_router_gates_normalized():
    cfg = _moe_cfg(router_score="sigmoid_norm")
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model),
                          jnp.float32)
    scores, _ = moe_mod.router_probs(x.reshape(-1, cfg.d_model),
                                     p["router"], cfg)
    gates, _ = jax.lax.top_k(scores, cfg.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0,
                               rtol=1e-6)


def test_moe_gradients_flow_to_router_and_experts():
    cfg = _moe_cfg()
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model),
                          jnp.float32)

    def loss(p):
        y, aux = moe_mod.moe_forward(p, x, cfg)
        return jnp.sum(y ** 2) + aux
    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["experts"]["gate"]))) > 0
