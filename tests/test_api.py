"""Declarative experiment API: spec JSON round-trip, solver-registry
completeness, legacy-wrapper parity (bit-identical on xla-ref), substrate
validation, and the attached comm-model wall-clock axis."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.api import (CommSpec, EngineSpec, ExperimentSpec, InitSpec,
                       ProblemSpec, SolverSpec, SOLVERS, SolverDef,
                       TopologySpec, get_solver, materialize,
                       register_solver, run_experiment, solver_names)
from repro.core import (centralized_altgdmin, dec_altgdmin, dgd_altgdmin,
                        dif_altgdmin)
from repro.core.engine import AltgdminEngine

TINY = ExperimentSpec(
    problem=ProblemSpec(d=40, T=12, r=3, n=20, L=4, kappa=1.5),
    topology=TopologySpec(family="erdos_renyi", p=0.6, seed=1,
                          weights="metropolis"),
    init=InitSpec(T_pm=10, T_con=5),
    solver=SolverSpec(name="dif_altgdmin", T_GD=15, T_con=2),
    engine=EngineSpec(backend="xla-ref"))


def _with_solver(spec, name):
    return dataclasses.replace(
        spec, solver=dataclasses.replace(spec.solver, name=name))


# ------------------------------------------------------- JSON round-trip

def test_spec_json_round_trip():
    spec = dataclasses.replace(
        TINY,
        topology=TopologySpec(family="ring", weights="circulant",
                              shifts=(-1, 1), self_weight=0.5),
        comm=CommSpec(model="tpu-ici", compute_s_per_iter=1e-4),
        substrate="simulator", name="rt")
    text = spec.to_json()
    back = ExperimentSpec.from_json(text)
    assert back == spec
    # through a generic JSON dump/load too (tuples become lists and are
    # normalized back)
    back2 = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back2 == spec
    assert isinstance(back2.topology.shifts, tuple)


def test_spec_from_dict_rejects_unknown_fields():
    d = TINY.to_dict()
    d["problem"]["bogus"] = 1
    with pytest.raises(ValueError, match="bogus"):
        ExperimentSpec.from_dict(d)


def test_spec_validation():
    with pytest.raises(ValueError):
        ProblemSpec(T=10, L=4)                       # L does not divide T
    with pytest.raises(ValueError):
        TopologySpec(family="smallworld")
    with pytest.raises(ValueError):
        TopologySpec(weights="chebyshev")
    with pytest.raises(ValueError):
        CommSpec(model="carrier-pigeon")
    with pytest.raises(ValueError):
        ExperimentSpec(substrate="abacus")
    # circulant weights must gossip over a matching circulant graph
    with pytest.raises(ValueError, match="circulant"):
        TopologySpec(family="erdos_renyi", weights="circulant")
    with pytest.raises(ValueError, match="circulant"):
        TopologySpec(family="ring", weights="circulant", shifts=(-2, 2))
    t = TopologySpec(family="circulant", weights="circulant",
                     shifts=(-2, 2))
    assert t.build_graph(8).degrees.tolist() == [2] * 8


# ------------------------------------------------------------- registry

def test_registry_covers_all_four_algorithms():
    assert set(solver_names()) >= {"dif_altgdmin", "dec_altgdmin",
                                   "centralized_altgdmin", "dgd_altgdmin"}


@pytest.mark.parametrize("name", sorted(SOLVERS))
def test_every_registered_solver_runs(name):
    trace = run_experiment(_with_solver(TINY, name), key=0)
    T_GD = TINY.solver.T_GD
    assert trace.sd_max.shape == (T_GD,)
    assert trace.sd_mean.shape == (T_GD,)
    assert trace.spread.shape == (T_GD,)
    assert np.all(np.isfinite(trace.sd_max))
    assert trace.time_axis.shape == (T_GD,)
    assert np.all(np.diff(trace.time_axis) > 0)      # cumulative clock
    assert trace.eta > 0
    L = TINY.problem.L if SOLVERS[name].decentralized else 1
    assert trace.U_nodes.shape[0] == L


def test_get_solver_unknown():
    with pytest.raises(ValueError, match="unknown solver"):
        get_solver("admm")


def test_register_solver_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        register_solver(SolverDef(name="dif_altgdmin",
                                  fn=dif_altgdmin))


# ----------------------------------------------- legacy-wrapper parity

_LEGACY = {
    "dif_altgdmin": lambda m, kw: dif_altgdmin(
        m.init.U0, m.Xg, m.yg, m.W, T_con=TINY.solver.T_con, **kw),
    "dec_altgdmin": lambda m, kw: dec_altgdmin(
        m.init.U0, m.Xg, m.yg, m.W, T_con=TINY.solver.T_con, **kw),
    "centralized_altgdmin": lambda m, kw: centralized_altgdmin(
        m.init.U0[0], m.Xg, m.yg, **kw),
    "dgd_altgdmin": lambda m, kw: dgd_altgdmin(
        m.init.U0, m.Xg, m.yg, m.adj, **kw),
}


@pytest.mark.parametrize("name", sorted(_LEGACY))
def test_run_experiment_matches_legacy_bit_identical(name):
    """Acceptance: run_experiment reproduces the legacy driver's
    trajectory bit-identically on xla-ref — no tolerance."""
    trace = run_experiment(_with_solver(TINY, name), key=7)
    m = trace.materialized
    legacy = _LEGACY[name](m, dict(eta=m.eta, T_GD=TINY.solver.T_GD,
                                   U_star=m.problem.U_star,
                                   backend="xla-ref"))
    np.testing.assert_array_equal(np.asarray(trace.U_nodes),
                                  np.asarray(legacy.U_nodes))
    np.testing.assert_array_equal(np.asarray(trace.B_nodes),
                                  np.asarray(legacy.B_nodes))
    np.testing.assert_array_equal(trace.sd_max,
                                  np.asarray(legacy.sd_max))
    np.testing.assert_array_equal(trace.spread,
                                  np.asarray(legacy.spread))
    assert trace.eta == legacy.eta


def test_shared_materialization_across_solvers():
    """Solvers differing only in SolverSpec.name see the same problem,
    graph, init, and η (the paper's figure-cell contract)."""
    a = materialize(_with_solver(TINY, "dif_altgdmin"), key=3)
    b = materialize(_with_solver(TINY, "dgd_altgdmin"), key=3)
    np.testing.assert_array_equal(np.asarray(a.Xg), np.asarray(b.Xg))
    np.testing.assert_array_equal(np.asarray(a.init.U0),
                                  np.asarray(b.init.U0))
    np.testing.assert_array_equal(a.graph.adj, b.graph.adj)
    assert a.eta == b.eta


def test_run_experiment_deterministic():
    t1 = run_experiment(TINY, key=5)
    t2 = run_experiment(TINY, key=5)
    np.testing.assert_array_equal(np.asarray(t1.U_nodes),
                                  np.asarray(t2.U_nodes))
    np.testing.assert_array_equal(t1.time_axis, t2.time_axis)


def test_sample_split_spec_runs():
    spec = dataclasses.replace(
        TINY, problem=dataclasses.replace(TINY.problem, n_folds=2))
    trace = run_experiment(spec, key=0)
    assert np.all(np.isfinite(trace.sd_max))
    # Algorithm 2 precedes the fold partition: the spectral init sees
    # the full unsplit data, so it matches the unsplit spec's init
    unsplit = materialize(TINY, key=0)
    split = materialize(spec, key=0)
    np.testing.assert_array_equal(np.asarray(split.init.U0),
                                  np.asarray(unsplit.init.U0))
    assert split.Xg.ndim == 5                    # solver data is folded


def test_materialized_reuse_matches_fresh_run():
    """The sweep-driver path: passing a shared Materialized must give
    the same Trace as materializing inside run_experiment."""
    mat = materialize(TINY, key=4)
    for name in sorted(SOLVERS):
        spec = _with_solver(TINY, name)
        fresh = run_experiment(spec, key=4)
        shared = run_experiment(spec, key=4, materialized=mat)
        np.testing.assert_array_equal(np.asarray(fresh.U_nodes),
                                      np.asarray(shared.U_nodes))
        assert fresh.eta == shared.eta


# --------------------------------------------------- engine & substrate

def test_engine_injection_conflict():
    spec = dataclasses.replace(TINY,
                               engine=EngineSpec(backend="pallas-interpret"))
    with pytest.raises(ValueError, match="conflicting"):
        run_experiment(spec, key=0, engine=AltgdminEngine("xla-ref"))


def test_mesh_substrate_validation():
    # every registered solver now carries a mesh runtime (PR 4)
    assert all(SOLVERS[n].mesh_fn is not None for n in solver_names()), [
        n for n in solver_names() if SOLVERS[n].mesh_fn is None]
    # ... but user-registered solvers without one still fail loudly
    if "sim_only_solver" not in SOLVERS:
        register_solver(SolverDef(name="sim_only_solver",
                                  fn=dif_altgdmin, topology="W"))
    mesh_spec = dataclasses.replace(TINY, substrate="mesh")
    with pytest.raises(ValueError, match="no mesh runtime"):
        run_experiment(_with_solver(mesh_spec, "sim_only_solver"), key=0)
    # weights are no longer restricted to circulant — with the right
    # device count a metropolis ER spec dispatches (subprocess tests
    # assert the parity).  When L != device_count, every program-derived
    # solver dispatches on the virtual-node tier as long as the node
    # count divides evenly over devices (since PR 9 that is ALL
    # registered solvers); only a hand-registered def without a virtual
    # runtime fails loudly on the node/device check.
    if "mesh_only_solver" not in SOLVERS:
        register_solver(SolverDef(name="mesh_only_solver",
                                  fn=dif_altgdmin,
                                  mesh_fn=SOLVERS["dif_altgdmin"].mesh_fn,
                                  topology="W"))
    if jax.device_count() != TINY.problem.L:
        with pytest.raises(ValueError, match="device"):
            run_experiment(_with_solver(mesh_spec, "mesh_only_solver"),
                           key=0)
        if TINY.problem.L % jax.device_count() == 0:
            trace = run_experiment(mesh_spec, key=0)   # virtual tier
            assert trace.U_nodes.shape[0] == TINY.problem.L
            dgd = run_experiment(_with_solver(mesh_spec, "dgd_altgdmin"),
                                 key=0)                # newly virtual-capable
            assert dgd.U_nodes.shape[0] == TINY.problem.L


# --------------------------------------------------------- wall clock

def test_comm_axis_prices_patterns_differently():
    """dgd gossips once per iteration, dif T_con times, centralized pays
    gather+broadcast — the attached wall-clock axes must reflect that."""
    dif = run_experiment(_with_solver(TINY, "dif_altgdmin"), key=0)
    dgd = run_experiment(_with_solver(TINY, "dgd_altgdmin"), key=0)
    assert dgd.time_axis[-1] < dif.time_axis[-1]    # T_con=2 vs 1 round
    ici = dataclasses.replace(TINY, comm=CommSpec(model="tpu-ici"))
    fast = run_experiment(ici, key=0)
    assert fast.time_axis[-1] < dif.time_axis[-1]   # 50 GB/s vs 1 Gbps
