"""Fused node-batched AltGDmin engine: backend registry semantics, parity
of every backend against the pure-jnp oracles (dtypes, padding, tpn=1),
identical sd_max trajectories across backends for all four algorithms
(driven through the declarative API), and the structural FLOP guarantee —
the fused kernel streams A = X_t U exactly once per task (the unfused
pair builds it twice)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (EngineSpec, ExperimentSpec, InitSpec, ProblemSpec,
                       SolverSpec, TopologySpec, run_experiment,
                       solver_names)
from repro.core import dif_altgdmin
from repro.core.engine import (AltgdminEngine, default_engine_backend,
                               resolve_engine)
from repro.distributed import circulant_weights
from repro.kernels import altgdmin_ls as ls
from repro.kernels import ops, ref


def _instance(L=3, tpn=4, n=20, d=100, r=4, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(ks[0], (L, tpn, n, d), dtype)
    U = jnp.stack([
        jnp.linalg.qr(jax.random.normal(jax.random.fold_in(ks[1], g),
                                        (d, r), jnp.float32))[0]
        for g in range(L)]).astype(dtype)
    y = jax.random.normal(ks[2], (L, tpn, n), dtype)
    return X, U, y


# ---------------------------------------------------------------- registry

def test_backend_registry_rejects_unknown():
    with pytest.raises(ValueError):
        ops.resolve_backend("vulkan")
    with pytest.raises(ValueError):
        AltgdminEngine("vulkan")


def test_backend_default_and_scope():
    base = ops.default_backend()
    assert base in ops.BACKENDS
    with ops.backend_scope("xla-ref"):
        assert ops.default_backend() == "xla-ref"
        with ops.backend_scope("pallas-interpret"):
            assert ops.default_backend() == "pallas-interpret"
        assert ops.default_backend() == "xla-ref"
    assert ops.default_backend() == base


def test_engine_honors_backend_scope_and_rejects_conflicts():
    with ops.backend_scope("pallas-interpret"):
        assert AltgdminEngine().backend == "pallas-interpret"
    eng = AltgdminEngine("xla-ref")
    assert resolve_engine(eng, "xla-ref") is eng
    assert resolve_engine(eng) is eng
    with pytest.raises(ValueError):
        resolve_engine(eng, "pallas-interpret")


def test_engine_backend_env(monkeypatch):
    monkeypatch.setenv("REPRO_ENGINE_BACKEND", "pallas-interpret")
    assert default_engine_backend() == "pallas-interpret"
    monkeypatch.delenv("REPRO_ENGINE_BACKEND")
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla-ref")
    assert default_engine_backend() == "xla-ref"
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    assert default_engine_backend() in ("pallas", "xla-ref")


# ---------------------------------------------------------------- parity

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,tpn,n,d,r,blk_d", [
    (3, 4, 20, 100, 4, 32),      # d not a multiple of blk_d → padding
    (2, 1, 25, 64, 3, 64),       # tpn = 1
    (4, 5, 16, 256, 6, 256),     # single d tile
])
def test_fused_step_matches_ref(L, tpn, n, d, r, blk_d, dtype):
    X, U, y = _instance(L, tpn, n, d, r, dtype)
    B_ref, G_ref = ops.altgdmin_fused_step(X, U, y, blk_d=blk_d,
                                           backend="xla-ref")
    B, G = ops.altgdmin_fused_step(X, U, y, blk_d=blk_d,
                                   backend="pallas-interpret")
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(B, np.float32),
                               np.asarray(B_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(G, np.float32),
                               np.asarray(G_ref, np.float32), **tol)


def test_fused_step_matches_per_task_oracles():
    """Cross-check against kernels/ref.py directly (not just the xla-ref
    dispatch route): per-node lstsq + gradient oracle."""
    L, tpn, n, d, r = 3, 4, 20, 96, 4
    X, U, y = _instance(L, tpn, n, d, r)
    B, G = ops.altgdmin_fused_step(X, U, y, blk_d=32,
                                   backend="pallas-interpret")
    for g in range(L):
        A = jnp.einsum("tnd,dr->tnr", X[g], U[g])
        B_or = jnp.stack([jnp.linalg.lstsq(A[t], y[g, t])[0]
                          for t in range(tpn)])
        np.testing.assert_allclose(np.asarray(B[g]), np.asarray(B_or),
                                   rtol=1e-3, atol=1e-4)
        G_or = ref.ref_altgdmin_grad(X[g], U[g], B_or, y[g])
        np.testing.assert_allclose(np.asarray(G[g]), np.asarray(G_or),
                                   rtol=1e-3, atol=1e-3)


def test_node_minimize_and_gradient_match_ref():
    L, tpn, n, d, r = 2, 3, 18, 80, 5
    X, U, y = _instance(L, tpn, n, d, r)
    B_ref = ops.altgdmin_node_minimize_B(X, U, y, blk_d=32,
                                         backend="xla-ref")
    B = ops.altgdmin_node_minimize_B(X, U, y, blk_d=32,
                                     backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(B), np.asarray(B_ref),
                               rtol=1e-4, atol=1e-5)
    G_ref = ops.altgdmin_node_gradient(X, U, B_ref, y, blk_d=32,
                                       backend="xla-ref")
    G = ops.altgdmin_node_gradient(X, U, B_ref, y, blk_d=32,
                                   backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(G), np.asarray(G_ref),
                               rtol=1e-4, atol=1e-4)


def test_mix_nodes_matches_agree_power():
    from repro.core.agree import agree_power
    L = 8
    W = jnp.asarray(circulant_weights(L, (-1, 1)), jnp.float32)
    Wp = jnp.linalg.matrix_power(W, 5)
    Z = jax.random.normal(jax.random.PRNGKey(2), (L, 7, 3), jnp.float32)
    out = ops.mix_nodes(Z, Wp, backend="pallas-interpret")
    want = agree_power(Z, W, 5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------- FLOP structure

def _count_a_builds(fn, *args, n, blk_d, r):
    """Count dot_general eqns inside the pallas_call body that build the
    streamed A accumulator: an (n, blk_d) X tile contracted with a
    (blk_d, r) U tile."""
    jaxpr = jax.make_jaxpr(fn)(*args)

    def walk(jx):
        total = 0
        for eqn in jx.eqns:
            if eqn.primitive.name == "dot_general":
                shapes = sorted(v.aval.shape for v in eqn.invars)
                if shapes == sorted([(n, blk_d), (blk_d, r)]):
                    total += 1
            for v in eqn.params.values():
                vals = v if isinstance(v, (list, tuple)) else [v]
                for item in vals:
                    inner = getattr(item, "jaxpr", item)
                    if hasattr(inner, "eqns"):
                        total += walk(inner)
        return total

    return walk(jaxpr.jaxpr)


def test_fused_kernel_builds_A_exactly_once():
    """Acceptance: the fused kernel performs exactly ONE streamed
    accumulation of A = X_t U per task per iteration, while the unfused
    gram+grad pair performs two (the gradient's pass-0 recompute)."""
    L, tpn, n, d, r, blk = 2, 3, 20, 64, 4, 32
    X, U, y = _instance(L, tpn, n, d, r)
    B = ops.altgdmin_node_minimize_B(X, U, y, blk_d=blk,
                                     backend="xla-ref")

    fused = _count_a_builds(
        lambda X, U, y: ls.node_fused_iter(X, U, y, blk_d=blk),
        X, U, y, n=n, blk_d=blk, r=r)
    gram = _count_a_builds(
        lambda X, U, y: ls.node_task_gram(X, U, y, blk_d=blk),
        X, U, y, n=n, blk_d=blk, r=r)
    grad = _count_a_builds(
        lambda X, U, B, y: ls.node_task_grad_tiles(X, U, B, y, blk_d=blk),
        X, U, B, y, n=n, blk_d=blk, r=r)

    assert fused == 1, f"fused kernel builds A {fused}× per task"
    assert gram + grad == 2, (gram, grad)


# ------------------------------------------------- trajectory parity

API_SPEC = ExperimentSpec(
    problem=ProblemSpec(d=60, T=24, r=3, n=25, L=6, kappa=1.5),
    topology=TopologySpec(family="ring", weights="circulant"),
    init=InitSpec(T_pm=20, T_con=8),
    solver=SolverSpec(name="dif_altgdmin", T_GD=50, T_con=3))


def _with(spec, *, solver=None, backend=None, **solver_kw):
    if solver is not None or solver_kw:
        spec = dataclasses.replace(
            spec, solver=dataclasses.replace(
                spec.solver, **({"name": solver} if solver else {}),
                **solver_kw))
    if backend is not None:
        spec = dataclasses.replace(spec, engine=EngineSpec(backend=backend))
    return spec


@pytest.mark.parametrize("algo", sorted(solver_names()))
def test_all_algorithms_trajectory_parity(algo):
    """Acceptance: identical sd_max trajectories on xla-ref vs fused
    backends (rtol=1e-4) for every registered solver, driven through
    the declarative API."""
    a = run_experiment(_with(API_SPEC, solver=algo, backend="xla-ref"),
                       key=0)
    b = run_experiment(_with(API_SPEC, solver=algo,
                             backend="pallas-interpret"), key=0)
    np.testing.assert_allclose(a.sd_max, b.sd_max, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a.B_nodes, np.float32),
                               np.asarray(b.B_nodes, np.float32),
                               rtol=1e-3, atol=1e-4)


def test_engine_xla_ref_is_bit_identical_to_seed_path():
    """The xla-ref engine IS the seed code path — same arrays out, no
    tolerance — whether selected via the spec or injected pre-built."""
    spec = _with(API_SPEC, T_GD=10, T_con=2, backend="xla-ref")
    res = run_experiment(spec, key=0)
    res2 = run_experiment(spec, key=0, engine=AltgdminEngine("xla-ref"))
    np.testing.assert_array_equal(np.asarray(res.U_nodes),
                                  np.asarray(res2.U_nodes))
    # and the legacy driver with the same materialized pieces agrees
    m = res.materialized
    legacy = dif_altgdmin(m.init.U0, m.Xg, m.yg, m.W, T_con=2, eta=m.eta,
                          T_GD=10, U_star=m.problem.U_star,
                          backend="xla-ref")
    np.testing.assert_array_equal(np.asarray(res.U_nodes),
                                  np.asarray(legacy.U_nodes))


def test_sample_split_fold_path_runs_fused():
    """With a fold axis the min and gradient halves see different data, so
    the engine must take the two-dispatch path — and still match xla-ref."""
    L, tpn, n, d, r, F = 3, 2, 15, 48, 3, 2
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    Xg = jax.random.normal(ks[0], (F, L, tpn, n, d), jnp.float32)
    yg = jax.random.normal(ks[1], (F, L, tpn, n), jnp.float32)
    U0 = jnp.stack([
        jnp.linalg.qr(jax.random.normal(jax.random.fold_in(ks[2], g),
                                        (d, r), jnp.float32))[0]
        for g in range(L)])
    W = jnp.asarray(circulant_weights(L, (-1, 1)))
    a = dif_altgdmin(U0, Xg, yg, W, eta=1e-3, T_GD=5, T_con=2,
                     backend="xla-ref")
    b = dif_altgdmin(U0, Xg, yg, W, eta=1e-3, T_GD=5, T_con=2,
                     backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(a.sd_max), np.asarray(b.sd_max),
                               rtol=1e-4, atol=1e-5)
