"""Dry-run smoke test: one (arch × shape × mesh) combination end-to-end in
a subprocess with 512 fake devices — proves the production-mesh pipeline
(mesh build, shardings, lower, compile, memory/cost/collective analyses,
calibration) works from a clean process."""
import os
import json
import subprocess
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [("mamba2-130m", "decode_32k")])
def test_dryrun_one_combo(tmp_path, arch, shape):
    out = tmp_path / "dryrun"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(out)],
        capture_output=True, text=True, cwd=REPO_ROOT,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
    rec = json.loads((out / f"{arch}_{shape}_16x16.json").read_text())
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    rf = rec["roofline"]
    assert rf["compute_s"] > 0 and rf["memory_s"] > 0
    assert rf["dominant"] in ("compute", "memory", "collective")
    assert rec["cost"]["flops"] > rec["cost_raw"]["flops"]  # calibration >
    assert rec["memory"]["peak_bytes"] > 0
