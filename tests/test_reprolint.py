"""The static-analysis suite itself (PR-10 tentpole, tools/reprolint).

Each rule is fed a known-bad planted fixture (a bare assert, a dense
(L, L) einsum, an f64→f32 cast, an undeclared env read, …) and must
catch it; each sanctioned/allowlisted pattern must pass.  The tree-wide
invariants (all 12 programs × 3 substrates clean) run in a subprocess
with 8 fake host devices, same as CI's ``analysis`` job.
"""
import json
import os
import subprocess
import sys
import textwrap
import types

import jax.numpy as jnp
import numpy as np
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from repro.analysis import astlint, findings as fnd, jaxlint
from repro.analysis.harness import Trace
from repro.core.program import DispatchBudget, get_program


def _rules(found):
    return sorted({f.rule for f in found})


def check(src, path, rules=astlint.ALL_RULES):
    return astlint.check_source(textwrap.dedent(src), path, rules)


# ------------------------------------------------------------- RL001

def test_rl001_catches_bare_assert_in_kernels():
    found = check("""
        def _kernel(x_ref, o_ref):
            assert x_ref.shape[0] % 8 == 0
            o_ref[...] = x_ref[...]
    """, "src/repro/kernels/planted.py")
    assert _rules(found) == ["RL001"]


def test_rl001_ignores_raises_and_non_kernel_files():
    src = """
        def _kernel(x):
            if x.shape[0] % 8:
                raise ValueError("bad block")
            return x
    """
    assert check(src, "src/repro/kernels/ok.py") == []
    # asserts OUTSIDE kernels/ are not RL001's business
    assert check("def f(x):\n    assert x\n", "src/repro/core/x.py") == []


def test_rl001_inline_allow_marker():
    found = check("""
        def _kernel(x):
            assert x  # reprolint: allow=RL001 — trace-time shape contract, unreachable at runtime
    """, "src/repro/kernels/planted.py")
    assert found == []


# ------------------------------------------------------------- RL002

def test_rl002_catches_unguarded_densify():
    found = check("""
        def hot_path(graph):
            W = graph.to_dense()
            return W @ W
    """, "src/repro/distributed/planted.py")
    assert _rules(found) == ["RL002"]


def test_rl002_catches_adj_access():
    found = check("def f(g):\n    return g.adj.sum()\n",
                  "src/repro/core/planted.py")
    assert _rules(found) == ["RL002"]


def test_rl002_allowlisted_patterns_pass():
    # the defining module is file-level exempt
    assert check("def f(g):\n    return g.adj\n",
                 "src/repro/distributed/graphs.py") == []
    # a justified marker suppresses
    found = check("""
        def f(g):
            return g.to_dense()  # reprolint: allow=RL002 — init tier, L <= DENSE_MATERIALIZE_MAX
    """, "src/repro/core/ok.py")
    assert found == []


def test_marker_without_justification_is_itself_a_finding():
    found = check("""
        def f(g):
            return g.to_dense()  # reprolint: allow=RL002
    """, "src/repro/core/bad.py")
    assert "RL000" in _rules(found) and "RL002" in _rules(found)


# ------------------------------------------------------------- RL003

def test_rl003_catches_stray_env_read():
    found = check("""
        import os
        def f():
            return os.environ.get("REPRO_KERNEL_BACKEND")
    """, "src/repro/core/planted.py")
    assert any(f.rule == "RL003" and "registry" in f.message
               for f in found)


def test_rl003_catches_undeclared_variable_typo():
    # the PR-3 bug class: a typo'd name silently reads nothing
    found = check("""
        def f(read_str):
            return read_str("REPRO_KERNEL_BACKEMD")
    """, "src/repro/core/planted.py")
    assert any(f.rule == "RL003" and "not declared" in f.message
               for f in found)


def test_rl003_registry_and_declared_literals_pass():
    # declared names referenced anywhere are fine
    assert check("""
        from repro.utils import env
        def f():
            return env.read_str("REPRO_KERNEL_BACKEND")
    """, "src/repro/core/ok.py") == []
    # the registry module itself may touch os.environ
    assert check("""
        import os
        def _lookup(name):
            return os.environ.get(name)
    """, "src/repro/utils/env.py") == []


# ------------------------------------------------------------- RL004

def test_rl004_catches_global_rng():
    found = check("""
        import numpy as np
        def f():
            return np.random.rand(3)
    """, "src/repro/core/planted.py")
    assert _rules(found) == ["RL004"]


def test_rl004_catches_unseeded_default_rng():
    found = check("import numpy as np\nrng = np.random.default_rng()\n",
                  "src/repro/core/planted.py")
    assert _rules(found) == ["RL004"]


def test_rl004_seeded_rng_passes():
    assert check("import numpy as np\nrng = np.random.default_rng(0)\n",
                 "src/repro/core/ok.py") == []


# ------------------------------------------------------------- RL005

def test_rl005_catches_attribute_mutation():
    found = check("""
        def _upd_planted(ctx, U, aux, tau):
            ctx.cache = U
            return U, aux, None
    """, "src/repro/core/planted.py")
    assert any(f.detail.startswith("mutation") for f in found)


def test_rl005_catches_foreign_capture():
    found = check("""
        GLOBAL_STATE = []
        def _upd_planted(ctx, U, aux, tau):
            GLOBAL_STATE.append(tau)
            return U, aux, None
    """, "src/repro/core/planted.py")
    assert any("capture" in f.detail and "GLOBAL_STATE" in f.detail
               for f in found)


def test_rl005_catches_python_if_on_tracer():
    found = check("""
        def _upd_planted(ctx, U, aux, tau):
            if tau > 0:
                U = ctx.mix(U)
            return U, aux, None
    """, "src/repro/core/planted.py")
    assert any(f.detail.startswith("tracer-if") for f in found)


def test_rl005_real_update_idioms_pass():
    # the three patterns the real bodies use: ctx-attr None test,
    # builtins (range), and the declared-pure ExactDiffusionCombine
    src = """
        def _upd_ok(ctx, U, cstate, tau):
            for j in range(ctx.local_steps):
                U = ctx.qr(U)
            sf = (ctx.send_fraction(U, cstate)
                  if ctx.send_fraction is not None else None)
            phi = ExactDiffusionCombine.correct(U, U, U)
            return ctx.qr(phi), cstate, sf
    """
    assert check(src, "src/repro/core/ok.py") == []


# ------------------------------------------------------------- RL006

def test_rl006_catches_rogue_runtime_function():
    found = check("""
        def _altgdmin_mesh(): pass
        def _altgdmin_virtual_mesh(): pass
        def dif_altgdmin_mesh(): pass
    """, "src/repro/core/runtime.py")
    assert any(f.rule == "RL006" and "dif_altgdmin_mesh" in f.symbol
               for f in found)


def test_rl006_catches_missing_skeleton():
    found = check("def _altgdmin_mesh(): pass\n",
                  "src/repro/core/runtime.py")
    assert any(f.detail == "missing:_altgdmin_virtual_mesh" for f in found)


def test_check_runtime_clean_delegates():
    r = subprocess.run(
        [sys.executable, "tools/check_runtime_clean.py"],
        capture_output=True, text=True, cwd=REPO_ROOT, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "RL006" in r.stdout


# ----------------------------------------------------- jaxpr analyzers

def _fake_trace(fn, *args, L, substrate="simulator", budget=None,
                rounds=1, n_shifts=0):
    program = types.SimpleNamespace(name="planted",
                                    dispatch_budget=budget)
    import jax
    return Trace(program=program, substrate=substrate,
                 dtype=args[0].dtype, jaxpr=jax.make_jaxpr(fn)(*args),
                 L=L, rounds=rounds, n_shifts=n_shifts, local_steps=1)


def test_jx002_catches_planted_dense_einsum():
    L = 8
    x = jnp.ones((L, 5))

    def planted(x):
        return jnp.einsum("id,jd->ij", x, x)    # (L, L) born here

    found = jaxlint.check_dense_node_axis(_fake_trace(planted, x, L=L))
    assert found and all(f.rule == "JX002" for f in found)
    assert any("L=8" in f.message for f in found)


def test_jx002_passthrough_of_existing_dense_operand_ok():
    L = 8
    W = jnp.ones((L, L))

    def passthrough(W):
        return (2.0 * W).T                       # inherits, never creates

    assert jaxlint.check_dense_node_axis(_fake_trace(passthrough, W,
                                                     L=L)) == []


def test_jx003_catches_planted_narrowing_cast():
    x = jnp.ones((4,), jnp.float64)

    def planted(x):
        return x.astype(jnp.float32) * 2.0       # f64 → f32

    found = jaxlint.check_precision_flow(_fake_trace(planted, x, L=8))
    assert found and all(f.rule == "JX003" for f in found)


def test_jx003_widening_and_f32_only_pass():
    x32 = jnp.ones((4,), jnp.float32)
    x64 = jnp.ones((4,), jnp.float64)
    t = _fake_trace(lambda x: x.astype(jnp.float64) + 1.0, x32, L=8)
    assert jaxlint.check_precision_flow(t) == []
    t = _fake_trace(lambda x: x + 1.0, x64, L=8)
    assert jaxlint.check_precision_flow(t) == []


def test_jx001_budget_formula():
    b = DispatchBudget(simulator=(1, 2, 0, 0), mesh=(1, 2, 1, 0),
                       virtual=(1, 1, 0, 0), wire_mesh=2)
    assert b.per_iter("simulator", 2, 0, 1) == 5
    assert b.per_iter("mesh", 2, 6, 1) == 17    # dif_quantized, mesh
    assert b.per_iter("virtual", 2, 7, 1) == 3


def test_every_program_declares_a_budget():
    from repro.core.program import program_names
    for name in program_names():
        assert get_program(name).dispatch_budget is not None, name


def test_registry_exposes_budget():
    from repro.api.registry import get_solver
    s = get_solver("dif_altgdmin")
    assert s.dispatch_budget is s.program.dispatch_budget is not None


# ------------------------------------------------------------ baseline

def test_baseline_roundtrip_and_stale_detection(tmp_path):
    f1 = fnd.Finding(rule="RL001", path="a.py", line=3, symbol="f",
                     message="m", detail="assert:f")
    p = tmp_path / "baseline.json"
    fnd.write_baseline(p, [f1])
    # the skeleton's TODO justification refuses to load
    with pytest.raises(ValueError, match="TODO|justification"):
        fnd.load_baseline(p)
    data = json.loads(p.read_text())
    data["suppressions"][0]["justification"] = "known, tracked in #12"
    p.write_text(json.dumps(data))
    base = fnd.load_baseline(p)
    new, sup, stale = fnd.split_by_baseline([f1], base)
    assert (new, len(sup), stale) == ([], 1, [])
    # once the finding is fixed, the entry goes stale
    new, sup, stale = fnd.split_by_baseline([], base)
    assert stale == [f1.fingerprint]


def test_shipped_baseline_is_empty():
    data = json.loads(open(os.path.join(
        REPO_ROOT, "tools/reprolint/baseline.json")).read())
    assert data == {"suppressions": []}


# ------------------------------------------------- tree-wide invariants

def test_ast_rules_clean_on_tree():
    assert astlint.run_ast_rules(REPO_ROOT) == []


def test_jaxpr_rules_clean_on_simulator_in_process():
    """The simulator substrate needs no fake devices — run one compressed
    and one masked program in-process; the full 12 × 3 matrix runs in
    the subprocess test below and in CI."""
    for name in ("dif_quantized", "dif_pushsum"):
        found = jaxlint.analyze_program(name, ("simulator",))
        assert found == [], [f.render() for f in found]


@pytest.mark.parametrize("args", [("--ast",), ("--jaxpr",)])
def test_reprolint_cli_clean_subprocess(args):
    r = subprocess.run(
        [sys.executable, "-m", "tools.reprolint", *args],
        capture_output=True, text=True, cwd=REPO_ROOT, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "reprolint: clean" in r.stdout
