from repro.optim.optimizers import (
    Optimizer, sgd, adam, adamw, clip_by_global_norm, apply_updates,
)
from repro.optim.schedules import (
    constant, linear_warmup, cosine_decay, warmup_cosine,
)
