"""Learning-rate schedules: step (int32 array) → lr (f32)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def linear_warmup(peak: float, warmup_steps: int):
    def f(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1),
                           1.0)
        return peak * frac
    return f


def cosine_decay(peak: float, decay_steps: int, floor: float = 0.0):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
    return f


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos)
    return f
