"""Optimizers as pure-JAX (init, update) pairs over parameter pytrees —
optax-style API without the dependency.

  opt = adamw(3e-4, weight_decay=0.1)
  state = opt.init(params)
  updates, state = opt.update(grads, state, params)
  params = apply_updates(params, updates)

Learning rates may be floats or schedules (callables step → lr); the step
counter lives inside the optimizer state.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else lr


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


# ----------------------------------------------------------------- SGD

def sgd(lr: Schedule, momentum: float = 0.0, nesterov: bool = False
        ) -> Optimizer:
    def init(params):
        mu = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
              if momentum else None)
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(
                jnp.float32), state["mu"], grads)
            g_eff = (jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), mu, grads)
                if nesterov else mu)
            upd = jax.tree.map(lambda g: -lr_t * g, g_eff)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return upd, {"step": step, "mu": None}

    return Optimizer(init, update)


# ----------------------------------------------------------------- Adam(W)

def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """AdamW with f32 moments (params may be lower precision)."""
    def init(params):
        def z(p):
            return jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(
            jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u
        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1, b2, eps, weight_decay=0.0)
