from repro.data.pipeline import (
    SyntheticLM, make_lm_batch, make_batch_for, node_task_loader,
)
