"""Data pipelines.

Two kinds of data feed this framework:

  * token streams for the architecture zoo — a deterministic synthetic LM
    stream (Zipf-ish marginals + Markov structure so losses actually
    decrease during the example runs), sharded per node;
  * the paper's MTRL task data — (X_t, y_t) regression pairs partitioned
    over nodes (repro.core.problem generates them; ``node_task_loader``
    wraps them as per-node iterators to mirror a real deployment where
    each node reads only its own shard).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp

from repro.models.frontends import vlm_batch_stub


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic language-model stream.

    Tokens follow a first-order Markov chain over ``vocab`` states with a
    learnable-in-principle structure: next ∼ (cur · a + seed-noise) mod V
    mixture.  Every (epoch, batch_index, node) triple maps to a unique
    PRNG fold, so multi-node loaders never overlap and runs replay
    exactly.
    """
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def batch(self, step: int, node: int = 0, n_nodes: int = 1):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
            node)
        k1, k2 = jax.random.split(key)
        first = jax.random.randint(k1, (self.batch_size, 1), 0,
                                   self.vocab_size, dtype=jnp.int32)
        noise = jax.random.randint(k2, (self.batch_size, self.seq_len), 0,
                                   17, dtype=jnp.int32)
        # Markov-ish recurrence, vectorized: t_{i+1} = (7 t_i + noise) mod V
        def body(carry, eps):
            nxt = (7 * carry + eps + 3) % self.vocab_size
            return nxt, nxt
        _, toks = jax.lax.scan(body, first[:, 0],
                               jnp.moveaxis(noise, 1, 0))
        toks = jnp.moveaxis(toks, 0, 1)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_lm_batch(key, cfg, batch: int, seq: int):
    """One random batch matching cfg's modality (labels = shifted tokens)."""
    if cfg.modality == "vlm":
        b = vlm_batch_stub(key, batch, seq, cfg)
    else:
        b = {"tokens": jax.random.randint(key, (batch, seq), 0,
                                          cfg.vocab_size, dtype=jnp.int32)}
    b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    return b


def make_batch_for(cfg, batch: int, seq: int, seed: int = 0):
    return make_lm_batch(jax.random.PRNGKey(seed), cfg, batch, seq)


def node_task_loader(problem, node: int):
    """Per-node view of an MTRL problem: yields the node's (X_t, y_t) task
    shard — the only data node g ever sees (federated constraint)."""
    tasks = problem.tasks_per_node[node]
    X = problem.X[..., tasks, :, :]
    y = problem.y[..., tasks, :]
    return {"tasks": tasks, "X": X, "y": y}
