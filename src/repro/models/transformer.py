"""Decoder-only transformer assembly, generic over the architecture zoo.

The layer stack is described by a *plan*: a list of segments, each a
contiguous run of layers with identical block structure.  Every segment is
executed as ONE ``lax.scan`` over stacked parameters, so compile time (and
HLO size) stays flat in depth — essential when lowering 61–81-layer
configs against 512 fake devices on one CPU core.

Segments:
  ("scan", kind, n)            — n identical (mixer, ffn) blocks;
  ("zamba", n_groups, period)  — n_groups × [period ssm blocks + ONE
                                 weight-tied shared-attention block]
                                 (Zamba2; the shared block's weights live
                                 once at the top level).

Modality handling (stub frontends per DESIGN.md carve-out):
  text / audio — token ids (B, S) through the embedding table (musicgen's
  EnCodec codec is the stubbed frontend; its output IS the 2048-vocab
  token stream);
  vlm — pre-projected patch embeddings (B, S_vis, d) are concatenated in
  front of the text token embeddings (anyres tiling ⇒ fixed vis budget).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import (
    init_embedding, embed, unembed, init_linear, linear, init_rms_norm,
    rms_norm,
)
from repro.models import blocks as blk


# ----------------------------------------------------------------- plan

def build_plan(cfg):
    """Segment the layer stack into homogeneous scannable runs."""
    if cfg.block_pattern == "zamba":
        period = cfg.shared_attn_period
        n_groups, rest = divmod(cfg.n_layers, period)
        plan = []
        if n_groups:
            plan.append(("zamba", n_groups, period))
        if rest:
            plan.append(("scan", ("ssm", "none"), rest))
        return plan
    if cfg.block_pattern == "ssm":
        return [("scan", ("ssm", "none"), cfg.n_layers)]
    # attention backbones, possibly with leading dense layers before MoE
    plan = []
    k = min(cfg.first_dense_layers, cfg.n_layers) if cfg.n_experts else 0
    if cfg.n_experts:
        if k:
            plan.append(("scan", ("attn", "dense"), k))
        plan.append(("scan", ("attn", "moe"), cfg.n_layers - k))
    else:
        plan.append(("scan", ("attn", "dense"), cfg.n_layers))
    return plan


def _stack_init(key, n, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ----------------------------------------------------------------- params

def init_params(key, cfg):
    plan = build_plan(cfg)
    ks = jax.random.split(key, len(plan) + 4)
    dt = jnp.dtype(cfg.param_dtype)
    params: dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_rms_norm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(ks[1], cfg.d_model, cfg.vocab_size,
                                        dt)
    if cfg.block_pattern == "zamba":
        params["shared_attn"] = blk.init_shared_attn(ks[2], cfg)
    for i, seg in enumerate(plan):
        if seg[0] == "scan":
            _, kind, n = seg
            params[f"seg{i}"] = _stack_init(
                ks[3 + i], n, lambda k: blk.init_block(k, cfg, kind))
        else:
            _, n_groups, period = seg

            def group_init(k, period=period):
                layers = [blk.init_block(kk, cfg, ("ssm", "none"))
                          for kk in jax.random.split(k, period)]
                return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
            params[f"seg{i}"] = _stack_init(ks[3 + i], n_groups, group_init)
    return params


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ----------------------------------------------------------------- embed

def embed_inputs(params, batch, cfg):
    """batch: {"tokens": (B, S)} or vlm {"tokens": (B, S_text),
    "vis_embed": (B, S_vis, d)} → (x, positions)."""
    tok_x = embed(params["embed"], batch["tokens"]).astype(
        jnp.dtype(cfg.dtype))
    if cfg.modality == "vlm" and "vis_embed" in batch:
        vis = batch["vis_embed"].astype(jnp.dtype(cfg.dtype))
        x = jnp.concatenate([vis, tok_x], axis=1)
    else:
        x = tok_x
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


# ----------------------------------------------------------------- forward

def forward(params, batch, cfg):
    """Full-sequence forward → (logits (B,S,V), aux_loss scalar)."""
    x, positions = embed_inputs(params, batch, cfg)
    aux = jnp.zeros((), jnp.float32)

    def run_scan(seg_params, x, aux, kind):
        def body(carry, p_layer):
            h, a = carry
            h, da = blk.block_forward(p_layer, h, cfg, kind, positions)
            return (h, a + da), None
        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_saveable
                      if cfg.remat_policy == "dots" else None)
            body = jax.checkpoint(body, prevent_cse=False, policy=policy)
        if cfg.unroll:       # cost-calibration mode (see launch/dryrun.py)
            n = jax.tree_util.tree_leaves(seg_params)[0].shape[0]
            carry = (x, aux)
            for i in range(n):
                carry, _ = body(carry, jax.tree.map(lambda q, i=i: q[i],
                                                    seg_params))
            return carry
        (x, aux), _ = jax.lax.scan(body, (x, aux), seg_params)
        return x, aux

    def run_zamba(seg_params, x, aux, period):
        shared = params["shared_attn"]

        def body(carry, p_group):
            h, a = carry
            for j in range(period):
                p_layer = jax.tree.map(lambda q, j=j: q[j], p_group)
                h, da = blk.block_forward(p_layer, h, cfg, ("ssm", "none"),
                                          positions)
                a = a + da
            h = blk.shared_attn_forward(shared, h, cfg, positions)
            return (h, a), None
        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_saveable
                      if cfg.remat_policy == "dots" else None)
            body = jax.checkpoint(body, prevent_cse=False, policy=policy)
        if cfg.unroll:
            n = jax.tree_util.tree_leaves(seg_params)[0].shape[0]
            carry = (x, aux)
            for i in range(n):
                carry, _ = body(carry, jax.tree.map(lambda q, i=i: q[i],
                                                    seg_params))
            return carry
        (x, aux), _ = jax.lax.scan(body, (x, aux), seg_params)
        return x, aux

    for i, seg in enumerate(build_plan(cfg)):
        if seg[0] == "scan":
            x, aux = run_scan(params[f"seg{i}"], x, aux, seg[1])
        else:
            x, aux = run_zamba(params[f"seg{i}"], x, aux, seg[2])

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = linear(params["lm_head"], x).astype(jnp.float32)
    return logits, aux


# ----------------------------------------------------------------- decode

class DecodeState(NamedTuple):
    caches: Any           # pytree of per-segment caches (stacked like params)
    shared_caches: Any    # zamba shared-attn caches (stacked per group)
    pos: jax.Array        # scalar int32 — next position to write


def _seg_cache(cfg, kind, batch, capacity, dtype, n):
    one = blk.block_init_cache(cfg, kind, batch, capacity, dtype)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy()
                        if hasattr(x, "shape") else x, one)


def init_cache(cfg, batch, capacity, dtype=None):
    """Allocate the full decode state. ``capacity`` = KV slots (full seq for
    decode_32k, sliding window for long_500k; SSM caches are O(1) anyway)."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    plan = build_plan(cfg)
    caches = []
    shared = None
    for seg in plan:
        if seg[0] == "scan":
            _, kind, n = seg
            caches.append(_seg_cache(cfg, kind, batch, capacity, dtype, n))
        else:
            _, n_groups, period = seg
            inner = _seg_cache(cfg, ("ssm", "none"), batch, capacity, dtype,
                               period)
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(),
                inner))
            shared = _seg_cache(cfg, ("attn", "dense"), batch, capacity,
                                dtype, n_groups)
    return DecodeState(caches=caches, shared_caches=shared,
                       pos=jnp.zeros((), jnp.int32))


def decode_step(params, state: DecodeState, tokens, cfg):
    """One decode step. tokens: (B, 1) int32 → (logits (B,1,V), new state)."""
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    pos = state.pos
    new_caches = []
    shared_cache = state.shared_caches

    for i, seg in enumerate(build_plan(cfg)):
        seg_params = params[f"seg{i}"]
        cache = state.caches[i]
        if seg[0] == "scan":
            _, kind, n = seg

            def body(h, xs):
                p_layer, c = xs
                h, c = blk.block_decode(p_layer, h, cfg, kind, c, pos)
                return h, c
            if cfg.unroll:
                cs = []
                for i in range(n):
                    x, ci = body(x, (jax.tree.map(lambda q, i=i: q[i],
                                                  seg_params),
                                     jax.tree.map(lambda q, i=i: q[i],
                                                  cache)))
                    cs.append(ci)
                cache = jax.tree.map(lambda *xs: jnp.stack(xs), *cs)
            else:
                x, cache = jax.lax.scan(body, x, (seg_params, cache))
            new_caches.append(cache)
        else:
            _, n_groups, period = seg
            shared = params["shared_attn"]

            def body(h, xs):
                p_group, c_group, c_shared = xs
                cs = []
                for j in range(period):
                    p_layer = jax.tree.map(lambda q, j=j: q[j], p_group)
                    c_layer = jax.tree.map(lambda q, j=j: q[j], c_group)
                    h, c_new = blk.block_decode(p_layer, h, cfg,
                                                ("ssm", "none"), c_layer,
                                                pos)
                    cs.append(c_new)
                c_group = jax.tree.map(lambda *xs: jnp.stack(xs), *cs)
                h, c_shared = blk.shared_attn_decode(shared, h, cfg,
                                                     c_shared, pos)
                return h, (c_group, c_shared)
            if cfg.unroll:
                groups, shareds = [], []
                n_groups = seg[1]
                for i in range(n_groups):
                    x, (cg, csh) = body(
                        x, (jax.tree.map(lambda q, i=i: q[i], seg_params),
                            jax.tree.map(lambda q, i=i: q[i], cache),
                            jax.tree.map(lambda q, i=i: q[i],
                                         shared_cache)))
                    groups.append(cg)
                    shareds.append(csh)
                cache = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
                shared_cache = jax.tree.map(lambda *xs: jnp.stack(xs),
                                            *shareds)
            else:
                x, (cache, shared_cache) = jax.lax.scan(
                    body, x, (seg_params, cache, shared_cache))
            new_caches.append(cache)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = linear(params["lm_head"], x).astype(jnp.float32)
    return logits, DecodeState(caches=new_caches, shared_caches=shared_cache,
                               pos=pos + 1)
