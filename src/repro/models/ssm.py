"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) sequence mixer.

Layer structure (Mamba2):
  in_proj → [z | x | B | C | dt]; causal conv1d (width K) over [x|B|C];
  SSD recurrence  h_t = exp(dt_t·A) h_{t-1} + dt_t · B_t ⊗ x_t,
                  y_t = C_t·h_t + D·x_t        (A scalar per head);
  gated RMSNorm  y ← norm(y · silu(z));  out_proj.

The SSD is evaluated with the paper's chunked algorithm: within a chunk of
Q tokens the recurrence is unrolled into a masked quadratic form (MXU
work), across chunks a lax.scan carries the (H, P, N) state — so
activation memory is O(S·Q), never O(S²).  ``repro.kernels.ssd_scan`` is
the Pallas TPU version of the same math.

Decode is the O(1)-per-token recurrent step on an SSMCache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import he_normal, init_linear, linear, init_rms_norm


class SSMCache(NamedTuple):
    conv: jax.Array    # (B, K−1, conv_channels) rolling conv window
    state: jax.Array   # (B, H, P, N) SSD state


# ----------------------------------------------------------------- params

def init_mamba2(key, cfg):
    d, di, N, H, P = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    assert H * P == di, f"ssm_heads*headdim must equal d_inner ({H}·{P}≠{di})"
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    conv_ch = di + 2 * N
    return {
        "in_proj": init_linear(ks[0], d, 2 * di + 2 * N + H, dt),
        "conv_w": he_normal(ks[1], (cfg.ssm_conv, conv_ch), cfg.ssm_conv, dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.geomspace(1e-3, 1e-1, H))).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rms_norm(di, dt),
        "out_proj": init_linear(ks[2], di, d, dt),
    }


def _split_in_proj(p, x, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = linear(p["in_proj"], x)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt_raw = zxbcdt[..., di + di + 2 * N:]
    return z, xbc, dt_raw


def _gated_out(p, y, z, cfg):
    di = cfg.d_inner
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm"]["scale"].astype(jnp.float32)).astype(y.dtype)
    return linear(p["out_proj"], g)


# ----------------------------------------------------------------- SSD

def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int, h0=None, unroll=False):
    """Chunked SSD.  x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,)
    negative reals; Bm/Cm: (B,S,N); D: (H,).  Returns (y, h_final)."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk
    Q = chunk

    def cm(a):      # chunk-major (nc, B, Q, ...)
        return jnp.moveaxis(a.reshape(Bb, nc, Q, *a.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = (cm(x.astype(jnp.float32)),
                       cm(dt.astype(jnp.float32)),
                       cm(Bm.astype(jnp.float32)),
                       cm(Cm.astype(jnp.float32)))
    A = A.astype(jnp.float32)
    D = D.astype(jnp.float32)
    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((Bb, H, P, N), jnp.float32))

    idx = jnp.arange(Q)
    causal = idx[:, None] >= idx[None, :]                     # (Q, Q)

    def body(h, xs):
        xq, dtq, Bq, Cq = xs                                  # (B,Q,·)
        dA = dtq * A                                          # (B,Q,H) ≤ 0
        cum = jnp.cumsum(dA, axis=1)
        seg = cum[:, -1]                                      # (B,H)
        # intra-chunk quadratic form
        L = jnp.where(causal[None, :, :, None],
                      jnp.exp(cum[:, :, None] - cum[:, None, :]), 0.0)
        scores = jnp.einsum("bin,bjn->bij", Cq, Bq)           # (B,Q,Q)
        M = scores[..., None] * L * dtq[:, None]              # (B,Q,Q,H)
        y = jnp.einsum("bijh,bjhp->bihp", M, xq)
        # inter-chunk contribution from carried state
        y = y + jnp.einsum("bin,bhpn->bihp", Cq, h) * jnp.exp(cum)[..., None]
        # chunk state and carry update
        decay = jnp.exp(seg[:, None] - cum) * dtq             # (B,Q,H)
        S_c = jnp.einsum("bqh,bqn,bqhp->bhpn", decay, Bq, xq)
        h = jnp.exp(seg)[..., None, None] * h + S_c
        return h, y

    if unroll:       # cost-calibration mode (see dryrun.py)
        h_fin, ys = h_init, []
        for i in range(nc):
            h_fin, yi = body(h_fin, (xc[i], dtc[i], Bc[i], Cc[i]))
            ys.append(yi)
        yc = jnp.stack(ys)
    else:
        h_fin, yc = jax.lax.scan(body, h_init, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bb, S + pad, H, P)[:, :S]
    y = y + x[:, :S].astype(jnp.float32) * D[:, None]
    return y, h_fin


def ssd_step(h, x_t, dt_t, A, B_t, C_t, D):
    """One-token SSD update. h: (B,H,P,N); x_t: (B,H,P); dt_t: (B,H);
    B_t/C_t: (B,N)."""
    a = jnp.exp(dt_t * A)                                    # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t.astype(jnp.float32),
                     x_t.astype(jnp.float32))
    h = a[..., None, None] * h + upd
    y = jnp.einsum("bn,bhpn->bhp", C_t.astype(jnp.float32), h)
    return h, y + x_t.astype(jnp.float32) * D[:, None]


# ----------------------------------------------------------------- layer

def mamba2_forward(p, x, cfg):
    """Full-sequence Mamba2 mixer. x: (B,S,d) → (B,S,d)."""
    B, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xbc, dt_raw = _split_in_proj(p, x, cfg)
    # causal depthwise conv via width-K shifted adds (K is tiny)
    K = cfg.ssm_conv
    conv = jnp.zeros(xbc.shape, jnp.float32)
    xbc_f = xbc.astype(jnp.float32)
    for i in range(K):
        shift = K - 1 - i
        rolled = jnp.pad(xbc_f, ((0, 0), (shift, 0), (0, 0)))[:, :S]
        conv = conv + rolled * p["conv_w"][i].astype(jnp.float32)
    xbc = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    xin = xbc[..., :di].reshape(B, S, H, P)
    Bm, Cm = xbc[..., di:di + N], xbc[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xin, dt, A, Bm, Cm, p["D"], cfg.ssm_chunk,
                       unroll=cfg.unroll)
    y = y.astype(x.dtype).reshape(B, S, di)
    return _gated_out(p, y, z, cfg)


def mamba2_init_cache(cfg, batch, dtype):
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32))


def mamba2_decode(p, x, cfg, cache: SSMCache):
    """One-token recurrent step. x: (B,1,d) → ((B,1,d), new cache)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xbc, dt_raw = _split_in_proj(p, x[:, 0], cfg)         # (B, ·)
    window = jnp.concatenate([cache.conv, xbc[:, None]], axis=1)  # (B,K,ch)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(
                          jnp.float32)
    xbc_t = jax.nn.silu(conv).astype(x.dtype)
    x_t = xbc_t[..., :di].reshape(B, H, P)
    B_t, C_t = xbc_t[..., di:di + N], xbc_t[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h, y = ssd_step(cache.state, x_t, dt, A, B_t, C_t, p["D"])
    y = _gated_out(p, y.astype(x.dtype).reshape(B, 1, di), z[:, None], cfg)
    return y, SSMCache(conv=window[:, 1:], state=h)
