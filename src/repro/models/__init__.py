from repro.models.transformer import (
    init_params, forward, init_cache, decode_step, count_params,
)
