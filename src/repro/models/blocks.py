"""Decoder blocks: (attn | mamba2) mixer + (dense SwiGLU | MoE) FFN with
pre-norm residuals; command-r's parallel attn∥ffn variant; zamba's extra
shared-attention residual.  Each block function returns (x, aux) where aux
is the MoE load-balance loss contribution (0 for dense)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_rms_norm, rms_norm, init_swiglu, swiglu
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


# ----------------------------------------------------------------- init

def init_block(key, cfg, kind):
    """kind = (mixer, ffn) with mixer ∈ {attn, ssm},
    ffn ∈ {dense, moe, none} ('none' ⇒ mixer-only block, mamba2 style)."""
    mixer, ffn = kind
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p = {"norm1": init_rms_norm(cfg.d_model, dt)}
    if mixer == "attn":
        p["mixer"] = (attn.init_mla(k1, cfg) if cfg.attn_impl == "mla"
                      else attn.init_gqa(k1, cfg))
    else:
        p["mixer"] = ssm_mod.init_mamba2(k1, cfg)
    if ffn == "none":
        return p
    if not cfg.parallel_block:
        p["norm2"] = init_rms_norm(cfg.d_model, dt)
    if ffn == "dense":
        p["ffn"] = init_swiglu(k2, cfg.d_model, cfg.d_ff, dt, cfg.mlp_bias)
    else:
        p["ffn"] = moe_mod.init_moe(k2, cfg)
    return p


def init_shared_attn(key, cfg):
    """Zamba2: ONE weight-tied attention+MLP block reused every
    ``shared_attn_period`` layers (the backbone's d_ff belongs here)."""
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    return {"norm": init_rms_norm(cfg.d_model, dt),
            "attn": attn.init_gqa(k1, cfg),
            "norm2": init_rms_norm(cfg.d_model, dt),
            "ffn": init_swiglu(k2, cfg.d_model, cfg.d_ff, dt, cfg.mlp_bias)}


# ----------------------------------------------------------------- fwd

def _mixer_fwd(p, h, cfg, kind_mixer, positions):
    if kind_mixer == "attn":
        if cfg.attn_impl == "mla":
            return attn.mla_forward(p["mixer"], h, cfg, positions)
        return attn.gqa_forward(p["mixer"], h, cfg, positions)
    return ssm_mod.mamba2_forward(p["mixer"], h, cfg)


def _ffn_fwd(p, h, cfg, kind_ffn):
    if kind_ffn == "dense":
        return swiglu(p["ffn"], h), jnp.zeros((), jnp.float32)
    return moe_mod.moe_forward(p["ffn"], h, cfg)


def block_forward(p, x, cfg, kind, positions):
    mixer, ffn = kind
    if ffn == "none":
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        return x + _mixer_fwd(p, h, cfg, mixer, positions), jnp.zeros(
            (), jnp.float32)
    if cfg.parallel_block:
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        a = _mixer_fwd(p, h, cfg, mixer, positions)
        f, aux = _ffn_fwd(p, h, cfg, ffn)
        return x + a + f, aux
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    x = x + _mixer_fwd(p, h, cfg, mixer, positions)
    h = rms_norm(p["norm2"], x, cfg.norm_eps)
    f, aux = _ffn_fwd(p, h, cfg, ffn)
    return x + f, aux


def shared_attn_forward(p_shared, x, cfg, positions):
    h = rms_norm(p_shared["norm"], x, cfg.norm_eps)
    x = x + attn.gqa_forward(p_shared["attn"], h, cfg, positions)
    h = rms_norm(p_shared["norm2"], x, cfg.norm_eps)
    return x + swiglu(p_shared["ffn"], h)


# ----------------------------------------------------------------- decode

def block_init_cache(cfg, kind, batch, capacity, dtype):
    mixer, _ = kind
    if mixer == "attn":
        if cfg.attn_impl == "mla":
            return attn.mla_init_cache(cfg, batch, capacity, dtype)
        return attn.gqa_init_cache(cfg, batch, capacity, dtype)
    return ssm_mod.mamba2_init_cache(cfg, batch, dtype)


def block_decode(p, x, cfg, kind, cache, pos):
    mixer, ffn = kind
    if ffn == "none":
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        if mixer == "attn":
            a, cache = (attn.mla_decode(p["mixer"], h, cfg, cache, pos)
                        if cfg.attn_impl == "mla"
                        else attn.gqa_decode(p["mixer"], h, cfg, cache, pos))
        else:
            a, cache = ssm_mod.mamba2_decode(p["mixer"], h, cfg, cache)
        return x + a, cache
    if cfg.parallel_block:
        h = rms_norm(p["norm1"], x, cfg.norm_eps)
        if mixer == "attn":
            a, cache = (attn.mla_decode(p["mixer"], h, cfg, cache, pos)
                        if cfg.attn_impl == "mla"
                        else attn.gqa_decode(p["mixer"], h, cfg, cache, pos))
        else:
            a, cache = ssm_mod.mamba2_decode(p["mixer"], h, cfg, cache)
        f, aux = _ffn_fwd(p, h, cfg, ffn)
        return x + a + f, cache
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        a, cache = (attn.mla_decode(p["mixer"], h, cfg, cache, pos)
                    if cfg.attn_impl == "mla"
                    else attn.gqa_decode(p["mixer"], h, cfg, cache, pos))
    else:
        a, cache = ssm_mod.mamba2_decode(p["mixer"], h, cfg, cache)
    x = x + a
    h = rms_norm(p["norm2"], x, cfg.norm_eps)
    f, _ = _ffn_fwd(p, h, cfg, ffn)
    return x + f, cache


def shared_attn_decode(p_shared, x, cfg, cache, pos):
    h = rms_norm(p_shared["norm"], x, cfg.norm_eps)
    a, cache = attn.gqa_decode(p_shared["attn"], h, cfg, cache, pos)
    x = x + a
    h = rms_norm(p_shared["norm2"], x, cfg.norm_eps)
    return x + swiglu(p_shared["ffn"], h), cache
