"""Mixture-of-Experts FFN with sort-based capacity dispatch (static shapes,
SPMD-friendly) — covers Arctic (128e top-2 + dense residual) and
DeepSeek-V3 (1 shared + 256 routed top-8, sigmoid router scores).

Dispatch scheme (token-dropping, GShard-style capacity):
  1. router scores → top-k (expert, gate) per token;
  2. flatten the (tokens × k) assignments and sort by expert id;
  3. position-within-expert via a running count; slots beyond the capacity
     C = ceil(tokens·k/E · capacity_factor) are dropped;
  4. scatter tokens into an (E·C, d) buffer, run every expert's SwiGLU on
     its contiguous C rows (vmap over stacked expert weights — one batched
     MXU matmul), gather back with gate weighting and scatter-add to
     tokens.

With experts sharded over the 'model' mesh axis, the scatter/gather pair
lowers to the expert-parallel all-to-all exchange.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import he_normal, init_swiglu, swiglu


def init_moe(key, cfg):
    d, E = cfg.d_model, cfg.n_experts
    dff = cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "router": he_normal(ks[0], (d, E), d, jnp.float32),  # router in f32
        "experts": {
            "gate": he_normal(ks[1], (E, d, dff), d, dt),
            "up": he_normal(ks[2], (E, d, dff), d, dt),
            "down": he_normal(ks[3], (E, dff, d), dff, dt),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_swiglu(ks[4], d,
                                  cfg.n_shared_experts * cfg.d_ff, dt)
    if cfg.dense_residual:
        p["dense"] = init_swiglu(ks[5], d, cfg.d_ff, dt)
    return p


def capacity(n_tokens: int, cfg) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)          # round up to 8 for tiling


def router_probs(x, router_w, cfg):
    """(N, E) routing scores in f32."""
    logits = x.astype(jnp.float32) @ router_w
    if cfg.router_score == "sigmoid_norm":     # deepseek-v3
        return jax.nn.sigmoid(logits), logits
    return jax.nn.softmax(logits, axis=-1), logits


def moe_forward(p, x, cfg):
    """x: (B, S, d) → (y, aux_loss).  Routed experts + optional shared
    expert(s) + optional dense residual branch."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, d)

    scores, logits = router_probs(xf, p["router"], cfg)
    gate_vals, expert_idx = jax.lax.top_k(scores, K)          # (N, K)
    if cfg.router_score == "sigmoid_norm":
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style) --------------------
    probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)   # (E,)
    counts = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    frac = counts / (N * K)
    aux = cfg.router_aux_coef * E * jnp.sum(frac * probs_mean)

    # ---- sort-based dispatch -------------------------------------------
    C = capacity(N, cfg)
    flat_e = expert_idx.reshape(-1)                    # (N·K,)
    flat_g = gate_vals.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(N), K)              # token of assignment
    order = jnp.argsort(flat_e)                        # stable
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    start = jnp.cumsum(counts) - counts                # (E,) first row/expert
    pos = jnp.arange(N * K) - start[se].astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)        # E·C = drop bin

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xf[st])
    h = buf[:E * C].reshape(E, C, d)

    w = jax.tree.map(lambda a: a.astype(x.dtype), p["experts"])
    h = jnp.einsum("ecd,edf->ecf", jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", h, w["gate"])) *
        jnp.einsum("ecd,edf->ecf", h, w["up"]), w["down"])   # (E, C, d)

    out_rows = h.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], out_rows[jnp.minimum(slot, E * C - 1)],
                         0.0) * sg[:, None].astype(x.dtype)
    y = jnp.zeros((N, d), x.dtype).at[st].add(gathered)

    if "shared" in p:
        y = y + swiglu(p["shared"], xf)
    if "dense" in p:
        y = y + swiglu(p["dense"], xf)
    return y.reshape(B, S, d), aux


def moe_forward_dense_fallback(p, x, cfg):
    """Oracle used in tests: evaluate EVERY expert densely and mix by the
    (renormalized) top-k gates — mathematically what dispatch computes when
    nothing is dropped. O(E·N·d·dff): only for tiny smoke shapes."""
    B, S, d = x.shape
    N = B * S
    xf = x.reshape(N, d)
    scores, _ = router_probs(xf, p["router"], cfg)
    gate_vals, expert_idx = jax.lax.top_k(scores, cfg.top_k)
    if cfg.router_score == "sigmoid_norm":
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    w = jax.tree.map(lambda a: a.astype(x.dtype), p["experts"])
    h = (jax.nn.silu(jnp.einsum("nd,edf->nef", xf, w["gate"]))
         * jnp.einsum("nd,edf->nef", xf, w["up"]))     # (N, E, F)
    all_out = jnp.einsum("nef,efd->ned", h, w["down"])  # (N, E, d)
    onehot = jax.nn.one_hot(expert_idx, cfg.n_experts,
                            dtype=gate_vals.dtype)     # (N, K, E)
    mix = jnp.einsum("nk,nke->ne", gate_vals, onehot)
    y = jnp.einsum("ne,ned->nd", mix.astype(x.dtype), all_out)
    if "shared" in p:
        y = y + swiglu(p["shared"], xf)
    if "dense" in p:
        y = y + swiglu(p["dense"], xf)
    return y.reshape(B, S, d)
