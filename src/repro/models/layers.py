"""Primitive layers shared by every architecture — pure-JAX (no flax):
parameters are plain dicts of jnp arrays, layers are (params, x) -> y
functions.  Initializers mirror common practice (trunc-normal fan-in).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def he_normal(key, shape, in_axis_size, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * (in_axis_size ** -0.5)).astype(dtype)


def init_linear(key, d_in, d_out, dtype, bias=False):
    p = {"w": he_normal(key, (d_in, d_out), d_in, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    """Weights are stored in param_dtype (f32) and cast to the activation
    dtype at use — activations stay in cfg.dtype (bf16) end to end."""
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_rms_norm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps: float):
    # compute in f32 for stability regardless of activation dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_embedding(key, vocab, d, dtype):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Tied logits: x @ tableᵀ (f32 accumulation for the softmax)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["table"].astype(jnp.float32))


# ---------------------------------------------------------------- SwiGLU

def init_swiglu(key, d_model, d_ff, dtype, bias=False):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": init_linear(k1, d_model, d_ff, dtype, bias),
            "up": init_linear(k2, d_model, d_ff, dtype, bias),
            "down": init_linear(k3, d_ff, d_model, dtype, bias)}


def swiglu(p, x):
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x))
                  * linear(p["up"], x))


# ---------------------------------------------------------------- RoPE

def rope_frequencies(d_head: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the even half of the head dim (f32)."""
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh) with rotate-half convention; positions: (..., S).

    Computed in f32 and cast back.
    """
    d_head = x.shape[-1]
    inv = rope_frequencies(d_head, theta)                 # (Dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv   # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
