"""Modality frontends — STUBS per the assignment carve-out.

The [audio] and [vlm] architectures specify the transformer backbone only;
the codec / vision tower is not implemented.  These helpers produce the
tensors a real frontend would emit, with the correct shapes/dtypes:

  * musicgen: EnCodec is a neural audio codec whose output is a token
    stream over a 2048-entry codebook — the backbone consumes token ids
    directly, so the stub is simply a synthetic token generator;
  * llava-next: the SigLIP/ViT tower + projector emit per-patch embeddings
    of width d_model; anyres tiling is approximated by a fixed patch
    budget ``cfg.vis_tokens`` per sample.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_token_stub(key, batch, seq_len, cfg):
    """Synthetic EnCodec token ids (B, S) in [0, vocab)."""
    return jax.random.randint(key, (batch, seq_len), 0, cfg.vocab_size,
                              dtype=jnp.int32)


def vision_embed_stub(key, batch, cfg, dtype=None):
    """Synthetic pre-projected patch embeddings (B, vis_tokens, d_model) —
    what the (stubbed) vision tower + projector would output."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    return (jax.random.normal(key, (batch, cfg.vis_tokens, cfg.d_model),
                              jnp.float32) * 0.02).astype(dtype)


def vlm_batch_stub(key, batch, seq_len, cfg):
    """Full VLM input batch: vis_tokens patch embeddings + text tokens such
    that the combined sequence length equals ``seq_len``."""
    if cfg.vis_tokens >= seq_len:
        raise ValueError(f"vis_tokens={cfg.vis_tokens} must be < seq_len")
    k1, k2 = jax.random.split(key)
    s_text = seq_len - cfg.vis_tokens
    return {
        "tokens": jax.random.randint(k1, (batch, s_text), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "vis_embed": vision_embed_stub(k2, batch, cfg),
    }
