"""Attention mixers: GQA/MQA/MHA (+ RoPE, qk-norm, sliding window) and MLA
(multi-head latent attention, DeepSeek-V3), with full-sequence (train /
prefill) and KV-cache single-token (decode) paths.

The softmax core has two jnp implementations:
  * ``attention_core`` — materializes the score matrix (used for short S);
  * ``chunked_attention`` — lax.scan over KV chunks with an online softmax
    (flash-attention math in pure jnp).  This is the production path for
    long sequences: activation memory is O(S·chunk), so dry-run
    memory_analysis reflects a realistic footprint.  The Pallas TPU kernel
    (repro.kernels.flash_attention) implements the same math with explicit
    VMEM tiling; ops.py dispatches between them.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import (
    init_linear, linear, init_rms_norm, rms_norm, apply_rope,
)

NEG_INF = -1e30
CHUNK_THRESHOLD = 2048      # use the chunked path above this KV length


# ======================================================================
# softmax cores
# ======================================================================

def _build_mask(q_pos, k_pos, window):
    """(B, Sq, Skv) bool — causal, optionally sliding-window, k valid."""
    m = (k_pos[:, None, :] <= q_pos[:, :, None]) & (k_pos[:, None, :] >= 0)
    if window is not None:
        m &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    return m


def attention_core(q, k, v, q_pos, k_pos, *, window=None, scale=None):
    """Reference core.  q: (B,Sq,H,D), k/v: (B,Skv,Hkv,D);
    q_pos: (B,Sq) int32, k_pos: (B,Skv) int32 (−1 ⇒ invalid slot)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    mask = _build_mask(q_pos, k_pos, window)[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_attention(q, k, v, q_pos, k_pos, *, window=None, scale=None,
                      chunk=1024, unroll=False):
    """Online-softmax attention, scanning KV in chunks (flash math).

    Same signature/semantics as :func:`attention_core`; activation memory is
    O(B·H·Sq·chunk) instead of O(B·H·Sq·Skv).
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    n = (Skv + pad) // chunk
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D)
    # chunk-major layout for scan
    kc = jnp.moveaxis(k.reshape(B, n, chunk, Hkv, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n, chunk, Hkv, D), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(B, n, chunk), 1, 0)

    def body(carry, xs):
        o, m, l = carry                            # (B,H,G,Sq,D), (B,H,G,Sq)
        kci, vci, pci = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kci.astype(jnp.float32))
        mask = _build_mask(q_pos, pci, window)[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = (o * alpha[..., None]
                 + jnp.einsum("bhgqk,bkhd->bhgqd", p, vci.astype(jnp.float32)))
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    if unroll:       # cost-calibration mode: every chunk visible in HLO
        carry = (o0, m0, l0)
        for i in range(n):
            carry, _ = body(carry, (kc[i], vc[i], pc[i]))
        o, m, l = carry
    else:
        (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), (kc, vc, pc))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, D).astype(q.dtype)


def attention_dispatch(q, k, v, q_pos, k_pos, *, window=None, scale=None,
                       unroll=False):
    if k.shape[1] > CHUNK_THRESHOLD:
        return chunked_attention(q, k, v, q_pos, k_pos, window=window,
                                 scale=scale, unroll=unroll)
    return attention_core(q, k, v, q_pos, k_pos, window=window, scale=scale)


# ======================================================================
# GQA (covers MHA / MQA / GQA; qk-norm; sliding window)
# ======================================================================

class KVCache(NamedTuple):
    k: jax.Array           # (B, capacity, Hkv, D)
    v: jax.Array           # (B, capacity, Hkv, D)
    positions: jax.Array   # (B, capacity) int32, −1 ⇒ empty slot


def init_gqa(key, cfg):
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p = {"wq": init_linear(ks[0], d, H * Dh, dt, cfg.attn_bias),
         "wk": init_linear(ks[1], d, Hkv * Dh, dt, cfg.attn_bias),
         "wv": init_linear(ks[2], d, Hkv * Dh, dt, cfg.attn_bias),
         "wo": init_linear(ks[3], H * Dh, d, dt, cfg.attn_bias)}
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(Dh, dt)
        p["k_norm"] = init_rms_norm(Dh, dt)
    return p


def _qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = linear(p["wq"], x).reshape(B, S, H, Dh)
    k = linear(p["wk"], x).reshape(B, S, Hkv, Dh)
    v = linear(p["wv"], x).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(p, x, cfg, positions):
    """Full-sequence path (train / prefill). x: (B,S,d); positions: (B,S)."""
    q, k, v = _qkv(p, x, cfg, positions)
    o = attention_dispatch(q, k, v, positions, positions,
                           window=cfg.sliding_window, unroll=cfg.unroll)
    return linear(p["wo"], o.reshape(x.shape[0], x.shape[1], -1))


def gqa_init_cache(cfg, batch, capacity, dtype):
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    return KVCache(
        k=jnp.zeros((batch, capacity, Hkv, Dh), dtype),
        v=jnp.zeros((batch, capacity, Hkv, Dh), dtype),
        positions=jnp.full((batch, capacity), -1, jnp.int32))


def gqa_decode(p, x, cfg, cache: KVCache, pos):
    """One-token decode. x: (B,1,d); pos: scalar int32 (current position).
    The cache is a ring buffer of size ``capacity`` (= full seq for
    decode_32k, = sliding window for long_500k)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    cap = cache.k.shape[1]
    slot = (pos % cap).astype(jnp.int32)
    z = jnp.zeros((), jnp.int32)
    k_all = jax.lax.dynamic_update_slice(cache.k, k, (z, slot, z, z))
    v_all = jax.lax.dynamic_update_slice(cache.v, v, (z, slot, z, z))
    pos_all = jax.lax.dynamic_update_slice(
        cache.positions, positions, (z, slot))
    o = attention_dispatch(q, k_all, v_all, positions, pos_all,
                           window=cfg.sliding_window, unroll=cfg.unroll)
    y = linear(p["wo"], o.reshape(B, 1, -1))
    return y, KVCache(k_all, v_all, pos_all)


# ======================================================================
# MLA — multi-head latent attention (DeepSeek-V3)
# ======================================================================

class MLACache(NamedTuple):
    ckv: jax.Array         # (B, capacity, kv_lora) compressed latent
    k_rope: jax.Array      # (B, capacity, rope_dim) shared rope key
    positions: jax.Array   # (B, capacity)


def init_mla(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wdq": init_linear(ks[0], d, qr, dt),
        "q_norm": init_rms_norm(qr, dt),
        "wuq": init_linear(ks[1], qr, H * (dn + dr), dt),
        "wdkv": init_linear(ks[2], d, kvr + dr, dt),
        "kv_norm": init_rms_norm(kvr, dt),
        "wuk": init_linear(ks[3], kvr, H * dn, dt),
        "wuv": init_linear(ks[4], kvr, H * dv, dt),
        "wo": init_linear(ks[5], H * dv, d, dt),
    }


def _mla_q(p, x, cfg, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    cq = rms_norm(p["q_norm"], linear(p["wdq"], x), cfg.norm_eps)
    q = linear(p["wuq"], cq).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, positions):
    kvr, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    dkv = linear(p["wdkv"], x)
    ckv = rms_norm(p["kv_norm"], dkv[..., :kvr], cfg.norm_eps)
    k_rope = dkv[..., kvr:][:, :, None]                 # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return ckv, k_rope


def mla_forward(p, x, cfg, positions):
    """Full-sequence MLA: expand latent to per-head K/V (prefill-style)."""
    B, S, _ = x.shape
    H, dn, dr, dv = (cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
                     cfg.v_head_dim)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    ckv, k_rope = _mla_latent(p, x, cfg, positions)
    k_nope = linear(p["wuk"], ckv).reshape(B, S, H, dn)
    v = linear(p["wuv"], ckv).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None],
                                          (B, S, H, dr))], axis=-1)
    # pad V up to the QK head dim so the shared cores apply, then slice
    o = attention_dispatch(q, k,
                           jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                       (0, dn + dr - dv))),
                           positions, positions, window=cfg.sliding_window,
                           scale=(dn + dr) ** -0.5, unroll=cfg.unroll)
    o = o[..., :dv].reshape(B, S, H * dv)
    return linear(p["wo"], o)


def mla_init_cache(cfg, batch, capacity, dtype):
    return MLACache(
        ckv=jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, capacity, cfg.rope_head_dim), dtype),
        positions=jnp.full((batch, capacity), -1, jnp.int32))


def mla_decode(p, x, cfg, cache: MLACache, pos):
    """Absorbed-matmul decode: scores against the latent cache directly —
    never materializes per-head K/V for the 32k/500k cache (the reason MLA
    exists)."""
    B = x.shape[0]
    H, dn, dr, dv = (cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
                     cfg.v_head_dim)
    kvr = cfg.kv_lora_rank
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)       # (B,1,H,dn/(dr))
    ckv_t, k_rope_t = _mla_latent(p, x, cfg, positions) # (B,1,kvr),(B,1,dr)
    cap = cache.ckv.shape[1]
    slot = (pos % cap).astype(jnp.int32)
    z = jnp.zeros((), jnp.int32)
    ckv = jax.lax.dynamic_update_slice(cache.ckv, ckv_t, (z, slot, z))
    k_rope = jax.lax.dynamic_update_slice(cache.k_rope, k_rope_t,
                                          (z, slot, z))
    pos_all = jax.lax.dynamic_update_slice(cache.positions, positions,
                                           (z, slot))
    # absorb W_uk into q: q_lat (B,1,H,kvr)
    wuk = p["wuk"]["w"].reshape(kvr, H, dn)
    q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bqhk,bsk->bhqs", q_lat, ckv.astype(jnp.float32))
         + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale
    mask = _build_mask(positions, pos_all, cfg.sliding_window)[:, None]
    s = jnp.where(mask, s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)                      # (B,H,1,S)
    o_lat = jnp.einsum("bhqs,bsk->bqhk", a, ckv.astype(jnp.float32))
    wuv = p["wuv"]["w"].reshape(kvr, H, dv)
    o = jnp.einsum("bqhk,khv->bqhv", o_lat, wuv.astype(jnp.float32))
    y = linear(p["wo"], o.reshape(B, 1, H * dv).astype(x.dtype))
    return y, MLACache(ckv, k_rope, pos_all)
