"""zamba2-7b — [hybrid] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + weight-tied shared attention
block every 6th layer [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,             # the shared attn block is full MHA
    d_ff=14336,
    vocab_size=32000,
    block_pattern="zamba",
    shared_attn_period=6,      # 13 groups of 6 + 3 trailing mamba layers
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    # windowed shared attention makes the 500k decode admissible (hybrid)
    sliding_window=8192,
    citation="arXiv:2411.15242",
)
