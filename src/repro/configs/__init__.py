"""Architecture registry: ``get_config("<arch-id>")`` returns the exact
assigned configuration; every entry cites its source in ``citation``."""
from __future__ import annotations

from repro.configs.base import ModelConfig

from repro.configs.granite_20b import CONFIG as granite_20b
from repro.configs.command_r_35b import CONFIG as command_r_35b
from repro.configs.zamba2_7b import CONFIG as zamba2_7b
from repro.configs.arctic_480b import CONFIG as arctic_480b
from repro.configs.mamba2_130m import CONFIG as mamba2_130m
from repro.configs.phi4_mini_3_8b import CONFIG as phi4_mini_3_8b
from repro.configs.deepseek_v3_671b import CONFIG as deepseek_v3_671b
from repro.configs.qwen3_1_7b import CONFIG as qwen3_1_7b
from repro.configs.musicgen_medium import CONFIG as musicgen_medium
from repro.configs.llava_next_mistral_7b import CONFIG as llava_next_mistral_7b

REGISTRY: dict[str, ModelConfig] = {
    c.name: c for c in [
        granite_20b, command_r_35b, zamba2_7b, arctic_480b, mamba2_130m,
        phi4_mini_3_8b, deepseek_v3_671b, qwen3_1_7b, musicgen_medium,
        llava_next_mistral_7b,
    ]
}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
