"""musicgen-medium — [audio] 48L d_model=1536 24H (kv=24 ⇒ MHA) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec codec is the stubbed frontend (DESIGN.md carve-out): the
backbone consumes the 2048-entry codebook token stream directly."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    modality="audio",
    citation="arXiv:2306.05284",
)
