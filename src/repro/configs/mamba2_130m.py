"""mamba2-130m — [ssm] 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_head=64,
    d_ff=0,                    # Mamba2 blocks have no separate FFN
    vocab_size=50280,
    attn_impl="none",
    block_pattern="ssm",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,              # d_inner = 1536, 24 heads of dim 64
    tie_embeddings=True,
    citation="arXiv:2405.21060",
)
