"""llava-next-mistral-7b — [vlm] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — mistral-7b backbone + anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

Vision tower + projector are the stubbed frontend (DESIGN.md carve-out):
``input_specs`` feeds pre-projected patch embeddings; anyres tiling is
approximated by a fixed budget of 2880 patch tokens (5 tiles × 576)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    modality="vlm",
    vis_tokens=2880,           # anyres: base 576 + 4 tiles × 576
    sliding_window=4096,       # mistral-7b-v0.1 sliding-window attention
    rope_theta=10_000.0,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
