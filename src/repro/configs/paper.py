"""The paper's own experimental configurations (Sec. V) as named presets
for the benchmark harness and examples."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MTRLConfig:
    """One Dec-MTRL experiment setting."""
    name: str
    L: int            # nodes
    d: int            # feature dimension
    T: int            # tasks
    r: int            # subspace rank
    n: int            # samples per task
    p: float          # Erdős–Rényi edge probability
    kappa: float = 1.0
    T_GD: int = 500
    T_con: int = 10
    T_pm: int = 30
    seed: int = 0
    n_trials: int = 100


# Experiment 1 (Fig. 1): L=20, d=T=600, r=4, n=30, p=0.5, T_GD=500,
# T_con ∈ {10, 20, 30}
EXPERIMENT1 = tuple(
    MTRLConfig(name=f"exp1_Tcon{tc}", L=20, d=600, T=600, r=4, n=30, p=0.5,
               T_GD=500, T_con=tc)
    for tc in (10, 20, 30))

# Experiment 2 (Fig. 2): L=d=T=100, r=10, n=50, T_con=10, T_GD=1500,
# p ∈ {varied}
EXPERIMENT2 = tuple(
    MTRLConfig(name=f"exp2_p{p}", L=100, d=100, T=100, r=10, n=50, p=p,
               T_GD=1500, T_con=10)
    for p in (0.2, 0.5, 0.8))

# Scaled-down variants for CI / CPU benchmarking (same regimes, ~20× less
# compute; used by benchmarks.run so the harness finishes on one core).
EXPERIMENT1_SMALL = tuple(
    MTRLConfig(name=f"exp1s_Tcon{tc}", L=10, d=150, T=150, r=4, n=30, p=0.5,
               T_GD=250, T_con=tc, n_trials=5)
    for tc in (2, 5, 10))

EXPERIMENT2_SMALL = tuple(
    MTRLConfig(name=f"exp2s_p{p}", L=20, d=80, T=80, r=4, n=40, p=p,
               T_GD=300, T_con=5, n_trials=5)
    for p in (0.2, 0.5, 0.8))
