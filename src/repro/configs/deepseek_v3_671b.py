"""deepseek-v3-671b — [moe] 61L d_model=7168 128H (kv=128 ⇒ MHA post-MLA)
d_ff=2048(expert) vocab=129280 — MLA (q_lora 1536, kv_lora 512, rope 64,
nope 128), 1 shared + 256 routed experts top-8, first 3 layers dense,
sigmoid router scores [arXiv:2412.19437].

Simplification (DESIGN.md §8): the MTP (multi-token-prediction) auxiliary
head is omitted — single-token LM head only.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                # dense layers / shared expert hidden dim
    vocab_size=129280,
    attn_impl="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    moe_d_ff=2048,             # routed expert hidden dim (assignment d_ff)
    first_dense_layers=3,
    router_score="sigmoid_norm",
    citation="arXiv:2412.19437",
)
