"""command-r-35b — [dense] 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias, parallel attn∥FFN blocks
[hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    attn_bias=False,
    parallel_block=True,       # Cohere parallel residual structure
    rope_theta=8_000_000.0,
    citation="hf:CohereForAI/c4ai-command-r-v01",
)
