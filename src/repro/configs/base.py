"""ModelConfig — one composable dataclass describing every architecture in
the assigned zoo (dense / MoE / SSM / hybrid / audio / VLM decoders).

Each ``src/repro/configs/<arch>.py`` instantiates this with the exact
assigned hyperparameters; ``smoke()`` derives the reduced variant used by
the CPU smoke tests (≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None   # default d_model // n_heads

    # ---- attention flavour ------------------------------------------------
    attn_impl: str = "gqa"         # gqa | mla | none (pure SSM)
    qk_norm: bool = False          # qwen3
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # window size, None = full causal
    attn_bias: bool = False
    parallel_block: bool = False   # command-r: attn ∥ ffn residual

    # ---- MLA (deepseek-v3) ------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MoE ---------------------------------------------------------------
    n_experts: int = 0             # 0 ⇒ dense FFN
    top_k: int = 0
    n_shared_experts: int = 0      # deepseek: 1 shared expert
    moe_d_ff: Optional[int] = None # expert hidden dim (defaults to d_ff)
    dense_residual: bool = False   # arctic: dense FFN ∥ MoE
    first_dense_layers: int = 0    # deepseek: first k layers dense
    router_score: str = "softmax"  # softmax | sigmoid_norm (deepseek-v3)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ---- SSM / hybrid -------------------------------------------------------
    block_pattern: str = "attn"    # attn | ssm | zamba (ssm + shared attn)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    shared_attn_period: int = 6    # zamba: shared attn every k-th layer

    # ---- block / embedding structure ---------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mlp_bias: bool = False

    # ---- modality (stub frontends; see DESIGN.md carve-out) ----------------
    modality: str = "text"         # text | audio | vlm
    vis_tokens: int = 0            # vlm: anyres patch-embedding budget

    # ---- numerics / execution ----------------------------------------------
    dtype: str = "bfloat16"        # activation dtype
    param_dtype: str = "float32"
    remat: bool = True             # checkpoint each scanned block
    remat_policy: str = "all"      # all | dots — 'dots' saves matmul
    #   outputs (jax.checkpoint dots_saveable policy): less recompute at
    #   higher live memory (a §Perf knob)
    unroll: bool = False           # python loops instead of lax.scan —
    #   used by the dry-run's cost CALIBRATION (XLA cost_analysis counts
    #   scan bodies once, not × trip count; see dryrun.py)
    citation: str = ""

    # ------------------------------------------------------------------ api
    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head",
                               self.d_model // max(self.n_heads, 1))
        if self.attn_impl == "mla" and self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.nope_head_dim)
        if self.n_experts and self.moe_d_ff is None:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.block_pattern in ("ssm", "zamba") and self.ssm_heads == 0:
            object.__setattr__(
                self, "ssm_heads",
                self.ssm_expand * self.d_model // self.ssm_headdim)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def is_subquadratic(self) -> bool:
        """True if a 500k-token decode is admissible (constant or windowed
        per-token state): SSM/hybrid natively, attention only when windowed."""
        if self.block_pattern == "ssm":
            return True
        if self.block_pattern == "zamba":
            # shared attn layers still need a window for 500k
            return self.sliding_window is not None
        return self.sliding_window is not None

    def mixer_kind(self, i: int) -> str:
        """Sequence-mixer of layer i: 'attn' or 'ssm'."""
        if self.block_pattern == "attn":
            return "attn"
        return "ssm"        # zamba's shared attn is *extra*, not a mixer swap

    def ffn_kind(self, i: int) -> str:
        if self.n_experts and i >= self.first_dense_layers:
            return "moe"
        if self.d_ff == 0 or self.block_pattern in ("ssm", "zamba"):
            # mamba2/zamba2: the SSM mixer is the whole block; zamba's d_ff
            # feeds the shared attention block's MLP instead
            return "none"
        return "dense"

    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant: ≤2 layers (plus shared-attn period
        shrunk so the hybrid path is still exercised), d_model ≤ 512,
        ≤4 experts, small vocab — runs a fwd/train step on 1 CPU core."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, n_heads)) if n_heads else 0
        updates = dict(
            name=self.name + "-smoke",
            n_layers=2 if self.block_pattern != "zamba" else 4,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=d_model // n_heads if n_heads else 32,
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=(min(self.sliding_window, 64)
                            if self.sliding_window else None),
            remat=False,
            dtype="float32",
        )
        if self.n_experts:
            updates.update(n_experts=4, top_k=min(self.top_k, 2),
                           moe_d_ff=min(self.moe_d_ff or self.d_ff, 256),
                           first_dense_layers=min(self.first_dense_layers, 1))
        if self.attn_impl == "mla":
            updates.update(q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
                           nope_head_dim=32, v_head_dim=32)
        if self.block_pattern in ("ssm", "zamba"):
            updates.update(ssm_state=min(self.ssm_state, 16), ssm_headdim=32,
                           ssm_heads=2 * d_model // 32, ssm_chunk=16,
                           shared_attn_period=2)
        if self.modality == "vlm":
            updates.update(vis_tokens=min(self.vis_tokens, 16))
        return dataclasses.replace(self, **updates)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        for i in range(L):
            fk = self.ffn_kind(i)
            per_layer += d if fk == "none" else 2 * d  # norms
            if self.parallel_block:
                per_layer -= d                         # single shared norm
            if self.mixer_kind(i) == "attn":
                per_layer += self._attn_params()
            else:
                per_layer += self._ssm_params()
            if fk == "none":
                pass
            elif fk == "dense":
                per_layer += 3 * d * self.d_ff
            else:
                per_layer += d * self.n_experts        # router
                per_layer += self.n_experts * 3 * d * self.moe_d_ff
                per_layer += self.n_shared_experts * 3 * d * self.d_ff
                if self.dense_residual:
                    per_layer += 3 * d * self.d_ff
        if self.block_pattern == "zamba":
            # one shared attn+MLP block (2 norms)
            per_layer += self._attn_params() + 3 * d * self.d_ff + 2 * d
        return emb + per_layer + d                     # final norm

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb + d
        for i in range(L):
            fk = self.ffn_kind(i)
            total += d if fk == "none" else 2 * d
            if self.parallel_block:
                total -= d
            total += (self._attn_params() if self.mixer_kind(i) == "attn"
                      else self._ssm_params())
            if fk == "none":
                pass
            elif fk == "dense":
                total += 3 * d * self.d_ff
            else:
                total += d * self.n_experts
                total += self.top_k * 3 * d * self.moe_d_ff
                total += self.n_shared_experts * 3 * d * self.d_ff
                if self.dense_residual:
                    total += 3 * d * self.d_ff
        if self.block_pattern == "zamba":
            total += self._attn_params() + 3 * d * self.d_ff + 2 * d
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_impl == "mla":
            qdim = self.n_heads * (self.nope_head_dim + self.rope_head_dim)
            p = d * self.q_lora_rank + self.q_lora_rank * qdim
            p += d * (self.kv_lora_rank + self.rope_head_dim)
            p += self.kv_lora_rank * self.n_heads * (self.nope_head_dim
                                                     + self.v_head_dim)
            p += self.n_heads * self.v_head_dim * d
            p += self.q_lora_rank + self.kv_lora_rank   # latent RMS norms
            return p
        h = self.n_heads * self.d_head
        hkv = self.n_kv_heads * self.d_head
        p = d * h + 2 * d * hkv + h * d
        if self.attn_bias:
            p += h + 2 * hkv + d
        if self.qk_norm:
            p += 2 * self.d_head
        return p

    def _ssm_params(self) -> int:
        di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
        in_proj = self.d_model * (2 * di + 2 * N + H)
        conv = (di + 2 * N) * (self.ssm_conv + 1)       # weights + bias
        return in_proj + conv + 3 * H + di + di * self.d_model  # + gated norm
