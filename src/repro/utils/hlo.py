"""HLO text analysis: collective-communication byte accounting for the
roofline's third term (cost_analysis does not expose collective bytes).

We parse the compiled module text and, for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op, account the RESULT
shape's bytes (a reasonable proxy for bytes-on-the-wire per participating
device; all-gather results count the gathered size, reduce-scatter the
scattered size).
"""
from __future__ import annotations

import collections
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<result>\([^)]*\)|[^=(]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(", re.M)


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """{op: {"count": int, "bytes": int}} + totals.  '-done' halves of
    async pairs are skipped (the '-start' carries the shape)."""
    per_op = collections.defaultdict(lambda: {"count": 0, "bytes": 0})
    for m in _OP_RE.finditer(hlo_text):
        line = m.group(0)
        if "-done(" in line:
            continue
        op = m.group("op")
        per_op[op]["count"] += 1
        per_op[op]["bytes"] += _shape_bytes(m.group("result"))
    total_bytes = sum(v["bytes"] for v in per_op.values())
    total_count = sum(v["count"] for v in per_op.values())
    return {"per_op": dict(per_op), "total_bytes": total_bytes,
            "total_count": total_count}


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def dominant_collective(stats: dict) -> str:
    if not stats["per_op"]:
        return "none"
    return max(stats["per_op"].items(), key=lambda kv: kv[1]["bytes"])[0]
