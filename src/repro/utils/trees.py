"""Small pytree helpers used across the framework (no optax dependency)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    return sum(int(x.size) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x, y):
    """a*x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_dot(a, b):
    """Inner product of two pytrees."""
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree_util.tree_reduce(jnp.add, leaves)


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)
