"""Version-portability shims for the jax APIs that moved between 0.4.x
and 0.5+/0.6+.

The repo targets current jax (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``); this container ships 0.4.37 where
``shard_map`` still lives under ``jax.experimental`` and mesh axis types do
not exist yet.  Everything here degrades to the old spelling with the same
semantics (all axes auto / collective-explicit inside shard_map), so the
rest of the codebase can use one call site.

Each shim is gated on ONE module-level feature probe (evaluated once at
import).  A shim may be deleted when its probe is True on the minimum
supported jax: ``_HAS_SHARD_MAP`` is still False on this container's
0.4.37, so the ``jax.experimental.shard_map`` fallback stays;
``_HAS_AXIS_TYPE`` likewise.
"""
from __future__ import annotations

import functools

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_SHARD_MAP = hasattr(jax, "shard_map")      # top-level since 0.6


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types when the API supports them."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def shard_map(f=None, *, mesh, in_specs, out_specs, axis_names=None,
              check_rep=True):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (0.4.x).

    ``axis_names`` is accepted for parity with the new API and dropped on
    0.4.x, where every mesh axis is implicitly named inside the body.
    ``check_rep=False`` disables the replication checker (``check_vma``
    in the new spelling), which has no rule for ``pallas_call`` —
    required whenever the body dispatches a Pallas kernel (the
    engine-routed substrate skeletons in ``repro.core.runtime``)."""
    if f is None:
        return functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, axis_names=axis_names,
                                 check_rep=check_rep)
    if _HAS_SHARD_MAP:
        kw = {} if axis_names is None else {"axis_names": axis_names}
        if not check_rep:
            kw["check_vma"] = False
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)
