"""Canonical ``REPRO_*`` environment-variable registry.

Every environment variable the package reads is declared HERE, with its
meaning, and read through the typed accessors below.  Scattered
``os.environ.get("REPRO_...")`` calls are forbidden by reprolint rule
RL003 — a typo'd variable name then fails loudly at the registry
(``unknown env var``) instead of silently reading nothing, which is the
PR-3 bug class (a misspelled backend override that fell through to the
default for months of wall-clock).

Adding a variable: add it to :data:`ENV_VARS` with a one-line doc, and
read it via :func:`read_str` / :func:`read_choice` / :func:`read_int` /
:func:`read_flag`.  The reprolint AST pass cross-checks every
``REPRO_*`` string literal in ``src/`` against this table.
"""
from __future__ import annotations

import os

ENV_VARS: dict[str, str] = {
    "REPRO_KERNEL_BACKEND":
        "default kernel backend for repro.kernels.ops when an op gets "
        "backend=None: 'pallas' | 'pallas-interpret' | 'xla-ref'",
    "REPRO_ENGINE_BACKEND":
        "default AltgdminEngine backend (falls back to "
        "REPRO_KERNEL_BACKEND, then xla-ref off-TPU); same choices",
}


def _lookup(name: str) -> str | None:
    if name not in ENV_VARS:
        raise KeyError(
            f"unknown env var {name!r}: every REPRO_* variable must be "
            f"declared in repro.utils.env.ENV_VARS (declared: "
            f"{sorted(ENV_VARS)})")
    val = os.environ.get(name)
    return val if val else None          # unset and empty are both "off"


def read_str(name: str) -> str | None:
    """The variable's value, or None when unset/empty."""
    return _lookup(name)


def read_choice(name: str, choices) -> str | None:
    """A validated enum read: unset → None, a value outside ``choices``
    → ValueError naming the offending variable."""
    val = _lookup(name)
    if val is not None and val not in choices:
        raise ValueError(
            f"invalid value {val!r} in environment variable {name}; "
            f"valid choices: {tuple(choices)}")
    return val


def read_int(name: str) -> int | None:
    val = _lookup(name)
    if val is None:
        return None
    try:
        return int(val)
    except ValueError:
        raise ValueError(f"environment variable {name} must be an "
                         f"integer, got {val!r}") from None


def read_flag(name: str) -> bool:
    """Boolean read: '1'/'true'/'yes'/'on' (any case) → True; unset or
    anything else → False."""
    val = _lookup(name)
    return val is not None and val.lower() in ("1", "true", "yes", "on")
