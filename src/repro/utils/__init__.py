from repro.utils.log import get_logger
from repro.utils import trees, hlo
