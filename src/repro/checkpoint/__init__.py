"""Checkpoint store: path-keyed .npz shards + msgpack manifest, with
crash-safe (stage-then-rename) saves.  ``latest_step`` only reports
complete checkpoints, so a hot-swapping reader (the serving subsystem's
:class:`repro.serving.publisher.HotSwapSource`) can never load a
partially written step."""
from repro.checkpoint.store import (latest_step, restore_checkpoint,
                                    save_checkpoint)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]
