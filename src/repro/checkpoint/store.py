"""Checkpointing: flatten a pytree to path-keyed .npz shards + a msgpack
manifest.  No orbax/tensorstore dependency; restore rebuilds the exact
tree structure (dicts, lists, NamedTuples are round-tripped by key path).

Layout:
    <dir>/step_000100/
        manifest.msgpack      # treedef repr + leaf paths + dtypes/shapes
        shard_00000.npz       # leaf arrays (chunked ≤ ``shard_bytes``)
"""
from __future__ import annotations

import os
import re
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
             for path, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]
    return paths, leaves


def save_checkpoint(directory: str, step: int, tree,
                    shard_bytes: int = 1 << 30) -> str:
    """Save ``tree`` under directory/step_{step:09d}. Returns the path.

    Crash-safe: shards and manifest are written into a ``step_*.tmp``
    staging directory and renamed into place only once complete, so a
    killed save can never be picked up by :func:`latest_step` (which
    also requires the manifest to exist)."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    if os.path.isdir(tmp):          # stale staging dir from a killed save
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves = _flatten_with_paths(tree)
    leaves = [np.asarray(x) for x in leaves]

    shards, cur, cur_bytes = [], {}, 0
    index = {}
    for p, arr in zip(paths, leaves):
        if cur_bytes + arr.nbytes > shard_bytes and cur:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        key = f"a{len(cur)}"
        cur[key] = arr
        index[p] = (len(shards), key)
        cur_bytes += arr.nbytes
    if cur:
        shards.append(cur)

    for i, shard in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i:05d}.npz"), **shard)
    manifest = {
        "step": step,
        "index": {p: list(v) for p, v in index.items()},
        "treedef": jax.tree_util.tree_structure(tree).__repr__(),
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.isdir(path):         # overwrite: retire the old complete dir
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def restore_checkpoint(directory: str, step: int, like):
    """Restore into the structure of ``like`` (a template pytree with the
    same treedef — e.g. freshly-initialized params)."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    shards = {}

    def load_shard(i):
        if i not in shards:
            shards[i] = np.load(os.path.join(path, f"shard_{i:05d}.npz"))
        return shards[i]

    paths, leaves = _flatten_with_paths(like)
    treedef = jax.tree_util.tree_structure(like)
    out = []
    for p, leaf in zip(paths, leaves):
        if p not in manifest["index"]:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        si, key = manifest["index"][p]
        arr = load_shard(si)[key]
        dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        out.append(jnp.asarray(arr, dtype=dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str):
    """Highest COMPLETE step number present, or None.

    A directory counts only when its ``manifest.msgpack`` exists — the
    manifest lands atomically with the rename in :func:`save_checkpoint`,
    so in-flight ``step_*.tmp`` staging dirs (excluded by the name
    pattern anyway) and manually truncated dirs are never offered to a
    hot-swapping reader."""
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))
             and os.path.isfile(os.path.join(directory, d,
                                             "manifest.msgpack"))]
    return max(steps) if steps else None
