"""Flash attention Pallas kernel (TPU target, validated interpret=True).

Online-softmax block streaming: Q tiles stay resident in VMEM while KV
tiles stream from HBM; running (m, l, o) accumulators live in VMEM
scratch.  Causal + sliding-window masking and GQA head grouping are
handled inside the kernel, so the S² score matrix never exists.

Grid: (B, H, Sq/blk_q, Skv/blk_k) — the KV-block dimension is innermost
and sequential ("arbitrary"), the rest parallel.  MXU alignment: blk_q and
blk_k default to 128, head_dim padded to a lane multiple by the wrapper
(ops.py).

Layouts: q (B, H, Sq, D); k, v (B, Hkv, Skv, D); out (B, H, Sq, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  blk_q: int, blk_k: int, causal: bool, window, scale: float,
                  offset: int):
    """``offset`` aligns query and key coordinates: query block-row i sits
    at absolute position i·blk_q + offset (aligned ends ⇒ offset =
    Skv_real − Sq_real; right-padded keys fall above the causal diagonal
    and are masked for free)."""
    qi, ki = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q + offset
    k_start = ki * blk_k

    # skip fully-masked KV blocks (strictly above the causal diagonal)
    run = True
    if causal:
        run = k_start <= q_start + blk_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (blk_q, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (blk_k, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)[:, None]               # (blk_q, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)[:, None]
        v = v_ref[0, 0].astype(jnp.float32)               # (blk_k, D)
        acc_scr[...] = (acc_scr[...] * alpha
                        + jax.lax.dot_general(p, v,
                                              (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale=None, blk_q: int = 128, blk_k: int = 128,
                    offset: int | None = None, interpret: bool = True):
    """q: (B,H,Sq,D); k,v: (B,Hkv,Skv,D) → (B,H,Sq,D).

    Sq and Skv must be multiples of the block sizes (ops.py pads).
    ``offset`` defaults to Skv − Sq (aligned ends)."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = H // Hkv
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)
    if Sq % blk_q or Skv % blk_k:
        raise ValueError(f"Sq={Sq}/Skv={Skv} must be multiples of blk_q={blk_q}/"
                         f"blk_k={blk_k} (ops.py pads)")
    scale = scale if scale is not None else D ** -0.5
    offset = Skv - Sq if offset is None else offset
    grid = (B, H, Sq // blk_q, Skv // blk_k)

    kernel = functools.partial(
        _flash_kernel, blk_q=blk_q, blk_k=blk_k, causal=causal,
        window=window, scale=scale, offset=offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),     # running max m
            pltpu.VMEM((blk_q, 1), jnp.float32),     # running sum l
            pltpu.VMEM((blk_q, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
