"""Mamba2 SSD chunked-scan Pallas kernel (TPU target, validated
interpret=True).

Per (batch, head) grid cell the chunk dimension is innermost and
sequential; the (P, N) inter-chunk state lives in VMEM scratch and is
carried across chunk iterations — the HBM traffic is exactly one read of
(x, dt, B, C) and one write of y per token.  Within a chunk the
recurrence is unrolled into the masked quadratic form (state-space
duality): two (Q×Q)·(Q×P/N) MXU matmuls instead of Q sequential steps.

Layouts: x (B, H, nc·Q, P); dt (B, H, nc·Q); Bm/Cm (B, nc·Q, N);
out y (B, H, nc·Q, P) (+ optional final state via a second out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, h_ref,
                h_scr, *, chunk: int):
    h_idx = pl.program_id(1)
    c_idx = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    a = A_ref[h_idx]                             # scalar A_h < 0
    Bm = B_ref[0].astype(jnp.float32)            # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)            # (Q, N)

    dA = dt * a                                  # (Q,)
    cum = jnp.cumsum(dA)                         # (Q,)
    seg = cum[-1]

    # intra-chunk: masked quadratic form on the MXU
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(li >= lj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q,Q)
    M = scores * L * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())))        # (Q,P)

    # inter-chunk: contribution of the carried state
    h = h_scr[...]                               # (P, N)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())))         # (Q,N)·(P,N)ᵀ → (Q,P)

    # D skip connection
    y = y + x * D_ref[h_idx]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h ← e^seg·h + Σ_q decay_q dt_q x_q B_qᵀ
    decay = jnp.exp(seg - cum) * dt              # (Q,)
    S_c = jax.lax.dot_general(x * decay[:, None], Bm,
                              (((0,), (0,)), ((), ())))             # (P,N)
    h_scr[...] = jnp.exp(seg) * h + S_c

    @pl.when(c_idx == nc - 1)
    def _emit_state():
        h_ref[0, 0] = h_scr[...]


def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
             interpret: bool = True):
    """x: (B,H,S,P); dt: (B,H,S); A: (H,); Bm/Cm: (B,S,N); D: (H,) →
    (y (B,H,S,P), h_final (B,H,P,N)).  S must be a multiple of ``chunk``
    (ops.py pads)."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} must be a multiple of chunk={chunk} "
                         f"(ops.py pads)")
    nc = S // chunk
    grid = (B, H, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec(memory_space=pltpu.SMEM),     # A: (H,) scalars
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),     # D: (H,) scalars
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm, D.astype(jnp.float32))
    return y, h
