"""Wire-compression kernels for the compressed consensus rules.

The compressed combine rules (``repro.distributed.consensus``:
``topk_gossip`` / ``quantized_gossip``) shrink what one gossip round
puts on the wire; these kernels implement the per-node encode/decode hot
paths on the pallas backends:

  * :func:`compress_topk` — rank-preserving top-k ROW sparsification of
    a node-batched ``(N, d, r)`` iterate block: the k rows with the
    largest squared row norms are selected per block (keeping whole rows
    keeps the payload a valid rank-≤r factor slice, unlike entrywise
    masking).  Selection is an iterative masked argmax (k small, ≤ d)
    so no sort network is needed; norms accumulate in f32.
  * :func:`dequant` — int8 wire payload → ``scale.dtype`` blocks
    (``q · scale`` with f32 accumulation), the decode half of the
    quantized wire format.

Both are dispatched through ``ops.py`` (``compress_topk`` / ``dequant``)
with ``ref.py`` oracles; float64 operands never reach them — the
consensus layer's shared ``_fused_wanted`` gate routes x64 runs to the
exact reference path, the same policy the combine kernels follow.

Caveat (same family as the in-kernel Cholesky of ``altgdmin_ls``): the
top-k selection loop uses a dynamic row gather and dynamic output
stores.  Interpret mode (the CI path) executes it exactly; if a future
Mosaic lowering rejects the dynamic indexing, hoist the selection to
``ops.py`` via ``jax.lax.top_k`` (the ``ref`` oracle keeps that
structure available).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(m_ref, vals_ref, idx_ref, *, k: int):
    m = m_ref[0]                                        # (d, r)
    s = jnp.sum(m.astype(jnp.float32) ** 2, axis=1)     # (d,) row norms

    def select(j, s):
        i0 = jnp.argmax(s).astype(jnp.int32)            # first max (stable)
        row = jax.lax.dynamic_index_in_dim(m, i0, axis=0, keepdims=True)
        pl.store(vals_ref, (pl.ds(0, 1), pl.ds(j, 1), slice(None)),
                 row[None])
        pl.store(idx_ref, (pl.ds(0, 1), pl.ds(j, 1)), i0[None, None])
        return s.at[i0].set(-jnp.inf)

    jax.lax.fori_loop(0, k, select, s)


def compress_topk(M, k: int, *, interpret: bool = True):
    """Top-k row sparsification.  M: (N, d, r) → (vals (N, k, r) in
    M.dtype, descending row-norm order; idx (N, k) int32).  One grid
    cell per node block; d×r is small (the subspace iterate), so the
    whole block sits in VMEM.  Ties between equal row norms resolve to
    the lowest index (matching ``lax.top_k``'s stable order)."""
    N, d, r = M.shape
    if not 1 <= k <= d:
        raise ValueError(f"compress_topk needs 1 <= k <= d, got k={k}, "
                         f"d={d}")
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(N,),
        in_specs=[pl.BlockSpec((1, d, r), lambda i: (i, 0, 0))],
        out_specs=(pl.BlockSpec((1, k, r), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((N, k, r), M.dtype),
                   jax.ShapeDtypeStruct((N, k), jnp.int32)),
        interpret=interpret,
    )(M)


def _dequant_kernel(scale_ref, q_ref, o_ref):
    s = scale_ref[0, 0, 0].astype(jnp.float32)
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s).astype(o_ref.dtype)


def dequant(q, scale, *, interpret: bool = True):
    """Decode an int8 wire payload: ``q · scale`` per node block with f32
    accumulation.  q: (N, d, r) int8; scale: (N, 1, 1) → (N, d, r) in
    scale.dtype."""
    N, d, r = q.shape
    if scale.shape != (N, 1, 1):
        raise ValueError(f"dequant needs a per-node (N, 1, 1) scale, got "
                         f"{scale.shape} for q {q.shape}")
    return pl.pallas_call(
        _dequant_kernel,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, 1, 1), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, d, r), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, d, r), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d, r), scale.dtype),
        interpret=interpret,
    )(scale, q)
