"""AltGDmin least-squares Pallas kernel — the paper's own compute hot loop
(Algorithm 3 lines 8 & 11), adapted for the MXU.

Per outer iteration every node evaluates, for each local task t:
    A_t = X_t U          (n×r tall-skinny),
    G_t = A_tᵀA_t,  c_t = A_tᵀ y_t      (the normal equations),
and, for the gradient, X_tᵀ(A_t b_t − y_t) b_tᵀ.  The d dimension (600 in
the paper's experiments, arbitrary in production) is the long streamed
axis: X_t tiles of (n, blk_d) and U tiles of (blk_d, r) stream through
VMEM while the (n, r) A-tile accumulates in scratch.  Tasks ride the
parallel grid dimension.  The tiny r×r Cholesky solve stays in jnp
(ops.py) — it is not MXU work.

Layouts: X (T, n, d); U (d, r); y (T, n) → G (T, r, r), c (T, r).

Node-batched fused engine (the production hot path): X (L, tpn, n, d),
per-node U (L, d, r), y (L, tpn, n).  All L·tpn task systems ride one
grid so a whole outer iteration — Gram, r×r solve, residual and gradient
tiles — is ONE ``pallas_call``, and the streamed A = X_t U accumulator is
built exactly once per task (the standalone gradient kernel rebuilds it
in its pass 0; the fused kernel reuses the min-step accumulator, saving
one of the three HBM sweeps over X and ~43% of the model FLOPs at the
paper's r=4 shape).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(x_ref, u_ref, y_ref, g_ref, c_ref, a_scr):
    di = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(di == 0)
    def _init():
        a_scr[...] = jnp.zeros_like(a_scr)

    x = x_ref[0].astype(jnp.float32)             # (n, blk_d)
    u = u_ref[...].astype(jnp.float32)           # (blk_d, r)
    a_scr[...] += jax.lax.dot_general(x, u, (((1,), (0,)), ((), ())))

    @pl.when(di == nd - 1)
    def _finalize():
        a = a_scr[...]                           # (n, r)
        y = y_ref[0].astype(jnp.float32)         # (n,)
        g_ref[0] = jax.lax.dot_general(a, a, (((0,), (0,)), ((), ())))
        c_ref[0] = jax.lax.dot_general(y[None, :], a,
                                       (((1,), (0,)), ((), ())))[0]


def task_gram(X, U, y, *, blk_d: int = 256, interpret: bool = True):
    """X: (T,n,d); U: (d,r); y: (T,n) → (G (T,r,r), c (T,r)).
    d must be a multiple of blk_d (ops.py pads)."""
    T, n, d = X.shape
    r = U.shape[1]
    blk_d = min(blk_d, d)
    if d % blk_d:
        raise ValueError(f"d={d} must be a multiple of blk_d={blk_d} "
                         f"(ops.py pads)")
    grid = (T, d // blk_d)

    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, blk_d), lambda t, i: (t, 0, i)),
            pl.BlockSpec((blk_d, r), lambda t, i: (i, 0)),
            pl.BlockSpec((1, n), lambda t, i: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, r, r), lambda t, i: (t, 0, 0)),
            pl.BlockSpec((1, r), lambda t, i: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, r, r), jnp.float32),
            jax.ShapeDtypeStruct((T, r), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, r), jnp.float32)],
        interpret=interpret,
    )(X, U, y)


def _grad_kernel(x_ref, u_ref, b_ref, y_ref, g_ref, a_scr, r_scr, *,
                 n: int):
    """Two passes over d per task (grid dims: task, pass, d-tile):
    pass 0 accumulates A = X U; pass 1 computes resid = A b − y once, then
    accumulates the (blk_d, r) gradient tile X_tileᵀ resid bᵀ directly into
    the output (gradient tiles are disjoint across d)."""
    pi, di = pl.program_id(1), pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((pi == 0) & (di == 0))
    def _init():
        a_scr[...] = jnp.zeros_like(a_scr)

    @pl.when(pi == 0)
    def _accum_a():
        x = x_ref[0].astype(jnp.float32)
        u = u_ref[...].astype(jnp.float32)
        a_scr[...] += jax.lax.dot_general(x, u, (((1,), (0,)), ((), ())))

    @pl.when((pi == 1) & (di == 0))
    def _resid():
        b = b_ref[0].astype(jnp.float32)             # (r,)
        y = y_ref[0].astype(jnp.float32)             # (n,)
        r_scr[...] = (jax.lax.dot_general(
            a_scr[...], b[:, None], (((1,), (0,)), ((), ())))[:, 0]
            - y)[:, None]                            # (n, 1)

    @pl.when(pi == 1)
    def _grad_tile():
        x = x_ref[0].astype(jnp.float32)             # (n, blk_d)
        b = b_ref[0].astype(jnp.float32)             # (r,)
        xtres = jax.lax.dot_general(x, r_scr[...],
                                    (((0,), (0,)), ((), ())))   # (blk_d,1)
        g_ref[0] = jax.lax.dot_general(xtres, b[None, :],
                                       (((1,), (0,)), ((), ())))


def task_grad_tiles(X, U, B, y, *, blk_d: int = 256,
                    interpret: bool = True):
    """Per-task gradient contributions, d-tiled:
    out (T, d, r) with out[t] = X_tᵀ(X_t U b_t − y_t) b_tᵀ.
    Sum over T outside (ops.py) to get ∇f = Σ_t out[t]."""
    T, n, d = X.shape
    r = U.shape[1]
    blk_d = min(blk_d, d)
    if d % blk_d:
        raise ValueError(f"d={d} must be a multiple of blk_d={blk_d} "
                         f"(ops.py pads)")
    grid = (T, 2, d // blk_d)

    kernel = functools.partial(_grad_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, blk_d), lambda t, p, i: (t, 0, i)),
            pl.BlockSpec((blk_d, r), lambda t, p, i: (i, 0)),
            pl.BlockSpec((1, r), lambda t, p, i: (t, 0)),
            pl.BlockSpec((1, n), lambda t, p, i: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_d, r), lambda t, p, i: (t, i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d, r), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n, r), jnp.float32),      # A accumulator
            pltpu.VMEM((n, 1), jnp.float32),      # residual
        ],
        interpret=interpret,
    )(X, U, B, y)


# ----------------------------------------------------------------------
# fused node-batched engine kernel
# ----------------------------------------------------------------------

def _chol_solve_unrolled(G, c, r: int):
    """Solve G b = c for SPD G: (r, r) via fully-unrolled Cholesky +
    forward/back substitution.  r is a static Python int (tiny: 4–10), so
    the O(r³) unroll is a handful of scalar ops — this is what lets the
    min-B solve live INSIDE the kernel instead of bouncing (G, c) to HBM
    and re-dispatching for the gradient."""
    Lc = [[None] * r for _ in range(r)]
    for i in range(r):
        for j in range(i + 1):
            s = G[i, j] - sum((Lc[i][k] * Lc[j][k] for k in range(j)),
                              jnp.float32(0))
            Lc[i][j] = jnp.sqrt(s) if i == j else s / Lc[j][j]
    z = [None] * r
    for i in range(r):
        z[i] = (c[i] - sum((Lc[i][k] * z[k] for k in range(i)),
                           jnp.float32(0))) / Lc[i][i]
    b = [None] * r
    for i in reversed(range(r)):
        b[i] = (z[i] - sum((Lc[k][i] * b[k] for k in range(i + 1, r)),
                           jnp.float32(0))) / Lc[i][i]
    return jnp.stack(b)


def _fused_iter_kernel(x_ref, u_ref, y_ref, b_ref, gt_ref,
                       a_scr, b_scr, r_scr, *, r: int):
    """Grid (L·tpn, 2, d//blk_d).  Pass 0 streams X/U d-tiles and
    accumulates A = X_t U (the ONLY A build); at the last d-tile it forms
    the normal equations in-register, solves them (unrolled Cholesky),
    emits b_t and caches the residual A b − y.  Pass 1 re-streams X d-tiles
    once to emit the disjoint gradient tiles X_tileᵀ resid b_tᵀ."""
    pi, di = pl.program_id(1), pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((pi == 0) & (di == 0))
    def _init():
        a_scr[...] = jnp.zeros_like(a_scr)

    @pl.when(pi == 0)
    def _accum_a():
        x = x_ref[0, 0].astype(jnp.float32)          # (n, blk_d)
        u = u_ref[0].astype(jnp.float32)             # (blk_d, r)
        a_scr[...] += jax.lax.dot_general(x, u, (((1,), (0,)), ((), ())))

    @pl.when((pi == 0) & (di == nd - 1))
    def _solve():
        a = a_scr[...]                               # (n, r)
        y = y_ref[0, 0].astype(jnp.float32)          # (n,)
        G = jax.lax.dot_general(a, a, (((0,), (0,)), ((), ())))
        c = jax.lax.dot_general(y[None, :], a, (((1,), (0,)), ((), ())))[0]
        b = _chol_solve_unrolled(G, c, r)            # (r,)
        b_ref[0, 0] = b
        b_scr[...] = b[None, :]
        r_scr[...] = (jax.lax.dot_general(
            a, b[:, None], (((1,), (0,)), ((), ())))[:, 0] - y)[:, None]

    @pl.when(pi == 1)
    def _grad_tile():
        x = x_ref[0, 0].astype(jnp.float32)          # (n, blk_d)
        xtres = jax.lax.dot_general(x, r_scr[...],
                                    (((0,), (0,)), ((), ())))   # (blk_d,1)
        gt_ref[0, 0] = jax.lax.dot_general(xtres, b_scr[...],
                                           (((1,), (0,)), ((), ())))


def node_fused_iter(X, U, y, *, blk_d: int = 256, interpret: bool = True):
    """One fused AltGDmin iteration for all nodes/tasks in one dispatch.

    X: (L, tpn, n, d); U: (L, d, r); y: (L, tpn, n) →
      B     (L, tpn, r)     — min-B solutions b_t = (X_t U_g)† y_t,
      tiles (L, tpn, d, r)  — per-task gradient contributions
                              X_tᵀ(X_t U_g b_t − y_t) b_tᵀ
    (sum tiles over tpn in ops.py for ∇f_g).  d must be a multiple of
    blk_d (ops.py pads)."""
    L, tpn, n, d = X.shape
    r = U.shape[2]
    blk_d = min(blk_d, d)
    if d % blk_d:
        raise ValueError(f"d={d} must be a multiple of blk_d={blk_d} "
                         f"(ops.py pads)")
    grid = (L * tpn, 2, d // blk_d)

    kernel = functools.partial(_fused_iter_kernel, r=r)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, n, blk_d),
                         lambda t, p, i: (t // tpn, t % tpn, 0, i)),
            pl.BlockSpec((1, blk_d, r), lambda t, p, i: (t // tpn, i, 0)),
            pl.BlockSpec((1, 1, n), lambda t, p, i: (t // tpn, t % tpn, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, r), lambda t, p, i: (t // tpn, t % tpn, 0)),
            pl.BlockSpec((1, 1, blk_d, r),
                         lambda t, p, i: (t // tpn, t % tpn, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, tpn, r), jnp.float32),
            jax.ShapeDtypeStruct((L, tpn, d, r), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, r), jnp.float32),      # A accumulator
            pltpu.VMEM((1, r), jnp.float32),      # b_t
            pltpu.VMEM((n, 1), jnp.float32),      # residual
        ],
        interpret=interpret,
    )(X, U, y)


def _gram_kernel_nb(x_ref, u_ref, y_ref, g_ref, c_ref, a_scr):
    """Node-batched _gram_kernel (rank-4 blocks, per-node U tile)."""
    di = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(di == 0)
    def _init():
        a_scr[...] = jnp.zeros_like(a_scr)

    x = x_ref[0, 0].astype(jnp.float32)              # (n, blk_d)
    u = u_ref[0].astype(jnp.float32)                 # (blk_d, r)
    a_scr[...] += jax.lax.dot_general(x, u, (((1,), (0,)), ((), ())))

    @pl.when(di == nd - 1)
    def _finalize():
        a = a_scr[...]                               # (n, r)
        y = y_ref[0, 0].astype(jnp.float32)          # (n,)
        g_ref[0, 0] = jax.lax.dot_general(a, a, (((0,), (0,)), ((), ())))
        c_ref[0, 0] = jax.lax.dot_general(y[None, :], a,
                                          (((1,), (0,)), ((), ())))[0]


def node_task_gram(X, U, y, *, blk_d: int = 256, interpret: bool = True):
    """Node-batched Gram systems (min-B half only — the sample-split path
    where min and gradient use different folds).
    X: (L, tpn, n, d); U: (L, d, r); y: (L, tpn, n) →
    (G (L, tpn, r, r), c (L, tpn, r))."""
    L, tpn, n, d = X.shape
    r = U.shape[2]
    blk_d = min(blk_d, d)
    if d % blk_d:
        raise ValueError(f"d={d} must be a multiple of blk_d={blk_d} "
                         f"(ops.py pads)")
    grid = (L * tpn, d // blk_d)

    return pl.pallas_call(
        _gram_kernel_nb,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, n, blk_d),
                         lambda t, i: (t // tpn, t % tpn, 0, i)),
            pl.BlockSpec((1, blk_d, r), lambda t, i: (t // tpn, i, 0)),
            pl.BlockSpec((1, 1, n), lambda t, i: (t // tpn, t % tpn, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, r, r), lambda t, i: (t // tpn, t % tpn, 0, 0)),
            pl.BlockSpec((1, 1, r), lambda t, i: (t // tpn, t % tpn, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, tpn, r, r), jnp.float32),
            jax.ShapeDtypeStruct((L, tpn, r), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, r), jnp.float32)],
        interpret=interpret,
    )(X, U, y)


def _grad_kernel_nb(x_ref, u_ref, b_ref, y_ref, g_ref, a_scr, r_scr):
    """Node-batched _grad_kernel (rank-4 blocks, per-node U tile)."""
    pi, di = pl.program_id(1), pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((pi == 0) & (di == 0))
    def _init():
        a_scr[...] = jnp.zeros_like(a_scr)

    @pl.when(pi == 0)
    def _accum_a():
        x = x_ref[0, 0].astype(jnp.float32)
        u = u_ref[0].astype(jnp.float32)
        a_scr[...] += jax.lax.dot_general(x, u, (((1,), (0,)), ((), ())))

    @pl.when((pi == 1) & (di == 0))
    def _resid():
        b = b_ref[0, 0].astype(jnp.float32)          # (r,)
        y = y_ref[0, 0].astype(jnp.float32)          # (n,)
        r_scr[...] = (jax.lax.dot_general(
            a_scr[...], b[:, None], (((1,), (0,)), ((), ())))[:, 0]
            - y)[:, None]                            # (n, 1)

    @pl.when(pi == 1)
    def _grad_tile():
        x = x_ref[0, 0].astype(jnp.float32)          # (n, blk_d)
        b = b_ref[0, 0].astype(jnp.float32)          # (r,)
        xtres = jax.lax.dot_general(x, r_scr[...],
                                    (((0,), (0,)), ((), ())))   # (blk_d,1)
        g_ref[0, 0] = jax.lax.dot_general(xtres, b[None, :],
                                          (((1,), (0,)), ((), ())))


def node_task_grad_tiles(X, U, B, y, *, blk_d: int = 256,
                         interpret: bool = True):
    """Node-batched gradient tiles with a given B (sample-split path —
    A must be rebuilt on the gradient fold's data, so this keeps the
    two-pass structure).  X: (L, tpn, n, d); U: (L, d, r); B: (L, tpn, r);
    y: (L, tpn, n) → (L, tpn, d, r)."""
    L, tpn, n, d = X.shape
    r = U.shape[2]
    blk_d = min(blk_d, d)
    if d % blk_d:
        raise ValueError(f"d={d} must be a multiple of blk_d={blk_d} "
                         f"(ops.py pads)")
    grid = (L * tpn, 2, d // blk_d)

    return pl.pallas_call(
        _grad_kernel_nb,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, n, blk_d),
                         lambda t, p, i: (t // tpn, t % tpn, 0, i)),
            pl.BlockSpec((1, blk_d, r), lambda t, p, i: (t // tpn, i, 0)),
            pl.BlockSpec((1, 1, r), lambda t, p, i: (t // tpn, t % tpn, 0)),
            pl.BlockSpec((1, 1, n), lambda t, p, i: (t // tpn, t % tpn, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_d, r),
                               lambda t, p, i: (t // tpn, t % tpn, i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, tpn, d, r), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n, r), jnp.float32),      # A accumulator
            pltpu.VMEM((n, 1), jnp.float32),      # residual
        ],
        interpret=interpret,
    )(X, U, B, y)
