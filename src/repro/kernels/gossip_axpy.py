"""Fused gossip combine kernel: z ← w_self·z + w_nbr·Σ_k nbr_k.

After the collective-permutes of one diffusion round, each device holds
its own block plus K neighbour blocks; this VPU kernel fuses the weighted
K+1-way combine into a single pass over VMEM tiles (instead of K separate
axpy sweeps through HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axpy_kernel(z_ref, nbr_ref, o_ref, *, w_self: float, w_nbr: float):
    z = z_ref[...].astype(jnp.float32)
    acc = w_self * z
    acc = acc + w_nbr * jnp.sum(nbr_ref[...].astype(jnp.float32), axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _mix_kernel(w_ref, z_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)               # (L, L)
    z = z_ref[...].astype(jnp.float32)               # (L, blk_c)
    o_ref[...] = jax.lax.dot_general(
        w, z, (((1,), (0,)), ((), ()))).astype(o_ref.dtype)


def mix_rows(W, Z, *, blk_c: int = 512, interpret: bool = True):
    """Fused consensus combine Z ← W Z for a precomputed mixing matrix
    (typically W^{T_con} from ``agree_power`` — the whole AGREE phase in
    ONE weighted combine instead of T_con HBM sweeps).  The node count L
    is small (≤ ~100), so W stays resident while Z streams in column
    tiles.  W: (L, L); Z: (L, M), M a multiple of blk_c (ops.py pads)."""
    L, M = Z.shape
    blk_c = min(blk_c, M)
    assert M % blk_c == 0
    return pl.pallas_call(
        _mix_kernel,
        grid=(M // blk_c,),
        in_specs=[
            pl.BlockSpec((L, L), lambda i: (0, 0)),
            pl.BlockSpec((L, blk_c), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((L, blk_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((L, M), jnp.float32),
        interpret=interpret,
    )(W, Z)


def gossip_combine(z, neighbors, w_self: float, w_nbr: float, *,
                   blk_rows: int = 256, interpret: bool = True):
    """z: (M, C); neighbors: (K, M, C) → (M, C)."""
    M, C = z.shape
    K = neighbors.shape[0]
    blk_rows = min(blk_rows, M)
    assert M % blk_rows == 0
    kernel = functools.partial(_axpy_kernel, w_self=w_self, w_nbr=w_nbr)
    return pl.pallas_call(
        kernel,
        grid=(M // blk_rows,),
        in_specs=[
            pl.BlockSpec((blk_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((K, blk_rows, C), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, C), z.dtype),
        interpret=interpret,
    )(z, neighbors)
