"""Fused gossip combine kernel: z ← w₀·z + Σ_k w_{k+1}·nbr_k.

After the collective-permutes of one diffusion round, each device holds
its own block plus K neighbour blocks; this VPU kernel fuses the weighted
K+1-way combine into a single pass over VMEM tiles (instead of K separate
axpy sweeps through HBM).  The weights arrive as a (K+1, 1) operand —
per-shift values rather than a uniform scalar pair — so arbitrary
weighted topologies (Metropolis rows, irregular graphs) lower to the
same ONE dispatch per round as the uniform ring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(w_ref, z_ref, nbr_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)               # (K+1, 1)
    z = z_ref[...].astype(jnp.float32)               # (blk, C)
    nbr = nbr_ref[...].astype(jnp.float32)           # (K, blk, C)
    acc = w[0, 0] * z + jnp.sum(w[1:, :, None] * nbr, axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _mix_kernel(w_ref, z_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)               # (L, L)
    z = z_ref[...].astype(jnp.float32)               # (L, blk_c)
    o_ref[...] = jax.lax.dot_general(
        w, z, (((1,), (0,)), ((), ()))).astype(o_ref.dtype)


def mix_rows(W, Z, *, blk_c: int = 512, interpret: bool = True):
    """Fused consensus combine Z ← W Z for a precomputed mixing matrix
    (typically W^{T_con} from ``agree_power`` — the whole AGREE phase in
    ONE weighted combine instead of T_con HBM sweeps).  The node count L
    is small (≤ ~100), so W stays resident while Z streams in column
    tiles.  W: (L, L); Z: (L, M), M a multiple of blk_c (ops.py pads).
    Output dtype follows Z (accumulation is f32 in-kernel)."""
    L, M = Z.shape
    blk_c = min(blk_c, M)
    if M % blk_c:
        raise ValueError(f"mix_rows needs M divisible by blk_c: "
                         f"M={M}, blk_c={blk_c} (ops.mix_nodes pads)")
    return pl.pallas_call(
        _mix_kernel,
        grid=(M // blk_c,),
        in_specs=[
            pl.BlockSpec((L, L), lambda i: (0, 0)),
            pl.BlockSpec((L, blk_c), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((L, blk_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((L, M), Z.dtype),
        interpret=interpret,
    )(W, Z)


def gossip_combine(z, neighbors, weights, *, blk_rows: int = 256,
                   interpret: bool = True):
    """z: (M, C); neighbors: (K, M, C); weights: (K+1,) → (M, C).

    Row counts not divisible by ``blk_rows`` are zero-padded and trimmed
    (the combine is row-wise, so padded rows never touch real ones)."""
    M, C = z.shape
    K = neighbors.shape[0]
    blk_rows = min(blk_rows, M)
    pad = (-M) % blk_rows
    if pad:
        z = jnp.pad(z, ((0, pad), (0, 0)))
        neighbors = jnp.pad(neighbors, ((0, 0), (0, pad), (0, 0)))
    Mp = M + pad
    w = jnp.asarray(weights, jnp.float32).reshape(K + 1, 1)
    out = pl.pallas_call(
        _combine_kernel,
        grid=(Mp // blk_rows,),
        in_specs=[
            pl.BlockSpec((K + 1, 1), lambda i: (0, 0)),
            pl.BlockSpec((blk_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((K, blk_rows, C), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, C), z.dtype),
        interpret=interpret,
    )(w, z, neighbors)
    return out[:M] if pad else out
