"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps).  Deliberately naive — clarity over
speed."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B,H,Sq,D), k/v: (B,Hkv,Skv,D) → (B,H,Sq,D). GQA by head
    grouping; f32 softmax."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, D) * scale
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32))
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= ki <= qi + (Skv - Sq)       # aligned ends (prefill/decode)
    if window is not None:
        mask &= (qi + (Skv - Sq) - ki) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def ref_ssd(x, dt, A, Bm, Cm, D, h0=None):
    """Sequential (token-by-token) SSD recurrence — the ground truth the
    chunked/kernel implementations must match.
    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N); D: (H,)."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    D = D.astype(jnp.float32)
    h = (h0.astype(jnp.float32) if h0 is not None
         else jnp.zeros((Bb, H, P, N), jnp.float32))

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        a = jnp.exp(dt_t * A)                              # (B,H)
        h = (a[..., None, None] * h
             + jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t, x_t))
        y = jnp.einsum("bn,bhpn->bhp", C_t, h)
        return h, y

    h_fin, ys = jax.lax.scan(
        step, h,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1) + xf * D[:, None]
    return y.astype(x.dtype), h_fin


def ref_task_gram(X, U, y):
    """The paper's per-task least-squares pieces, batched over tasks:
    A_t = X_t U;  G_t = A_tᵀA_t;  c_t = A_tᵀ y_t.
    X: (T,n,d), U: (d,r), y: (T,n) → G: (T,r,r), c: (T,r)."""
    A = jnp.einsum("tnd,dr->tnr", X.astype(jnp.float32),
                   U.astype(jnp.float32))
    G = jnp.einsum("tnr,tns->trs", A, A)
    c = jnp.einsum("tnr,tn->tr", A, y.astype(jnp.float32))
    return G, c


def ref_altgdmin_grad(X, U, B, y):
    """∇_U f = Σ_t X_tᵀ (X_t U b_t − y_t) b_tᵀ.
    X: (T,n,d), U: (d,r), B: (T,r), y: (T,n) → (d,r)."""
    resid = (jnp.einsum("tnd,dr,tr->tn", X.astype(jnp.float32),
                        U.astype(jnp.float32), B.astype(jnp.float32))
             - y.astype(jnp.float32))
    return jnp.einsum("tnd,tn,tr->dr", X.astype(jnp.float32), resid,
                      B.astype(jnp.float32))


def ref_compress_topk(M, k):
    """Top-k row sparsification oracle: per (d, r) block, the k rows with
    the largest squared row norms (norms in the OPERAND dtype — the f64
    exact path stays exact; on f32 data this matches the kernel's f32
    accumulation bit-for-bit).  M: (N, d, r) → (vals (N, k, r), idx
    (N, k) int32, descending row-norm order, ties to lowest index)."""
    s = jnp.sum(M * M, axis=-1)                         # (N, d)
    _, idx = jax.lax.top_k(s, k)                        # (N, k) stable
    vals = jnp.take_along_axis(M, idx[..., None], axis=1)
    return vals, idx.astype(jnp.int32)


def ref_dequant(q, scale):
    """int8 wire decode oracle: q.astype(scale.dtype) * scale.
    q: (N, d, r); scale: (N, 1, 1) → (N, d, r) in scale.dtype."""
    return q.astype(scale.dtype) * scale


def ref_gossip_combine(z, neighbors, weights):
    """z ← w₀·z + Σ_k w_{k+1}·neighbors[k].  z: (...,), neighbors:
    (K, ...), weights: (K+1,) — per-shift values (uniform rings pass the
    same value K times)."""
    w = jnp.asarray(weights, jnp.float32)
    acc = w[0] * z.astype(jnp.float32)
    for k in range(neighbors.shape[0]):
        acc = acc + w[k + 1] * neighbors[k].astype(jnp.float32)
    return acc.astype(z.dtype)
