"""jit'd public wrappers around the Pallas kernels, plus the backend
dispatch registry.

Every op routes through one of three named backends:

  * ``pallas``           — compiled Pallas (the TPU production path);
  * ``pallas-interpret`` — same kernel body executed in interpret mode
                           (CPU-exact validation of the TPU code path);
  * ``xla-ref``          — the pure-jnp oracle from :mod:`repro.kernels.ref`
                           (XLA decides the schedule; numerics fallback).

Selection order: explicit ``backend=`` argument → ``set_default_backend``
→ ``REPRO_KERNEL_BACKEND`` env var → ``pallas`` on TPU / ``pallas-interpret``
elsewhere.  Wrappers also handle padding to block multiples and layout
conversion from the model's (B, S, H, D) convention to the kernels'
(B, H, S, D).
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from repro.utils import env as env_registry
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import altgdmin_ls as _ls
from repro.kernels import compress as _cp
from repro.kernels import gossip_axpy as _ga
from repro.kernels import ref as _ref


# ------------------------------------------------------------ dispatch

BACKENDS = ("pallas", "pallas-interpret", "xla-ref")
_default_backend: str | None = None


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _validate(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"expected one of {BACKENDS}")
    return name


def default_backend(*, extra_env: str | None = None,
                    off_tpu_fallback: str = "pallas-interpret") -> str:
    """The backend used when an op gets ``backend=None``.  Resolution:
    programmatic override (set_default_backend / backend_scope) →
    ``extra_env`` (if given) → ``REPRO_KERNEL_BACKEND`` → ``pallas`` on
    TPU / ``off_tpu_fallback`` elsewhere.  The AltGDmin engine shares
    this chain with ``extra_env="REPRO_ENGINE_BACKEND"`` and an
    ``xla-ref`` fallback (seed-numerics default off-TPU).

    Env reads go through the :mod:`repro.utils.env` registry, which
    validates at resolve time: a bad value fails with a message naming
    the offending variable, and an undeclared variable name fails at
    the registry instead of silently reading nothing."""
    if _default_backend is not None:
        return _default_backend
    for var in (extra_env, "REPRO_KERNEL_BACKEND"):
        env = env_registry.read_choice(var, BACKENDS) if var else None
        if env:
            return env
    return "pallas" if _on_tpu() else _validate(off_tpu_fallback)


def set_default_backend(name: str | None) -> None:
    """Process-wide override (None restores env/auto selection)."""
    global _default_backend
    _default_backend = None if name is None else _validate(name)


@contextlib.contextmanager
def backend_scope(name: str):
    """Temporarily select a backend for every op in the ``with`` body."""
    global _default_backend
    prev = _default_backend
    set_default_backend(name)
    try:
        yield
    finally:
        _default_backend = prev


def resolve_backend(backend: str | None) -> str:
    return default_backend() if backend is None else _validate(backend)


def _interp(backend: str) -> bool:
    """interpret flag for the two Pallas backends (callers must have
    routed xla-ref elsewhere already)."""
    return backend != "pallas"


# ------------------------------------------------------------ attention

def flash_attention(q, k, v, *, causal=True, window=None, blk_q=128,
                    blk_k=128, backend=None):
    """Model layout: q (B,S,H,D); k,v (B,Skv,Hkv,D) → (B,S,H,D)."""
    return _flash_attention(q, k, v, causal=causal, window=window,
                            blk_q=blk_q, blk_k=blk_k,
                            backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k", "backend"))
def _flash_attention(q, k, v, *, causal, window, blk_q, blk_k, backend):
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    qT = jnp.swapaxes(q, 1, 2)
    kT = jnp.swapaxes(k, 1, 2)
    vT = jnp.swapaxes(v, 1, 2)
    if backend == "xla-ref":
        o = _ref.ref_attention(qT, kT, vT, causal=causal, window=window,
                               scale=D ** -0.5)
        return jnp.swapaxes(o, 1, 2)
    blk_q_ = min(blk_q, Sq)
    blk_k_ = min(blk_k, Skv)
    pq = (-Sq) % blk_q_
    pk = (-Skv) % blk_k_
    if pq:
        qT = jnp.pad(qT, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        # right-pad keys: padded slots sit above the causal diagonal of
        # every real query (offset uses REAL lengths), so causal masking
        # excludes them for free
        kT = jnp.pad(kT, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vT = jnp.pad(vT, ((0, 0), (0, 0), (0, pk), (0, 0)))
    o = _fa.flash_attention(qT, kT, vT, causal=causal, window=window,
                            scale=D ** -0.5, blk_q=blk_q_, blk_k=blk_k_,
                            offset=Skv - Sq, interpret=_interp(backend))
    if pq:
        o = o[:, :, :Sq]
    return jnp.swapaxes(o, 1, 2)


# ------------------------------------------------------------ SSD

def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128, backend=None):
    """Model layout: x (B,S,H,P); dt (B,S,H); Bm/Cm (B,S,N) →
    (y (B,S,H,P), h_final (B,H,P,N))."""
    return _ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                     backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def _ssd_scan(x, dt, A, Bm, Cm, D, *, chunk, backend):
    if backend == "xla-ref":
        return _ref.ref_ssd(x, dt, A, Bm, Cm, D)
    B, S, H, P = x.shape
    chunk_ = min(chunk, S)
    pad = (-S) % chunk_
    xT = jnp.swapaxes(x, 1, 2)                       # (B,H,S,P)
    dtT = jnp.swapaxes(dt, 1, 2)                     # (B,H,S)
    if pad:
        xT = jnp.pad(xT, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtT = jnp.pad(dtT, ((0, 0), (0, 0), (0, pad)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = _ssd.ssd_scan(xT, dtT, A, Bm, Cm, D, chunk=chunk_,
                         interpret=_interp(backend))
    y = jnp.swapaxes(y[:, :, :S], 1, 2)
    return y, h


# ------------------------------------------------------------ MTRL LS

def _solve_spd(G, c):
    return jax.scipy.linalg.solve(G, c, assume_a="pos")


def _pad_d(X, U, blk_d):
    """Pad the streamed d axis (last of X, second-to-last of U) to a
    block multiple.  Zero columns contribute nothing to A = X U, so the
    Gram/gradient results are exact after trimming."""
    d = X.shape[-1]
    blk = min(blk_d, d)
    pad = (-d) % blk
    if pad:
        X = jnp.pad(X, ((0, 0),) * (X.ndim - 1) + ((0, pad),))
        U = jnp.pad(U, ((0, 0),) * (U.ndim - 2) + ((0, pad), (0, 0)))
    return X, U, blk


def altgdmin_minimize_B(X, U, y, *, blk_d=256, backend=None):
    """b_t = (X_t U)† y_t via kernel Gram + tiny jnp Cholesky solve.
    X: (T,n,d); U: (d,r); y: (T,n) → B (T,r)."""
    return _altgdmin_minimize_B(X, U, y, blk_d=blk_d,
                                backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("blk_d", "backend"))
def _altgdmin_minimize_B(X, U, y, *, blk_d, backend):
    if backend == "xla-ref":
        G, c = _ref.ref_task_gram(X, U, y)
    else:
        Xp, Up, blk = _pad_d(X, U, blk_d)
        G, c = _ls.task_gram(Xp, Up, y, blk_d=blk,
                             interpret=_interp(backend))
    return jax.vmap(_solve_spd)(G, c)


def altgdmin_gradient(X, U, B, y, *, blk_d=256, backend=None):
    """∇_U f = Σ_t X_tᵀ(X_t U b_t − y_t) b_tᵀ via the fused two-pass
    kernel. X: (T,n,d); U: (d,r); B: (T,r); y: (T,n) → (d,r)."""
    return _altgdmin_gradient(X, U, B, y, blk_d=blk_d,
                              backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("blk_d", "backend"))
def _altgdmin_gradient(X, U, B, y, *, blk_d, backend):
    if backend == "xla-ref":
        return _ref.ref_altgdmin_grad(X, U, B, y)
    d = X.shape[2]
    Xp, Up, blk = _pad_d(X, U, blk_d)
    tiles = _ls.task_grad_tiles(Xp, Up, B, y, blk_d=blk,
                                interpret=_interp(backend))
    return jnp.sum(tiles, axis=0)[:d]


# ---------------------------------------------- MTRL LS (node-batched)

def altgdmin_node_minimize_B(X, U, y, *, blk_d=256, backend=None):
    """Node-batched min step: all L·tpn task systems in one dispatch.
    X: (L,tpn,n,d); U: (L,d,r); y: (L,tpn,n) → B (L,tpn,r)."""
    return _altgdmin_node_minimize_B(X, U, y, blk_d=blk_d,
                                     backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("blk_d", "backend"))
def _altgdmin_node_minimize_B(X, U, y, *, blk_d, backend):
    if backend == "xla-ref":
        G, c = jax.vmap(_ref.ref_task_gram)(X, U, y)
    else:
        Xp, Up, blk = _pad_d(X, U, blk_d)
        G, c = _ls.node_task_gram(Xp, Up, y, blk_d=blk,
                                  interpret=_interp(backend))
    return jax.vmap(jax.vmap(_solve_spd))(G, c)


def altgdmin_node_gradient(X, U, B, y, *, blk_d=256, backend=None):
    """Node-batched gradients with a given B (sample-split path).
    X: (L,tpn,n,d); U: (L,d,r); B: (L,tpn,r); y: (L,tpn,n) → (L,d,r)."""
    return _altgdmin_node_gradient(X, U, B, y, blk_d=blk_d,
                                   backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("blk_d", "backend"))
def _altgdmin_node_gradient(X, U, B, y, *, blk_d, backend):
    if backend == "xla-ref":
        return jax.vmap(_ref.ref_altgdmin_grad)(X, U, B, y)
    d = X.shape[3]
    Xp, Up, blk = _pad_d(X, U, blk_d)
    tiles = _ls.node_task_grad_tiles(Xp, Up, B, y, blk_d=blk,
                                     interpret=_interp(backend))
    return jnp.sum(tiles, axis=1)[:, :d]


def altgdmin_fused_step(X, U, y, *, blk_d=256, backend=None):
    """The fused engine iteration (min-B + gradient, one A build, one
    dispatch).  X: (L,tpn,n,d); U: (L,d,r); y: (L,tpn,n) →
    (B (L,tpn,r), grad (L,d,r))."""
    return _altgdmin_fused_step(X, U, y, blk_d=blk_d,
                                backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("blk_d", "backend"))
def _altgdmin_fused_step(X, U, y, *, blk_d, backend):
    if backend == "xla-ref":
        G, c = jax.vmap(_ref.ref_task_gram)(X, U, y)
        B = jax.vmap(jax.vmap(_solve_spd))(G, c)
        return B, jax.vmap(_ref.ref_altgdmin_grad)(X, U, B, y)
    d = X.shape[3]
    Xp, Up, blk = _pad_d(X, U, blk_d)
    B, tiles = _ls.node_fused_iter(Xp, Up, y, blk_d=blk,
                                   interpret=_interp(backend))
    return B, jnp.sum(tiles, axis=1)[:, :d]


# ------------------------------------------------------------ gossip

def gossip_combine(z, neighbors, weights, *, backend=None):
    """Fused z ← w₀·z + Σ_k w_{k+1}·neighbors[k] over arbitrary-shape z.
    ``weights``: (K+1,) per-shift values — a uniform ring passes the same
    neighbour weight K times; arbitrary weighted topologies pass their
    own W-row slice.  ONE kernel dispatch either way."""
    return _gossip_combine(z, neighbors,
                           jnp.asarray(weights, jnp.float32),
                           backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _gossip_combine(z, neighbors, weights, *, backend):
    if backend == "xla-ref":
        return _ref.ref_gossip_combine(z, neighbors, weights)
    shape = z.shape
    flat = z.reshape(-1)
    n = flat.shape[0]
    C, R = 256, 8                 # lane width × row tile
    pad = (-n) % (C * R)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    nbr = neighbors.reshape(neighbors.shape[0], -1)
    if pad:
        nbr = jnp.pad(nbr, ((0, 0), (0, pad)))
    M = flat.shape[0] // C
    out = _ga.gossip_combine(flat.reshape(M, C),
                             nbr.reshape(neighbors.shape[0], M, C),
                             weights, blk_rows=R,
                             interpret=_interp(backend))
    return out.reshape(-1)[:n].reshape(shape)


def compress_topk(M, k, *, backend=None):
    """Rank-preserving top-k ROW sparsification of node blocks: per
    (d, r) block the k rows with the largest squared row norms.
    M: (N, d, r) → (vals (N, k, r) in M.dtype, descending row-norm
    order; idx (N, k) int32).  The wire carries (vals, idx) — k·(r+1)
    entries instead of d·r."""
    return _compress_topk(M, k=int(k), backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("k", "backend"))
def _compress_topk(M, *, k, backend):
    if M.ndim != 3:
        raise ValueError(f"compress_topk wants node-batched (N, d, r) "
                         f"blocks, got shape {M.shape}")
    if not 1 <= k <= M.shape[1]:
        raise ValueError(f"compress_topk needs 1 <= k <= d, got k={k}, "
                         f"d={M.shape[1]}")
    if backend == "xla-ref":
        return _ref.ref_compress_topk(M, k)
    return _cp.compress_topk(M, k, interpret=_interp(backend))


def dequant(q, scale, *, backend=None):
    """Decode an int8 wire payload: q · scale per node block (f32
    accumulation on the kernel backends).  q: (N, d, r) int8;
    scale: (N, 1, 1) → (N, d, r) in scale.dtype."""
    return _dequant(q, scale, backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _dequant(q, scale, *, backend):
    if backend == "xla-ref":
        return _ref.ref_dequant(q, scale)
    return _cp.dequant(q, scale, interpret=_interp(backend))


def mix_nodes(Z, W, *, blk_c=512, backend=None):
    """Consensus combine Z ← W Z over the leading node axis for a dense
    precomputed mixer (e.g. W^{T_con}): the whole AGREE phase in one
    fused sweep.  Z: (L, ...); W: (L, L) → same shape AND dtype as Z
    (accumulation is f32)."""
    return _mix_nodes(Z, W, blk_c=blk_c, backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("blk_c", "backend"))
def _mix_nodes(Z, W, *, blk_c, backend):
    L = Z.shape[0]
    flat = Z.reshape(L, -1)
    if backend == "xla-ref":
        out = W.astype(jnp.float32) @ flat.astype(jnp.float32)
        return out.astype(Z.dtype).reshape(Z.shape)
    M = flat.shape[1]
    blk = min(blk_c, M)
    pad = (-M) % blk
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = _ga.mix_rows(W, flat, blk_c=blk, interpret=_interp(backend))
    return out[:, :M].reshape(Z.shape)
