"""jit'd public wrappers around the Pallas kernels.

Dispatch policy: compiled Pallas on TPU backends, interpret=True
elsewhere (this container is CPU-only — interpret mode executes the
kernel body in Python, validating the exact TPU code path numerically).
Wrappers also handle padding to block multiples and layout conversion
from the model's (B, S, H, D) convention to the kernels' (B, H, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import altgdmin_ls as _ls
from repro.kernels import gossip_axpy as _ga


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag):
    return (not _on_tpu()) if flag is None else flag


# ------------------------------------------------------------ attention

@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, blk_q=128,
                    blk_k=128, interpret=None):
    """Model layout: q (B,S,H,D); k,v (B,Skv,Hkv,D) → (B,S,H,D)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    qT = jnp.swapaxes(q, 1, 2)
    kT = jnp.swapaxes(k, 1, 2)
    vT = jnp.swapaxes(v, 1, 2)
    blk_q_ = min(blk_q, Sq)
    blk_k_ = min(blk_k, Skv)
    pq = (-Sq) % blk_q_
    pk = (-Skv) % blk_k_
    if pq:
        qT = jnp.pad(qT, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        # right-pad keys: padded slots sit above the causal diagonal of
        # every real query (offset uses REAL lengths), so causal masking
        # excludes them for free
        kT = jnp.pad(kT, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vT = jnp.pad(vT, ((0, 0), (0, 0), (0, pk), (0, 0)))
    o = _fa.flash_attention(qT, kT, vT, causal=causal, window=window,
                            scale=D ** -0.5, blk_q=blk_q_, blk_k=blk_k_,
                            offset=Skv - Sq,
                            interpret=_interpret(interpret))
    if pq:
        o = o[:, :, :Sq]
    return jnp.swapaxes(o, 1, 2)


# ------------------------------------------------------------ SSD

@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128, interpret=None):
    """Model layout: x (B,S,H,P); dt (B,S,H); Bm/Cm (B,S,N) →
    (y (B,S,H,P), h_final (B,H,P,N))."""
    B, S, H, P = x.shape
    chunk_ = min(chunk, S)
    pad = (-S) % chunk_
    xT = jnp.swapaxes(x, 1, 2)                       # (B,H,S,P)
    dtT = jnp.swapaxes(dt, 1, 2)                     # (B,H,S)
    if pad:
        xT = jnp.pad(xT, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtT = jnp.pad(dtT, ((0, 0), (0, 0), (0, pad)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = _ssd.ssd_scan(xT, dtT, A, Bm, Cm, D, chunk=chunk_,
                         interpret=_interpret(interpret))
    y = jnp.swapaxes(y[:, :, :S], 1, 2)
    return y, h


# ------------------------------------------------------------ MTRL LS

@functools.partial(jax.jit, static_argnames=("blk_d", "interpret"))
def altgdmin_minimize_B(X, U, y, *, blk_d=256, interpret=None):
    """b_t = (X_t U)† y_t via kernel Gram + tiny jnp Cholesky solve.
    X: (T,n,d); U: (d,r); y: (T,n) → B (T,r)."""
    d = X.shape[2]
    blk = min(blk_d, d)
    pad = (-d) % blk
    if pad:
        X = jnp.pad(X, ((0, 0), (0, 0), (0, pad)))
        U = jnp.pad(U, ((0, pad), (0, 0)))
    G, c = _ls.task_gram(X, U, y, blk_d=blk,
                         interpret=_interpret(interpret))
    return jax.vmap(lambda g, ci: jax.scipy.linalg.solve(
        g, ci, assume_a="pos"))(G, c)


@functools.partial(jax.jit, static_argnames=("blk_d", "interpret"))
def altgdmin_gradient(X, U, B, y, *, blk_d=256, interpret=None):
    """∇_U f = Σ_t X_tᵀ(X_t U b_t − y_t) b_tᵀ via the fused two-pass
    kernel. X: (T,n,d); U: (d,r); B: (T,r); y: (T,n) → (d,r)."""
    d = X.shape[2]
    blk = min(blk_d, d)
    pad = (-d) % blk
    Xp, Up = X, U
    if pad:
        Xp = jnp.pad(X, ((0, 0), (0, 0), (0, pad)))
        Up = jnp.pad(U, ((0, pad), (0, 0)))
    tiles = _ls.task_grad_tiles(Xp, Up, B, y, blk_d=blk,
                                interpret=_interpret(interpret))
    return jnp.sum(tiles, axis=0)[:d]


# ------------------------------------------------------------ gossip

@functools.partial(jax.jit, static_argnames=("w_self", "w_nbr",
                                             "interpret"))
def gossip_combine(z, neighbors, w_self, w_nbr, *, interpret=None):
    """Fused z ← w_self·z + w_nbr·Σ neighbors over arbitrary-shape z."""
    shape = z.shape
    flat = z.reshape(-1)
    n = flat.shape[0]
    C, R = 256, 8                 # lane width × row tile
    pad = (-n) % (C * R)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    nbr = neighbors.reshape(neighbors.shape[0], -1)
    if pad:
        nbr = jnp.pad(nbr, ((0, 0), (0, pad)))
    M = flat.shape[0] // C
    out = _ga.gossip_combine(flat.reshape(M, C),
                             nbr.reshape(neighbors.shape[0], M, C),
                             w_self, w_nbr, blk_rows=R,
                             interpret=_interpret(interpret))
    return out.reshape(-1)[:n].reshape(shape)
