# TPU Pallas kernels (pl.pallas_call + BlockSpec) for the compute hot spots,
# each with a jit'd wrapper in ops.py and a pure-jnp oracle in ref.py.
from repro.kernels import ops, ref
