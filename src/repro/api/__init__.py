# Declarative experiment API: build an ExperimentSpec, call
# run_experiment, get a Trace.  See spec.py for the schema, registry.py
# for the solver table, runner.py for materialization + substrate
# dispatch.
from repro.api.spec import (
    ExperimentSpec, ProblemSpec, TopologySpec, InitSpec, SolverSpec,
    EngineSpec, CommSpec, SystemSpec, GRAPH_FAMILIES, WEIGHT_SCHEMES,
    SUBSTRATES, AVAILABILITY_KINDS,
)
from repro.api.registry import (
    SOLVERS, SolverDef, register_solver, get_solver, solver_names,
)
from repro.api.runner import (
    Trace, Materialized, run_experiment, materialize, comm_time_axis,
    system_time_axis,
)
