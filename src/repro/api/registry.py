"""Solver registry — the AltGDmin-family algorithms behind ONE call
convention.

Every registered solver is now a :class:`~repro.core.program.
SolverProgram`: the registry derives its simulator, mesh, and
virtual-mesh entry points from the program's three lowerings
(:func:`~repro.core.program.lower_simulator` /
:func:`~repro.core.program.lower_mesh` /
:func:`~repro.core.program.lower_virtual_mesh`), and the call-convention
metadata a :class:`SolverDef` used to duplicate — which topology
materialization the solver consumes (``"W"``/``"adj"``/``"none"``),
whether it is decentralized, which
:class:`~repro.distributed.consensus.CombineRule` carries its
communication (the rule's :class:`CommSignature` prices the wall-clock
axis), and the extra SolverSpec knobs it takes — comes straight off the
program, so :func:`repro.api.runner.run_experiment` can drive any
registered solver identically on any substrate.  ``register_solver``
stays open for hand-built defs, but the normal path is
:func:`register_program_solver`: register the ~20-line program once and
all three substrates (plus the runner dispatch) follow.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import altgdmin as _alg
from repro.core.program import (SolverProgram, get_program,
                                lower_mesh, lower_simulator,
                                lower_virtual_mesh, program_names)
from repro.distributed.consensus import COMBINE_RULES, CommSignature, get_rule


@dataclasses.dataclass(frozen=True)
class SolverDef:
    """One registered algorithm.

    ``fn`` is the simulator entry point (the program's simulator
    lowering); ``call`` (below) adapts the uniform convention onto it.
    ``topology`` names what the solver consumes: ``"W"`` (mixing
    matrix), ``"adj"`` (float adjacency), ``"none"`` (fusion center).
    ``combine`` names the CombineRule that carries the solver's
    communication; its signature prices the wall-clock axis (gossip:
    T_con AGREE rounds/iter, neighbor: 1 exchange/iter, central: gather
    + broadcast/iter).  ``mesh_capable`` marks solvers with a shard_map
    runtime.  ``spec_kwargs`` lists extra SolverSpec fields the driver
    consumes (forwarded by the runner, e.g. ``local_steps`` for
    ``beyond_central``).  ``virtual_mesh_fn`` is the virtual-node mesh
    runtime (L = devices × block; the runner dispatches to it when L is
    a multiple of the device count).  ``program`` is the underlying
    :class:`~repro.core.program.SolverProgram` when the def was derived
    from one.
    """
    name: str
    fn: Callable
    topology: str = "W"             # "W" | "adj" | "none"
    combine: str = "gossip"         # CombineRule name (comm signature)
    decentralized: bool = True
    mesh_fn: Callable | None = None  # shard_map runtime, if one exists
    spec_kwargs: tuple = ()          # extra SolverSpec fields fn takes
    takes_avail: bool = False        # consumes a (T_GD, L) avail mask
    virtual_mesh_fn: Callable | None = None  # virtual-node mesh runtime
    program: SolverProgram | None = None     # source program, if derived

    @property
    def mesh_capable(self) -> bool:
        return self.mesh_fn is not None

    @property
    def dispatch_budget(self):
        """The program's statically-enforced per-iteration kernel
        budget (:class:`~repro.core.program.DispatchBudget`; rule JX001
        of ``tools/reprolint``), or None for hand-built defs."""
        return self.program.dispatch_budget if self.program else None

    @property
    def comm(self) -> str:
        """Legacy alias: the combine rule's pricing pattern."""
        return self.signature(1).pattern

    def signature(self, T_con: int, **params) -> CommSignature:
        """The solver's per-iteration communication signature.
        ``params`` optionally carries the payload context (problem dims
        ``d``/``r`` + the SolverSpec compression knobs) so compressed
        rules can report their actual wire format; base rules ignore
        it."""
        return get_rule(self.combine).signature(T_con, **params)

    def call(self, U0_nodes, Xg, yg, W, adj, *, eta: float, T_GD: int,
             T_con: int, U_star=None, engine=None,
             **extra) -> _alg.RunResult:
        """Uniform convention: stacked node-major inputs; the def routes
        the topology the solver needs and drops what it ignores.
        ``extra`` forwards the fields named in ``spec_kwargs``."""
        kw = dict(eta=eta, T_GD=T_GD, U_star=U_star, engine=engine, **extra)
        if self.topology == "none":
            U0 = U0_nodes if self.decentralized else U0_nodes[0]
            return self.fn(U0, Xg, yg, **kw)
        if self.topology == "adj":
            return self.fn(U0_nodes, Xg, yg, adj, **kw)
        return self.fn(U0_nodes, Xg, yg, W, T_con=T_con, **kw)


SOLVERS: dict[str, SolverDef] = {}


def register_solver(solver: SolverDef) -> SolverDef:
    if solver.name in SOLVERS:
        raise ValueError(f"solver {solver.name!r} already registered")
    if solver.topology not in ("W", "adj", "none"):
        raise ValueError(f"bad topology kind {solver.topology!r}")
    if solver.combine not in COMBINE_RULES:
        raise ValueError(f"unknown combine rule {solver.combine!r}; "
                         f"registered: {sorted(COMBINE_RULES)}")
    SOLVERS[solver.name] = solver
    return solver


def register_program_solver(name: str) -> SolverDef:
    """Derive and register a SolverDef from a registered
    :class:`~repro.core.program.SolverProgram`: all three substrate
    entry points come from the program's lowerings, and the call
    convention metadata from its fields."""
    p = get_program(name)
    if p.dispatch_budget is None:
        raise ValueError(
            f"program {p.name!r} has no dispatch_budget; every "
            f"registry-derived solver must declare its per-iteration "
            f"kernel budget (statically enforced by tools/reprolint)")
    return register_solver(SolverDef(
        name=p.name, fn=lower_simulator(p),
        topology=p.topology, combine=p.combine,
        decentralized=p.decentralized,
        mesh_fn=lower_mesh(p),
        spec_kwargs=p.spec_kwargs,
        takes_avail=p.takes_avail,
        virtual_mesh_fn=lower_virtual_mesh(p),
        program=p))


def get_solver(name: str) -> SolverDef:
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(f"unknown solver {name!r}; registered: "
                         f"{sorted(SOLVERS)}") from None


def solver_names() -> tuple[str, ...]:
    return tuple(sorted(SOLVERS))


# All 12 solvers — the paper's algorithms, the compressed-wire variants
# (stateful rules: error feedback / last-sent state rides the lowerings'
# aux scan carry), and the dropout-tolerant variants (the runner
# materializes the SystemSpec availability mask and forwards it as
# ``avail=`` on every substrate) — derive from their programs.
for _name in program_names():
    register_program_solver(_name)
del _name
