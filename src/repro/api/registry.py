"""Solver registry — the AltGDmin-family algorithms behind ONE call
convention.

The legacy drivers in :mod:`repro.core.altgdmin` have mutually
inconsistent signatures (W vs adjacency vs no topology argument; stacked
``U0_nodes`` vs a single ``U0``).  A :class:`SolverDef` records those
differences as data — which topology materialization the solver consumes
(``"W"``/``"adj"``/``"none"``), whether it is decentralized, and which
:class:`~repro.distributed.consensus.CombineRule` carries its
communication (the rule's :class:`CommSignature` prices the wall-clock
axis) — so :func:`repro.api.runner.run_experiment` can drive any
registered solver identically.  ``register_solver`` is open: the
combine-rule variants of Exact Subspace Diffusion and Beyond
Centralization plug in below without touching the runner.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import altgdmin as _alg
from repro.core import runtime as _runtime
from repro.distributed.consensus import COMBINE_RULES, CommSignature, get_rule


@dataclasses.dataclass(frozen=True)
class SolverDef:
    """One registered algorithm.

    ``fn`` is the legacy driver; ``call`` (below) adapts the uniform
    convention onto it.  ``topology`` names what the solver consumes:
    ``"W"`` (mixing matrix), ``"adj"`` (float adjacency), ``"none"``
    (fusion center).  ``combine`` names the CombineRule that carries the
    solver's communication; its signature prices the wall-clock axis
    (gossip: T_con AGREE rounds/iter, neighbor: 1 exchange/iter,
    central: gather + broadcast/iter).  ``mesh_capable`` marks solvers
    with a shard_map runtime.  ``spec_kwargs`` lists extra SolverSpec
    fields the driver consumes (forwarded by the runner, e.g.
    ``local_steps`` for ``beyond_central``).  ``virtual_mesh_fn`` is the
    virtual-node mesh runtime (L = devices × block; the runner
    dispatches to it when L is a multiple of the device count).
    """
    name: str
    fn: Callable
    topology: str = "W"             # "W" | "adj" | "none"
    combine: str = "gossip"         # CombineRule name (comm signature)
    decentralized: bool = True
    mesh_fn: Callable | None = None  # shard_map runtime, if one exists
    spec_kwargs: tuple = ()          # extra SolverSpec fields fn takes
    takes_avail: bool = False        # consumes a (T_GD, L) avail mask
    virtual_mesh_fn: Callable | None = None  # virtual-node mesh runtime

    @property
    def mesh_capable(self) -> bool:
        return self.mesh_fn is not None

    @property
    def comm(self) -> str:
        """Legacy alias: the combine rule's pricing pattern."""
        return self.signature(1).pattern

    def signature(self, T_con: int, **params) -> CommSignature:
        """The solver's per-iteration communication signature.
        ``params`` optionally carries the payload context (problem dims
        ``d``/``r`` + the SolverSpec compression knobs) so compressed
        rules can report their actual wire format; base rules ignore
        it."""
        return get_rule(self.combine).signature(T_con, **params)

    def call(self, U0_nodes, Xg, yg, W, adj, *, eta: float, T_GD: int,
             T_con: int, U_star=None, engine=None,
             **extra) -> _alg.RunResult:
        """Uniform convention: stacked node-major inputs; the def routes
        the topology the solver needs and drops what it ignores.
        ``extra`` forwards the fields named in ``spec_kwargs``."""
        kw = dict(eta=eta, T_GD=T_GD, U_star=U_star, engine=engine, **extra)
        if self.topology == "none":
            U0 = U0_nodes if self.decentralized else U0_nodes[0]
            return self.fn(U0, Xg, yg, **kw)
        if self.topology == "adj":
            return self.fn(U0_nodes, Xg, yg, adj, **kw)
        return self.fn(U0_nodes, Xg, yg, W, T_con=T_con, **kw)


SOLVERS: dict[str, SolverDef] = {}


def register_solver(solver: SolverDef) -> SolverDef:
    if solver.name in SOLVERS:
        raise ValueError(f"solver {solver.name!r} already registered")
    if solver.topology not in ("W", "adj", "none"):
        raise ValueError(f"bad topology kind {solver.topology!r}")
    if solver.combine not in COMBINE_RULES:
        raise ValueError(f"unknown combine rule {solver.combine!r}; "
                         f"registered: {sorted(COMBINE_RULES)}")
    SOLVERS[solver.name] = solver
    return solver


def get_solver(name: str) -> SolverDef:
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(f"unknown solver {name!r}; registered: "
                         f"{sorted(SOLVERS)}") from None


def solver_names() -> tuple[str, ...]:
    return tuple(sorted(SOLVERS))


register_solver(SolverDef(
    name="dif_altgdmin", fn=_alg.dif_altgdmin,
    topology="W", combine="gossip",
    mesh_fn=_runtime.dif_altgdmin_mesh,
    virtual_mesh_fn=_runtime.dif_altgdmin_virtual_mesh))

register_solver(SolverDef(
    name="dec_altgdmin", fn=_alg.dec_altgdmin,
    topology="W", combine="gossip",
    mesh_fn=_runtime.dec_altgdmin_mesh))

register_solver(SolverDef(
    name="centralized_altgdmin", fn=_alg.centralized_altgdmin,
    topology="none", combine="central", decentralized=False,
    mesh_fn=_runtime.centralized_altgdmin_mesh))

register_solver(SolverDef(
    name="dgd_altgdmin", fn=_alg.dgd_altgdmin,
    topology="adj", combine="neighbor",
    mesh_fn=_runtime.dgd_altgdmin_mesh))

register_solver(SolverDef(
    name="exact_diffusion", fn=_alg.exact_diffusion_altgdmin,
    topology="W", combine="exact_diffusion",
    mesh_fn=_runtime.exact_diffusion_mesh))

register_solver(SolverDef(
    name="beyond_central", fn=_alg.beyond_central_altgdmin,
    topology="W", combine="beyond_central",
    mesh_fn=_runtime.beyond_central_mesh,
    spec_kwargs=("local_steps",)))

# compressed-wire variants (stateful rules — error feedback / last-sent
# state rides the drivers' scan carries); their signatures report the
# compressed entries/bytes so the wall-clock axis prices the real payload
register_solver(SolverDef(
    name="dif_topk", fn=_alg.dif_topk_altgdmin,
    topology="W", combine="topk_gossip",
    mesh_fn=_runtime.dif_topk_mesh,
    spec_kwargs=("compression_k", "consensus_gamma")))

register_solver(SolverDef(
    name="dif_quantized", fn=_alg.dif_quantized_altgdmin,
    topology="W", combine="quantized_gossip",
    mesh_fn=_runtime.dif_quantized_mesh,
    spec_kwargs=("compression", "consensus_gamma")))

register_solver(SolverDef(
    name="dif_event", fn=_alg.dif_event_altgdmin,
    topology="W", combine="event_gossip",
    mesh_fn=_runtime.dif_event_mesh,
    spec_kwargs=("event_threshold", "consensus_gamma")))

# dropout-tolerant variants (system-realism layer): the runner
# materializes the experiment's SystemSpec availability mask — one
# (T_GD, L) fault schedule shared by the trajectory AND the simulated
# time axis — and forwards it as ``avail=`` on both substrates
register_solver(SolverDef(
    name="dif_partial", fn=_alg.dif_partial_altgdmin,
    topology="W", combine="partial_gossip",
    mesh_fn=_runtime.dif_partial_mesh, takes_avail=True))

register_solver(SolverDef(
    name="dif_stale", fn=_alg.dif_stale_altgdmin,
    topology="W", combine="stale_gossip",
    mesh_fn=_runtime.dif_stale_mesh, takes_avail=True))

register_solver(SolverDef(
    name="dif_pushsum", fn=_alg.dif_pushsum_altgdmin,
    topology="W", combine="push_sum_gossip",
    mesh_fn=_runtime.dif_pushsum_mesh, takes_avail=True))
