"""Declarative experiment specs — the single description of a Dec-MTRL run.

An :class:`ExperimentSpec` replaces the hand-wired six-step liturgy
(``generate_problem → node_view → graph/weights → spectral_init →
resolve_eta → algorithm(...)``) with one nested frozen dataclass that a
sweep driver can build, mutate with :func:`dataclasses.replace`, and
serialize losslessly to JSON (``to_dict``/``from_dict``).  Every field is
a plain int/float/str/tuple so a spec is hashable, diffable, and exactly
round-trippable — the property the benchmark harness relies on to key
result rows by spec.

The five sub-specs mirror the liturgy's stages:

  * :class:`ProblemSpec`  — the synthetic Dec-MTRL instance (paper Sec. II);
  * :class:`TopologySpec` — graph family + mixing-weight scheme (Sec. III);
  * :class:`InitSpec`     — Algorithm 2's spectral initialization;
  * :class:`SolverSpec`   — which algorithm, η (None = Theorem-1 auto),
                            T_GD and the solver's own T_con;
  * :class:`EngineSpec`   — kernel backend for the iteration engine;

plus ``substrate`` selecting the single-host simulator or the shard_map
mesh runtime, and :class:`CommSpec` for the emulated wall-clock axis.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

import numpy as np

from repro.distributed import graphs as _graphs
from repro.distributed import mixing as _mixing


GRAPH_FAMILIES = ("erdos_renyi", "ring", "path", "torus2d", "hypercube",
                  "complete", "star", "circulant", "barabasi_albert",
                  "hierarchical", "cluster_cliques")
WEIGHT_SCHEMES = ("metropolis", "equal_neighbor", "lazy", "circulant")
REPRESENTATIONS = ("auto", "dense", "sparse")
SUBSTRATES = ("simulator", "mesh")
COMM_MODELS = ("ethernet-1gbps", "tpu-ici")
AVAILABILITY_KINDS = ("always", "bernoulli", "markov")


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """The synthetic multi-task linear-regression instance (paper Sec. II)."""
    d: int = 100            # feature dimension
    T: int = 64             # tasks (L must divide T)
    r: int = 4              # subspace rank
    n: int = 30             # samples per task
    L: int = 8              # nodes
    kappa: float = 1.0      # condition number of Σ*
    noise_std: float = 0.0
    dtype: str = "float64"
    n_folds: int = 0        # >1 → Algorithm 3 sample splitting

    def __post_init__(self):
        if self.T % self.L:
            raise ValueError(f"L must divide T, got T={self.T}, L={self.L}")


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Graph family + mixing-weight scheme.

    ``family`` fields are union-style: ``p``/``seed`` apply to
    ``erdos_renyi``, ``rows``/``cols`` to ``torus2d``, ``dim`` to
    ``hypercube``; the rest need only L (taken from the problem).
    ``weights="circulant"`` is the mesh-native scheme (each shift = one
    collective-permute, uniform weights shared by every device); the
    other schemes run on the mesh too — the consensus layer decomposes
    their W into per-shift, per-device weights (one permute per distinct
    cyclic shift of the sparsity pattern).

    The scale families: ``barabasi_albert`` (``ba_m`` attachments per
    new node), ``hierarchical`` (``branching``-ary tree), and
    ``cluster_cliques`` (pods of ``clique`` nodes on a bridge ring) are
    sparse-born — no (L, L) allocation at any size.  ``representation``
    picks the mixing-matrix lowering: ``"auto"`` (default) takes the
    sparse path above the consensus layer's node-count/density cutoff,
    ``"sparse"``/``"dense"`` force it (the parity tests force both on
    the same small graph).
    """
    family: str = "erdos_renyi"
    p: float = 0.5
    seed: int = 0
    rows: int = 0
    cols: int = 0
    dim: int = 0
    ba_m: int = 2                          # barabasi_albert attachments
    branching: int = 4                     # hierarchical tree arity
    clique: int = 8                        # cluster_cliques pod size
    weights: str = "metropolis"
    beta: float = 0.5                      # lazy weights
    shifts: tuple = (-1, 1)                # circulant weights
    self_weight: Optional[float] = None    # circulant weights
    representation: str = "auto"

    def __post_init__(self):
        if self.family not in GRAPH_FAMILIES:
            raise ValueError(f"unknown graph family {self.family!r}; "
                             f"expected one of {GRAPH_FAMILIES}")
        if self.weights not in WEIGHT_SCHEMES:
            raise ValueError(f"unknown weight scheme {self.weights!r}; "
                             f"expected one of {WEIGHT_SCHEMES}")
        if self.representation not in REPRESENTATIONS:
            raise ValueError(f"unknown representation "
                             f"{self.representation!r}; expected one of "
                             f"{REPRESENTATIONS}")
        # JSON round-trips tuples as lists; normalize back.
        object.__setattr__(self, "shifts", tuple(self.shifts))
        # Circulant weights gossip over the circulant graph of `shifts`;
        # reject family/weights combinations that would make the stored
        # graph and the mixing matrix describe different topologies.
        if self.weights == "circulant":
            if self.family == "ring" and set(self.shifts) != {-1, 1}:
                raise ValueError(
                    f"family='ring' is the circulant graph of shifts "
                    f"(-1, 1); got shifts={self.shifts} — use "
                    f"family='circulant'")
            if self.family not in ("ring", "circulant"):
                raise ValueError(
                    f"weights='circulant' mixes over the circulant graph "
                    f"of its shifts; family={self.family!r} would "
                    f"disagree — use family='ring' or 'circulant'")

    def build_graph(self, L: int) -> _graphs.Graph:
        if self.family == "erdos_renyi":
            return _graphs.erdos_renyi(L, self.p, seed=self.seed)
        if self.family == "ring":
            return _graphs.ring(L)
        if self.family == "path":
            return _graphs.path_graph(L)
        if self.family == "torus2d":
            if self.rows * self.cols != L:
                raise ValueError(f"torus2d rows*cols={self.rows * self.cols} "
                                 f"!= L={L}")
            return _graphs.torus2d(self.rows, self.cols)
        if self.family == "hypercube":
            if (1 << self.dim) != L:
                raise ValueError(f"hypercube 2^dim={1 << self.dim} != L={L}")
            return _graphs.hypercube(self.dim)
        if self.family == "complete":
            return _graphs.complete(L)
        if self.family == "circulant":
            return _graphs.circulant(L, self.shifts)
        if self.family == "barabasi_albert":
            return _graphs.barabasi_albert(L, m=self.ba_m, seed=self.seed)
        if self.family == "hierarchical":
            return _graphs.hierarchical(L, branching=self.branching)
        if self.family == "cluster_cliques":
            return _graphs.cluster_of_cliques(L, clique=self.clique,
                                              seed=self.seed)
        return _graphs.star(L)

    def use_sparse(self, L: int, graph=None) -> bool:
        """Whether this topology takes the sparse consensus lowering:
        forced by ``representation``, or (auto) the consensus layer's
        node-count/density cutoff."""
        from repro.distributed.consensus import (SPARSE_DENSITY_THRESHOLD,
                                                 SPARSE_MIN_NODES)
        if self.representation != "auto":
            return self.representation == "sparse"
        g = graph if graph is not None else self.build_graph(L)
        return L >= SPARSE_MIN_NODES and g.density <= SPARSE_DENSITY_THRESHOLD

    def build_weights(self, L: int,
                      graph: _graphs.Graph | None = None) -> np.ndarray:
        """The dense (L, L) mixing matrix W for the AGREE protocol."""
        if self.weights == "circulant":
            return _mixing.circulant_weights(L, self.shifts, self.self_weight)
        g = graph if graph is not None else self.build_graph(L)
        if isinstance(g, _graphs.SparseGraph):
            g = g.to_dense()  # reprolint: allow=RL002 — dense-weights branch; to_dense raises above DENSE_MATERIALIZE_MAX
        if self.weights == "metropolis":
            return _mixing.metropolis_weights(g)
        if self.weights == "equal_neighbor":
            return _mixing.equal_neighbor_weights(g)
        return _mixing.lazy_weights(g, self.beta)

    def build_sparse_weights(self, L: int, graph=None
                             ) -> _mixing.SparseWeights:
        """The same mixing matrix in :class:`SparseWeights` form — the
        O(E) path, never allocating (L, L)."""
        if self.weights == "circulant":
            return _mixing.circulant_weights_sparse(L, self.shifts,
                                                    self.self_weight)
        g = graph if graph is not None else self.build_graph(L)
        if self.weights == "metropolis":
            return _mixing.metropolis_weights_sparse(g)
        if self.weights == "equal_neighbor":
            return _mixing.equal_neighbor_weights_sparse(g)
        return _mixing.lazy_weights_sparse(g, self.beta)


@dataclasses.dataclass(frozen=True)
class InitSpec:
    """Algorithm 2 — decentralized truncated spectral initialization."""
    T_pm: int = 30          # power-method iterations
    T_con: int = 10         # AGREE rounds inside the init
    broadcast: bool = True  # paper lines 14-15 (node-0 basis broadcast)


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Which algorithm, with its step size and iteration budget.

    ``eta=None`` resolves via Theorem 1's η = c_η/(n σ*max²), estimating
    σ*max from the spectral init's R diagonal (the paper's recipe).
    The tail fields are consumed only by solvers that declare them in
    their registry ``spec_kwargs`` (a non-default value on any other
    solver is rejected at run time):

      * ``local_steps``      — ``beyond_central``: local adapt steps per
        single gossip round;
      * ``compression``      — ``dif_quantized``: wire format, one of
        ``"bf16"`` (None → default) / ``"int8"`` / ``"int8_stochastic"``;
      * ``compression_k``    — ``dif_topk``: rows kept per gossip round
        (0 → d/4);
      * ``event_threshold``  — ``dif_event``: relative-change trigger θ
        (0 → always send, i.e. dense gossip);
      * ``consensus_gamma``  — compressed rules: the CHOCO consensus
        step size γ ∈ (0, 1] relaxing each round toward the combined
        value, ``Z ← Z + γ(combine(Z) − Z)`` — γ < 1 keeps ``dif_topk``
        stable at aggressive compression (k ≪ d/4); γ = 1 is the
        historical full step (bit-identical to pre-γ trajectories).
    """
    name: str = "dif_altgdmin"
    T_GD: int = 250
    T_con: int = 10
    eta: Optional[float] = None
    c_eta: float = 0.4
    local_steps: int = 1
    compression: Optional[str] = None
    compression_k: int = 0
    event_threshold: float = 0.0
    consensus_gamma: float = 1.0

    def __post_init__(self):
        if self.local_steps < 1:
            raise ValueError(f"local_steps must be >= 1, got "
                             f"{self.local_steps}")
        if self.compression_k < 0:
            raise ValueError(f"compression_k must be >= 0 (0 = the rule's "
                             f"d/4 default), got {self.compression_k}")
        if self.event_threshold < 0:
            raise ValueError(f"event_threshold must be >= 0, got "
                             f"{self.event_threshold}")
        if not 0.0 < self.consensus_gamma <= 1.0:
            raise ValueError(f"consensus_gamma must be in (0, 1] (1 = the "
                             f"full CHOCO step), got {self.consensus_gamma}")


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Iteration-engine backend (see :mod:`repro.core.engine`);
    ``backend=None`` → env/auto selection (xla-ref off-TPU)."""
    backend: Optional[str] = None
    blk_d: int = 256


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Network model for the emulated wall-clock axis (paper Sec. V)."""
    model: str = "ethernet-1gbps"
    compute_s_per_iter: float = 1e-3
    seed: int = 0

    def __post_init__(self):
        if self.model not in COMM_MODELS:
            raise ValueError(f"unknown comm model {self.model!r}; "
                             f"expected one of {COMM_MODELS}")

    def rng(self) -> np.random.Generator:
        """The ONE seeded generator every priced or simulated time axis
        draws its jitter from — two runs of the same spec produce
        identical axes."""
        return np.random.default_rng(self.seed)


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """Fault-injection and simulated-time model — the system-realism
    layer.  When an :class:`ExperimentSpec` carries one, the runner (a)
    samples a per-iteration node availability mask from the seeded
    process below (consumed by the dropout-tolerant ``dif_partial`` /
    ``dif_stale`` / ``dif_pushsum`` solvers — all ``T_con`` gossip
    rounds of one outer iteration share the iteration's mask), and (b)
    REPLACES the closed-form comm-model pricing with the event-driven
    clock of :mod:`repro.core.system_clock`, so ``Trace.time_axis``
    becomes measured simulated seconds.

    Availability process (``availability``):

      * ``"always"``    — every node live every iteration (the
        degenerate anchor: trajectories must match dense gossip
        bit-for-bit);
      * ``"bernoulli"`` — node g is live at iteration τ iid with
        probability ``p_on``;
      * ``"markov"``    — 2-state on/off chain per node (start on):
        P(on→off) = ``p_drop``, P(off→on) = ``p_return``.

    Heterogeneous compute: per-node speed multipliers drawn once from
    U[1, 1+``speed_spread``], plus a straggler tail — each (iteration,
    node) compute independently slows by ``straggler_factor`` with
    probability ``straggler_prob``.  ``latency_s``/``jitter_std_s``
    override the CommSpec network model's link distribution when set
    (``None`` keeps the model's own).  All draws derive from ``seed``
    (masks and speeds) or the CommSpec seed (clock jitter), so the layer
    is reproducible from the spec alone.
    """
    availability: str = "always"
    p_on: float = 1.0
    p_drop: float = 0.0
    p_return: float = 1.0
    speed_spread: float = 0.0
    straggler_prob: float = 0.0
    straggler_factor: float = 1.0
    latency_s: Optional[float] = None
    jitter_std_s: Optional[float] = None
    seed: int = 0

    def __post_init__(self):
        if self.availability not in AVAILABILITY_KINDS:
            raise ValueError(f"unknown availability kind "
                             f"{self.availability!r}; expected one of "
                             f"{AVAILABILITY_KINDS}")
        for field in ("p_on", "p_drop", "p_return", "straggler_prob"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field} must be a probability in "
                                 f"[0, 1], got {v}")
        if self.speed_spread < 0:
            raise ValueError(f"speed_spread must be >= 0, got "
                             f"{self.speed_spread}")
        if self.straggler_factor < 1:
            raise ValueError(f"straggler_factor multiplies compute time "
                             f"and must be >= 1, got "
                             f"{self.straggler_factor}")
        for field in ("latency_s", "jitter_std_s"):
            v = getattr(self, field)
            if v is not None and v < 0:
                raise ValueError(f"{field} must be >= 0 (or None for the "
                                 f"comm model's own), got {v}")

    @property
    def is_always_on(self) -> bool:
        """True when the availability process can never drop a node —
        the regime every solver (not just the dropout-tolerant three)
        may run under."""
        return (self.availability == "always"
                or (self.availability == "bernoulli" and self.p_on == 1.0)
                or (self.availability == "markov" and self.p_drop == 0.0))

    def availability_mask(self, T_GD: int, L: int) -> np.ndarray:
        """The seeded (T_GD, L) bool mask — True = node live.  Host
        numpy, generated ONCE by the runner and fed identically to the
        simulator scan and the mesh runtime (substrate determinism)."""
        if self.is_always_on:
            return np.ones((T_GD, L), dtype=bool)
        rng = np.random.default_rng([self.seed, 0])
        if self.availability == "bernoulli":
            return rng.random((T_GD, L)) < self.p_on
        mask = np.empty((T_GD, L), dtype=bool)
        state = np.ones(L, dtype=bool)              # markov: start on
        for t in range(T_GD):
            u = rng.random(L)
            state = np.where(state, u >= self.p_drop, u < self.p_return)
            mask[t] = state
        return mask

    def node_speeds(self, L: int) -> np.ndarray:
        """Per-node compute-time multipliers in [1, 1+speed_spread]."""
        if self.speed_spread == 0:
            return np.ones(L)
        rng = np.random.default_rng([self.seed, 1])
        return 1.0 + self.speed_spread * rng.random(L)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One fully-specified Dec-MTRL experiment cell."""
    problem: ProblemSpec = ProblemSpec()
    topology: TopologySpec = TopologySpec()
    init: InitSpec = InitSpec()
    solver: SolverSpec = SolverSpec()
    engine: EngineSpec = EngineSpec()
    comm: CommSpec = CommSpec()
    system: Optional[SystemSpec] = None
    substrate: str = "simulator"
    name: str = ""

    def __post_init__(self):
        if self.substrate not in SUBSTRATES:
            raise ValueError(f"unknown substrate {self.substrate!r}; "
                             f"expected one of {SUBSTRATES}")

    # ------------------------------------------------- JSON round-trip

    def to_dict(self) -> dict:
        """Plain-JSON-types dict (tuples become lists)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: dict) -> "ExperimentSpec":
        return _from_dict(cls, data)

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))


def _from_dict(cls, data):
    """Reconstruct a (nested) spec dataclass, rejecting unknown keys so a
    mistyped sweep field fails loudly instead of silently defaulting."""
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = set(data) - set(fields)
    if unknown:
        raise ValueError(f"{cls.__name__}: unknown field(s) {sorted(unknown)}")
    kwargs = {}
    for key, value in data.items():
        sub = _SUBSPEC_TYPES.get((cls, key))
        # optional sub-specs (system) round-trip None as None
        kwargs[key] = (_from_dict(sub, value)
                       if sub is not None and value is not None else value)
    return cls(**kwargs)


_SUBSPEC_TYPES = {
    (ExperimentSpec, "problem"): ProblemSpec,
    (ExperimentSpec, "topology"): TopologySpec,
    (ExperimentSpec, "init"): InitSpec,
    (ExperimentSpec, "solver"): SolverSpec,
    (ExperimentSpec, "engine"): EngineSpec,
    (ExperimentSpec, "comm"): CommSpec,
    (ExperimentSpec, "system"): SystemSpec,
}
