"""``run_experiment(spec, key) -> Trace`` — the one entry point.

Materializes the spec (problem → node view → graph/weights → spectral
init → η), dispatches to the registered solver on the chosen substrate,
and returns a :class:`Trace` carrying the per-iteration metrics, the
final iterates, the resolved η, and the comm-model wall-clock axis so
figure code stops recomputing it.

Substrates:

  * ``"simulator"`` — the single-host node-batched simulator
    (:mod:`repro.core.altgdmin`), any topology/solver;
  * ``"mesh"``      — the shard_map runtime (one node per device,
    AGREE = collective-permute gossip).  Requires a mesh-capable solver
    and L = available devices; ANY weight scheme runs — circulant
    weights lower to the native uniform ring form, and every other
    scheme (metropolis/equal_neighbor/lazy on arbitrary graphs) is
    decomposed into per-shift, per-device weights by the consensus
    layer.  The min-B and gradient phases route through the same
    :class:`AltgdminEngine` backend as the simulator, so
    ``pallas``/``pallas-interpret`` reach hardware nodes.

Determinism: the problem and init keys are derived from the caller's
``key`` by ``fold_in``, so two specs that share problem/topology/init
sub-specs (e.g. the four solvers of one figure cell) see identical data,
graphs, and starting bases.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import SolverDef, get_solver
from repro.api.spec import ExperimentSpec, SystemSpec
from repro.core import comm_model as _cm
from repro.core import system_clock as _sysclock
from repro.core.altgdmin import RunResult, resolve_eta
from repro.core.problem import (MTRLProblem, generate_problem, node_view,
                                split_samples)
from repro.core.spectral import SpectralInit, decentralized_spectral_init
from repro.distributed import consensus as _consensus
from repro.distributed.graphs import Graph, SparseGraph
from repro.utils.compat import make_mesh


_COMM_MODELS = {"ethernet-1gbps": _cm.ETHERNET_1GBPS,
                "tpu-ici": _cm.TPU_ICI}


@dataclasses.dataclass(frozen=True)
class Materialized:
    """The spec's liturgy, executed: everything a solver call needs.

    On the sparse representation (``TopologySpec.use_sparse``) ``W`` is
    a :class:`~repro.distributed.mixing.SparseWeights` and ``adj`` the
    :class:`~repro.distributed.graphs.SparseGraph` itself — nothing
    (L, L)-shaped is ever materialized; the consensus layer lowers both
    to padded segment-sum rounds."""
    problem: MTRLProblem
    Xg: jax.Array
    yg: jax.Array
    graph: Graph | SparseGraph
    W: jax.Array                 # or SparseWeights (sparse representation)
    adj: jax.Array               # or SparseGraph  (sparse representation)
    init: SpectralInit
    eta: float


@dataclasses.dataclass(frozen=True)
class Trace:
    """Result of one experiment run.

    ``sd_max``/``sd_mean``/``spread`` are per-iteration (length T_GD);
    ``time_axis`` is the cumulative emulated wall-clock under the spec's
    comm model, priced by the solver's communication pattern (gossip /
    neighbor / central) — the x-axis of the paper's Fig. 1 right panes.
    ``time_axis_source`` records how it was priced: ``"closed_form"``
    (the comm-model formula) or ``"simulated"`` (the event-driven
    system clock, whenever the spec carries a SystemSpec).
    """
    spec: ExperimentSpec
    U_nodes: jax.Array
    B_nodes: jax.Array
    sd_max: np.ndarray
    sd_mean: np.ndarray
    spread: np.ndarray
    eta: float
    time_axis: np.ndarray
    materialized: Materialized
    time_axis_source: str = "closed_form"

    @property
    def final_sd_max(self) -> float:
        return float(self.sd_max[-1])


def _as_key(key: Union[jax.Array, int, None]) -> jax.Array:
    if key is None:
        return jax.random.PRNGKey(0)
    if isinstance(key, int):
        return jax.random.PRNGKey(key)
    return key


def materialize(spec: ExperimentSpec, key=None) -> Materialized:
    """Run the setup liturgy for a spec: generate the problem, build the
    topology, run the spectral init, resolve η."""
    key = _as_key(key)
    p = spec.problem
    dtype = jnp.dtype(p.dtype)
    prob = generate_problem(jax.random.fold_in(key, 0), d=p.d, T=p.T, r=p.r,
                            n=p.n, L=p.L, kappa=p.kappa,
                            noise_std=p.noise_std, dtype=dtype)
    # the init sees the full unsplit data (Algorithm 2 precedes the
    # fold partition of Algorithm 3 line 4)
    Xg_init, yg_init = node_view(prob)
    if p.n_folds > 1:
        prob = split_samples(prob, p.n_folds)
    Xg, yg = node_view(prob)
    graph = spec.topology.build_graph(p.L)
    if spec.topology.use_sparse(p.L, graph):
        sg = graph if isinstance(graph, SparseGraph) else graph.to_sparse()
        graph = sg
        W = spec.topology.build_sparse_weights(p.L, sg)
        adj = sg
    else:
        W = jnp.asarray(spec.topology.build_weights(p.L, graph), dtype)
        adj = jnp.asarray(graph.adj, dtype)  # reprolint: allow=RL002 — dense branch: use_sparse() declined, L below the sparse tier
    init = decentralized_spectral_init(
        jax.random.fold_in(key, 1), Xg_init, yg_init, W, kappa=prob.kappa,
        mu=prob.mu, r=p.r, T_pm=spec.init.T_pm, T_con=spec.init.T_con,
        broadcast=spec.init.broadcast)
    eta = _resolve_spec_eta(spec, init)
    return Materialized(problem=prob, Xg=Xg, yg=yg, graph=graph, W=W,
                        adj=adj, init=init, eta=eta)


def _resolve_spec_eta(spec: ExperimentSpec, init) -> float:
    return resolve_eta(spec.solver.eta, spec.problem.n, R_diag=init.R_diag,
                       L=spec.problem.L, c_eta=spec.solver.c_eta)


def comm_time_axis(spec: ExperimentSpec, solver: SolverDef,
                   graph: Graph) -> np.ndarray:
    """Cumulative emulated wall-clock per outer iteration, priced from
    the solver's CombineRule comm signature under the spec's network
    model (one d×r exchange per neighbour per round).  Solvers that
    consume ``local_steps`` (beyond_central) pay that many compute
    units per outer iteration — the comm savings are not free local
    work."""
    p, c = spec.problem, spec.comm
    compute = c.compute_s_per_iter
    if "local_steps" in solver.spec_kwargs:
        compute *= spec.solver.local_steps
    # payload context: compressed rules fill entries_per_round /
    # bytes_per_entry from these, base rules ignore them
    sig = solver.signature(spec.solver.T_con, d=p.d, r=p.r,
                           compression=spec.solver.compression,
                           compression_k=spec.solver.compression_k,
                           event_threshold=spec.solver.event_threshold)
    return _cm.time_axis_from_signature(
        sig, spec.solver.T_GD, p.d, p.r,
        p.L, graph.max_degree, compute,
        model=_COMM_MODELS[c.model], rng=c.rng())


def _system_model(spec: ExperimentSpec) -> _cm.NetworkModel:
    """The comm model with the SystemSpec's link overrides applied."""
    model = _COMM_MODELS[spec.comm.model]
    s = spec.system
    if s is not None and (s.latency_s is not None
                         or s.jitter_std_s is not None):
        model = dataclasses.replace(
            model,
            latency_s=(model.latency_s if s.latency_s is None
                       else s.latency_s),
            jitter_std_s=(model.jitter_std_s if s.jitter_std_s is None
                          else s.jitter_std_s))
    return model


def system_time_axis(spec: ExperimentSpec, solver: SolverDef, graph: Graph,
                     avail: np.ndarray | None = None,
                     send_frac: np.ndarray | None = None) -> np.ndarray:
    """Simulated wall-clock axis under the spec's :class:`SystemSpec` —
    the event-driven clock of :mod:`repro.core.system_clock` replacing
    the closed-form pricing.  ``avail`` reuses a mask the solver run
    already materialized (one fault schedule for trajectory AND time);
    ``send_frac`` feeds the event rule's measured per-iteration trigger
    rate into the wire pricing.  Non-gossip patterns (central / no
    communication) keep the closed-form axis under the overridden link
    model: the clock simulates neighbour gossip only."""
    p, c, s = spec.problem, spec.comm, spec.system
    T_GD = spec.solver.T_GD
    compute = c.compute_s_per_iter
    if "local_steps" in solver.spec_kwargs:
        compute *= spec.solver.local_steps
    sig = solver.signature(spec.solver.T_con, d=p.d, r=p.r,
                           compression=spec.solver.compression,
                           compression_k=spec.solver.compression_k,
                           event_threshold=spec.solver.event_threshold)
    model = _system_model(spec)
    if sig.pattern in ("central", "none") or sig.rounds_per_iter == 0:
        return _cm.time_axis_from_signature(
            sig, T_GD, p.d, p.r, p.L, graph.max_degree, compute,
            model=model, rng=c.rng())
    if avail is None:
        avail = (s.availability_mask(T_GD, p.L) if solver.takes_avail
                 else np.ones((T_GD, p.L), bool))
    entries = sig.entries_per_round
    return _sysclock.simulated_time_axis(
        avail=avail, rounds_per_iter=sig.rounds_per_iter,
        neighbors=graph.neighbor_lists(), model=model,
        compute_s_per_iter=compute, speeds=s.node_speeds(p.L),
        straggler_prob=s.straggler_prob,
        straggler_factor=s.straggler_factor,
        n_entries=p.d * p.r if entries is None else entries,
        bytes_per_entry=sig.bytes_per_entry,
        rng=np.random.default_rng([c.seed, s.seed]),
        send_fraction=send_frac)


def run_experiment(spec: ExperimentSpec, key=None, *, engine=None,
                   materialized: Materialized | None = None,
                   checkpoint_every: int | None = None,
                   checkpoint_dir: str | None = None) -> Trace:
    """Materialize ``spec`` and run it end to end.

    ``engine`` optionally injects a pre-built :class:`AltgdminEngine`
    (must agree with ``spec.engine.backend`` if both are given);
    otherwise one is constructed from the spec.

    ``materialized`` optionally reuses an earlier :func:`materialize`
    result — the sweep-driver path, where the four solvers of one figure
    cell share problem/topology/init and should not pay the setup (data
    generation + T_pm power iterations) four times.  The caller must
    pass a materialization of a spec sharing this spec's problem /
    topology / init sub-specs and key; η is re-resolved from this spec's
    SolverSpec either way.

    ``checkpoint_every`` (with ``checkpoint_dir``) publishes U snapshots
    for the serving subsystem: the spectral init at step 0, then the
    node bases every that-many outer iterations (and at T_GD), each a
    crash-safe checkpoint via
    :func:`repro.serving.publisher.publish_representation`.  The run is
    executed in segments of that length with the U iterate chained
    through, so a server can hot-swap to fresher U's while the solver
    keeps refining (the drifting-U continual mode).  Solvers whose scan
    carry is just U (dif/dec/dgd/centralized, partial/pushsum) produce
    BIT-IDENTICAL trajectories to the unsegmented run (pinned in
    tests/test_serving.py); solvers carrying auxiliary state
    (exact_diffusion's ψ, the compressed rules' public copies,
    stale_gossip's queue) re-anchor that state at segment boundaries.
    Simulator substrate only; incompatible with ``n_folds > 1`` (the
    fold schedule restarts per segment).
    """
    from repro.core.engine import resolve_engine
    solver = get_solver(spec.solver.name)
    # spec-only validation runs BEFORE the expensive materialization so
    # an invalid sweep cell fails without paying the setup liturgy: a
    # non-default solver knob on a solver that ignores it must raise
    # instead of silently running without it
    for field, default in (("local_steps", 1), ("compression", None),
                           ("compression_k", 0), ("event_threshold", 0.0),
                           ("consensus_gamma", 1.0)):
        value = getattr(spec.solver, field)
        if value != default and field not in solver.spec_kwargs:
            raise ValueError(
                f"solver {solver.name!r} does not consume {field} "
                f"(got {field}={value}); only solvers declaring it in "
                f"spec_kwargs honor the field")
    # availability: the SystemSpec's fault schedule feeds the
    # dropout-tolerant solvers; a faulty schedule on a solver with no
    # notion of dropped nodes must raise, not silently run fault-free
    if (spec.system is not None and not spec.system.is_always_on
            and not solver.takes_avail):
        raise ValueError(
            f"spec.system schedules node dropout but solver "
            f"{solver.name!r} cannot consume an availability mask; use "
            f"one of the dropout-tolerant solvers (dif_partial / "
            f"dif_stale / dif_pushsum)")
    avail_np = None
    if solver.takes_avail:
        sys_spec = spec.system if spec.system is not None else SystemSpec()
        avail_np = sys_spec.availability_mask(spec.solver.T_GD,
                                              spec.problem.L)
    if checkpoint_every is not None:
        if checkpoint_dir is None:
            raise ValueError("checkpoint_every needs checkpoint_dir")
        if checkpoint_every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got "
                             f"{checkpoint_every}")
        if spec.substrate == "mesh":
            raise ValueError("checkpoint publishing runs on "
                             "substrate='simulator' only")
        if spec.problem.n_folds > 1:
            raise ValueError("checkpoint_every segments the run, which "
                             "would restart the n_folds sample-split "
                             "schedule; use n_folds <= 1")
    mat = materialize(spec, key) if materialized is None else materialized
    eta = _resolve_spec_eta(spec, mat.init)
    eng = resolve_engine(engine, spec.engine.backend,
                         blk_d=spec.engine.blk_d)
    if spec.substrate == "mesh":
        result = _run_mesh(spec, solver, mat, eng, eta, avail=avail_np)
    elif checkpoint_every is not None:
        result = _run_segmented(spec, solver, mat, eng, eta,
                                avail=avail_np, every=checkpoint_every,
                                directory=checkpoint_dir)
    else:
        extra = {k: getattr(spec.solver, k) for k in solver.spec_kwargs}
        if avail_np is not None:
            extra["avail"] = jnp.asarray(avail_np)
        # reprolint: allow=RL002 — Materialized.adj field: SparseGraph on the sparse path, dense only below the use_sparse gate
        result = solver.call(mat.init.U0, mat.Xg, mat.yg, mat.W, mat.adj,
                             eta=eta, T_GD=spec.solver.T_GD,
                             T_con=spec.solver.T_con,
                             U_star=mat.problem.U_star, engine=eng,
                             **extra)
    if spec.system is not None:
        sf = getattr(result, "send_frac", None)
        time_axis = system_time_axis(
            spec, solver, mat.graph, avail=avail_np,
            send_frac=None if sf is None else np.asarray(sf))
        source = "simulated"
    else:
        time_axis = comm_time_axis(spec, solver, mat.graph)
        source = "closed_form"
    return Trace(spec=spec, U_nodes=result.U_nodes, B_nodes=result.B_nodes,
                 sd_max=np.asarray(result.sd_max),
                 sd_mean=np.asarray(result.sd_mean),
                 spread=np.asarray(result.spread), eta=result.eta,
                 time_axis=time_axis, materialized=mat,
                 time_axis_source=source)


def _run_segmented(spec: ExperimentSpec, solver: SolverDef,
                   mat: Materialized, eng, eta: float, *,
                   avail: np.ndarray | None, every: int,
                   directory: str) -> RunResult:
    """The checkpoint-publishing driver: run the solver in segments of
    ``every`` iterations, chaining the U iterate and publishing a
    serving checkpoint after each segment (plus the step-0 init).  The
    availability schedule is sliced per segment so the fault sequence
    matches the unsegmented run row for row."""
    from repro.serving.publisher import publish_representation
    T_GD = spec.solver.T_GD
    extra = {k: getattr(spec.solver, k) for k in solver.spec_kwargs}
    publish_representation(directory, 0, mat.init.U0)
    U_cur = mat.init.U0
    chunks = []
    done = 0
    while done < T_GD:
        seg = min(every, T_GD - done)
        kw = dict(extra)
        if avail is not None:
            kw["avail"] = jnp.asarray(avail[done:done + seg])
        # reprolint: allow=RL002 — Materialized.adj field: SparseGraph on the sparse path, dense only below the use_sparse gate
        res = solver.call(U_cur, mat.Xg, mat.yg, mat.W, mat.adj, eta=eta,
                          T_GD=seg, T_con=spec.solver.T_con,
                          U_star=mat.problem.U_star, engine=eng, **kw)
        done += seg
        publish_representation(directory, done, res.U_nodes)
        chunks.append(res)
        U_cur = res.U_nodes
    def cat(name):
        return jnp.concatenate([getattr(c, name) for c in chunks])

    sfs = [c.send_frac for c in chunks]
    return RunResult(chunks[-1].U_nodes, chunks[-1].B_nodes,
                     cat("sd_max"), cat("sd_mean"), cat("spread"), eta,
                     send_frac=(jnp.concatenate(sfs)
                                if all(s is not None for s in sfs)
                                else None))


def _run_mesh(spec: ExperimentSpec, solver: SolverDef, mat: Materialized,
              eng, eta: float, avail: np.ndarray | None = None) -> RunResult:
    topo, p = spec.topology, spec.problem
    if not solver.mesh_capable:
        raise ValueError(f"solver {solver.name!r} has no mesh runtime; "
                         f"use substrate='simulator'")
    if p.n_folds > 1:
        raise ValueError("substrate='mesh' does not support sample "
                         "splitting (n_folds > 1)")
    n_dev = jax.device_count()
    if p.L != n_dev:
        if (solver.virtual_mesh_fn is not None and n_dev >= 1
                and p.L % n_dev == 0):
            return _run_virtual_mesh(spec, solver, mat, eng, eta, n_dev,
                                     avail=avail)
        raise ValueError(f"substrate='mesh' needs one device per node: "
                         f"L={p.L} but {n_dev} devices are available "
                         f"(the virtual-node tier needs a solver with a "
                         f"virtual mesh runtime and n_dev | L)")
    mesh = make_mesh((p.L,), ("nodes",))
    kw = {k: getattr(spec.solver, k) for k in solver.spec_kwargs}
    if avail is not None:
        kw.update(avail=jnp.asarray(avail))
    if topo.weights == "circulant":
        # mesh-native uniform weights: each shift one collective-permute
        kw.update(shifts=topo.shifts, self_weight=topo.self_weight)
    elif solver.topology == "adj":
        # the solver averages neighbours (excl. self): lower the same
        # row-stochastic adj/deg matrix the simulator driver builds
        # reprolint: allow=RL002 — one-node-per-device mesh tier: L == device count, far below the sparse tier
        kw.update(W=np.asarray(_consensus.neighbor_average_matrix(mat.adj)))
    else:
        # arbitrary weighted topology: the consensus layer decomposes W
        # into per-shift, per-device weights (metropolis/lazy/... rows)
        kw.update(W=np.asarray(mat.W))
    return solver.mesh_fn(
        mat.init.U0, mat.Xg, mat.yg, mesh, "nodes", eta=eta,
        T_GD=spec.solver.T_GD, T_con=spec.solver.T_con,
        engine=eng, U_star=mat.problem.U_star, **kw)


def _run_virtual_mesh(spec: ExperimentSpec, solver: SolverDef,
                      mat: Materialized, eng, eta: float, n_dev: int,
                      avail: np.ndarray | None = None) -> RunResult:
    """The virtual-node mesh tier: L = n_dev × block, contiguous blocks
    of virtual nodes per device — co-located gossip is an on-device
    segment-sum, only cross-device edge classes pay collective-permutes.
    Any mixing matrix (dense or SparseWeights) decomposes; the W is the
    SAME one the simulator mixes with (for ``"adj"`` solvers, the same
    row-stochastic neighbour average the simulator builds), so
    trajectories agree to the consensus layer's parity tolerance."""
    from repro.distributed.mixing import SparseWeights
    if solver.topology == "adj":
        # reprolint: allow=RL002 — Materialized.adj field: SparseGraph on the sparse path, dense only below the use_sparse gate
        W = np.asarray(_consensus.neighbor_average_matrix(mat.adj))
    else:
        W = mat.W
    if not isinstance(W, SparseWeights):
        W = SparseWeights.from_dense(np.asarray(W))
    vt = _consensus.VirtualTopology.from_weights(W, n_dev)
    mesh = make_mesh((n_dev,), ("nodes",))
    kw = {k: getattr(spec.solver, k) for k in solver.spec_kwargs}
    if avail is not None:
        kw.update(avail=jnp.asarray(avail))
    return solver.virtual_mesh_fn(
        mat.init.U0, mat.Xg, mat.yg, mesh, "nodes", vt=vt, eta=eta,
        T_GD=spec.solver.T_GD, T_con=spec.solver.T_con,
        engine=eng, U_star=mat.problem.U_star, **kw)
