"""Serving launcher: batched prefill + decode loop with the KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params, init_cache, decode_step
from repro.utils.log import get_logger

log = get_logger("repro.serve")


def generate(arch: str, *, smoke: bool = True, batch: int = 2,
             prompt_len: int = 16, gen: int = 8, capacity: int | None = None,
             temperature: float = 0.0, seed: int = 0):
    """Prefill via teacher-forced decode steps (cache fill), then sample
    ``gen`` tokens greedily (temperature 0) or with Gumbel noise."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    cap = capacity or (prompt_len + gen)
    if cfg.sliding_window:
        cap = min(cap, cfg.sliding_window)
    state = init_cache(cfg, batch=batch, capacity=cap)
    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (batch, prompt_len), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    step = jax.jit(lambda p, s, t: decode_step(p, s, t, cfg))

    # Prefill: ONE jitted dispatch scanning the teacher-forced decode
    # step over the prompt, instead of prompt_len separate jit calls —
    # at smoke shapes the Python dispatch loop dominated prefill time.
    # Same per-token arithmetic as the old loop, so generated ids are
    # unchanged.
    @jax.jit
    def prefill(p, s, toks):                         # toks: (B, S)
        def body(st, tok):
            lg, st = decode_step(p, st, tok[:, None], cfg)
            return st, lg
        st, logits_all = jax.lax.scan(body, s, jnp.swapaxes(toks, 0, 1))
        return logits_all[-1], st

    t0 = time.time()
    logits, state = prefill(params, state, prompt)   # cache fill
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = []
    t1 = time.time()
    for i in range(gen):
        if temperature > 0:
            g = jax.random.gumbel(jax.random.fold_in(key, 100 + i),
                                  logits.shape)
            tok = jnp.argmax(logits / temperature + g, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        out.append(tok[:, 0])
        logits, state = step(params, state, tok)
    t_decode = time.time() - t1
    tokens = jnp.stack(out, axis=1)
    log.info("prefill %d tok in %.2fs; decode %d tok in %.2fs "
             "(%.1f tok/s/seq)", prompt_len, t_prefill, gen, t_decode,
             gen / max(t_decode, 1e-9))
    return tokens, dict(prefill_s=t_prefill, decode_s=t_decode,
                        tok_per_s=gen / max(t_decode, 1e-9))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    tokens, stats = generate(args.arch, smoke=args.smoke, batch=args.batch,
                             prompt_len=args.prompt_len, gen=args.gen,
                             temperature=args.temperature)
    print("generated token ids (first row):", tokens[0].tolist())
    print(stats)


if __name__ == "__main__":
    main()
