import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) combination against 512 placeholder CPU devices and record
memory/cost/collective analyses for the roofline tables.

MUST be run as its own process (the device-count fake above precedes every
other import — jax locks the device count on first init).

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import ARCH_IDS, get_config                 # noqa: E402
from repro.launch.mesh import (                                # noqa: E402
    make_production_mesh, n_chips, PEAK_FLOPS_BF16, HBM_BW, ICI_BW,
)
from repro.launch.shapes import SHAPES                         # noqa: E402
from repro.launch.specs import input_specs                     # noqa: E402
from repro.utils.hlo import collective_stats, dominant_collective  # noqa: E402


def calib_depths(cfg):
    """(a, b): two reduced depths whose cost DIFFERENCE isolates one bulk
    layer (zamba: one 6-layer group incl. the shared-attn application)."""
    if cfg.block_pattern == "zamba":
        p = cfg.shared_attn_period
        return p, 2 * p
    if cfg.n_experts and cfg.first_dense_layers:
        return 2, 3        # 1 dense + {1,2} moe layers
    return 1, 2


def calib_seqs(cfg, shape):
    """Three reduced sequence lengths (decode: cache capacities) at which
    the calibration lowers are cheap: short enough that unrolled
    attention/SSD chunk loops stay tiny, long enough to fit vlm patch
    budgets and resolve the quadratic attention term."""
    if cfg.modality == "vlm" and shape.kind != "decode":
        v = cfg.vis_tokens
        return (v + 256, v + 512, v + 768)
    return (512, 768, 1024)


def calib_target_seq(cfg, shape):
    """Sequence value the fit is evaluated at: the real seq_len, except
    decode shapes where cost scales with the CACHE CAPACITY (for
    long_500k that's the sliding window of the lowered variant)."""
    from repro.launch.shapes import cache_capacity, long_ctx_variant
    if shape.kind == "decode":
        vcfg = (long_ctx_variant(cfg)[0] if shape.name == "long_500k"
                else cfg)
        return cache_capacity(vcfg, shape)
    return shape.seq_len


def calibrate(cfg, shape, mesh, **kw):
    """Calibrated (flops, hbm_bytes, collective_bytes).

    XLA's cost_analysis counts a lax.scan body ONCE, not × trip count, so
    a full-depth/full-seq lowering under-reports all three metrics.  We
    exploit the EXACT polynomial structure of the cost:
        m(L, S) = base(S) + L · layer(S),
    with base linear in S (embedding/head/optimizer) and layer at most
    quadratic in S (causal attention; SSD/MoE/decode are linear).  Six
    cheap lowerings — two depths (calib_depths) × three short sequences
    (calib_seqs), all with cfg.unroll=True so nothing hides in a scan —
    determine layer(S_i) by depth-differencing, a quadratic fit gives
    layer(S), a linear fit gives base(S), and the result is evaluated at
    (n_layers, target_seq).
    """
    import dataclasses as _dc
    import numpy as _np
    a, b = calib_depths(cfg)
    seqs = calib_seqs(cfg, shape)
    target = calib_target_seq(cfg, shape)
    ms = {}
    for depth in (a, b):
        for sq in seqs:
            sh = _dc.replace(shape, seq_len=sq)
            ms[(depth, sq)] = _np.array(
                _measure(_calib_cfg(cfg, depth), sh, mesh, **kw))
    S = _np.array(seqs, dtype=float)
    layer_pts = _np.stack([(ms[(b, s)] - ms[(a, s)]) / (b - a)
                           for s in seqs])              # (3, 3 metrics)
    base_pts = _np.stack([ms[(a, s)] - a * layer_pts[i]
                          for i, s in enumerate(seqs)])
    out = []
    for j in range(3):                                   # per metric
        qc = _np.polyfit(S, layer_pts[:, j], 2)          # layer: quadratic
        lc = _np.polyfit(S, base_pts[:, j], 1)           # base: linear
        layer_t = _np.polyval(qc, target)
        base_t = _np.polyval(lc, target)
        out.append(float(max(base_t + cfg.n_layers * layer_t, 0.0)))
    return tuple(out)


def _calib_cfg(cfg, depth: int):
    import dataclasses as _dc
    fd = min(cfg.first_dense_layers, 1)
    return _dc.replace(cfg, n_layers=depth, unroll=True,
                       first_dense_layers=fd)


def _measure(arch_cfg, shape_name, mesh, aggregation, t_con, fused,
             **variant):
    """Lower+compile one spec; return (flops, hbm_bytes, coll_bytes)."""
    spec = input_specs(arch_cfg, shape_name, mesh, aggregation=aggregation,
                       t_con=t_con, fused=fused, **variant)
    with mesh:
        compiled = jax.jit(
            spec.step_fn,
            in_shardings=spec.in_shardings).lower(*spec.args).compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # jax<=0.4.x: one dict per device
        cost = cost[0] if cost else {}
    coll = collective_stats(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]))


def roofline_terms(flops_per_dev, hbm_bytes_per_dev, coll_bytes_per_dev):
    """The three roofline terms, in seconds (per device ≡ per chip, since
    the SPMD program is per-device)."""
    return {
        "compute_s": flops_per_dev / PEAK_FLOPS_BF16,
        "memory_s": hbm_bytes_per_dev / HBM_BW,
        "collective_s": coll_bytes_per_dev / ICI_BW,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense, train) / 6·N_active·D; 2·N·D for pure
    forward (prefill), 2·N_active per decoded token."""
    n_act = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_act * tokens


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            aggregation: str = "diffusion", t_con: int = 1,
            fused: bool = True, calibrate_cost: bool | None = None,
            **variant) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    spec = input_specs(cfg, shape_name, mesh, aggregation=aggregation,
                       t_con=t_con, fused=fused, **variant)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips(mesh), "kind": spec.kind,
        "aggregation": aggregation if spec.kind == "train" else None,
        "t_con": t_con if spec.kind == "train" else None,
        "variant": {k: v for k, v in variant.items() if v},
        "note": spec.note, "status": "ok",
    }
    t0 = time.time()
    with mesh:
        lowered = jax.jit(spec.step_fn,
                          in_shardings=spec.in_shardings).lower(*spec.args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or
                          (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "output_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0))),
    }
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # jax<=0.4.x: one dict per device
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    rec["cost"] = {"flops": flops, "bytes_accessed": hbm_bytes}

    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    rec["collectives"] = coll
    rec["dominant_collective"] = dominant_collective(coll)
    rec["cost_raw"] = {"flops": flops, "bytes_accessed": hbm_bytes,
                       "collective_bytes": coll["total_bytes"],
                       "caveat": "lax.scan bodies counted once by XLA — "
                                 "see cost (calibrated)"}

    # ---- calibrated cost: 2 depths × 3 short seqs, polynomial fit.
    # The roofline table is single-pod only (the multi-pod pass just
    # proves the 'pod' axis shards), so calibration defaults off there.
    if calibrate_cost is None:
        calibrate_cost = not multi_pod
    if not calibrate_cost:
        rec["cost"] = dict(rec["cost_raw"],
                           caveat="multi-pod: raw (uncalibrated) cost — "
                                  "roofline uses the single-pod record")
        flops = rec["cost"]["flops"]
        hbm_bytes = rec["cost"]["bytes_accessed"]
        coll_bytes = rec["cost"]["collective_bytes"]
    else:
        kw = dict(aggregation=aggregation, t_con=t_con, fused=fused,
                  **variant)
        t2 = time.time()
        flops, hbm_bytes, coll_bytes = calibrate(cfg, shape, mesh, **kw)
        rec["calibrate_s"] = round(time.time() - t2, 2)
        rec["cost"] = {"flops": flops, "bytes_accessed": hbm_bytes,
                       "collective_bytes": coll_bytes,
                       "calib_depths": list(calib_depths(cfg)),
                       "calib_seqs": list(calib_seqs(cfg, shape)),
                       "calib_target_seq": calib_target_seq(cfg, shape)}

    terms = roofline_terms(flops, hbm_bytes, coll_bytes)
    dom = max(terms, key=terms.get)
    mf = model_flops(spec.cfg, shape)
    hlo_total_flops = flops * n_chips(mesh)
    rec["roofline"] = {
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops_total": mf,
        "hlo_flops_total": hlo_total_flops,
        "useful_flops_ratio": (mf / hlo_total_flops
                               if hlo_total_flops else None),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--aggregation", default="diffusion")
    ap.add_argument("--t-con", type=int, default=1)
    ap.add_argument("--no-fused", action="store_true")
    ap.add_argument("--wire-dtype", default=None)
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--shard-cache-slots", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shape_names = (list(SHAPES) if args.shape == "all"
                   else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for sname in shape_names:
            for multi in meshes:
                mesh_tag = "2x16x16" if multi else "16x16"
                tag = f"{arch}_{sname}_{mesh_tag}"
                if args.tag:
                    tag += f"_{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    try:
                        with open(path) as f:
                            if json.load(f).get("status") == "ok":
                                print(f"{tag}: skip (exists)", flush=True)
                                continue
                    except Exception:
                        pass
                try:
                    rec = run_one(arch, sname, multi,
                                  aggregation=args.aggregation,
                                  t_con=args.t_con,
                                  fused=not args.no_fused,
                                  wire_dtype=args.wire_dtype,
                                  remat_policy=args.remat_policy,
                                  shard_cache_slots=args.shard_cache_slots)
                except Exception as e:              # record, keep going
                    failures += 1
                    rec = {"arch": arch, "shape": sname, "mesh": mesh_tag,
                           "status": "FAILED", "error": repr(e),
                           "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec.get("roofline", {})
                print(f"{tag}: {rec['status']}"
                      + (f" dom={r.get('dominant')}"
                         f" compute={r.get('compute_s', 0):.2e}s"
                         f" mem={r.get('memory_s', 0):.2e}s"
                         f" coll={r.get('collective_s', 0):.2e}s"
                         f" lower={rec.get('lower_s')}s"
                         f" compile={rec.get('compile_s')}s"
                         if rec["status"] == "ok" else ""),
                      flush=True)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
