"""input_specs — ShapeDtypeStruct stand-ins + shardings for every
(arch × input-shape × mesh) combination; no device memory is ever
allocated (the dry-run lowers against these).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib
from repro.launch import shapes as shapes_lib
from repro.launch import sharding as shard_lib
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.optim import adamw, warmup_cosine
from repro.distributed.aggregation import AggregationConfig


class DryRunSpec(NamedTuple):
    step_fn: Any              # callable to jit
    args: tuple               # ShapeDtypeStruct pytrees
    in_shardings: tuple
    kind: str                 # train | prefill | decode
    cfg: Any                  # (possibly variant) ModelConfig used
    note: str


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def abstract_params(cfg, n_nodes: int | None = None):
    """ShapeDtypeStructs of the parameter tree (optionally node-stacked),
    via eval_shape — zero allocation."""
    shapes = jax.eval_shape(
        lambda k: tfm.init_params(k, cfg), jax.random.PRNGKey(0))
    if n_nodes:
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((n_nodes,) + x.shape, x.dtype),
            shapes)
    return shapes


def batch_struct(cfg, batch: int, seq: int, lead_nodes: int | None = None):
    """Abstract input batch for one step (training adds labels)."""
    def with_lead(shape):
        return (lead_nodes,) + shape if lead_nodes else shape
    if cfg.modality == "vlm":
        s_text = seq - cfg.vis_tokens
        b = {
            "tokens": jax.ShapeDtypeStruct(with_lead((batch, s_text)),
                                           jnp.int32),
            "vis_embed": jax.ShapeDtypeStruct(
                with_lead((batch, cfg.vis_tokens, cfg.d_model)),
                jnp.dtype(cfg.dtype)),
            "labels": jax.ShapeDtypeStruct(with_lead((batch, s_text)),
                                           jnp.int32),
        }
    else:
        b = {"tokens": jax.ShapeDtypeStruct(with_lead((batch, seq)),
                                            jnp.int32),
             "labels": jax.ShapeDtypeStruct(with_lead((batch, seq)),
                                            jnp.int32)}
    return b


def make_optimizer(cfg):
    return adamw(warmup_cosine(3e-4, 200, 10_000), weight_decay=0.1)


def input_specs(arch_cfg, shape_name: str, mesh, *,
                aggregation: str = "diffusion", t_con: int = 1,
                fused: bool = True, wire_dtype: str | None = None,
                remat_policy: str | None = None,
                shard_cache_slots: bool = False) -> DryRunSpec:
    """Assemble (step_fn, abstract args, shardings) for one combination.
    The keyword knobs are the §Perf hillclimb variants.  ``shape_name``
    may also be an InputShape instance (the cost calibration passes
    seq-reduced variants)."""
    shape = (shape_name if isinstance(shape_name, shapes_lib.InputShape)
             else shapes_lib.get_shape(shape_name))
    model_size = mesh.shape.get("model", 1)
    note = ""
    cfg = arch_cfg
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)

    if shape.kind == "train":
        n_nodes = mesh_lib.n_nodes(mesh)
        lead = mesh_lib.node_axes(mesh)
        assert shape.global_batch % n_nodes == 0
        per_node = shape.global_batch // n_nodes
        params = abstract_params(cfg, n_nodes)
        opt = make_optimizer(cfg)
        opt_state = jax.eval_shape(opt.init, params)
        state = steps_lib.TrainState(
            params=params, opt_state=opt_state,
            step=jax.ShapeDtypeStruct((), jnp.int32))
        batch = batch_struct(cfg, per_node, shape.seq_len,
                             lead_nodes=n_nodes)
        agg = AggregationConfig(strategy=aggregation, t_con=t_con,
                                local_patterns=("embed", "lm_head"),
                                wire_dtype=wire_dtype)
        make = (steps_lib.make_train_step_fused if fused
                else steps_lib.make_train_step)
        step = make(cfg, opt, agg, n_nodes)
        pspec = shard_lib.param_specs(params, lead=lead,
                                      model_size=model_size)
        ospec = shard_lib.param_specs(opt_state, lead=lead,
                                      model_size=model_size)
        state_spec = steps_lib.TrainState(params=pspec, opt_state=ospec,
                                          step=P())
        bspec = shard_lib.batch_specs(batch, lead)
        return DryRunSpec(
            step_fn=step, args=(state, batch),
            in_shardings=(_shardings(state_spec, mesh),
                          _shardings(bspec, mesh)),
            kind="train", cfg=cfg, note=note)

    # ---------------- serving: single param copy --------------------
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_batch_devs = 1
    for a in batch_axes:
        n_batch_devs *= mesh.shape[a]

    if shape.name == "long_500k":
        cfg, note = shapes_lib.long_ctx_variant(cfg)

    params = abstract_params(cfg)
    # serving weights are cast to the activation dtype (bf16): inference
    # needs no f32 master copy, halving weight HBM
    serve_dt = jnp.dtype(cfg.dtype)
    params = jax.tree.map(
        lambda x: (jax.ShapeDtypeStruct(x.shape, serve_dt)
                   if jnp.issubdtype(x.dtype, jnp.floating) else x),
        params)
    # 2-D weight sharding ('model' × data axes): the only serving layout
    # in which the 480B/671B archs fit 16 GB/chip HBM
    pspec = shard_lib.param_specs(params, lead=None, model_size=model_size,
                                  fsdp_axes=batch_axes,
                                  fsdp_size=n_batch_devs)

    if shape.kind == "prefill":
        batch = batch_struct(cfg, shape.global_batch, shape.seq_len)
        del batch["labels"]
        lead = batch_axes if shape.global_batch % n_batch_devs == 0 else None
        bspec = shard_lib.batch_specs(batch, lead)
        step = steps_lib.make_prefill_step(cfg)
        return DryRunSpec(
            step_fn=step, args=(params, batch),
            in_shardings=(_shardings(pspec, mesh),
                          _shardings(bspec, mesh)),
            kind="prefill", cfg=cfg, note=note)

    # decode
    cap = shapes_lib.cache_capacity(cfg, shape)
    state = jax.eval_shape(
        lambda: tfm.init_cache(cfg, batch=shape.global_batch, capacity=cap))
    lead = batch_axes if shape.global_batch % n_batch_devs == 0 else None
    if lead is None:
        note = (note + " | " if note else "") + (
            f"batch {shape.global_batch} < {n_batch_devs} node devices — "
            "cache replicated over data axes, weights sharded on 'model'")
    cspec = shard_lib.cache_specs(state, lead, cfg, shard_heads=False,
                                  shard_slots=shard_cache_slots)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tspec = P(lead) if lead else P()
    step = steps_lib.make_serve_step(cfg)
    return DryRunSpec(
        step_fn=step,
        args=(params, state, tokens),
        in_shardings=(_shardings(pspec, mesh),
                      _shardings(cspec, mesh),
                      NamedSharding(mesh, tspec)),
        kind="decode", cfg=cfg, note=note)
