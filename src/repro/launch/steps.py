"""Step functions for the distributed trainer/server.

Training layout (the paper's decentralized setting mapped to the mesh):
every param/optimizer leaf carries a leading **node axis** of size
n_nodes = Π mesh[pod, data].  Node g's replica trains on node g's batch
shard; communication between replicas is the pluggable aggregation
strategy (diffusion = the paper's Dif-AltGDmin pattern; allreduce = the
fusion-center baseline; consensus = Dec-AltGDmin; dgd; local).  Within a
node, tensor parallelism over 'model' is implicit via param shardings.

Serving layout: ONE param copy (no node axis) — prefill is a full-sequence
forward; decode is one token against a KV/SSM cache.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.distributed.aggregation import (
    AggregationConfig, aggregate_gradients, aggregate_params,
)
from repro.optim.optimizers import apply_updates


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def cross_entropy(logits, labels):
    """Mean next-token NLL. logits: (B,S,V) f32; labels: (B,S_l) aligned to
    the LAST S_l positions (vlm prepends vis tokens that carry no loss)."""
    S_l = labels.shape[1]
    lt = logits[:, -S_l:]
    ls = jax.nn.log_softmax(lt, axis=-1)
    nll = -jnp.take_along_axis(ls, labels[..., None], axis=-1)
    return jnp.mean(nll)


def loss_fn(params, batch, cfg):
    logits, aux = tfm.forward(params, batch, cfg)
    return cross_entropy(logits, batch["labels"]) + aux


# ----------------------------------------------------------------- train

def replicate_for_nodes(tree, n_nodes: int):
    """Stack n_nodes copies along a new leading axis (dim 0)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_nodes,) + x.shape), tree)


def make_train_step(cfg, opt, agg: AggregationConfig, n_nodes: int):
    """Returns step(state, batch) → (state, metrics).

    batch leaves: (n_nodes, per_node_batch, ...).  Gradients are computed
    per node (vmap over the node axis), then communicated per the
    aggregation strategy; optimizer update is node-local (vmapped
    elementwise); diffusion gossips the updated parameters.
    """
    grad_one = jax.grad(loss_fn, has_aux=False)

    def step(state: TrainState, batch):
        losses = jax.vmap(lambda p, b: loss_fn(p, b, cfg))(
            state.params, batch)
        grads = jax.vmap(lambda p, b: grad_one(p, b, cfg))(
            state.params, batch)
        grads = aggregate_gradients(grads, agg)            # consensus/AR
        updates, opt_state = opt.update(grads, state.opt_state,
                                        state.params)
        params = apply_updates(state.params, updates)
        params = aggregate_params(params, agg)             # diffusion/dgd
        metrics = {"loss": jnp.mean(losses),
                   "loss_per_node": losses}
        return TrainState(params, opt_state, state.step + 1), metrics

    return step


def make_train_step_fused(cfg, opt, agg: AggregationConfig, n_nodes: int):
    """value_and_grad fusion of :func:`make_train_step` (one backward pass
    computes both loss and grads — the production variant; kept separate
    so EXPERIMENTS.md §Perf can A/B the fusion)."""
    vg = jax.value_and_grad(loss_fn)

    def step(state: TrainState, batch):
        losses, grads = jax.vmap(lambda p, b: vg(p, b, cfg))(
            state.params, batch)
        grads = aggregate_gradients(grads, agg)
        updates, opt_state = opt.update(grads, state.opt_state,
                                        state.params)
        params = apply_updates(state.params, updates)
        params = aggregate_params(params, agg)
        return (TrainState(params, opt_state, state.step + 1),
                {"loss": jnp.mean(losses), "loss_per_node": losses})

    return step


# ----------------------------------------------------------------- serve

def make_prefill_step(cfg):
    """Full-sequence forward; returns last-position logits (the sampler's
    input) — (B, V)."""
    def prefill(params, batch):
        logits, _ = tfm.forward(params, batch, cfg)
        return logits[:, -1]
    return prefill


def make_serve_step(cfg):
    """ONE decode token: (params, state, tokens (B,1)) → (logits, state)."""
    def serve(params, state, tokens):
        return tfm.decode_step(params, state, tokens, cfg)
    return serve
