"""The four assigned input shapes + per-(arch, shape) admissibility."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


LONG_CTX_WINDOW = 8_192    # beyond-paper sliding-window variant for 500k


def long_ctx_variant(cfg):
    """Config actually lowered for long_500k.  SSM/hybrid/windowed archs
    run as-is (sub-quadratic state); full-attention archs get the
    sliding-window VARIANT (window 8192) — the documented carve-out that
    makes a 524288-token decode admissible (DESIGN.md §Shape×arch skips).
    """
    import dataclasses
    if cfg.is_subquadratic:
        return cfg, ""
    variant = dataclasses.replace(cfg, sliding_window=LONG_CTX_WINDOW,
                                  name=cfg.name + "+swa8k")
    return variant, (f"{cfg.name}: full attention at 524k is inadmissible "
                     f"(85 GB-class KV cache); lowered the sliding-window "
                     f"variant (window={LONG_CTX_WINDOW}) instead")


def admissible(cfg, shape: InputShape) -> tuple[bool, str]:
    """All assigned archs are decoders (no encoder-only decode skips);
    long_500k is handled via :func:`long_ctx_variant`."""
    return True, ""


def cache_capacity(cfg, shape: InputShape) -> int:
    """KV-cache slots for a decode shape: the full context, truncated to
    the sliding window when one exists (ring buffer semantics)."""
    cap = shape.seq_len
    if cfg.sliding_window is not None:
        cap = min(cap, cfg.sliding_window)
    return cap
