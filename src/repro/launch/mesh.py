"""Production meshes.

Single pod: (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods; the
'pod' axis crosses the DCN, which is exactly the expensive inter-node link
the paper's decentralized setting targets (pods-as-nodes diffusion).

Everything here is a FUNCTION — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax

from repro.utils.compat import make_mesh

# TPU v5e hardware constants (roofline denominators; EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def node_axes(mesh) -> tuple:
    """Mesh axes that carry the decentralized node dimension (the leading
    param/batch axis of the diffusion trainer)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def n_nodes(mesh) -> int:
    out = 1
    for a in node_axes(mesh):
        out *= mesh.shape[a]
    return out


def n_chips(mesh) -> int:
    out = 1
    for a in mesh.shape:
        out *= mesh.shape[a]
    return out


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): 1-D data mesh."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
