"""Sharding rules: Megatron-style tensor parallelism over the 'model' axis
+ the decentralized node axis over ('pod','data') for training.

Parameter leaves are classified by their tree path:
  column-parallel (output dim on 'model'): wq wk wv wuq wuk wuv gate up
      in_proj lm_head
  row-parallel (input dim on 'model'):     wo down out_proj
  expert-parallel (expert dim on 'model'): experts/{gate,up,down}
  vocab-sharded:                           embed table
  replicated:                              norms, biases, router, conv,
                                           A_log, dt_bias, D

Leaves may carry leading [node] and/or [layer-stack] axes before the
matrix dims; rules always address the TRAILING dims, so they compose with
scan-stacking and the node axis transparently.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import PartitionSpec as P

COL_PAT = re.compile(
    r"(wq|wk|wv|wuq|wuk|wuv|wdq|wdkv|in_proj|lm_head|gate|up)(/w)?$")
ROW_PAT = re.compile(r"(wo|down|out_proj)(/w)?$")
EMBED_PAT = re.compile(r"embed/table$")
EXPERT_PAT = re.compile(r"experts/(gate|up|down)$")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _pad_spec(leaf_ndim: int, trailing: tuple, lead) -> P:
    """Build a spec: [lead] on axis 0 (or None), None-padding, then the
    trailing entries on the last len(trailing) dims."""
    spec = [None] * leaf_ndim
    if lead is not None and leaf_ndim > 0:
        spec[0] = lead
    for i, s in enumerate(trailing):
        idx = leaf_ndim - len(trailing) + i
        if idx == 0 and lead is not None:
            continue            # never double-assign dim 0
        if s is not None:
            spec[idx] = s
    return P(*spec)


def param_spec_for(path: str, shape: tuple, model_size: int,
                   lead=None) -> P:
    """lead: mesh axes for the node dimension (dim 0), or None (serving).

    Divisibility-aware: a rule only fires if the target dim divides evenly
    by the 'model' axis size; otherwise it falls back (col → row →
    replicate).  E.g. mamba2's in_proj output (2·d_inner+2N+H) is never a
    multiple of 16, so it shards its INPUT dim (row-parallel) instead.
    """
    ndim = len(shape)

    def div(dim_from_end: int) -> bool:
        idx = ndim - dim_from_end
        return idx >= 0 and shape[idx] % model_size == 0

    if EXPERT_PAT.search(path):
        if div(3):                   # experts (E, d, ff): E on 'model'
            return _pad_spec(ndim, ("model", None, None), lead)
        return _pad_spec(ndim, (), lead)
    if EMBED_PAT.search(path):
        if div(2):                   # vocab-sharded
            return _pad_spec(ndim, ("model", None), lead)
        if div(1):                   # fallback: shard d_model
            return _pad_spec(ndim, (None, "model"), lead)
        return _pad_spec(ndim, (), lead)
    if COL_PAT.search(path):
        if div(1):
            return _pad_spec(ndim, (None, "model"), lead)
        if div(2):                   # fallback row-parallel
            return _pad_spec(ndim, ("model", None), lead)
        return _pad_spec(ndim, (), lead)
    if ROW_PAT.search(path):
        if div(2):
            return _pad_spec(ndim, ("model", None), lead)
        if div(1):
            return _pad_spec(ndim, (None, "model"), lead)
        return _pad_spec(ndim, (), lead)
    return _pad_spec(ndim, (), lead)


def _add_fsdp(spec: P, shape: tuple, fsdp_axes: tuple, fsdp_size: int) -> P:
    """Serving FSDP: fill ONE unsharded trailing matrix dim (≥2 dims from
    the end count as matrix dims) with the data axes, largest first —
    weights then shard over the whole mesh, which is the only layout in
    which the big archs fit HBM."""
    entries = list(spec)
    entries += [None] * (len(shape) - len(entries))
    cand = [i for i in range(max(len(shape) - 3, 0), len(shape))
            if entries[i] is None and shape[i] % fsdp_size == 0
            and shape[i] >= fsdp_size]
    if cand:
        i = max(cand, key=lambda j: shape[j])
        entries[i] = (fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0])
    return P(*entries)


def param_specs(params, lead=None, model_size: int = 16, fsdp_axes=None,
                fsdp_size: int = 16):
    """PartitionSpec pytree matching ``params``.

    ``lead``: node-axis mesh axes applied to dim 0 of every leaf (training
    layout — each node holds its own replica, FSDP over 'model' only).
    ``fsdp_axes``: serving layout — additionally shard one matrix dim of
    every weight over the data axes (2-D weight sharding), so a 671B-param
    model fits 256×16 GB HBM.  Rules address trailing dims, so scan-stack
    axes pass through."""
    lead_ = tuple(lead) if lead else None
    fsdp = tuple(fsdp_axes) if fsdp_axes else None

    def spec(path, leaf):
        shape = tuple(leaf.shape)
        s = param_spec_for(_path_str(path), shape, model_size, lead_)
        if fsdp and leaf.ndim >= 2 and leaf.size >= 1 << 16:
            s = _add_fsdp(s, shape, fsdp, fsdp_size)
        return s
    return jax.tree_util.tree_map_with_path(spec, params)


def batch_specs(batch, lead) -> dict:
    """Batch pytree specs: leading (node/batch) dim over ``lead``."""
    lead_ = tuple(lead) if lead else None

    def spec(_, leaf):
        return _pad_spec(leaf.ndim, (), lead_)
    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_specs(state, batch_axes, cfg, shard_heads: bool = True,
                shard_slots: bool = False):
    """Decode-state specs, built STRUCTURALLY from the decode plan (cache
    pytrees are NamedTuples, so name-based rules don't apply).

    Per cache class (after ``stack`` leading layer axes):
      KVCache   k/v (…,B,cap,Hkv,Dh) → batch on data axes, heads on 'model'
      MLACache  ckv/k_rope (…,B,cap,r) → batch only (per-token latent —
                the point of MLA: nothing per-head to shard in the cache)
      SSMCache  conv (…,B,K−1,ch) → batch; state (…,B,H,P,N) → batch +
                heads on 'model'
    """
    from repro.models.transformer import build_plan, DecodeState
    from repro.models.attention import KVCache, MLACache
    from repro.models.ssm import SSMCache

    lead = tuple(batch_axes) if batch_axes else None
    # shard_slots: KV capacity dim over 'model' — the decode-memory
    # hillclimb (a 77 GiB/dev 32k MHA cache becomes 4.8 GiB/dev);
    # mutually exclusive with head sharding (same mesh axis)
    slots = "model" if shard_slots else None
    model = "model" if (shard_heads and not shard_slots) else None

    def kv(extra: int):
        e = (None,) * extra
        return KVCache(k=P(*e, lead, slots, model, None),
                       v=P(*e, lead, slots, model, None),
                       positions=P(*e, lead, slots))

    def mla(extra: int):
        e = (None,) * extra
        return MLACache(ckv=P(*e, lead, slots, None),
                        k_rope=P(*e, lead, slots, None),
                        positions=P(*e, lead, slots))

    def ssm(extra: int):
        e = (None,) * extra
        return SSMCache(conv=P(*e, lead, None, None),
                        state=P(*e, lead, model, None, None))

    def seg_spec(kind, extra):
        mixer, _ = kind
        if mixer == "attn":
            return mla(extra) if cfg.attn_impl == "mla" else kv(extra)
        return ssm(extra)

    caches, shared = [], None
    for seg in build_plan(cfg):
        if seg[0] == "scan":
            caches.append(seg_spec(seg[1], extra=1))
        else:
            caches.append(ssm(extra=2))            # (n_groups, period, …)
            shared = kv(extra=1)                   # (n_groups, …)
    return DecodeState(caches=caches, shared_caches=shared, pos=P())
