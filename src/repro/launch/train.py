"""Training launcher: decentralized-diffusion LM training on the local
mesh (or the production mesh when run under real hardware / fake devices).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --aggregation diffusion --nodes 4

On this CPU container the smoke flag is mandatory for non-trivial archs;
the full configs are exercised via dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.distributed.aggregation import AggregationConfig
from repro.launch import steps as steps_lib
from repro.models import init_params
from repro.models.frontends import vlm_batch_stub
from repro.optim import adamw, warmup_cosine
from repro.checkpoint import save_checkpoint
from repro.utils.log import get_logger

log = get_logger("repro.train")


def make_batch(cfg, key, n_nodes, per_node, seq):
    if cfg.modality == "vlm":
        b = vlm_batch_stub(key, n_nodes * per_node, seq, cfg)
        b = jax.tree.map(
            lambda x: x.reshape((n_nodes, per_node) + x.shape[1:]), b)
    else:
        toks = jax.random.randint(key, (n_nodes, per_node, seq), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
        b = {"tokens": toks}
    b["labels"] = jnp.roll(b["tokens"], -1, axis=-1)
    return b


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          n_nodes: int = 4, per_node_batch: int = 2, seq: int = 64,
          aggregation: str = "diffusion", t_con: int = 1,
          lr: float = 3e-4, seed: int = 0, ckpt_dir: str | None = None,
          use_markov_data: bool = True, log_every: int = 10):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    params = steps_lib.replicate_for_nodes(params, n_nodes)
    opt = adamw(warmup_cosine(lr, max(steps // 10, 1), steps),
                weight_decay=0.1)
    opt_state = opt.init(params)
    state = steps_lib.TrainState(params, opt_state,
                                 jnp.zeros((), jnp.int32))
    agg = AggregationConfig(strategy=aggregation, t_con=t_con,
                            local_patterns=("embed", "lm_head"))
    step_fn = jax.jit(steps_lib.make_train_step_fused(cfg, opt, agg,
                                                      n_nodes))
    ds = SyntheticLM(cfg.vocab_size, seq, n_nodes * per_node_batch,
                     seed=seed)

    history = []
    t0 = time.time()
    for i in range(steps):
        if use_markov_data and cfg.modality != "vlm":
            flat = ds.batch(i)
            b = {"tokens": flat["tokens"].reshape(n_nodes, per_node_batch,
                                                  seq)}
            b["labels"] = jnp.roll(b["tokens"], -1, axis=-1)
        else:
            b = make_batch(cfg, jax.random.fold_in(key, i), n_nodes,
                           per_node_batch, seq)
        state, metrics = step_fn(state, b)
        loss = float(metrics["loss"])
        history.append(loss)
        if i % log_every == 0 or i == steps - 1:
            log.info("step %4d loss %.4f (%.2f s)", i, loss,
                     time.time() - t0)
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, state.params)
        log.info("saved checkpoint to %s", ckpt_dir)
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--per-node-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--aggregation", default="diffusion")
    ap.add_argument("--t-con", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    _, history = train(args.arch, smoke=args.smoke, steps=args.steps,
                       n_nodes=args.nodes,
                       per_node_batch=args.per_node_batch, seq=args.seq,
                       aggregation=args.aggregation, t_con=args.t_con,
                       lr=args.lr, ckpt_dir=args.ckpt_dir)
    print(f"final loss: {history[-1]:.4f} (from {history[0]:.4f})")


if __name__ == "__main__":
    main()
