"""Decentralized truncated spectral initialization — Algorithm 2.

Steps (per node g, simulator layout: node axis leading):
  1. local truncation level  α_g^(in) = 9κ²μ² (L/nT) Σ_{t∈S_g} Σ_i y_ti²,
     AGREE'd to an approximate global average α_g;
  2. truncated covariance columns Θ_g^(0) = [ (1/n) X_tᵀ y_t,trnc ]_{t∈S_g};
  3. decentralized orthogonal (power) iteration on (1/L) Σ_g Θ_g Θ_gᵀ:
     local matmul → AGREE → local QR, repeated T_pm times (all nodes start
     from the SAME Gaussian seed, paper line 8);
  4. broadcast of node 0's basis via AGREE (paper lines 14–15) followed by a
     local QR to restore orthonormality — this pins node-wise consistency
     ρ^(0). (The pseudocode places the broadcast inside the τ-loop; running
     it once after the loop is equivalent for the guarantee and cheaper —
     noted deviation.)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.agree import agree
from repro.distributed.consensus import maybe_sparsify


class SpectralInit(NamedTuple):
    U0: jax.Array        # (L, d, r) initial bases per node
    R_diag: jax.Array    # (L, r) diagonal of the final power-method R
    alpha: jax.Array     # (L,) truncation levels after AGREE


def _qr_pos(M):
    """QR with positive-diagonal R for determinism across nodes."""
    Q, R = jnp.linalg.qr(M)
    s = jnp.sign(jnp.diagonal(R, axis1=-2, axis2=-1))
    s = jnp.where(s == 0, 1.0, s)
    return Q * s[..., None, :], R * s[..., :, None]


def decentralized_spectral_init(key: jax.Array, Xg: jax.Array, yg: jax.Array,
                                W: jax.Array, *, kappa: float, mu: float,
                                r: int, T_pm: int, T_con: int,
                                broadcast: bool = True) -> SpectralInit:
    """Xg: (L, tpn, n, d) node-major designs, yg: (L, tpn, n), W: (L, L).

    Every AGREE here (the α threshold, the power-iteration combines, the
    node-0 broadcast) routes through :func:`maybe_sparsify`, so at scale
    (L ≥ 512, sparse graph) the init's consensus rounds run on the same
    padded-COO segment-sum lowering as the solver programs instead of
    dense (L, L) matmuls — identical arithmetic per round (pinned ≤1e-12
    in tests/test_sparse.py)."""
    W = maybe_sparsify(W)
    L, tpn, n, d = Xg.shape
    T = L * tpn
    dtype = Xg.dtype

    # --- lines 3-4: truncation threshold, gossiped ---------------------
    alpha_in = 9.0 * kappa**2 * mu**2 * (L / (n * T)) * jnp.sum(
        yg**2, axis=(1, 2))                                   # (L,)
    alpha = agree(alpha_in, W, T_con)

    # --- lines 5-7: truncated covariance columns ------------------------
    mask = (yg**2 <= alpha[:, None, None]).astype(dtype)
    y_trnc = yg * mask
    # Θ_g^(0): (L, d, tpn); column t = (1/n) X_tᵀ y_t,trnc
    Theta0 = jnp.einsum("gtnd,gtn->gdt", Xg, y_trnc) / n

    # --- lines 8-9: common Gaussian start, QR ---------------------------
    U_init = jax.random.normal(key, (d, r), dtype=dtype)      # same seed ∀g
    U, _ = _qr_pos(U_init)
    U = jnp.broadcast_to(U, (L, d, r))

    # --- lines 10-13: decentralized orthogonal iteration ----------------
    def pm_step(U, _):
        V = jnp.einsum("gdt,get,ger->gdr", Theta0, Theta0, U)  # Θ_gΘ_gᵀU_g
        V = agree(V, W, T_con)
        Q, R = _qr_pos(V)
        return Q, jnp.diagonal(R, axis1=-2, axis2=-1)

    U, R_diags = jax.lax.scan(pm_step, U, None, length=T_pm)
    R_diag = R_diags[-1]                                      # (L, r)

    # --- lines 14-15: broadcast node 0's basis --------------------------
    if broadcast:
        U_bc = jnp.zeros_like(U).at[0].set(U[0])
        U_bc = agree(U_bc, W, T_con)    # ≈ U_0 / L at every node
        U, _ = _qr_pos(U_bc)

    return SpectralInit(U0=U, R_diag=R_diag, alpha=alpha)
