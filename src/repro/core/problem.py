"""Synthetic multi-task linear-regression problem generator (paper Sec. II).

Model: for task t ∈ [T], y_t = X_t θ*_t with θ*_t = U* b*_t,
Θ* = U* Σ* V*ᵀ rank-r, X_t ∈ R^{n×d} i.i.d. standard Gaussian
(Assumption 2), incoherent B* (Assumption 1).  Tasks are partitioned
evenly over L nodes (the decentralized setting).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class MTRLProblem:
    """A generated Dec-MTRL instance.

    X: (T, n, d) design matrices (or (F, T, n, d) when sample-split into
       F folds — see :func:`split_samples`).
    y: (T, n) responses (or (F, T, n)).
    U_star: (d, r) orthonormal ground-truth basis.
    B_star: (r, T) coefficients; Theta_star = U_star @ B_star.
    tasks_per_node: (L, T/L) int array — node g owns row g (the sets S_g).
    """
    X: jax.Array
    y: jax.Array
    U_star: jax.Array
    B_star: jax.Array
    sigma_max: float
    sigma_min: float
    mu: float
    tasks_per_node: np.ndarray

    @property
    def d(self) -> int:
        return self.U_star.shape[0]

    @property
    def r(self) -> int:
        return self.U_star.shape[1]

    @property
    def T(self) -> int:
        return self.B_star.shape[1]

    @property
    def n(self) -> int:
        return self.y.shape[-1]

    @property
    def L(self) -> int:
        return self.tasks_per_node.shape[0]

    @property
    def kappa(self) -> float:
        return self.sigma_max / self.sigma_min

    @property
    def Theta_star(self) -> jax.Array:
        return self.U_star @ self.B_star


def generate_problem(key: jax.Array, *, d: int, T: int, r: int, n: int,
                     L: int, kappa: float = 1.0, noise_std: float = 0.0,
                     dtype=jnp.float64) -> MTRLProblem:
    """Generate the paper's synthetic setting.

    U* = QR(Gaussian d×r); V* = QR(Gaussian T×r); Σ* = diag geomspace so the
    condition number is exactly ``kappa``; scaling keeps σ*_min = 1.
    """
    if T % L != 0:
        raise ValueError(f"simulator requires L | T, got T={T}, L={L}")
    k_u, k_v, k_x, k_n = jax.random.split(key, 4)

    gu = jax.random.normal(k_u, (d, r), dtype=dtype)
    U_star, _ = jnp.linalg.qr(gu)
    gv = jax.random.normal(k_v, (T, r), dtype=dtype)
    V_star, _ = jnp.linalg.qr(gv)
    sig = jnp.geomspace(kappa, 1.0, r).astype(dtype)
    B_star = (sig[:, None] * V_star.T)  # (r, T)

    X = jax.random.normal(k_x, (T, n, d), dtype=dtype)
    Theta = U_star @ B_star                       # (d, T)
    y = jnp.einsum("tnd,dt->tn", X, Theta)
    if noise_std > 0:
        y = y + noise_std * jax.random.normal(k_n, y.shape, dtype=dtype)

    # incoherence parameter mu of Assumption 1 (measured, not imposed; for
    # Haar V* it concentrates near a small constant)
    bt_norms2 = jnp.sum(B_star ** 2, axis=0)
    mu = float(jnp.sqrt(jnp.max(bt_norms2) * T / (r * sig[0] ** 2)))

    tasks = np.arange(T).reshape(L, T // L)
    return MTRLProblem(X=X, y=y, U_star=U_star, B_star=B_star,
                       sigma_max=float(sig[0]), sigma_min=float(sig[-1]),
                       mu=mu, tasks_per_node=tasks)


def split_samples(problem: MTRLProblem, n_folds: int) -> MTRLProblem:
    """Sample-splitting (Algorithm 3 line 4): partition each task's n samples
    into ``n_folds`` disjoint folds (requires n_folds | n).  Returns a
    problem whose X/y carry a leading fold axis.  The paper's own simulations
    skip this; we expose it for the theory-path tests."""
    n = problem.n
    if n % n_folds != 0:
        raise ValueError(f"n_folds={n_folds} must divide n={n}")
    m = n // n_folds
    X = problem.X.reshape(problem.T, n_folds, m, problem.d).transpose(1, 0, 2, 3)
    y = problem.y.reshape(problem.T, n_folds, m).transpose(1, 0, 2)
    return dataclasses.replace(problem, X=X, y=y)


def node_view(problem: MTRLProblem):
    """Reshape task-major data into node-major (L, T/L, ...) blocks."""
    L, tpn = problem.tasks_per_node.shape
    Xg = problem.X[..., problem.tasks_per_node.reshape(-1), :, :]
    yg = problem.y[..., problem.tasks_per_node.reshape(-1), :]
    if problem.X.ndim == 4:   # folded
        Xg = Xg.reshape(problem.X.shape[0], L, tpn, problem.n, problem.d)
        yg = yg.reshape(problem.y.shape[0], L, tpn, problem.n)
    else:
        Xg = Xg.reshape(L, tpn, problem.n, problem.d)
        yg = yg.reshape(L, tpn, problem.n)
    return Xg, yg
