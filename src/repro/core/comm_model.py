"""Communication wall-clock model of the paper's Sec. V simulations.

The paper emulates a 1 Gbps / 5 ms network: per AGREE message
    t_comm = 5·10⁻³ + 8·d·r / 10⁹ + jitter   seconds
(double precision, 8 bytes/entry), with parallel send/receive — only the
max over a node's concurrent transfers counts.  We reproduce that model so
Fig. 1/2 "execution time" x-axes are comparable, and extend it with the
TPU-fabric constants used by the roofline analysis (50 GB/s/link ICI) so
the same experiment can be re-costed on the production target.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    bandwidth_bytes: float = 1e9 / 8     # 1 Gbps, in bytes/s
    latency_s: float = 5e-3
    jitter_std_s: float = 2e-4
    bytes_per_entry: int = 8             # double precision

    def message_time(self, n_entries: int, rng: np.random.Generator | None
                     = None, bytes_per_entry: int | None = None) -> float:
        """t_comm for one message of ``n_entries`` scalars (paper Sec. V).
        ``bytes_per_entry`` overrides the model's native wire precision
        for compressed payloads (a CommSignature's f32/bf16/int8 wire)."""
        bpe = self.bytes_per_entry if bytes_per_entry is None \
            else bytes_per_entry
        t = self.latency_s + bpe * n_entries / self.bandwidth_bytes
        if rng is not None and self.jitter_std_s > 0:
            t += float(abs(rng.normal(0.0, self.jitter_std_s)))
        return t


ETHERNET_1GBPS = NetworkModel()                         # the paper's network
TPU_ICI = NetworkModel(bandwidth_bytes=50e9, latency_s=1e-6,
                       jitter_std_s=0.0, bytes_per_entry=2)   # bf16 on ICI


def agree_round_time(d: int, r: int, max_deg: int, model: NetworkModel,
                     rng: np.random.Generator | None = None,
                     parallel: bool = True, *, n_entries: int | None = None,
                     bytes_per_entry: int | None = None) -> float:
    """Wall-clock of ONE gossip round exchanging a message with every
    neighbour — a dense d×r matrix unless ``n_entries`` /
    ``bytes_per_entry`` describe a compressed payload.  With parallel
    send/receive (the paper's assumption) only the slowest concurrent
    message counts; otherwise they serialize."""
    n = d * r if n_entries is None else n_entries
    times = [model.message_time(n, rng, bytes_per_entry=bytes_per_entry)
             for _ in range(max_deg)]
    return max(times) if parallel else sum(times)


def agree_round_time_degrees(d: int, r: int, degrees, model: NetworkModel,
                             rng: np.random.Generator | None = None, *,
                             n_entries: int | None = None,
                             bytes_per_entry: int | None = None) -> float:
    """Degree-weighted gossip round: node g exchanges ``degrees[g]``
    messages — one per incident edge, Σ_g deg_g = 2·|E| wire messages
    total, derived from the (sparse) edge set instead of a uniform
    ``max_deg`` assumption — and the synchronous round barrier is the
    max over every message."""
    n = d * r if n_entries is None else n_entries
    t = 0.0
    for deg in degrees:
        for _ in range(int(deg)):
            t = max(t, model.message_time(n, rng,
                                          bytes_per_entry=bytes_per_entry))
    return t


def decentralized_time_axis(n_iters: int, T_con: int, d: int, r: int,
                            max_deg: int, compute_time_per_iter: float,
                            model: NetworkModel = ETHERNET_1GBPS,
                            seed: int = 0, *, n_entries: int | None = None,
                            bytes_per_entry: int | None = None,
                            rng: np.random.Generator | None = None,
                            degrees=None) -> np.ndarray:
    """Cumulative wall-clock after each outer iteration for a decentralized
    run: per iteration, T_con gossip rounds + local compute.  ``rng``
    threads a caller-seeded generator (e.g. ``CommSpec.rng()``) through
    every jitter draw; without one, ``seed`` builds it here — either way
    the axis is reproducible.  ``degrees`` (per-node, from the graph's
    edge set) switches the round pricing to the degree-weighted message
    count of :func:`agree_round_time_degrees`."""
    rng = np.random.default_rng(seed) if rng is None else rng

    def round_time():
        if degrees is not None:
            return agree_round_time_degrees(
                d, r, degrees, model, rng, n_entries=n_entries,
                bytes_per_entry=bytes_per_entry)
        return agree_round_time(d, r, max_deg, model, rng,
                                n_entries=n_entries,
                                bytes_per_entry=bytes_per_entry)

    per_iter = np.array([
        sum(round_time() for _ in range(T_con)) + compute_time_per_iter
        for _ in range(n_iters)])
    return np.cumsum(per_iter)


def time_axis_from_signature(sig, n_iters: int, d: int, r: int, L: int,
                             max_deg: int, compute_s_per_iter: float,
                             model: NetworkModel = ETHERNET_1GBPS,
                             seed: int = 0, *,
                             rng: np.random.Generator | None = None,
                             degrees=None) -> np.ndarray:
    """Price a solver's wall-clock axis from its CombineRule
    :class:`~repro.distributed.consensus.CommSignature`: ``"central"``
    is a gather + broadcast per iteration, ``"none"`` is compute only,
    and the decentralized patterns cost ``rounds_per_iter`` gossip
    rounds with every neighbour.  The signature's payload fields
    (``entries_per_round``/``bytes_per_entry``) override the dense d×r
    exchange at the model's native precision, so compressed combine
    rules price their actual wire format.  ``rng`` threads one seeded
    generator through every jitter draw (``seed`` builds one
    otherwise).  ``degrees`` prices each round's message count from the
    graph's edge set (2·|E| messages, degree-weighted) instead of the
    uniform ``max_deg`` — dense and sparse representations of the same
    graph report identical degrees, so their axes agree draw for draw
    (the pricing-consistency regression)."""
    if sig.pattern == "central":
        return centralized_time_axis(n_iters, d, r, L, compute_s_per_iter,
                                     model=model, seed=seed, rng=rng)
    if sig.pattern == "none" or sig.rounds_per_iter == 0:
        return np.cumsum(np.full(n_iters, compute_s_per_iter))
    return decentralized_time_axis(
        n_iters, sig.rounds_per_iter, d, r, max_deg, compute_s_per_iter,
        model=model, seed=seed, rng=rng,
        n_entries=getattr(sig, "entries_per_round", None),
        bytes_per_entry=getattr(sig, "bytes_per_entry", None),
        degrees=degrees)


def centralized_time_axis(n_iters: int, d: int, r: int, L: int,
                          compute_time_per_iter: float,
                          model: NetworkModel = ETHERNET_1GBPS,
                          seed: int = 0, *,
                          rng: np.random.Generator | None = None
                          ) -> np.ndarray:
    """Centralized AltGDmin: one gather of gradients (L parallel uploads) +
    one broadcast of U per iteration."""
    rng = np.random.default_rng(seed) if rng is None else rng
    per_iter = np.array([
        max(model.message_time(d * r, rng) for _ in range(L))     # gather
        + max(model.message_time(d * r, rng) for _ in range(L))   # broadcast
        + compute_time_per_iter
        for _ in range(n_iters)])
    return np.cumsum(per_iter)
