"""Theorem 1 / complexity formulas of the paper, as executable functions.

These encode the *scaling* of the paper's guarantees (universal constants C
are arguments, default 1):

  * iteration counts  T_GD, T_pm, T_con,GD, T_con,init  (Theorem 1 a–b);
  * sample complexity  nT ≳ κ⁶ μ² (d+T) r (κ²r + log 1/ε)  (Theorem 1 c);
  * time  τ_time  and communication  τ_comm  complexities (Sec. III), for
    both Dif-AltGDmin (this paper) and Dec-AltGDmin [9] so the benchmark
    tables can show the claimed improvements (κ² vs κ⁴, ε-independent
    T_con,GD, no log d in τ_gd).

Also Proposition 1's consensus-round bound and the connectivity requirement
Eq. (2).
"""
from __future__ import annotations

import dataclasses
import math


def prop1_consensus_rounds(L: int, eps_con: float, gamma_W: float,
                           C: float = 1.0) -> int:
    """Proposition 1: T_con ≥ C · log(L/ε_con) / log(1/γ(W))."""
    if not 0.0 < gamma_W < 1.0:
        raise ValueError(f"need 0 < gamma(W) < 1, got {gamma_W}")
    return max(1, math.ceil(C * math.log(L / eps_con) / math.log(1.0 / gamma_W)))


def eq2_connectivity_requirement(L: int, eps_con: float, T_con: int,
                                 C: float = 1.0) -> float:
    """Eq. (2): γ(W) ≤ exp(−C log(L/ε_con)/T_con) — the largest admissible
    consensus contraction factor for a fixed round budget."""
    return math.exp(-C * math.log(L / eps_con) / T_con)


# ----------------------------------------------------------------------
# Theorem 1 parts a)–c)
# ----------------------------------------------------------------------

def T_pm(d: int, kappa: float, C: float = 1.0) -> int:
    """a) T_pm = Cκ²(log d + log κ)."""
    return max(1, math.ceil(C * kappa**2 * (math.log(d) + math.log(kappa))))


def T_con_init(L: int, d: int, r: int, kappa: float, gamma_W: float,
               C: float = 1.0) -> int:
    """a) T_con,init = C (log L + log d + log r + log κ)/log(1/γ(W))."""
    num = math.log(L) + math.log(d) + math.log(r) + math.log(max(kappa, 1.0 + 1e-12))
    return max(1, math.ceil(C * num / math.log(1.0 / gamma_W)))


def T_GD(kappa: float, eps: float, C: float = 1.0) -> int:
    """b) T_GD = Cκ² log(1/ε)."""
    return max(1, math.ceil(C * kappa**2 * math.log(1.0 / eps)))


def T_con_GD(L: int, r: int, kappa: float, gamma_W: float,
             C: float = 1.0) -> int:
    """b) T_con,GD = C (log L + log r + log κ)/log(1/γ(W)).

    The headline property: INDEPENDENT of the target accuracy ε, unlike
    Dec-AltGDmin's log(1/ε_con) ≳ log(Ldκ(1/ε)^{κ²})."""
    num = math.log(L) + math.log(r) + math.log(max(kappa, 1.0 + 1e-12))
    return max(1, math.ceil(C * num / math.log(1.0 / gamma_W)))


def T_con_GD_dec(L: int, d: int, kappa: float, eps: float, gamma_W: float,
                 C: float = 1.0) -> int:
    """Dec-AltGDmin's [9] consensus rounds per GD iteration:
    log(1/ε_con) ≳ log(L d κ (1/ε)^{κ²})  ⇒  grows with κ² log(1/ε)."""
    num = (math.log(L) + math.log(d) + math.log(max(kappa, 1.0 + 1e-12))
           + kappa**2 * math.log(1.0 / eps))
    return max(1, math.ceil(C * num / math.log(1.0 / gamma_W)))


def sample_complexity(d: int, T: int, r: int, kappa: float, mu: float,
                      eps: float, C: float = 1.0) -> float:
    """c) nT ≳ C κ⁶ μ² (d+T) r (κ²r + log(1/ε)) — lower bound on nT."""
    return C * kappa**6 * mu**2 * (d + T) * r * (kappa**2 * r + math.log(1.0 / eps))


def eta_star(n: int, sigma_max: float, c_eta: float = 0.4) -> float:
    """Theorem 1 step size η = c_η/(n σ*max²)."""
    return c_eta / (n * sigma_max**2)


def contraction_factor(kappa: float, c_eta: float = 0.4) -> float:
    """Per-iteration subspace-distance contraction of Lemma 1 Eq. (12):
    δ^(τ) ≤ (1 − 0.3 c_η/κ²) δ^(τ−1)."""
    return 1.0 - 0.3 * c_eta / kappa**2


# ----------------------------------------------------------------------
# Sec. III — time & communication complexity (Dif vs Dec), per paper
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ComplexityReport:
    algorithm: str
    tau_init: float      # initialization time complexity (flop-count scale)
    tau_gd: float        # GD-phase time complexity
    tau_comm: float      # total communication complexity (scalar-sends scale)
    T_pm: int
    T_GD: int
    T_con_init: int
    T_con_GD: int

    @property
    def tau_time(self) -> float:
        return self.tau_init + self.tau_gd


def _w_per_round(n: int, d: int, r: int, T: int) -> float:
    """ϖ = O(ndrT): aggregate per-round compute of one LS+grad (or PM) pass."""
    return float(n) * d * r * T


def dif_complexity(*, n: int, d: int, T: int, r: int, L: int, kappa: float,
                   eps: float, gamma_W: float, max_deg: int,
                   C: float = 1.0) -> ComplexityReport:
    """Eq. (4)-(5): τ_time = (T_con,init·T_pm)ϖ_init + (T_con,GD·T_GD)ϖ_gd,
    τ_comm = (T_con,init·T_pm + T_con,GD·T_GD)·(d r L max_deg)."""
    tpm = T_pm(d, kappa, C)
    tci = T_con_init(L, d, r, kappa, gamma_W, C)
    tgd = T_GD(kappa, eps, C)
    tcg = T_con_GD(L, r, kappa, gamma_W, C)
    w = _w_per_round(n, d, r, T)
    comm_unit = d * r * L * max_deg
    return ComplexityReport(
        algorithm="dif_altgdmin",
        tau_init=tci * tpm * w, tau_gd=tcg * tgd * w,
        tau_comm=(tci * tpm + tcg * tgd) * comm_unit,
        T_pm=tpm, T_GD=tgd, T_con_init=tci, T_con_GD=tcg)


def dec_complexity(*, n: int, d: int, T: int, r: int, L: int, kappa: float,
                   eps: float, gamma_W: float, max_deg: int,
                   C: float = 1.0) -> ComplexityReport:
    """Dec-AltGDmin [9] for comparison: κ⁴ scaling, ε-dependent consensus.

    τ_init ≈ κ⁴ max(log²d, log²κ, log²L, log²(1/ε))/log(1/γ) · ndrT
    τ_gd   ≈ κ⁴ log(1/ε) max(log(1/ε), log L, log d, log κ)/log(1/γ) · ndrT
    """
    # iteration structure: same T_pm/T_GD shape but with κ⁴-grade consensus
    tpm = max(1, math.ceil(C * kappa**2 * (math.log(d) + math.log(kappa))))
    # [9]'s T_con depends on ε (both phases)
    tci = T_con_GD_dec(L, d, kappa, eps, gamma_W, C)
    tgd = max(1, math.ceil(C * kappa**2 * math.log(1.0 / eps)))
    tcg = T_con_GD_dec(L, d, kappa, eps, gamma_W, C)
    w = _w_per_round(n, d, r, T)
    comm_unit = d * r * L * max_deg
    return ComplexityReport(
        algorithm="dec_altgdmin",
        tau_init=tci * tpm * w, tau_gd=tcg * tgd * w,
        tau_comm=(tci * tpm + tcg * tgd) * comm_unit,
        T_pm=tpm, T_GD=tgd, T_con_init=tci, T_con_GD=tcg)
