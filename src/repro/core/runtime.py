"""Dif-AltGDmin on the production mesh — the paper's Algorithm 3 with
nodes = mesh devices and AGREE = collective-permute ring gossip.

This is the hardware counterpart of the simulator in core/altgdmin.py:
each device holds ONE node's task shard (X_g, y_g) and subspace iterate
U_g; per outer iteration it solves its local LS, takes the projected-GD
pre-image, exchanges the iterate with its ring neighbours T_con times
(``lax.ppermute`` — nearest-neighbour on the ICI torus), and retracts
with a local QR.  Numerically identical to the simulator run with the
circulant ring W (tests/test_runtime_mesh.py), so every Theorem-1
guarantee transfers with γ(W) = γ(ring).

The min-B and gradient phases route through the same
:class:`repro.core.engine.AltgdminEngine` as the simulator (``engine=``/
``backend=`` kwargs): ``xla-ref`` reproduces the seed einsum numerics,
``pallas``/``pallas-interpret`` run the fused node-batched kernel on each
device — the hardware nodes get the fused production path.  Only the
gossip stays runtime-specific (collective-permutes instead of the
simulator's dense ``W`` products).

The federated property is structural: only Ŭ_g (d×r) crosses the wire;
X_g, y_g, B_g never leave the device.

Pass ``U_star`` to additionally record the simulator's per-iteration
metrics (sd_max / sd_mean / consensus spread, via one all-gather of the
d×r iterate per iteration) and get a full :class:`RunResult` back;
without it the return is the legacy ``(U_nodes, B_nodes)`` pair and no
extra collective runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import AltgdminEngine, resolve_engine
from repro.core.metrics import consensus_spread, subspace_distance
from repro.core.spectral import _qr_pos
from repro.distributed.gossip import ring_weights
from repro.utils.compat import shard_map as _shard_map


def dif_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                      T_GD: int, T_con: int,
                      shifts=(-1, 1), self_weight=None,
                      engine: AltgdminEngine | None = None,
                      backend: str | None = None, U_star=None):
    """U0: (L, d, r); Xg: (L, tpn, n, d); yg: (L, tpn, n) — leading axis
    sharded over ``axis_name`` (L = mesh axis size: one node per device).
    Returns (U_nodes, B_nodes) with the same layouts, or a
    :class:`~repro.core.altgdmin.RunResult` when ``U_star`` is given."""
    from repro.core.altgdmin import RunResult

    L = mesh.shape[axis_name]
    if U0.shape[0] != L:
        raise ValueError(f"need one node per device: L={U0.shape[0]} vs "
                         f"mesh axis {L}")
    sw, wn = ring_weights(shifts, self_weight)
    eta_L = eta * L
    eng = resolve_engine(engine, backend)
    with_metrics = U_star is not None

    def local_min_B(U, X, y):
        """b_t = (X_t U)† y_t for the device's tasks, through the engine
        (node-batch of one). X: (tpn, n, d)."""
        return eng.minimize_B(U[None], X[None], y[None])[0]

    def local_min_grad(U, X, y):
        """Fused min-B + gradient — ONE kernel dispatch per device per
        iteration on the pallas backends."""
        B, G = eng.min_grad(U[None], X[None], y[None], X[None], y[None],
                            same_data=True)
        return B[0], G[0]

    def gossip(z):
        def round_(carry, _):
            acc = sw * carry
            for s in shifts:
                perm = [(i, (i - s) % L) for i in range(L)]
                acc = acc + wn * jax.lax.ppermute(carry, axis_name, perm)
            return acc, None
        out, _ = jax.lax.scan(round_, z, None, length=T_con)
        return out

    def body(U0, Xg, yg, U_star):
        U = U0[0]                       # this device's node
        X, y = Xg[0], yg[0]

        def step(U, _):
            _, G = local_min_grad(U, X, y)
            U_breve = U - eta_L * G                  # local adapt
            U_tilde = gossip(U_breve)                # combine (diffusion)
            U_new, _ = _qr_pos(U_tilde)              # projection
            if not with_metrics:
                return U_new, None
            U_all = jax.lax.all_gather(U_new, axis_name)     # (L, d, r)
            return U_new, (subspace_distance(U_new, U_star),
                           consensus_spread(U_all))

        U_fin, metrics = jax.lax.scan(step, U, None, length=T_GD)
        B_fin = local_min_B(U_fin, X, y)
        if not with_metrics:
            return U_fin[None], B_fin[None]
        sd, spread = metrics
        return U_fin[None], B_fin[None], sd[None], spread[None]

    sharded = P(axis_name)
    out_specs = ((sharded,) * 4) if with_metrics else (sharded, sharded)
    run = _shard_map(body, mesh=mesh,
                     in_specs=(sharded, sharded, sharded, P()),
                     out_specs=out_specs,
                     axis_names={axis_name},
                     check_rep=not eng.fused)

    U_dummy = U0[0] if U_star is None else U_star
    out = run(U0, Xg, yg, U_dummy)
    if not with_metrics:
        return out
    U_fin, B_fin, sd, spread = out          # sd/spread: (L, T_GD)
    return RunResult(U_nodes=U_fin, B_nodes=B_fin,
                     sd_max=jnp.max(sd, axis=0),
                     sd_mean=jnp.mean(sd, axis=0),
                     spread=spread[0], eta=eta)
