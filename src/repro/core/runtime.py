"""AltGDmin on the production mesh — the paper's algorithms with
nodes = mesh devices and AGREE = collective-permute ring gossip.

This is the hardware counterpart of the simulator in core/altgdmin.py:
each device holds ONE node's task shard (X_g, y_g) and subspace iterate
U_g; per outer iteration it solves its local LS, takes the projected-GD
pre-image, exchanges iterates (or gradients) with its ring neighbours via
``lax.ppermute`` — nearest-neighbour on the ICI torus — and retracts with
a local QR.  Numerically identical to the simulator run with the
circulant ring W (tests/test_runtime_mesh.py), so every Theorem-1
guarantee transfers with γ(W) = γ(ring).

All three decentralized solvers share one shard_map skeleton
(:func:`_altgdmin_mesh`) and differ only in the per-iteration update:

  * :func:`dif_altgdmin_mesh` — adapt-then-combine (Algorithm 3);
  * :func:`dec_altgdmin_mesh` — combine-then-adjust (gossip the
    gradients [9]);
  * :func:`dgd_altgdmin_mesh` — DGD's self-excluding neighbour average
    (Experiment 1 iii).

The min-B and gradient phases route through the same
:class:`repro.core.engine.AltgdminEngine` as the simulator (``engine=``/
``backend=`` kwargs), and the combine phase through the unified
:class:`~repro.distributed.consensus.CombineRule` mesh lowering: per
gossip round the K neighbour blocks arrive by collective-permute and are
merged in ONE fused ``gossip_axpy.gossip_combine`` dispatch on the
pallas backends (the unfused weighted-sum chain remains the xla-ref /
float64 exact path).

The federated property is structural: only Ŭ_g (d×r) crosses the wire;
X_g, y_g, B_g never leave the device.

Pass ``U_star`` to additionally record the simulator's per-iteration
metrics (sd_max / sd_mean / consensus spread, via one all-gather of the
d×r iterate per iteration) and get a full :class:`RunResult` back;
without it the return is the legacy ``(U_nodes, B_nodes)`` pair and no
extra collective runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import AltgdminEngine, resolve_engine
from repro.core.metrics import consensus_spread, subspace_distance
from repro.core.spectral import _qr_pos
from repro.distributed.consensus import get_rule
from repro.utils.compat import shard_map as _shard_map


def _altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                   T_GD: int, make_update,
                   engine: AltgdminEngine | None,
                   backend: str | None, U_star):
    """Shared shard_map skeleton for the decentralized mesh solvers.

    ``make_update(eng) -> update(U, G)`` builds the per-iteration update
    (this device's iterate + local gradient → new iterate) from the
    resolved engine, so the closure can pick the engine's backend for
    its fused combine; everything else — the local fused min-B +
    gradient dispatch, the scan, the optional metrics all-gather, the
    final min-B — is solver-independent.
    """
    from repro.core.altgdmin import RunResult

    L = mesh.shape[axis_name]
    if U0.shape[0] != L:
        raise ValueError(f"need one node per device: L={U0.shape[0]} vs "
                         f"mesh axis {L}")
    eng = resolve_engine(engine, backend)
    update = make_update(eng)
    with_metrics = U_star is not None

    def local_min_B(U, X, y):
        """b_t = (X_t U)† y_t for the device's tasks, through the engine
        (node-batch of one). X: (tpn, n, d)."""
        return eng.minimize_B(U[None], X[None], y[None])[0]

    def local_min_grad(U, X, y):
        """Fused min-B + gradient — ONE kernel dispatch per device per
        iteration on the pallas backends."""
        B, G = eng.min_grad(U[None], X[None], y[None], X[None], y[None],
                            same_data=True)
        return B[0], G[0]

    def body(U0, Xg, yg, U_star):
        U = U0[0]                       # this device's node
        X, y = Xg[0], yg[0]

        def step(U, _):
            _, G = local_min_grad(U, X, y)
            U_new = update(U, G)
            if not with_metrics:
                return U_new, None
            U_all = jax.lax.all_gather(U_new, axis_name)     # (L, d, r)
            return U_new, (subspace_distance(U_new, U_star),
                           consensus_spread(U_all))

        U_fin, metrics = jax.lax.scan(step, U, None, length=T_GD)
        B_fin = local_min_B(U_fin, X, y)
        if not with_metrics:
            return U_fin[None], B_fin[None]
        sd, spread = metrics
        return U_fin[None], B_fin[None], sd[None], spread[None]

    sharded = P(axis_name)
    out_specs = ((sharded,) * 4) if with_metrics else (sharded, sharded)
    run = _shard_map(body, mesh=mesh,
                     in_specs=(sharded, sharded, sharded, P()),
                     out_specs=out_specs,
                     axis_names={axis_name},
                     check_rep=not eng.fused)

    U_dummy = U0[0] if U_star is None else U_star
    out = run(U0, Xg, yg, U_dummy)
    if not with_metrics:
        return out
    U_fin, B_fin, sd, spread = out          # sd/spread: (L, T_GD)
    return RunResult(U_nodes=U_fin, B_nodes=B_fin,
                     sd_max=jnp.max(sd, axis=0),
                     sd_mean=jnp.mean(sd, axis=0),
                     spread=spread[0], eta=eta)


def dif_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                      T_GD: int, T_con: int,
                      shifts=(-1, 1), self_weight=None,
                      engine: AltgdminEngine | None = None,
                      backend: str | None = None, U_star=None):
    """Algorithm 3 on the mesh: adapt (local projected-GD pre-image),
    THEN combine (T_con gossip rounds on the updated iterate), then the
    QR retraction.  U0: (L, d, r); Xg: (L, tpn, n, d); yg: (L, tpn, n) —
    leading axis sharded over ``axis_name`` (one node per device).
    Returns (U_nodes, B_nodes) with the same layouts, or a
    :class:`~repro.core.altgdmin.RunResult` when ``U_star`` is given."""
    L = mesh.shape[axis_name]
    eta_L = eta * L

    def make_update(eng):
        gossip = get_rule("gossip").make_mesh_mixer(
            axis_name, L, T_con, shifts, self_weight, backend=eng.backend)

        def update(U, G):
            U_breve = U - eta_L * G                  # local adapt
            U_tilde = gossip(U_breve)                # combine (diffusion)
            return _qr_pos(U_tilde)[0]               # projection
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star)


def dec_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                      T_GD: int, T_con: int,
                      shifts=(-1, 1), self_weight=None,
                      engine: AltgdminEngine | None = None,
                      backend: str | None = None, U_star=None):
    """Dec-AltGDmin [9] on the mesh: combine-then-adjust — T_con gossip
    rounds on the *gradients*, then the projected-GD step with the
    gossiped estimate.  Same layouts/returns as
    :func:`dif_altgdmin_mesh`."""
    L = mesh.shape[axis_name]
    eta_L = eta * L

    def make_update(eng):
        gossip = get_rule("gossip").make_mesh_mixer(
            axis_name, L, T_con, shifts, self_weight, backend=eng.backend)

        def update(U, G):
            G_hat = gossip(G)                        # consensus on grads
            return _qr_pos(U - eta_L * G_hat)[0]
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star)


def dgd_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                      T_GD: int, T_con: int = 1,
                      shifts=(-1, 1), self_weight=None,
                      engine: AltgdminEngine | None = None,
                      backend: str | None = None, U_star=None):
    """DGD-variation on the mesh (Experiment 1 iii):
    Ũ_g ← QR((1/K) Σ_s U_{g+s} − η ∇f_g) — ONE self-excluding neighbour
    exchange per iteration (the circulant graph of ``shifts`` is
    K-regular, so the simulator's (1/deg) adjacency average is exactly
    the equal-weight shift average).  ``T_con``/``self_weight`` are
    accepted for signature uniformity and ignored: the rule is a single
    round with structurally zero self weight."""
    L = mesh.shape[axis_name]

    def make_update(eng):
        nbr_mix = get_rule("neighbor").make_mesh_mixer(
            axis_name, L, 1, shifts, backend=eng.backend)

        def update(U, G):
            return _qr_pos(nbr_mix(U) - eta * G)[0]
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star)
