"""Dif-AltGDmin on the production mesh — the paper's Algorithm 3 with
nodes = mesh devices and AGREE = collective-permute ring gossip.

This is the hardware counterpart of the simulator in core/altgdmin.py:
each device holds ONE node's task shard (X_g, y_g) and subspace iterate
U_g; per outer iteration it solves its local LS, takes the projected-GD
pre-image, exchanges the iterate with its ring neighbours T_con times
(``lax.ppermute`` — nearest-neighbour on the ICI torus), and retracts
with a local QR.  Numerically identical to the simulator run with the
circulant ring W (tests/test_runtime_mesh.py), so every Theorem-1
guarantee transfers with γ(W) = γ(ring).

The federated property is structural: only Ŭ_g (d×r) crosses the wire;
X_g, y_g, B_g never leave the device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.spectral import _qr_pos
from repro.distributed.gossip import ring_weights
from repro.utils.compat import shard_map as _shard_map


def dif_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                      T_GD: int, T_con: int,
                      shifts=(-1, 1), self_weight=None):
    """U0: (L, d, r); Xg: (L, tpn, n, d); yg: (L, tpn, n) — leading axis
    sharded over ``axis_name`` (L = mesh axis size: one node per device).
    Returns (U_nodes, B_nodes) with the same layouts."""
    L = mesh.shape[axis_name]
    if U0.shape[0] != L:
        raise ValueError(f"need one node per device: L={U0.shape[0]} vs "
                         f"mesh axis {L}")
    sw, wn = ring_weights(shifts, self_weight)
    eta_L = eta * L

    def local_min_B(U, X, y):
        """b_t = (X_t U)† y_t for the device's tasks. X: (tpn, n, d)."""
        A = jnp.einsum("tnd,dr->tnr", X, U)
        G = jnp.einsum("tnr,tns->trs", A, A)
        c = jnp.einsum("tnr,tn->tr", A, y)
        return jax.vmap(lambda g, ci: jax.scipy.linalg.solve(
            g, ci, assume_a="pos"))(G, c)

    def local_grad(U, B, X, y):
        resid = jnp.einsum("tnd,dr,tr->tn", X, U, B) - y
        return jnp.einsum("tnd,tn,tr->dr", X, resid, B)

    def gossip(z):
        def round_(carry, _):
            acc = sw * carry
            for s in shifts:
                perm = [(i, (i - s) % L) for i in range(L)]
                acc = acc + wn * jax.lax.ppermute(carry, axis_name, perm)
            return acc, None
        out, _ = jax.lax.scan(round_, z, None, length=T_con)
        return out

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name)),
        axis_names={axis_name})
    def run(U0, Xg, yg):
        U = U0[0]                       # this device's node
        X, y = Xg[0], yg[0]

        def step(U, _):
            B = local_min_B(U, X, y)
            G = local_grad(U, B, X, y)
            U_breve = U - eta_L * G                  # local adapt
            U_tilde = gossip(U_breve)                # combine (diffusion)
            U_new, _ = _qr_pos(U_tilde)              # projection
            return U_new, None

        U_fin, _ = jax.lax.scan(step, U, None, length=T_GD)
        B_fin = local_min_B(U_fin, X, y)
        return U_fin[None], B_fin[None]

    return run(U0, Xg, yg)
