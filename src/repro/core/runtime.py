"""Substrate skeletons for AltGDmin on the production mesh.

This module holds the two shard_map iteration skeletons the program
lowerings in :mod:`repro.core.program` execute on:

  * :func:`_altgdmin_mesh`         — one node per device; per iteration
    each device solves its local LS, applies the program's update (the
    combine crossing the wire by ``lax.ppermute``), and retracts with a
    local QR.  Numerically identical to the simulator run with the same
    W (tests/test_runtime_mesh.py, tests/test_programs.py), so every
    Theorem-1 guarantee transfers with γ(W) of the actual topology.
  * :func:`_altgdmin_virtual_mesh` — the virtual-node block tier
    (L = devices × block): each device is a small simulator over a
    contiguous (block, d, r) slab; co-located gossip edges run as
    on-device segment-sums and one collective-permute crosses the wire
    per cross-device shift class
    (:class:`~repro.distributed.consensus.VirtualTopology`).

Neither skeleton knows any solver: the per-iteration update arrives as
``make_update(eng) -> update(U, aux, min_grad[, xt])`` built by
:func:`repro.core.program.lower_mesh` /
:func:`~repro.core.program.lower_virtual_mesh` from a
:class:`~repro.core.program.SolverProgram`.  The historical per-solver
``*_mesh`` closures this module used to carry are gone — the program
registry derives every solver's mesh and virtual-mesh entry points, and
``tools/check_runtime_clean.py`` guards against them growing back.

Topologies: the consensus layer lowers ANY concrete mixing matrix to
collective-permutes (``W=`` kwarg — one permute per distinct cyclic
shift of W's sparsity pattern, each device combining with its own W
row; see :func:`repro.distributed.consensus.mesh_weights_from_matrix`).
Without ``W`` the historical uniform circulant of ``shifts`` /
``self_weight`` runs (nearest-neighbour on the ICI torus).

The min-B and gradient phases route through the same
:class:`repro.core.engine.AltgdminEngine` as the simulator (``engine=``/
``backend=`` kwargs).  The federated property is structural: only the
iterate (or the rule's compact payload) crosses the wire; X_g, y_g, B_g
never leave the device.

Pass ``U_star`` to additionally record the simulator's per-iteration
metrics (sd_max / sd_mean / consensus spread, via one all-gather of the
iterate per iteration) and get a full :class:`RunResult` back; without
it the return is the legacy ``(U_nodes, B_nodes)`` pair and no extra
collective runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import AltgdminEngine, resolve_engine
from repro.core.metrics import consensus_spread, subspace_distance
from repro.utils.compat import shard_map as _shard_map


def _altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                   T_GD: int, make_update,
                   engine: AltgdminEngine | None,
                   backend: str | None, U_star, init_aux=None, xs=None):
    """Shared shard_map skeleton for the decentralized mesh solvers.

    ``make_update(eng) -> update(U, aux, min_grad)`` builds the
    per-iteration update from the resolved engine: it receives this
    device's iterate, the solver's auxiliary scan state (``None`` unless
    ``init_aux`` is given — e.g. exact diffusion's ψ correction), and a
    ``min_grad(U) -> (B, G)`` closure over the device's local data (ONE
    fused kernel dispatch per call on the pallas backends), and returns
    ``(U_new, aux_new)``.  Everything else — the scan, the optional
    metrics all-gather, the final min-B — is solver-independent.
    ``init_aux(U_local)`` seeds the auxiliary state from the device's
    starting iterate.

    ``xs`` (optional) is a pytree of per-iteration scan inputs with a
    leading T_GD axis, replicated to every device (the dropout solvers'
    availability masks); when given, the update is called as
    ``update(U, aux, min_grad, xt)`` with iteration τ's slice.
    """
    from repro.core.altgdmin import RunResult

    L = mesh.shape[axis_name]
    if U0.shape[0] != L:
        raise ValueError(f"need one node per device: L={U0.shape[0]} vs "
                         f"mesh axis {L}")
    eng = resolve_engine(engine, backend)
    update = make_update(eng)
    with_metrics = U_star is not None
    has_xs = xs is not None

    def local_min_B(U, X, y):
        """b_t = (X_t U)† y_t for the device's tasks, through the engine
        (node-batch of one). X: (tpn, n, d)."""
        return eng.minimize_B(U[None], X[None], y[None])[0]

    def local_min_grad(U, X, y):
        """Fused min-B + gradient — ONE kernel dispatch per device per
        call on the pallas backends."""
        B, G = eng.min_grad(U[None], X[None], y[None], X[None], y[None],
                            same_data=True)
        return B[0], G[0]

    def body(U0, Xg, yg, U_star, *rest):
        U = U0[0]                       # this device's node
        X, y = Xg[0], yg[0]

        def mg(U_):
            return local_min_grad(U_, X, y)

        def step(carry, xt):
            U, aux = carry
            if has_xs:
                U_new, aux_new = update(U, aux, mg, xt)
            else:
                U_new, aux_new = update(U, aux, mg)
            if not with_metrics:
                return (U_new, aux_new), None
            U_all = jax.lax.all_gather(U_new, axis_name)     # (L, d, r)
            return (U_new, aux_new), (subspace_distance(U_new, U_star),
                                      consensus_spread(U_all))

        aux0 = init_aux(U) if init_aux is not None else None
        xseq = rest[0] if has_xs else None
        (U_fin, _), metrics = jax.lax.scan(
            step, (U, aux0), xseq, length=None if has_xs else T_GD)
        B_fin = local_min_B(U_fin, X, y)
        if not with_metrics:
            return U_fin[None], B_fin[None]
        sd, spread = metrics
        return U_fin[None], B_fin[None], sd[None], spread[None]

    sharded = P(axis_name)
    out_specs = ((sharded,) * 4) if with_metrics else (sharded, sharded)
    run = _shard_map(body, mesh=mesh,
                     in_specs=(sharded, sharded, sharded, P())
                     + ((P(),) if has_xs else ()),
                     out_specs=out_specs,
                     axis_names={axis_name},
                     check_rep=not eng.fused)

    U_dummy = U0[0] if U_star is None else U_star
    out = run(U0, Xg, yg, U_dummy, *((xs,) if has_xs else ()))
    if not with_metrics:
        return out
    U_fin, B_fin, sd, spread = out          # sd/spread: (L, T_GD)
    return RunResult(U_nodes=U_fin, B_nodes=B_fin,
                     sd_max=jnp.max(sd, axis=0),
                     sd_mean=jnp.mean(sd, axis=0),
                     spread=spread[0], eta=eta)


def _altgdmin_virtual_mesh(U0, Xg, yg, mesh, axis_name: str, *, vt,
                           eta: float, T_GD: int, make_update,
                           engine: AltgdminEngine | None,
                           backend: str | None, U_star, init_aux=None,
                           xs=None):
    """Shared shard_map skeleton for the VIRTUAL-NODE mesh tier:
    L = devices × block nodes, each device holding a contiguous
    (block, d, r) slab of iterates and the matching data shard.  The
    local min-B/gradient phases run node-batched through the engine
    exactly like the simulator (a device IS a small simulator over its
    block); the combine inside the program's update is the
    :class:`~repro.distributed.consensus.VirtualTopology` lowering —
    co-located gossip as an on-device segment-sum shuffle, one
    collective-permute per cross-device edge class.  ``vt`` carries the
    decomposed mixing matrix (``VirtualTopology.from_weights``).

    Same ``make_update``/``init_aux``/``xs`` contract as
    :func:`_altgdmin_mesh`, except the per-device iterate is the
    (block, d, r) slab and ``min_grad`` is node-batched over it.
    Federated structure is preserved: only the (block, d, r) iterate
    slab (or the rule's compact payload) crosses the wire, never data."""
    from repro.core.altgdmin import RunResult

    D = mesh.shape[axis_name]
    L = U0.shape[0]
    if vt.n_dev != D or vt.n_nodes != L:
        raise ValueError(f"VirtualTopology is {vt.n_dev} dev × {vt.block} "
                         f"block but the run has {D} devices and L={L}")
    eng = resolve_engine(engine, backend)
    update = make_update(eng)
    with_metrics = U_star is not None
    has_xs = xs is not None

    def body(U0b, Xb, yb, U_star_, *rest):
        # U0b: (V, d, r) — this device's block of virtual nodes
        def mg(U_):
            return eng.min_grad(U_, Xb, yb, Xb, yb, same_data=True)

        def step(carry, xt):
            U, aux = carry
            if has_xs:
                U_new, aux_new = update(U, aux, mg, xt)
            else:
                U_new, aux_new = update(U, aux, mg)
            if not with_metrics:
                return (U_new, aux_new), None
            sd = jax.vmap(lambda u: subspace_distance(u, U_star_))(U_new)
            U_all = jax.lax.all_gather(U_new, axis_name)   # (D, V, d, r)
            spread = consensus_spread(
                U_all.reshape(L, *U_all.shape[2:]))
            return (U_new, aux_new), (sd, spread)

        aux0 = init_aux(U0b) if init_aux is not None else None
        xseq = rest[0] if has_xs else None
        (U_fin, _), metrics = jax.lax.scan(
            step, (U0b, aux0), xseq, length=None if has_xs else T_GD)
        B_fin = eng.minimize_B(U_fin, Xb, yb)
        if not with_metrics:
            return U_fin, B_fin
        sd, spread = metrics                         # (T, V), (T,)
        return U_fin, B_fin, sd[None], spread[None]

    sharded = P(axis_name)
    out_specs = ((sharded,) * 4) if with_metrics else (sharded, sharded)
    run = _shard_map(body, mesh=mesh,
                     in_specs=(sharded, sharded, sharded, P())
                     + ((P(),) if has_xs else ()),
                     out_specs=out_specs,
                     axis_names={axis_name},
                     check_rep=not eng.fused)

    U_dummy = U0[0] if U_star is None else U_star
    out = run(U0, Xg, yg, U_dummy, *((xs,) if has_xs else ()))
    if not with_metrics:
        return out
    U_fin, B_fin, sd, spread = out       # sd: (D, T_GD, V), spread: (D, T)
    return RunResult(U_nodes=U_fin, B_nodes=B_fin,
                     sd_max=jnp.max(sd, axis=(0, 2)),
                     sd_mean=jnp.mean(sd, axis=(0, 2)),
                     spread=spread[0], eta=eta)
