"""AltGDmin on the production mesh — the paper's algorithms with
nodes = mesh devices and AGREE = collective-permute gossip.

This is the hardware counterpart of the simulator in core/altgdmin.py:
each device holds ONE node's task shard (X_g, y_g) and subspace iterate
U_g; per outer iteration it solves its local LS, takes the projected-GD
pre-image, exchanges iterates (or gradients) with its graph neighbours
via ``lax.ppermute``, and retracts with a local QR.  Numerically
identical to the simulator run with the same W
(tests/test_runtime_mesh.py), so every Theorem-1 guarantee transfers
with γ(W) of the actual topology.

Topologies: the consensus layer lowers ANY concrete mixing matrix to
collective-permutes (``W=`` kwarg — one permute per distinct cyclic
shift of W's sparsity pattern, each device combining with its own W
row; see :func:`repro.distributed.consensus.mesh_weights_from_matrix`).
Without ``W`` the historical uniform circulant of ``shifts`` /
``self_weight`` runs (nearest-neighbour on the ICI torus).

All six registered solvers share one shard_map skeleton
(:func:`_altgdmin_mesh`) and differ only in the per-iteration update:

  * :func:`dif_altgdmin_mesh` — adapt-then-combine (Algorithm 3);
  * :func:`dec_altgdmin_mesh` — combine-then-adjust (gossip the
    gradients [9]);
  * :func:`dgd_altgdmin_mesh` — DGD's self-excluding neighbour average
    (Experiment 1 iii);
  * :func:`centralized_altgdmin_mesh` — fusion center (exact gradient
    ``psum``, AltGDmin [10]);
  * :func:`exact_diffusion_mesh` — bias-corrected combine
    (arXiv:2304.07358; the ψ correction state rides the scan carry);
  * :func:`beyond_central_mesh` — ``local_steps`` local adapt steps then
    ONE gossip round (arXiv:2512.22675);
  * :func:`dif_topk_mesh` / :func:`dif_quantized_mesh` /
    :func:`dif_event_mesh` — the compressed-wire variants: per gossip
    round each device encodes its error-compensated iterate (top-k rows
    / bf16-int8 quantization / event-triggered hold), the COMPACT
    payload crosses the wire by collective-permute, and the K+1
    decompressed blocks still merge in ONE fused ``gossip_combine``
    dispatch; the compression state (error-feedback residual /
    last-sent iterate) rides the aux scan carry;
  * :func:`dif_partial_mesh` / :func:`dif_stale_mesh` /
    :func:`dif_pushsum_mesh` — the dropout-tolerant variants: a
    (T_GD, L) availability mask rides the scan ``xs`` replicated to
    every device; down devices are frozen for the iteration and the
    masked combine rules reroute weight (partial), substitute stale
    copies (stale), or bias-correct with a push-sum weight carry
    (pushsum).

The min-B and gradient phases route through the same
:class:`repro.core.engine.AltgdminEngine` as the simulator (``engine=``/
``backend=`` kwargs), and the combine phase through the unified
:class:`~repro.distributed.consensus.CombineRule` mesh lowering: per
gossip round the K neighbour blocks arrive by collective-permute and are
merged in ONE fused ``gossip_axpy.gossip_combine`` dispatch on the
pallas backends (the unfused weighted-sum chain remains the xla-ref /
float64 exact path) — uniform or per-device weights alike.

The federated property is structural: only Ŭ_g (d×r) crosses the wire;
X_g, y_g, B_g never leave the device.

Pass ``U_star`` to additionally record the simulator's per-iteration
metrics (sd_max / sd_mean / consensus spread, via one all-gather of the
d×r iterate per iteration) and get a full :class:`RunResult` back;
without it the return is the legacy ``(U_nodes, B_nodes)`` pair and no
extra collective runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import AltgdminEngine, resolve_engine
from repro.core.metrics import consensus_spread, subspace_distance
from repro.core.spectral import _qr_pos
from repro.distributed.consensus import ExactDiffusionCombine, get_rule
from repro.utils.compat import shard_map as _shard_map


def _altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                   T_GD: int, make_update,
                   engine: AltgdminEngine | None,
                   backend: str | None, U_star, init_aux=None, xs=None):
    """Shared shard_map skeleton for the decentralized mesh solvers.

    ``make_update(eng) -> update(U, aux, min_grad)`` builds the
    per-iteration update from the resolved engine: it receives this
    device's iterate, the solver's auxiliary scan state (``None`` unless
    ``init_aux`` is given — e.g. exact diffusion's ψ correction), and a
    ``min_grad(U) -> (B, G)`` closure over the device's local data (ONE
    fused kernel dispatch per call on the pallas backends), and returns
    ``(U_new, aux_new)``.  Everything else — the scan, the optional
    metrics all-gather, the final min-B — is solver-independent.
    ``init_aux(U_local)`` seeds the auxiliary state from the device's
    starting iterate.

    ``xs`` (optional) is a pytree of per-iteration scan inputs with a
    leading T_GD axis, replicated to every device (the dropout solvers'
    availability masks); when given, the update is called as
    ``update(U, aux, min_grad, xt)`` with iteration τ's slice.
    """
    from repro.core.altgdmin import RunResult

    L = mesh.shape[axis_name]
    if U0.shape[0] != L:
        raise ValueError(f"need one node per device: L={U0.shape[0]} vs "
                         f"mesh axis {L}")
    eng = resolve_engine(engine, backend)
    update = make_update(eng)
    with_metrics = U_star is not None
    has_xs = xs is not None

    def local_min_B(U, X, y):
        """b_t = (X_t U)† y_t for the device's tasks, through the engine
        (node-batch of one). X: (tpn, n, d)."""
        return eng.minimize_B(U[None], X[None], y[None])[0]

    def local_min_grad(U, X, y):
        """Fused min-B + gradient — ONE kernel dispatch per device per
        call on the pallas backends."""
        B, G = eng.min_grad(U[None], X[None], y[None], X[None], y[None],
                            same_data=True)
        return B[0], G[0]

    def body(U0, Xg, yg, U_star, *rest):
        U = U0[0]                       # this device's node
        X, y = Xg[0], yg[0]

        def mg(U_):
            return local_min_grad(U_, X, y)

        def step(carry, xt):
            U, aux = carry
            if has_xs:
                U_new, aux_new = update(U, aux, mg, xt)
            else:
                U_new, aux_new = update(U, aux, mg)
            if not with_metrics:
                return (U_new, aux_new), None
            U_all = jax.lax.all_gather(U_new, axis_name)     # (L, d, r)
            return (U_new, aux_new), (subspace_distance(U_new, U_star),
                                      consensus_spread(U_all))

        aux0 = init_aux(U) if init_aux is not None else None
        xseq = rest[0] if has_xs else None
        (U_fin, _), metrics = jax.lax.scan(
            step, (U, aux0), xseq, length=None if has_xs else T_GD)
        B_fin = local_min_B(U_fin, X, y)
        if not with_metrics:
            return U_fin[None], B_fin[None]
        sd, spread = metrics
        return U_fin[None], B_fin[None], sd[None], spread[None]

    sharded = P(axis_name)
    out_specs = ((sharded,) * 4) if with_metrics else (sharded, sharded)
    run = _shard_map(body, mesh=mesh,
                     in_specs=(sharded, sharded, sharded, P())
                     + ((P(),) if has_xs else ()),
                     out_specs=out_specs,
                     axis_names={axis_name},
                     check_rep=not eng.fused)

    U_dummy = U0[0] if U_star is None else U_star
    out = run(U0, Xg, yg, U_dummy, *((xs,) if has_xs else ()))
    if not with_metrics:
        return out
    U_fin, B_fin, sd, spread = out          # sd/spread: (L, T_GD)
    return RunResult(U_nodes=U_fin, B_nodes=B_fin,
                     sd_max=jnp.max(sd, axis=0),
                     sd_mean=jnp.mean(sd, axis=0),
                     spread=spread[0], eta=eta)


def dif_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                      T_GD: int, T_con: int,
                      shifts=(-1, 1), self_weight=None, W=None,
                      engine: AltgdminEngine | None = None,
                      backend: str | None = None, U_star=None):
    """Algorithm 3 on the mesh: adapt (local projected-GD pre-image),
    THEN combine (T_con gossip rounds on the updated iterate), then the
    QR retraction.  U0: (L, d, r); Xg: (L, tpn, n, d); yg: (L, tpn, n) —
    leading axis sharded over ``axis_name`` (one node per device).
    ``W=`` gossips over an arbitrary concrete mixing matrix; otherwise
    the uniform circulant of ``shifts``/``self_weight``.
    Returns (U_nodes, B_nodes) with the same layouts, or a
    :class:`~repro.core.altgdmin.RunResult` when ``U_star`` is given."""
    L = mesh.shape[axis_name]
    eta_L = eta * L

    def make_update(eng):
        gossip = get_rule("gossip").make_mesh_mixer(
            axis_name, L, T_con, shifts, self_weight, W=W,
            backend=eng.backend)

        def update(U, aux, mg):
            _, G = mg(U)
            U_breve = U - eta_L * G                  # local adapt
            U_tilde = gossip(U_breve)                # combine (diffusion)
            return _qr_pos(U_tilde)[0], aux          # projection
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star)


def dif_altgdmin_virtual_mesh(U0, Xg, yg, mesh, axis_name: str, *, vt,
                              eta: float, T_GD: int, T_con: int,
                              engine: AltgdminEngine | None = None,
                              backend: str | None = None, U_star=None):
    """Algorithm 3 on the VIRTUAL-NODE mesh tier: L = devices × block
    nodes, each device holding a contiguous (block, d, r) slab of
    iterates and the matching data shard.  The local min-B/gradient
    phases run node-batched through the engine exactly like the
    simulator (a device IS a small simulator over its block); the
    combine phase is the
    :class:`~repro.distributed.consensus.VirtualTopology` lowering —
    co-located gossip as an on-device segment-sum shuffle, one
    collective-permute per cross-device edge class.  ``vt`` carries the
    decomposed mixing matrix (``VirtualTopology.from_weights``).
    Federated structure is preserved: only the (block, d, r) iterate
    slab crosses the wire, never data."""
    from repro.core.altgdmin import RunResult

    D = mesh.shape[axis_name]
    L = U0.shape[0]
    if vt.n_dev != D or vt.n_nodes != L:
        raise ValueError(f"VirtualTopology is {vt.n_dev} dev × {vt.block} "
                         f"block but the run has {D} devices and L={L}")
    eta_L = eta * L
    eng = resolve_engine(engine, backend)
    mixer = get_rule("gossip").make_virtual_mesh_mixer(
        axis_name, vt, T_con, backend=eng.backend)
    with_metrics = U_star is not None

    def body(U0b, Xb, yb, U_star_):
        # U0b: (V, d, r) — this device's block of virtual nodes
        def step(carry, _):
            U = carry
            _, G = eng.min_grad(U, Xb, yb, Xb, yb, same_data=True)
            U_breve = U - eta_L * G                  # local adapt
            U_tilde = mixer(U_breve)                 # combine (diffusion)
            U_new = jax.vmap(lambda u: _qr_pos(u)[0])(U_tilde)
            if not with_metrics:
                return U_new, None
            sd = jax.vmap(lambda u: subspace_distance(u, U_star_))(U_new)
            U_all = jax.lax.all_gather(U_new, axis_name)   # (D, V, d, r)
            spread = consensus_spread(
                U_all.reshape(L, *U_all.shape[2:]))
            return U_new, (sd, spread)

        U_fin, metrics = jax.lax.scan(step, U0b, None, length=T_GD)
        B_fin = eng.minimize_B(U_fin, Xb, yb)
        if not with_metrics:
            return U_fin, B_fin
        sd, spread = metrics                         # (T, V), (T,)
        return U_fin, B_fin, sd[None], spread[None]

    sharded = P(axis_name)
    out_specs = ((sharded,) * 4) if with_metrics else (sharded, sharded)
    run = _shard_map(body, mesh=mesh,
                     in_specs=(sharded, sharded, sharded, P()),
                     out_specs=out_specs,
                     axis_names={axis_name},
                     check_rep=not eng.fused)

    U_dummy = U0[0] if U_star is None else U_star
    out = run(U0, Xg, yg, U_dummy)
    if not with_metrics:
        return out
    U_fin, B_fin, sd, spread = out       # sd: (D, T_GD, V), spread: (D, T)
    return RunResult(U_nodes=U_fin, B_nodes=B_fin,
                     sd_max=jnp.max(sd, axis=(0, 2)),
                     sd_mean=jnp.mean(sd, axis=(0, 2)),
                     spread=spread[0], eta=eta)


def dec_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                      T_GD: int, T_con: int,
                      shifts=(-1, 1), self_weight=None, W=None,
                      engine: AltgdminEngine | None = None,
                      backend: str | None = None, U_star=None):
    """Dec-AltGDmin [9] on the mesh: combine-then-adjust — T_con gossip
    rounds on the *gradients*, then the projected-GD step with the
    gossiped estimate.  Same layouts/returns/topology kwargs as
    :func:`dif_altgdmin_mesh`."""
    L = mesh.shape[axis_name]
    eta_L = eta * L

    def make_update(eng):
        gossip = get_rule("gossip").make_mesh_mixer(
            axis_name, L, T_con, shifts, self_weight, W=W,
            backend=eng.backend)

        def update(U, aux, mg):
            _, G = mg(U)
            G_hat = gossip(G)                        # consensus on grads
            return _qr_pos(U - eta_L * G_hat)[0], aux
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star)


def dgd_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                      T_GD: int, T_con: int = 1,
                      shifts=(-1, 1), self_weight=None, W=None,
                      engine: AltgdminEngine | None = None,
                      backend: str | None = None, U_star=None):
    """DGD-variation on the mesh (Experiment 1 iii):
    Ũ_g ← QR((1/deg_g) Σ_{g'∈N_g} U_g' − η ∇f_g) — ONE self-excluding
    neighbour exchange per iteration.  Without ``W`` the circulant graph
    of ``shifts`` is K-regular, so the simulator's (1/deg) adjacency
    average is exactly the equal-weight shift average; pass ``W=`` the
    precomputed row-stochastic neighbour matrix (adj/deg, zero diagonal)
    for irregular graphs.  ``T_con``/``self_weight`` are accepted for
    signature uniformity and ignored: the rule is a single round with
    structurally zero self weight."""
    L = mesh.shape[axis_name]

    def make_update(eng):
        nbr_mix = get_rule("neighbor").make_mesh_mixer(
            axis_name, L, 1, shifts, W=W, backend=eng.backend)

        def update(U, aux, mg):
            _, G = mg(U)
            return _qr_pos(nbr_mix(U) - eta * G)[0], aux
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star)


def centralized_altgdmin_mesh(U0, Xg, yg, mesh, axis_name: str, *,
                              eta: float, T_GD: int, T_con: int = 0,
                              shifts=(), self_weight=None, W=None,
                              engine: AltgdminEngine | None = None,
                              backend: str | None = None, U_star=None):
    """AltGDmin [10] with a fusion center on the mesh: every device
    computes its local gradient, the exact sum arrives by one ``psum``
    (the all-reduce the fusion center amounts to), and all devices take
    the identical projected-GD step.  U0's node axis is broadcast from
    node 0 so every device starts (and stays) on the same iterate —
    the returned U_nodes rows are all equal to the simulator's single U.
    ``T_con``/``shifts``/``self_weight``/``W`` are accepted for mesh_fn
    signature uniformity and ignored (no graph: the combine is exact)."""
    U0 = jnp.broadcast_to(U0[:1], U0.shape)

    def make_update(eng):
        def update(U, aux, mg):
            _, G = mg(U)
            grad = jax.lax.psum(G, axis_name)        # fusion-center sum
            return _qr_pos(U - eta * grad)[0], aux
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star)


def exact_diffusion_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                         T_GD: int, T_con: int,
                         shifts=(-1, 1), self_weight=None, W=None,
                         engine: AltgdminEngine | None = None,
                         backend: str | None = None, U_star=None):
    """Exact Subspace Diffusion (arXiv:2304.07358) on the mesh:
    adapt-correct-combine.  The previous adapt state ψ rides the scan
    carry as ONE extra (d, r) buffer per device; per iteration
    ψ = U − ηL∇f, φ = ψ + U − ψ_prev (the bias correction — vanishing at
    τ=0 where ψ_prev = U0), then T_con gossip rounds on φ and the QR
    retraction.  Same layouts/returns/topology kwargs as
    :func:`dif_altgdmin_mesh`."""
    L = mesh.shape[axis_name]
    eta_L = eta * L

    def make_update(eng):
        gossip = get_rule("exact_diffusion").make_mesh_mixer(
            axis_name, L, T_con, shifts, self_weight, W=W,
            backend=eng.backend)

        def update(U, psi_prev, mg):
            _, G = mg(U)
            psi = U - eta_L * G                          # adapt
            phi = ExactDiffusionCombine.correct(psi, psi_prev, U)
            return _qr_pos(gossip(phi))[0], psi          # combine+project
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star,
                          init_aux=lambda U: U)


def beyond_central_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                        T_GD: int, T_con: int = 1, local_steps: int = 1,
                        shifts=(-1, 1), self_weight=None, W=None,
                        engine: AltgdminEngine | None = None,
                        backend: str | None = None, U_star=None):
    """Beyond Centralization (arXiv:2512.22675) on the mesh:
    ``local_steps`` full local adapt steps (fused min-B + projected GD +
    retraction, no communication) per outer iteration, then ONE gossip
    round — the wire carries a single d×r exchange per iteration
    regardless of ``T_con`` (which the combine rule ignores by
    construction).  Same layouts/returns/topology kwargs as
    :func:`dif_altgdmin_mesh`."""
    L = mesh.shape[axis_name]
    eta_L = eta * L

    def make_update(eng):
        mix1 = get_rule("beyond_central").make_mesh_mixer(
            axis_name, L, T_con, shifts, self_weight, W=W,
            backend=eng.backend)

        def update(U, aux, mg):
            for _ in range(local_steps):             # local adapt epoch
                _, G = mg(U)
                U = _qr_pos(U - eta_L * G)[0]
            return _qr_pos(mix1(U))[0], aux          # one combine round
        return update

    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star)


# ----------------------------------------------------------------------
# compressed-wire variants (stateful consensus rules)
# ----------------------------------------------------------------------

def _compressed_dif_mesh(U0, Xg, yg, mesh, axis_name: str, *,
                         rule_name: str, eta: float, T_GD: int, T_con: int,
                         shifts=(-1, 1), self_weight=None, W=None,
                         engine: AltgdminEngine | None = None,
                         backend: str | None = None, U_star=None,
                         **rule_kw):
    """Adapt-then-combine over a STATEFUL compressed combine rule: the
    rule's per-device compression state (error-feedback residual /
    last-sent iterate, kept node-batched with N = 1 so the encode is
    substrate-independent) rides the shared skeleton's aux scan carry.
    Per gossip round only the rule's compact payload crosses the wire;
    the K+1 decompressed blocks merge in ONE fused ``gossip_combine``
    dispatch on the pallas backends."""
    L = mesh.shape[axis_name]
    eta_L = eta * L
    rule = get_rule(rule_name)

    def make_update(eng):
        mix = rule.make_mesh_state_mixer(
            axis_name, L, T_con, shifts, self_weight, W=W,
            backend=eng.backend, **rule_kw)

        def update(U, cstate, mg):
            _, G = mg(U)
            U_breve = U - eta_L * G                  # local adapt
            U_tilde, cstate = mix(U_breve, cstate)   # compressed diffusion
            return _qr_pos(U_tilde)[0], cstate       # projection
        return update

    # one neighbour-copy buffer per distinct cyclic shift of the topology
    n_shifts = len(rule._mesh_weights(L, shifts, self_weight, W)[0])
    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star,
                          init_aux=lambda U: rule.init_mesh_state(
                              U, n_shifts, **rule_kw))


def dif_topk_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                  T_GD: int, T_con: int, compression_k: int = 0,
                  consensus_gamma: float = 1.0,
                  shifts=(-1, 1), self_weight=None, W=None,
                  engine: AltgdminEngine | None = None,
                  backend: str | None = None, U_star=None):
    """``dif_topk`` on the mesh: each gossip round permutes only the
    ``compression_k`` (0 → d/4) largest-norm rows + their int32 indices
    of the error-compensated iterate.  Same layouts/returns/topology
    kwargs as :func:`dif_altgdmin_mesh`."""
    return _compressed_dif_mesh(U0, Xg, yg, mesh, axis_name,
                                rule_name="topk_gossip", eta=eta,
                                T_GD=T_GD, T_con=T_con, shifts=shifts,
                                self_weight=self_weight, W=W, engine=engine,
                                backend=backend, U_star=U_star,
                                compression_k=compression_k,
                                consensus_gamma=consensus_gamma)


def dif_quantized_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                       T_GD: int, T_con: int, compression: str | None = None,
                       consensus_gamma: float = 1.0,
                       shifts=(-1, 1), self_weight=None, W=None,
                       engine: AltgdminEngine | None = None,
                       backend: str | None = None, U_star=None):
    """``dif_quantized`` on the mesh: the permuted payload is the
    low-precision wire cast (``compression``: bf16 default / int8 /
    int8_stochastic) of the error-compensated iterate; accumulation
    stays f32.  Same layouts/returns/topology kwargs as
    :func:`dif_altgdmin_mesh`."""
    return _compressed_dif_mesh(U0, Xg, yg, mesh, axis_name,
                                rule_name="quantized_gossip", eta=eta,
                                T_GD=T_GD, T_con=T_con, shifts=shifts,
                                self_weight=self_weight, W=W, engine=engine,
                                backend=backend, U_star=U_star,
                                compression=compression,
                                consensus_gamma=consensus_gamma)


def dif_event_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                   T_GD: int, T_con: int, event_threshold: float = 0.0,
                   consensus_gamma: float = 1.0,
                   shifts=(-1, 1), self_weight=None, W=None,
                   engine: AltgdminEngine | None = None,
                   backend: str | None = None, U_star=None):
    """``dif_event`` on the mesh: a device re-broadcasts its iterate only
    when it moved more than θ·‖U_g‖_F since the last send (the SPMD
    program still executes the permute every round — the saving is a
    message-count one on real event-driven networks).  θ = 0 recovers
    :func:`dif_altgdmin_mesh` bit-identically."""
    return _compressed_dif_mesh(U0, Xg, yg, mesh, axis_name,
                                rule_name="event_gossip", eta=eta,
                                T_GD=T_GD, T_con=T_con, shifts=shifts,
                                self_weight=self_weight, W=W, engine=engine,
                                backend=backend, U_star=U_star,
                                event_threshold=event_threshold,
                                consensus_gamma=consensus_gamma)


# ----------------------------------------------------------------------
# dropout-tolerant variants (availability-masked consensus rules)
# ----------------------------------------------------------------------

def _masked_dif_mesh(U0, Xg, yg, mesh, axis_name: str, *, rule_name: str,
                     eta: float, T_GD: int, T_con: int, avail=None,
                     shifts=(-1, 1), self_weight=None, W=None,
                     engine: AltgdminEngine | None = None,
                     backend: str | None = None, U_star=None):
    """Adapt-then-combine under a per-iteration availability mask
    ``avail: (T_GD, L)`` (truthy = live), replicated to every device and
    riding the skeleton's scan ``xs``.  Down devices still execute the
    SPMD program (a static schedule cannot elide a step) but their
    iterate is frozen for the iteration and the masked combine rule
    routes weight/stale-copies/push-sum mass around them — the simulated
    system clock prices the time they actually save.  ``avail=None``
    reproduces the dense mesh solver (bit-for-bit for ``partial_gossip``
    / ``stale_gossip``)."""
    L = mesh.shape[axis_name]
    eta_L = eta * L
    rule = get_rule(rule_name)
    stateful = rule_name == "stale_gossip"
    if avail is None:
        avail = jnp.ones((T_GD, L), bool)
    avail = jnp.asarray(avail).astype(bool)
    if avail.shape != (T_GD, L):
        raise ValueError(f"availability mask {avail.shape} does not "
                         f"match (T_GD, L) = ({T_GD}, {L})")

    def make_update(eng):
        if stateful:
            mix = rule.make_mesh_masked_state_mixer(
                axis_name, L, T_con, shifts, self_weight, W=W,
                backend=eng.backend)
        else:
            mix = rule.make_mesh_masked_mixer(
                axis_name, L, T_con, shifts, self_weight, W=W,
                backend=eng.backend)

        def update(U, aux, mg, m):
            g = jax.lax.axis_index(axis_name)
            _, G = mg(U)
            U_breve = U - eta_L * G                  # local adapt
            if stateful:
                U_tilde, aux = mix(U_breve, aux, m)
            else:
                U_tilde = mix(U_breve, m)
            # down this iteration: frozen (no adapt/combine/retraction)
            U_new = jnp.where(m[g], _qr_pos(U_tilde)[0], U)
            return U_new, aux
        return update

    init_aux = (lambda U: rule.init_mesh_state(U)) if stateful else None
    return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta, T_GD=T_GD,
                          make_update=make_update, engine=engine,
                          backend=backend, U_star=U_star,
                          init_aux=init_aux, xs=avail)


def dif_partial_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                     T_GD: int, T_con: int, avail=None,
                     shifts=(-1, 1), self_weight=None, W=None,
                     engine: AltgdminEngine | None = None,
                     backend: str | None = None, U_star=None):
    """``dif_partial`` on the mesh: per gossip round each device zeroes
    the weights of links with a down endpoint and folds the lost mass
    into its self weight (its row of the masked mixing matrix).  Full
    availability reproduces :func:`dif_altgdmin_mesh` bit-for-bit."""
    return _masked_dif_mesh(U0, Xg, yg, mesh, axis_name,
                            rule_name="partial_gossip", eta=eta,
                            T_GD=T_GD, T_con=T_con, avail=avail,
                            shifts=shifts, self_weight=self_weight, W=W,
                            engine=engine, backend=backend, U_star=U_star)


def dif_stale_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                   T_GD: int, T_con: int, avail=None,
                   shifts=(-1, 1), self_weight=None, W=None,
                   engine: AltgdminEngine | None = None,
                   backend: str | None = None, U_star=None):
    """``dif_stale`` on the mesh: each device's last-published copy
    rides the aux scan carry (ONE extra d×r buffer); a down neighbour's
    permuted payload is its stale copy, combined with the DENSE weights.
    Full availability reproduces :func:`dif_altgdmin_mesh`
    bit-for-bit."""
    return _masked_dif_mesh(U0, Xg, yg, mesh, axis_name,
                            rule_name="stale_gossip", eta=eta,
                            T_GD=T_GD, T_con=T_con, avail=avail,
                            shifts=shifts, self_weight=self_weight, W=W,
                            engine=engine, backend=backend, U_star=U_star)


def dif_pushsum_mesh(U0, Xg, yg, mesh, axis_name: str, *, eta: float,
                     T_GD: int, T_con: int, avail=None,
                     shifts=(-1, 1), self_weight=None, W=None,
                     engine: AltgdminEngine | None = None,
                     backend: str | None = None, U_star=None):
    """``dif_pushsum`` on the mesh: each live device renormalizes its
    own column of the masked matrix (requires symmetric W — validated),
    pre-scales its (iterate, weight-scalar) payload, and the readout
    z/w bias-corrects the directed masked topology.  Full availability
    matches :func:`dif_altgdmin_mesh` to float round-off."""
    return _masked_dif_mesh(U0, Xg, yg, mesh, axis_name,
                            rule_name="push_sum_gossip", eta=eta,
                            T_GD=T_GD, T_con=T_con, avail=avail,
                            shifts=shifts, self_weight=self_weight, W=W,
                            engine=engine, backend=backend, U_star=U_star)
