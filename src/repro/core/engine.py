"""Fused node-batched AltGDmin iteration engine.

The simulator's hot loop (Algorithm 3 lines 8–14) factors into three
phases per outer iteration: min-B (per-task least squares), the gradient
of f_g w.r.t. U_g, and the AGREE combine.  This module is the single
place where those phases bind to an execution backend:

  * ``xla-ref``          — the seed's unfused ``vmap``/``einsum`` paths,
                           dtype-preserving (works in x64); the numerics
                           fallback every other backend is tested against.
  * ``pallas-interpret`` — the fused node-batched Pallas kernel
                           (:func:`repro.kernels.altgdmin_ls.node_fused_iter`)
                           executed in interpret mode (CPU-exact validation
                           of the TPU code path).
  * ``pallas``           — the same kernel compiled (TPU production).

On the fused backends one outer iteration is ONE kernel dispatch that
streams ``A = X_t U`` exactly once per task (the unfused path builds it
twice: once for the Gram system, once in the gradient's pass 0), and the
AGREE phase is hoisted onto the precomputed ``W^{T_con}`` single-product
form (:func:`repro.core.agree.agree_power`) executed as one fused
weighted combine (``ops.mix_nodes``) instead of T_con HBM sweeps.

Backend selection: explicit argument → ``REPRO_ENGINE_BACKEND`` env →
``REPRO_KERNEL_BACKEND`` env → ``pallas`` on TPU, ``xla-ref`` elsewhere
(so existing CPU callers keep bit-identical trajectories by default).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.consensus import get_rule
from repro.kernels import ops


# ----------------------------------------------------------------------
# reference phase implementations (the seed's unfused simulator paths)
# ----------------------------------------------------------------------

def ref_minimize_B(U_nodes, Xg, yg):
    """Min step (Algorithm 3 line 8): column-wise least squares
    b_t = (X_t U_g)† y_t, batched over nodes and local tasks.

    Solved via the normal equations with a Cholesky solve — A = X_t U_g is
    n×r with tiny r, and AᵀA is well conditioned whp under Assumption 2.
    """
    def per_task(U, X, y):
        A = X @ U                       # (n, r)
        G = A.T @ A                     # (r, r)
        c = A.T @ y                     # (r,)
        return jax.scipy.linalg.solve(G, c, assume_a="pos")

    return jax.vmap(lambda U, Xs, ys:
                    jax.vmap(lambda X, y: per_task(U, X, y))(Xs, ys)
                    )(U_nodes, Xg, yg)                     # (L, tpn, r)


def ref_grad_U(U_nodes, B_nodes, Xg, yg):
    """Local gradient (Algorithm 3 line 11):
    ∇f_g = Σ_{t∈S_g} X_tᵀ (X_t U_g b_t − y_t) b_tᵀ."""
    def per_node(U, Xs, ys, Bs):
        resid = jnp.einsum("tnd,dr,tr->tn", Xs, U, Bs) - ys    # (tpn, n)
        return jnp.einsum("tnd,tn,tr->dr", Xs, resid, Bs)      # (d, r)

    return jax.vmap(per_node)(U_nodes, Xg, yg, B_nodes)        # (L, d, r)


def default_engine_backend() -> str:
    """ops.default_backend's chain (override → env → auto) with the
    engine's extra env var and an xla-ref off-TPU fallback — NOT
    pallas-interpret, so CPU simulator runs keep seed numerics unless
    fused is asked for."""
    return ops.default_backend(extra_env="REPRO_ENGINE_BACKEND",
                               off_tpu_fallback="xla-ref")


class AltgdminEngine:
    """Binds the three AltGDmin phases to a kernel backend.

    One instance is shared by all four algorithm drivers in
    :mod:`repro.core.altgdmin`; construct with ``backend=`` to opt into
    the fused path, or leave None for env/auto selection."""

    def __init__(self, backend: str | None = None, *, blk_d: int = 256):
        if backend is None:
            backend = default_engine_backend()
        if backend not in ops.BACKENDS:
            raise ValueError(f"unknown engine backend {backend!r}; "
                             f"expected one of {ops.BACKENDS}")
        self.backend = backend
        self.blk_d = blk_d

    @property
    def fused(self) -> bool:
        return self.backend != "xla-ref"

    # ------------------------------------------------------------ phases

    def minimize_B(self, U_nodes, Xg, yg):
        """(L, tpn, r) min-B solutions."""
        if not self.fused:
            return ref_minimize_B(U_nodes, Xg, yg)
        B = ops.altgdmin_node_minimize_B(Xg, U_nodes, yg, blk_d=self.blk_d,
                                         backend=self.backend)
        return B.astype(U_nodes.dtype)

    def grad_U(self, U_nodes, B_nodes, Xg, yg):
        """(L, d, r) local gradients for a given B (sample-split path)."""
        if not self.fused:
            return ref_grad_U(U_nodes, B_nodes, Xg, yg)
        G = ops.altgdmin_node_gradient(Xg, U_nodes, B_nodes, yg,
                                       blk_d=self.blk_d,
                                       backend=self.backend)
        return G.astype(U_nodes.dtype)

    def min_grad(self, U_nodes, X_min, y_min, X_grad, y_grad, *,
                 same_data: bool):
        """Min-B on (X_min, y_min) then ∇f on (X_grad, y_grad).

        When both halves see the same fold (``same_data`` — the paper's
        simulations) and the backend is fused, this is ONE kernel dispatch
        reusing the streamed A accumulator; otherwise A must be rebuilt on
        the gradient fold and the two-dispatch path runs."""
        if self.fused and same_data:
            B, G = ops.altgdmin_fused_step(X_min, U_nodes, y_min,
                                           blk_d=self.blk_d,
                                           backend=self.backend)
            return B.astype(U_nodes.dtype), G.astype(U_nodes.dtype)
        B = self.minimize_B(U_nodes, X_min, y_min)
        return B, self.grad_U(U_nodes, B, X_grad, y_grad)

    # ----------------------------------------------------------- combine

    def make_mixer(self, W, T_con: int, *, rule: str = "gossip"):
        """The AGREE phase as a callable Z ↦ consensus(Z), lowered by the
        named :class:`~repro.distributed.consensus.CombineRule`.

        xla-ref keeps the exact sequential T_con-round product (seed
        numerics, any dtype); fused backends hoist onto the precomputed
        W^{T_con} single combine, with the f64 fallback to the exact
        path (the fused kernel accumulates in f32)."""
        return get_rule(rule).make_sim_mixer(W, T_con, backend=self.backend)

    def make_neighbor_mixer(self, M):
        """DGD's row-stochastic neighbour average Z ↦ M Z (single round,
        no self weight — M comes in precomputed)."""
        return get_rule("neighbor").make_sim_mixer(M, backend=self.backend)

    def make_state_mixer(self, W, T_con: int, *, rule: str, **rule_kw):
        """Stateful combine for the compressed/event-triggered rules:
        ``(Z, state) ↦ (Z', state')``.  ``rule_kw`` carries the rule's
        spec knobs (``compression_k``, ``compression``,
        ``event_threshold``); the state itself comes from the rule's
        ``init_state`` and rides the driver's scan carry."""
        return get_rule(rule).make_sim_state_mixer(
            W, T_con, backend=self.backend, **rule_kw)

    def make_masked_mixer(self, W, T_con: int, *, rule: str):
        """Availability-masked combine (dropout-tolerant rules):
        ``(Z, m) ↦ Z'`` where ``m: (L,)`` is the current iteration's
        participation mask."""
        return get_rule(rule).make_sim_masked_mixer(
            W, T_con, backend=self.backend)

    def make_masked_state_mixer(self, W, T_con: int, *, rule: str,
                                **rule_kw):
        """Stateful availability-masked combine (``stale_gossip``):
        ``(Z, state, m) ↦ (Z', state')``."""
        return get_rule(rule).make_sim_masked_state_mixer(
            W, T_con, backend=self.backend, **rule_kw)

    # ------------------------------------------------- virtual mesh combine

    def make_virtual_mixer(self, vt, axis_name: str, T_con: int, *,
                           rule: str = "gossip"):
        """The AGREE phase on the virtual-node block tier: a per-device
        closure ``z (V, d, r) ↦ z'`` running T_con sparse segment-sum
        rounds (co-located edges on-device, one ppermute per cross-device
        shift class)."""
        return get_rule(rule).make_virtual_mesh_mixer(
            axis_name, vt, T_con, backend=self.backend)

    def make_virtual_state_mixer(self, vt, axis_name: str, T_con: int, *,
                                 rule: str, **rule_kw):
        """Stateful virtual-tier combine (compressed/event rules):
        ``(z, state) ↦ (z', state')`` with the block's stacked public
        copies as state (``init_state`` on the block slice)."""
        return get_rule(rule).make_virtual_mesh_state_mixer(
            axis_name, vt, T_con, backend=self.backend, **rule_kw)

    def make_virtual_masked_mixer(self, vt, axis_name: str, T_con: int, *,
                                  rule: str):
        """Availability-masked virtual-tier combine: ``(z, m) ↦ z'``
        with ``m: (L,)`` replicated on every device."""
        return get_rule(rule).make_virtual_mesh_masked_mixer(
            axis_name, vt, T_con, backend=self.backend)

    def make_virtual_masked_state_mixer(self, vt, axis_name: str,
                                        T_con: int, *, rule: str,
                                        **rule_kw):
        """Stateful availability-masked virtual-tier combine
        (``stale_gossip``): ``(z, state, m) ↦ (z', state')``."""
        return get_rule(rule).make_virtual_mesh_masked_state_mixer(
            axis_name, vt, T_con, backend=self.backend, **rule_kw)


def resolve_engine(engine=None, backend: str | None = None,
                   blk_d: int = 256) -> AltgdminEngine:
    """Normalize the (engine, backend) pair every algorithm driver takes:
    pass an engine through, else build one from ``backend``.  Passing
    both with disagreeing backends is an error (the explicit engine would
    silently win otherwise)."""
    if engine is not None:
        if backend is not None and backend != engine.backend:
            raise ValueError(
                f"conflicting engine selection: engine.backend="
                f"{engine.backend!r} but backend={backend!r}")
        return engine
    return AltgdminEngine(backend, blk_d=blk_d)
