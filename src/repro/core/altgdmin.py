"""AltGDmin family — Algorithm 3 (Dif-AltGDmin) and the three baselines
compared in the paper's Experiment 1:

  * ``dif_altgdmin``        — the paper's contribution (adapt-then-combine);
  * ``dec_altgdmin``        — [9]'s combine-then-adjust (consensus on
                              gradients before the projected-GD step);
  * ``centralized_altgdmin``— AltGDmin [10] with a fusion center (exact
                              gradient aggregation);
  * ``dgd_altgdmin``        — the DGD-variation defined in Experiment 1:
                              Ũ_g ← QR((1/deg_g) Σ_{g'∈N_g} U_g' − η ∇f_g);

plus the related-work combine-rule variants enabled by the unified
consensus layer (:mod:`repro.distributed.consensus`):

  * ``exact_diffusion_altgdmin`` — the projection-corrected combine of
    Exact Subspace Diffusion (arXiv:2304.07358): the adapt iterate is
    bias-corrected with the previous adapt state before the AGREE
    product, so the combine tracks the exact fixed point;
  * ``beyond_central_altgdmin``  — the communication-efficient variant of
    Beyond Centralization (arXiv:2512.22675): several local adapt steps
    per outer iteration, then ONE gossip round (a single d×r exchange
    per iteration instead of the T_con-round chain);

and the compressed-communication variants on the consensus layer's
stateful wire rules (top-k sparsified / quantized / event-triggered
gossip with error feedback riding the scan carry):

  * ``dif_topk_altgdmin``      — ``topk_gossip`` (k rows per round);
  * ``dif_quantized_altgdmin`` — ``quantized_gossip`` (bf16/int8 wire);
  * ``dif_event_altgdmin``     — ``event_gossip`` (threshold-triggered);

and the dropout-tolerant variants consuming a (T_GD, L) availability
mask (system-realism layer; down nodes are frozen for the iteration):

  * ``dif_partial_altgdmin`` — ``partial_gossip`` (masked weights);
  * ``dif_stale_altgdmin``   — ``stale_gossip`` (last-delivered copies);
  * ``dif_pushsum_altgdmin`` — ``push_sum_gossip`` (bias-corrected
    ratio consensus for the directed masked topology).

Simulator layout: node axis leading. U_nodes: (L, d, r); per-node data
Xg: (L, tpn, n, d), yg: (L, tpn, n).  All loops are lax.scan so tracing
stays cheap for T_GD in the hundreds.

Sample splitting: if Xg/yg carry a leading fold axis (F, L, ...), the
0-based iteration τ = 0, 1, … uses fold (2τ mod F) for the min step and
fold (2τ+1 mod F) for the gradient step, mirroring Algorithm 3's
disjoint-set schedule (consecutive fresh folds per iteration, wrapping
modulo F); the final B refit reuses the LAST min fold, 2·(T_GD−1) mod F,
so B is fit on the same data that produced the final U.  Without a fold
axis the same data is reused every iteration (as in the paper's
simulations) and the refit fold index is irrelevant.

Execution: every driver routes its min-B/gradient/combine phases through
an :class:`repro.core.engine.AltgdminEngine` (``engine=`` or ``backend=``
kwargs).  The default backend off-TPU is ``xla-ref`` — the seed's unfused
einsum paths, bit-identical to the pre-engine code; ``pallas`` /
``pallas-interpret`` select the fused node-batched kernel where one outer
iteration is a single dispatch and AGREE runs as one precomputed
W^{T_con} combine.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import (AltgdminEngine, ref_grad_U, ref_minimize_B,
                               resolve_engine)
from repro.core.metrics import subspace_distance, consensus_spread
from repro.core.spectral import _qr_pos
from repro.distributed.consensus import (ExactDiffusionCombine, get_rule,
                                         neighbor_average_matrix)


class RunResult(NamedTuple):
    U_nodes: jax.Array       # (L, d, r) final bases ((1,d,r) for centralized)
    B_nodes: jax.Array       # (L, tpn, r) final coefficients
    sd_max: jax.Array        # (T_GD,) max_g SD₂(U_g, U*) per iteration
    sd_mean: jax.Array       # (T_GD,)
    spread: jax.Array        # (T_GD,) max_{g,g'} ||U_g − U_g'||_F
    eta: float
    # (T_GD,) measured per-iteration send rate (event-triggered rule
    # only; feeds the system clock's wire pricing).  Trailing default
    # keeps the historical 6-positional constructors working.
    send_frac: Optional[jax.Array] = None


# ----------------------------------------------------------------------
# shared pieces
# ----------------------------------------------------------------------

# The unfused reference implementations live in repro.core.engine (they
# are the engine's xla-ref backend); re-exported here under their
# historical names.
minimize_B = ref_minimize_B
grad_U = ref_grad_U


def theta_nodes(U_nodes, B_nodes):
    """θ_t = U_g b_t for local tasks: (L, tpn, d)."""
    return jnp.einsum("gdr,gtr->gtd", U_nodes, B_nodes)


def _select(Xg, yg, fold):
    if Xg.ndim == 5:     # (F, L, tpn, n, d)
        F = Xg.shape[0]
        i = fold % F
        return Xg[i], yg[i]
    return Xg, yg


def _metrics(U_nodes, U_star):
    sd = jax.vmap(lambda U: subspace_distance(U, U_star))(U_nodes)
    return jnp.max(sd), jnp.mean(sd), consensus_spread(U_nodes)


def resolve_eta(eta, n, sigma_max=None, R_diag=None, L=None,
                c_eta: float = 0.4):
    """η = c_η / (n σ*max²) (Theorem 1).  When σ*max is unknown, estimate
    σ̂max² = L · max diag(R^(T_pm)) from the spectral init (the power method
    converges to the top eigenvalue of (1/L) Θ*Θ*ᵀ = σ*max²/L), matching the
    paper's simulation recipe."""
    if eta is not None:
        return float(eta)
    if sigma_max is not None:
        return c_eta / (n * sigma_max**2)
    sig2 = float(L * jnp.max(R_diag))
    return c_eta / (n * sig2)


# ----------------------------------------------------------------------
# algorithms
# ----------------------------------------------------------------------

def dif_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int, T_con: int,
                 U_star=None, engine: Optional[AltgdminEngine] = None,
                 backend: Optional[str] = None) -> RunResult:
    """Algorithm 3: adapt (min-B + local projected-GD pre-image) THEN
    combine (AGREE on the updated iterate), then QR retraction."""
    L = U0_nodes.shape[0]
    U_star_ = U_star if U_star is not None else U0_nodes[0]
    eng = resolve_engine(engine, backend)
    same_data = Xg.ndim == 4                  # no sample-split fold axis
    mix = eng.make_mixer(W, T_con)

    def step(U, tau):
        Xb, yb = _select(Xg, yg, 2 * tau)
        Xc, yc = _select(Xg, yg, 2 * tau + 1)
        B, G = eng.min_grad(U, Xb, yb, Xc, yc,
                            same_data=same_data)   # lines 8 & 11, fused
        U_breve = U - (eta * L) * G           # local update (line 12)
        U_tilde = mix(U_breve)                # diffusion     (line 13)
        U_new, _ = _qr_pos(U_tilde)           # projection    (line 14)
        return U_new, _metrics(U_new, U_star_)

    U_fin, (sd_max, sd_mean, spread) = jax.lax.scan(
        step, U0_nodes, jnp.arange(T_GD))
    B_fin = eng.minimize_B(U_fin, *_select(Xg, yg, 2 * (T_GD - 1)))
    return RunResult(U_fin, B_fin, sd_max, sd_mean, spread, eta)


def dec_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int, T_con: int,
                 U_star=None, engine: Optional[AltgdminEngine] = None,
                 backend: Optional[str] = None) -> RunResult:
    """Dec-AltGDmin [9]: combine-then-adjust — consensus on the *gradients*
    first, then each node takes the projected-GD step with the gossiped
    gradient estimate."""
    L = U0_nodes.shape[0]
    U_star_ = U_star if U_star is not None else U0_nodes[0]
    eng = resolve_engine(engine, backend)
    same_data = Xg.ndim == 4
    mix = eng.make_mixer(W, T_con)

    def step(U, tau):
        Xb, yb = _select(Xg, yg, 2 * tau)
        Xc, yc = _select(Xg, yg, 2 * tau + 1)
        B, G = eng.min_grad(U, Xb, yb, Xc, yc, same_data=same_data)
        G_hat = mix(G)                        # consensus on gradients
        U_new, _ = _qr_pos(U - (eta * L) * G_hat)
        return U_new, _metrics(U_new, U_star_)

    U_fin, (sd_max, sd_mean, spread) = jax.lax.scan(
        step, U0_nodes, jnp.arange(T_GD))
    B_fin = eng.minimize_B(U_fin, *_select(Xg, yg, 2 * (T_GD - 1)))
    return RunResult(U_fin, B_fin, sd_max, sd_mean, spread, eta)


def centralized_altgdmin(U0, Xg, yg, *, eta: float, T_GD: int,
                         U_star=None, engine: Optional[AltgdminEngine] = None,
                         backend: Optional[str] = None) -> RunResult:
    """AltGDmin [10] with a fusion center: exact gradient sum, single U.
    U0: (d, r).  Data still node-major for API symmetry."""
    U_star_ = U_star if U_star is not None else U0
    eng = resolve_engine(engine, backend)
    same_data = Xg.ndim == 4

    def step(U, tau):
        Xb, yb = _select(Xg, yg, 2 * tau)
        Xc, yc = _select(Xg, yg, 2 * tau + 1)
        Ub = jnp.broadcast_to(U[None], (Xb.shape[0],) + U.shape)
        B, G = eng.min_grad(Ub, Xb, yb, Xc, yc, same_data=same_data)
        grad = jnp.sum(G, axis=0)             # fusion-center aggregation
        U_new, _ = _qr_pos(U - eta * grad)
        sd = subspace_distance(U_new, U_star_)
        return U_new, (sd, sd, jnp.zeros((), U.dtype))

    U_fin, (sd_max, sd_mean, spread) = jax.lax.scan(
        step, U0, jnp.arange(T_GD))
    Xb, yb = _select(Xg, yg, 0)
    B_fin = eng.minimize_B(jnp.broadcast_to(U_fin[None],
                                            (Xb.shape[0],) + U_fin.shape),
                           Xb, yb)
    return RunResult(U_fin[None], B_fin, sd_max, sd_mean, spread, eta)


def exact_diffusion_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int,
                             T_con: int, U_star=None,
                             engine: Optional[AltgdminEngine] = None,
                             backend: Optional[str] = None) -> RunResult:
    """Exact Subspace Diffusion (arXiv:2304.07358): adapt-correct-combine.

    Per iteration: ψ_g = U_g − ηL ∇f_g (adapt), then the bias correction
    φ_g = ψ_g + U_g^{prev-combined} − ψ_g^{prev} (the exact-diffusion
    recursion — at τ=0 the correction vanishes), then T_con AGREE rounds
    on φ and the QR retraction back onto the Grassmannian (the subspace
    "projection" step).  Removes the diffusion bias floor when the nodes'
    local minimizers disagree (heterogeneous tasks)."""
    L = U0_nodes.shape[0]
    U_star_ = U_star if U_star is not None else U0_nodes[0]
    eng = resolve_engine(engine, backend)
    same_data = Xg.ndim == 4
    mix = eng.make_mixer(W, T_con, rule="exact_diffusion")

    def step(carry, tau):
        U, psi_prev = carry
        Xb, yb = _select(Xg, yg, 2 * tau)
        Xc, yc = _select(Xg, yg, 2 * tau + 1)
        B, G = eng.min_grad(U, Xb, yb, Xc, yc, same_data=same_data)
        psi = U - (eta * L) * G                        # adapt
        phi = ExactDiffusionCombine.correct(psi, psi_prev, U)
        U_tilde = mix(phi)                             # combine
        U_new, _ = _qr_pos(U_tilde)                    # projection
        return (U_new, psi), _metrics(U_new, U_star_)

    (U_fin, _), (sd_max, sd_mean, spread) = jax.lax.scan(
        step, (U0_nodes, U0_nodes), jnp.arange(T_GD))
    B_fin = eng.minimize_B(U_fin, *_select(Xg, yg, 2 * (T_GD - 1)))
    return RunResult(U_fin, B_fin, sd_max, sd_mean, spread, eta)


def beyond_central_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int,
                            T_con: int = 1, local_steps: int = 1,
                            U_star=None,
                            engine: Optional[AltgdminEngine] = None,
                            backend: Optional[str] = None) -> RunResult:
    """Beyond Centralization (arXiv:2512.22675): communication-efficient
    AltGDmin — ``local_steps`` full local adapt steps (min-B + projected
    GD + retraction, no communication) per outer iteration, then ONE
    gossip round.  The wire cost per outer iteration is a single d×r
    neighbour exchange, independent of ``T_con`` (which the combine rule
    ignores by construction)."""
    L = U0_nodes.shape[0]
    U_star_ = U_star if U_star is not None else U0_nodes[0]
    eng = resolve_engine(engine, backend)
    same_data = Xg.ndim == 4
    mix1 = eng.make_mixer(W, T_con, rule="beyond_central")

    def step(U, tau):
        for j in range(local_steps):                   # local adapt epoch
            fold = tau * local_steps + j
            Xb, yb = _select(Xg, yg, 2 * fold)
            Xc, yc = _select(Xg, yg, 2 * fold + 1)
            B, G = eng.min_grad(U, Xb, yb, Xc, yc, same_data=same_data)
            U, _ = _qr_pos(U - (eta * L) * G)
        U_new, _ = _qr_pos(mix1(U))                    # one combine round
        return U_new, _metrics(U_new, U_star_)

    U_fin, (sd_max, sd_mean, spread) = jax.lax.scan(
        step, U0_nodes, jnp.arange(T_GD))
    # the last LOCAL min fold: iteration T_GD−1's final adapt step
    B_fin = eng.minimize_B(U_fin, *_select(Xg, yg,
                                           2 * (T_GD * local_steps - 1)))
    return RunResult(U_fin, B_fin, sd_max, sd_mean, spread, eta)


def dgd_altgdmin(U0_nodes, Xg, yg, adj, *, eta: float, T_GD: int,
                 U_star=None, engine: Optional[AltgdminEngine] = None,
                 backend: Optional[str] = None) -> RunResult:
    """DGD-variation of AltGDmin (Experiment 1 (iii)):
    Ũ_g ← QR( (1/deg_g) Σ_{g'∈N_g} U_g'^{(τ-1)} − η ∇f_g ).
    ``adj``: (L, L) adjacency (no self loops), per the paper's formula the
    neighbour average EXCLUDES the node itself."""
    M = neighbor_average_matrix(adj)          # row-stochastic neighbour avg
    U_star_ = U_star if U_star is not None else U0_nodes[0]
    eng = resolve_engine(engine, backend)
    same_data = Xg.ndim == 4
    nbr_mix = eng.make_neighbor_mixer(M)

    def step(U, tau):
        Xb, yb = _select(Xg, yg, 2 * tau)
        Xc, yc = _select(Xg, yg, 2 * tau + 1)
        B, G = eng.min_grad(U, Xb, yb, Xc, yc, same_data=same_data)
        nbr = nbr_mix(U)
        U_new, _ = _qr_pos(nbr - eta * G)
        return U_new, _metrics(U_new, U_star_)

    U_fin, (sd_max, sd_mean, spread) = jax.lax.scan(
        step, U0_nodes, jnp.arange(T_GD))
    B_fin = eng.minimize_B(U_fin, *_select(Xg, yg, 2 * (T_GD - 1)))
    return RunResult(U_fin, B_fin, sd_max, sd_mean, spread, eta)


# ----------------------------------------------------------------------
# compressed-communication variants (stateful consensus rules)
# ----------------------------------------------------------------------

def _compressed_dif(U0_nodes, Xg, yg, W, *, rule_name: str, eta: float,
                    T_GD: int, T_con: int, U_star, engine, backend,
                    **rule_kw) -> RunResult:
    """Dif-AltGDmin (adapt-then-combine) with a STATEFUL compressed
    combine rule: the rule's per-node compression state (error-feedback
    residual / last-sent iterate) rides the lax.scan carry next to U and
    is updated by every gossip round, so compression error is fed back
    instead of discarded."""
    L = U0_nodes.shape[0]
    U_star_ = U_star if U_star is not None else U0_nodes[0]
    eng = resolve_engine(engine, backend)
    same_data = Xg.ndim == 4
    rule = get_rule(rule_name)
    mix = eng.make_state_mixer(W, T_con, rule=rule_name, **rule_kw)
    state0 = rule.init_state(U0_nodes, **rule_kw)
    # Event rule: also record the measured trigger rate per iteration
    # (first-round decision against the carried public copies — the
    # same condition the rule's encode uses), the telemetry the system
    # clock prices actual wire traffic with.
    is_event = rule_name == "event_gossip"
    threshold = float(rule_kw.get("event_threshold", 0.0))

    def step(carry, tau):
        U, cstate = carry
        Xb, yb = _select(Xg, yg, 2 * tau)
        Xc, yc = _select(Xg, yg, 2 * tau + 1)
        B, G = eng.min_grad(U, Xb, yb, Xc, yc, same_data=same_data)
        U_breve = U - (eta * L) * G              # local adapt
        if is_event:
            sf = rule.send_fraction(U_breve, cstate, threshold)
        U_tilde, cstate = mix(U_breve, cstate)   # compressed diffusion
        U_new, _ = _qr_pos(U_tilde)              # projection
        out = _metrics(U_new, U_star_)
        if is_event:
            out = out + (sf,)
        return (U_new, cstate), out

    (U_fin, _), outs = jax.lax.scan(
        step, (U0_nodes, state0), jnp.arange(T_GD))
    sfrac = None
    if is_event:
        sd_max, sd_mean, spread, sfrac = outs
    else:
        sd_max, sd_mean, spread = outs
    B_fin = eng.minimize_B(U_fin, *_select(Xg, yg, 2 * (T_GD - 1)))
    return RunResult(U_fin, B_fin, sd_max, sd_mean, spread, eta,
                     send_frac=sfrac)


def dif_topk_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int,
                      T_con: int, compression_k: int = 0,
                      consensus_gamma: float = 1.0, U_star=None,
                      engine: Optional[AltgdminEngine] = None,
                      backend: Optional[str] = None) -> RunResult:
    """Dif-AltGDmin over the ``topk_gossip`` rule: each gossip round
    exchanges only the ``compression_k`` largest-norm rows of the
    error-compensated iterate (0 → d/4), with the compression error fed
    back next round.  ``compression_k = d`` recovers ``dif_altgdmin``
    bit-identically on the exact (xla-ref / f64) path; fused backends
    agree to f32 round-off only, since dense gossip hoists the whole
    AGREE phase into one precomputed W^{T_con} combine while the
    compressed rule must mix round by round.  ``consensus_gamma`` is
    the CHOCO consensus step size: ``Z ← Z + γ(W x̂ − Z)`` relaxes the
    gossip move toward the compressed average, stabilizing aggressive
    sparsification (k ≪ d/4); γ = 1 is the plain combine, preserved
    bit-for-bit."""
    return _compressed_dif(U0_nodes, Xg, yg, W, rule_name="topk_gossip",
                           eta=eta, T_GD=T_GD, T_con=T_con, U_star=U_star,
                           engine=engine, backend=backend,
                           compression_k=compression_k,
                           consensus_gamma=consensus_gamma)


def dif_quantized_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int,
                           T_con: int, compression: Optional[str] = None,
                           consensus_gamma: float = 1.0, U_star=None,
                           engine: Optional[AltgdminEngine] = None,
                           backend: Optional[str] = None) -> RunResult:
    """Dif-AltGDmin over the ``quantized_gossip`` rule: the wire carries
    a low-precision cast of the error-compensated iterate —
    ``compression`` picks ``"bf16"`` (default), ``"int8"``, or
    ``"int8_stochastic"`` — while the combine accumulates in f32 (f64 on
    the exact x64 path)."""
    return _compressed_dif(U0_nodes, Xg, yg, W,
                           rule_name="quantized_gossip", eta=eta,
                           T_GD=T_GD, T_con=T_con, U_star=U_star,
                           engine=engine, backend=backend,
                           compression=compression,
                           consensus_gamma=consensus_gamma)


def dif_event_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int,
                       T_con: int, event_threshold: float = 0.0,
                       consensus_gamma: float = 1.0, U_star=None,
                       engine: Optional[AltgdminEngine] = None,
                       backend: Optional[str] = None) -> RunResult:
    """Dif-AltGDmin over the ``event_gossip`` rule: a node re-broadcasts
    its iterate only when ‖U_g − U_g^last-sent‖_F > θ·‖U_g‖_F
    (θ = ``event_threshold``); neighbours combine with the stale
    last-sent value otherwise.  θ = 0 recovers ``dif_altgdmin``
    bit-identically on the exact (xla-ref / f64) path (fused backends:
    f32 round-off vs the hoisted W^{T_con} dense combine)."""
    return _compressed_dif(U0_nodes, Xg, yg, W, rule_name="event_gossip",
                           eta=eta, T_GD=T_GD, T_con=T_con, U_star=U_star,
                           engine=engine, backend=backend,
                           event_threshold=event_threshold,
                           consensus_gamma=consensus_gamma)


# ----------------------------------------------------------------------
# dropout-tolerant variants (availability-masked consensus rules)
# ----------------------------------------------------------------------

def _masked_dif(U0_nodes, Xg, yg, W, *, rule_name: str, eta: float,
                T_GD: int, T_con: int, avail, U_star, engine,
                backend) -> RunResult:
    """Dif-AltGDmin (adapt-then-combine) under a per-iteration node
    availability mask ``avail: (T_GD, L)`` (truthy = live).  Down nodes
    are FULLY frozen for the iteration — no adapt, no combine, no
    retraction — and the masked combine rule decides how the live nodes
    mix around the hole (weight folding / stale copies / push-sum).
    All T_con AGREE rounds of one iteration share its mask: node churn
    is an outer-iteration phenomenon here.  ``avail=None`` (or all
    ones) reproduces the dense drivers — bit-for-bit for
    ``partial_gossip`` and ``stale_gossip``, to float round-off for
    ``push_sum_gossip`` (its ratio correction is different arithmetic).
    """
    L = U0_nodes.shape[0]
    U_star_ = U_star if U_star is not None else U0_nodes[0]
    eng = resolve_engine(engine, backend)
    same_data = Xg.ndim == 4
    rule = get_rule(rule_name)
    stateful = rule_name == "stale_gossip"
    if avail is None:
        avail = jnp.ones((T_GD, L), bool)
    avail = jnp.asarray(avail).astype(bool)
    if avail.shape != (T_GD, L):
        raise ValueError(f"availability mask {avail.shape} does not "
                         f"match (T_GD, L) = ({T_GD}, {L})")
    if stateful:
        mix = eng.make_masked_state_mixer(W, T_con, rule=rule_name)
        state0 = rule.init_state(U0_nodes)
    else:
        mix = eng.make_masked_mixer(W, T_con, rule=rule_name)

    def step(carry, xt):
        tau, m = xt
        U = carry[0] if stateful else carry
        Xb, yb = _select(Xg, yg, 2 * tau)
        Xc, yc = _select(Xg, yg, 2 * tau + 1)
        B, G = eng.min_grad(U, Xb, yb, Xc, yc, same_data=same_data)
        U_breve = U - (eta * L) * G              # local adapt
        if stateful:
            U_tilde, cstate = mix(U_breve, carry[1], m)
        else:
            U_tilde = mix(U_breve, m)
        # down nodes are frozen for the whole iteration (a masked rule
        # already returns their iterate unchanged through the combine,
        # but the adapt/retraction must be undone too)
        U_new = jnp.where(m[:, None, None], _qr_pos(U_tilde)[0], U)
        out = _metrics(U_new, U_star_)
        return ((U_new, cstate) if stateful else U_new), out

    carry0 = (U0_nodes, state0) if stateful else U0_nodes
    carry_fin, (sd_max, sd_mean, spread) = jax.lax.scan(
        step, carry0, (jnp.arange(T_GD), avail))
    U_fin = carry_fin[0] if stateful else carry_fin
    B_fin = eng.minimize_B(U_fin, *_select(Xg, yg, 2 * (T_GD - 1)))
    return RunResult(U_fin, B_fin, sd_max, sd_mean, spread, eta)


def dif_partial_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int,
                         T_con: int, avail=None, U_star=None,
                         engine: Optional[AltgdminEngine] = None,
                         backend: Optional[str] = None) -> RunResult:
    """Dif-AltGDmin over ``partial_gossip``: per iteration, links with a
    down endpoint carry no weight and the lost mass folds into the self
    weight (the effective matrix stays doubly stochastic for symmetric
    W).  ``avail`` all-ones reproduces ``dif_altgdmin`` bit-for-bit."""
    return _masked_dif(U0_nodes, Xg, yg, W, rule_name="partial_gossip",
                       eta=eta, T_GD=T_GD, T_con=T_con, avail=avail,
                       U_star=U_star, engine=engine, backend=backend)


def dif_stale_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int,
                       T_con: int, avail=None, U_star=None,
                       engine: Optional[AltgdminEngine] = None,
                       backend: Optional[str] = None) -> RunResult:
    """Dif-AltGDmin over ``stale_gossip``: every node's last-published
    copy persists in the scan carry; live nodes combine dense weights
    with a down neighbour's STALE copy instead of reweighting around
    it.  ``avail`` all-ones reproduces ``dif_altgdmin`` bit-for-bit."""
    return _masked_dif(U0_nodes, Xg, yg, W, rule_name="stale_gossip",
                       eta=eta, T_GD=T_GD, T_con=T_con, avail=avail,
                       U_star=U_star, engine=engine, backend=backend)


def dif_pushsum_altgdmin(U0_nodes, Xg, yg, W, *, eta: float, T_GD: int,
                         T_con: int, avail=None, U_star=None,
                         engine: Optional[AltgdminEngine] = None,
                         backend: Optional[str] = None) -> RunResult:
    """Dif-AltGDmin over ``push_sum_gossip``: the masked mixing matrix
    is column-stochastic (each live sender renormalizes its own column)
    and a companion weight scalar carried through the same matrix
    bias-corrects the readout z/w — exact averaging under the DIRECTED
    effective topologies dropout induces.  ``avail`` all-ones matches
    ``dif_altgdmin`` to float round-off (the ratio correction is
    genuinely different arithmetic)."""
    return _masked_dif(U0_nodes, Xg, yg, W, rule_name="push_sum_gossip",
                       eta=eta, T_GD=T_GD, T_con=T_con, avail=avail,
                       U_star=U_star, engine=engine, backend=backend)
