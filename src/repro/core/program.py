"""Solver programs — one declarative IR, three lowerings.

The AltGDmin family is one alternating loop: a local min-B/gradient
step through the :class:`~repro.core.engine.AltgdminEngine`, a
per-solver combination of the iterate with a
:class:`~repro.distributed.consensus.CombineRule`, and the QR
retraction.  Historically the repo encoded that loop 2–3 times per
solver — a simulator scan driver in :mod:`repro.core.altgdmin`, a
hand-written ``*_mesh`` closure on :func:`repro.core.runtime.
_altgdmin_mesh`, and (for ``dif_altgdmin`` only) a separate
virtual-node runtime.  A :class:`SolverProgram` captures the loop ONCE
as data:

  * ``update`` — the per-iteration body, written against a substrate-
    independent :class:`ProgramCtx` (``min_grad``/``mix``/``qr``/
    ``all_sum``/``where_live`` plus the step sizes);
  * ``mixer`` — which CombineRule lowering family carries the combine
    (``plain``/``neighbor``/``central``/``state``/``masked``/
    ``masked_state``);
  * ``aux`` — what rides the scan carry next to U (nothing, the
    previous adapt iterate, or the rule's consensus state);
  * call-convention metadata (``topology``/``stacked``/``spec_kwargs``/
    ``defaults``/``refit``) that the registry previously special-cased
    per solver.

Three *lowerings* execute any program:

  * :func:`lower_simulator`   — stacked ``lax.scan`` over the node axis
    (dense or sparse segment-sum combine, both engine backends),
    bit-identical to the legacy drivers (which remain in
    :mod:`repro.core.altgdmin` as the pinned oracles);
  * :func:`lower_mesh`        — shard_map with one node per device,
    per-shift ``ppermute`` gossip rounds, on the shared
    :func:`~repro.core.runtime._altgdmin_mesh` skeleton;
  * :func:`lower_virtual_mesh`— the virtual-node block tier
    (L = devices × block): co-located edges as on-device segment-sums,
    one collective-permute per cross-device shift class, on
    :func:`~repro.core.runtime._altgdmin_virtual_mesh`.

Registering a new solver is ~20 lines: write an ``update`` body against
the ctx, ``register_program`` it with its combine rule, and all three
substrates (plus the runner's substrate dispatch) come for free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.altgdmin import RunResult, _metrics, _select
from repro.core.engine import resolve_engine
from repro.core.metrics import subspace_distance
from repro.core.runtime import _altgdmin_mesh, _altgdmin_virtual_mesh
from repro.core.spectral import _qr_pos
from repro.distributed.consensus import (ExactDiffusionCombine, get_rule,
                                         neighbor_average_matrix)


class ProgramCtx(NamedTuple):
    """What a solver's per-iteration ``update`` may touch — each lowering
    binds these to its substrate.

    ``min_grad(U, fold)`` — fused min-B + gradient on iteration
    ``fold``'s sample-split folds (simulator; the mesh substrates have
    no fold axis and ignore ``fold``); ``mix`` — the combine closure of
    the program's mixer family; ``qr`` — the positive-diagonal QR
    retraction (vmapped over the block on the virtual tier);
    ``all_sum`` — the fusion-center exact gradient sum (``central``
    programs only); ``where_live(m, a, b)`` — per-node freeze under an
    availability mask (masked programs only); ``send_fraction(Z, st)``
    — the event rule's measured trigger rate (simulator only; None
    elsewhere, so the extra output is skipped)."""
    min_grad: Callable
    mix: Optional[Callable]
    qr: Callable
    eta: float
    eta_L: float
    local_steps: int
    all_sum: Optional[Callable]
    where_live: Optional[Callable]
    send_fraction: Optional[Callable]


# ----------------------------------------------------------------------
# refit-fold schedules (the _select index of the final B refit)
# ----------------------------------------------------------------------

def _refit_last_min(T_GD: int, local_steps: int) -> int:
    """The last min fold, 2·(T_GD−1): B is fit on the same data that
    produced the final U."""
    return 2 * (T_GD - 1)


def _refit_last_local(T_GD: int, local_steps: int) -> int:
    """Beyond-central: iteration T_GD−1's final LOCAL adapt step."""
    return 2 * (T_GD * local_steps - 1)


def _refit_first(T_GD: int, local_steps: int) -> int:
    """Centralized: the historical fold-0 refit."""
    return 0


# ----------------------------------------------------------------------
# the program IR
# ----------------------------------------------------------------------

MIXERS = ("plain", "neighbor", "central", "state", "masked",
          "masked_state")


class DispatchBudget(NamedTuple):
    """Statically-enforced kernel-dispatch pricing of a program's
    lowerings (checked by ``python -m tools.reprolint``, rule JX001).

    Each substrate entry is coefficients ``(a, b, c, d)`` of the
    per-outer-iteration ``pallas_call`` count on fused backends::

        count = a + R·(b + c·K) + d·local_steps

    where R is the combine rule's ``CommSignature.rounds_per_iter`` and
    K the number of cyclic shift classes of the decomposed mixing
    matrix (0 on the simulator — its AGREE chain is the hoisted
    W^{T_con} combine).  ``a`` counts the round-independent dispatches
    (the fused min-B+gradient; the hoisted combine), ``b``/``c`` the
    per-round and per-round-per-shift ones (stateful encode/decode),
    ``d`` the local adapt epoch.  One extra dispatch — the final B
    refit — always sits outside the outer scan and is budgeted
    separately by the analyzer.

    ``wire_mesh`` / ``wire_virtual`` price the gossip structure (rule
    JX004): ppermutes per outer iteration must equal R·K·wire — wire is
    1 for value-shipping rules, 2 where a payload rides with each
    message (top-k indices, quantization scales, push-sum weights), 0
    for the fusion-center psum."""
    simulator: tuple
    mesh: tuple
    virtual: tuple
    wire_mesh: int = 1
    wire_virtual: int = 1

    def per_iter(self, substrate: str, rounds: int, n_shifts: int,
                 local_steps: int) -> int:
        a, b, c, d = getattr(self, "virtual" if substrate == "virtual"
                             else substrate)
        return a + rounds * (b + c * n_shifts) + d * local_steps


@dataclasses.dataclass(frozen=True)
class SolverProgram:
    """One AltGDmin-family solver as data.

    ``update(ctx, U, aux, tau[, m]) -> (U_new, aux_new, extra)`` is the
    per-iteration body; ``aux`` is the scan-carry slot declared by the
    ``aux`` field (``None`` | ``"iterate"`` — the previous adapt state,
    seeded with U0 | ``"state"`` — the combine rule's ``init_state``);
    ``extra`` is an optional per-iteration scalar recorded next to the
    metrics (the event rule's send fraction; None elsewhere).
    ``mixer`` picks the CombineRule lowering family, ``rule_kwargs``
    names the spec knobs forwarded into stateful mixers and their
    ``init_state``, and ``defaults`` carries the knobs' default values
    as ``((name, value), ...)``.  ``stacked=False`` marks the one
    fusion-center program whose simulator carries a single (d, r)
    iterate.  ``refit(T_GD, local_steps)`` is the ``_select`` index of
    the final B refit."""
    name: str
    combine: str
    update: Callable
    mixer: str = "plain"
    stacked: bool = True
    topology: str = "W"              # "W" | "adj" | "none"
    decentralized: bool = True
    takes_avail: bool = False
    records_send_frac: bool = False
    aux: Optional[str] = None        # None | "iterate" | "state"
    spec_kwargs: tuple = ()
    rule_kwargs: tuple = ()
    defaults: tuple = ()             # ((name, value), ...)
    refit: Callable = _refit_last_min
    dispatch_budget: Optional[DispatchBudget] = None

    def __post_init__(self):
        if self.mixer not in MIXERS:
            raise ValueError(f"bad mixer kind {self.mixer!r}; expected "
                             f"one of {MIXERS}")
        if self.aux not in (None, "iterate", "state"):
            raise ValueError(f"bad aux kind {self.aux!r}")


def _resolve_spec(program: SolverProgram, spec_kw: dict) -> dict:
    unknown = set(spec_kw) - set(program.spec_kwargs)
    if unknown:
        raise TypeError(f"solver {program.name!r} got unexpected spec "
                        f"kwargs {sorted(unknown)}; takes "
                        f"{sorted(program.spec_kwargs)}")
    kw = dict(program.defaults)
    kw.update(spec_kw)
    return kw


def _check_avail(program: SolverProgram, avail, T_GD: int, L: int):
    """The masked drivers' legacy validation, shared by all lowerings."""
    if not program.takes_avail:
        if avail is not None:
            raise TypeError(f"solver {program.name!r} does not take an "
                            f"availability mask")
        return None
    if avail is None:
        avail = jnp.ones((T_GD, L), bool)
    avail = jnp.asarray(avail).astype(bool)
    if avail.shape != (T_GD, L):
        raise ValueError(f"availability mask {avail.shape} does not "
                         f"match (T_GD, L) = ({T_GD}, {L})")
    return avail


# ----------------------------------------------------------------------
# per-iteration update bodies (substrate-independent)
# ----------------------------------------------------------------------

def _upd_dif(ctx, U, aux, tau):
    """Algorithm 3: adapt-then-combine."""
    _, G = ctx.min_grad(U, tau)
    U_breve = U - ctx.eta_L * G           # local adapt (line 12)
    U_tilde = ctx.mix(U_breve)            # diffusion   (line 13)
    return ctx.qr(U_tilde), aux, None     # projection  (line 14)


def _upd_dec(ctx, U, aux, tau):
    """Dec-AltGDmin [9]: combine-then-adjust (consensus on gradients)."""
    _, G = ctx.min_grad(U, tau)
    G_hat = ctx.mix(G)
    return ctx.qr(U - ctx.eta_L * G_hat), aux, None


def _upd_central(ctx, U, aux, tau):
    """AltGDmin [10] with a fusion center: exact gradient sum."""
    _, G = ctx.min_grad(U, tau)
    grad = ctx.all_sum(G)
    return ctx.qr(U - ctx.eta * grad), aux, None


def _upd_dgd(ctx, U, aux, tau):
    """DGD-variation (Experiment 1 iii): self-excluding neighbour
    average of the PREVIOUS iterate minus the plain-η local gradient."""
    _, G = ctx.min_grad(U, tau)
    nbr = ctx.mix(U)
    return ctx.qr(nbr - ctx.eta * G), aux, None


def _upd_exact_diffusion(ctx, U, psi_prev, tau):
    """Exact Subspace Diffusion (arXiv:2304.07358):
    adapt-correct-combine; aux carries the previous adapt state ψ."""
    _, G = ctx.min_grad(U, tau)
    psi = U - ctx.eta_L * G                        # adapt
    phi = ExactDiffusionCombine.correct(psi, psi_prev, U)
    return ctx.qr(ctx.mix(phi)), psi, None         # combine + project


def _upd_beyond_central(ctx, U, aux, tau):
    """Beyond Centralization (arXiv:2512.22675): ``local_steps`` full
    local adapt steps, then ONE combine round."""
    for j in range(ctx.local_steps):               # local adapt epoch
        fold = tau * ctx.local_steps + j
        _, G = ctx.min_grad(U, fold)
        U = ctx.qr(U - ctx.eta_L * G)
    return ctx.qr(ctx.mix(U)), aux, None           # one combine round


def _upd_compressed(ctx, U, cstate, tau):
    """Adapt-then-combine over a STATEFUL compressed rule; the error-
    feedback state rides the aux carry.  The measured send fraction
    (event rule, simulator lowering only) is recorded BEFORE the mix —
    the same first-round trigger decision the encode uses."""
    _, G = ctx.min_grad(U, tau)
    U_breve = U - ctx.eta_L * G                    # local adapt
    sf = (ctx.send_fraction(U_breve, cstate)
          if ctx.send_fraction is not None else None)
    U_tilde, cstate = ctx.mix(U_breve, cstate)     # compressed diffusion
    return ctx.qr(U_tilde), cstate, sf             # projection


def _upd_masked(ctx, U, aux, tau, m):
    """Adapt-then-combine under an availability mask; down nodes are
    FULLY frozen for the iteration (no adapt/combine/retraction)."""
    _, G = ctx.min_grad(U, tau)
    U_breve = U - ctx.eta_L * G                    # local adapt
    U_tilde = ctx.mix(U_breve, m)
    return ctx.where_live(m, ctx.qr(U_tilde), U), aux, None


def _upd_masked_state(ctx, U, cstate, tau, m):
    """The stale-copy variant: the last-published copies ride the aux
    carry through the masked state mixer."""
    _, G = ctx.min_grad(U, tau)
    U_breve = U - ctx.eta_L * G                    # local adapt
    U_tilde, cstate = ctx.mix(U_breve, cstate, m)
    return ctx.where_live(m, ctx.qr(U_tilde), U), cstate, None


# ----------------------------------------------------------------------
# lowerings
# ----------------------------------------------------------------------

def lower_simulator(program: SolverProgram) -> Callable:
    """Stacked single-host simulator: ``run(U0, Xg, yg, topo, *, eta,
    T_GD, T_con, ...) -> RunResult``, trajectory-bit-identical to the
    legacy :mod:`repro.core.altgdmin` driver on both engine backends.
    ``topo`` is the mixing matrix (``"W"`` programs), the adjacency
    (``"adj"``), or absent (``"none"``) — the registry's per-topology
    call convention, preserved."""

    def run(U0, Xg, yg, topo=None, *, eta, T_GD, T_con=1, U_star=None,
            engine=None, backend=None, avail=None, **spec_kw):
        kw = _resolve_spec(program, spec_kw)
        rule_kw = {k: kw[k] for k in program.rule_kwargs}
        local_steps = int(kw.get("local_steps", 1))
        eng = resolve_engine(engine, backend)
        same_data = Xg.ndim == 4              # no sample-split fold axis
        if program.stacked:
            L = U0.shape[0]
            U_star_ = U_star if U_star is not None else U0[0]
        else:
            L = Xg.shape[0] if Xg.ndim == 4 else Xg.shape[1]
            U_star_ = U_star if U_star is not None else U0
        eta_L = eta * L
        avail_ = _check_avail(program, avail, T_GD, L)
        rule = get_rule(program.combine)

        mix = all_sum = None
        if program.mixer == "plain":
            mix = eng.make_mixer(topo, T_con, rule=program.combine)
        elif program.mixer == "neighbor":
            mix = eng.make_neighbor_mixer(neighbor_average_matrix(topo))
        elif program.mixer == "central":
            def all_sum(G):
                return jnp.sum(G, axis=0)     # fusion-center aggregation
        elif program.mixer == "state":
            mix = eng.make_state_mixer(topo, T_con, rule=program.combine,
                                       **rule_kw)
        elif program.mixer == "masked":
            mix = eng.make_masked_mixer(topo, T_con, rule=program.combine)
        elif program.mixer == "masked_state":
            mix = eng.make_masked_state_mixer(topo, T_con,
                                              rule=program.combine)

        if program.aux == "iterate":
            aux0 = U0
        elif program.aux == "state":
            aux0 = rule.init_state(U0, **rule_kw)
        else:
            aux0 = None

        send_fraction = None
        if program.records_send_frac:
            threshold = float(kw.get("event_threshold", 0.0))

            def send_fraction(Z, st):
                return rule.send_fraction(Z, st, threshold)

        def min_grad(U, fold):
            Xb, yb = _select(Xg, yg, 2 * fold)
            Xc, yc = _select(Xg, yg, 2 * fold + 1)
            if program.stacked:
                return eng.min_grad(U, Xb, yb, Xc, yc, same_data=same_data)
            Ub = jnp.broadcast_to(U[None], (Xb.shape[0],) + U.shape)
            return eng.min_grad(Ub, Xb, yb, Xc, yc, same_data=same_data)

        def where_live(m, a, b):
            return jnp.where(m[:, None, None], a, b)

        ctx = ProgramCtx(min_grad=min_grad, mix=mix,
                         qr=lambda M: _qr_pos(M)[0], eta=eta, eta_L=eta_L,
                         local_steps=local_steps, all_sum=all_sum,
                         where_live=where_live, send_fraction=send_fraction)

        if program.stacked:
            def metrics(U_new):
                return _metrics(U_new, U_star_)
        else:
            def metrics(U_new):
                sd = subspace_distance(U_new, U_star_)
                return (sd, sd, jnp.zeros((), U_new.dtype))

        def step(carry, xt):
            U, aux = carry
            if program.takes_avail:
                tau, m = xt
                U_new, aux_new, extra = program.update(ctx, U, aux, tau, m)
            else:
                U_new, aux_new, extra = program.update(ctx, U, aux, xt)
            out = metrics(U_new)
            if extra is not None:
                out = out + (extra,)
            return (U_new, aux_new), out

        xs = ((jnp.arange(T_GD), avail_) if program.takes_avail
              else jnp.arange(T_GD))
        (U_fin, _), outs = jax.lax.scan(step, (U0, aux0), xs)
        sfrac = None
        if program.records_send_frac:
            sd_max, sd_mean, spread, sfrac = outs
        else:
            sd_max, sd_mean, spread = outs

        Xb, yb = _select(Xg, yg, program.refit(T_GD, local_steps))
        if program.stacked:
            U_out, B_fin = U_fin, eng.minimize_B(U_fin, Xb, yb)
        else:
            B_fin = eng.minimize_B(
                jnp.broadcast_to(U_fin[None],
                                 (Xb.shape[0],) + U_fin.shape), Xb, yb)
            U_out = U_fin[None]
        return RunResult(U_out, B_fin, sd_max, sd_mean, spread, eta,
                         send_frac=sfrac)

    run.__name__ = run.__qualname__ = f"{program.name}__simulator"
    run.__doc__ = (f"Simulator lowering of the {program.name!r} solver "
                   f"program (combine rule {program.combine!r}).")
    return run


def lower_mesh(program: SolverProgram) -> Callable:
    """One-node-per-device shard_map lowering on the shared
    :func:`~repro.core.runtime._altgdmin_mesh` skeleton: ``run(U0, Xg,
    yg, mesh, axis_name, *, eta, T_GD, T_con, shifts, self_weight, W,
    ...)`` — the historical ``*_mesh`` signature, for every program."""

    def run(U0, Xg, yg, mesh, axis_name, *, eta, T_GD, T_con=1,
            shifts=(-1, 1), self_weight=None, W=None, engine=None,
            backend=None, U_star=None, avail=None, **spec_kw):
        kw = _resolve_spec(program, spec_kw)
        rule_kw = {k: kw[k] for k in program.rule_kwargs}
        local_steps = int(kw.get("local_steps", 1))
        L = mesh.shape[axis_name]
        eta_L = eta * L
        rule = get_rule(program.combine)
        if not program.stacked:
            # fusion center: every device starts (and stays) on node
            # 0's iterate — the psum keeps the rows identical
            U0 = jnp.broadcast_to(U0[:1], U0.shape)
        xs = _check_avail(program, avail, T_GD, L)

        def make_update(eng):
            mix = all_sum = None
            if program.mixer == "plain":
                mix = rule.make_mesh_mixer(axis_name, L, T_con, shifts,
                                           self_weight, W=W,
                                           backend=eng.backend)
            elif program.mixer == "neighbor":
                # single self-excluding round; T_con / self_weight are
                # structurally ignored by the rule
                mix = rule.make_mesh_mixer(axis_name, L, 1, shifts, W=W,
                                           backend=eng.backend)
            elif program.mixer == "central":
                def all_sum(G):
                    return jax.lax.psum(G, axis_name)
            elif program.mixer == "state":
                mix = rule.make_mesh_state_mixer(
                    axis_name, L, T_con, shifts, self_weight, W=W,
                    backend=eng.backend, **rule_kw)
            elif program.mixer == "masked":
                mix = rule.make_mesh_masked_mixer(
                    axis_name, L, T_con, shifts, self_weight, W=W,
                    backend=eng.backend)
            elif program.mixer == "masked_state":
                mix = rule.make_mesh_masked_state_mixer(
                    axis_name, L, T_con, shifts, self_weight, W=W,
                    backend=eng.backend)

            def where_live(m, a, b):
                return jnp.where(m[jax.lax.axis_index(axis_name)], a, b)

            def update(U, aux, mg, xt=None):
                ctx = ProgramCtx(min_grad=lambda U_, fold: mg(U_),
                                 mix=mix, qr=lambda M: _qr_pos(M)[0],
                                 eta=eta, eta_L=eta_L,
                                 local_steps=local_steps, all_sum=all_sum,
                                 where_live=where_live, send_fraction=None)
                if program.takes_avail:
                    U_new, aux_new, _ = program.update(ctx, U, aux, 0, xt)
                else:
                    U_new, aux_new, _ = program.update(ctx, U, aux, 0)
                return U_new, aux_new
            return update

        if program.aux == "iterate":
            def init_aux(U):
                return U
        elif program.aux == "state":
            if program.mixer == "state":
                # one neighbour-copy buffer per distinct cyclic shift
                n_shifts = len(rule._mesh_weights(L, shifts, self_weight,
                                                  W)[0])

                def init_aux(U):
                    return rule.init_mesh_state(U, n_shifts, **rule_kw)
            else:
                def init_aux(U):
                    return rule.init_mesh_state(U)
        else:
            init_aux = None

        return _altgdmin_mesh(U0, Xg, yg, mesh, axis_name, eta=eta,
                              T_GD=T_GD, make_update=make_update,
                              engine=engine, backend=backend,
                              U_star=U_star, init_aux=init_aux, xs=xs)

    run.__name__ = run.__qualname__ = f"{program.name}__mesh"
    run.__doc__ = (f"Mesh lowering of the {program.name!r} solver "
                   f"program (combine rule {program.combine!r}).")
    return run


def lower_virtual_mesh(program: SolverProgram) -> Callable:
    """Virtual-node block-tier lowering (L = devices × block) on
    :func:`~repro.core.runtime._altgdmin_virtual_mesh`: each device is a
    small simulator over its (block, d, r) slab; the combine is the
    rule's ``make_virtual_mesh_*`` sparse-round lowering.  ``run(U0, Xg,
    yg, mesh, axis_name, *, vt, eta, T_GD, T_con, ...)``."""

    def run(U0, Xg, yg, mesh, axis_name, *, vt, eta, T_GD, T_con=1,
            engine=None, backend=None, U_star=None, avail=None,
            **spec_kw):
        kw = _resolve_spec(program, spec_kw)
        rule_kw = {k: kw[k] for k in program.rule_kwargs}
        local_steps = int(kw.get("local_steps", 1))
        L = U0.shape[0]
        eta_L = eta * L                       # L is the GLOBAL node count
        rule = get_rule(program.combine)
        if not program.stacked:
            U0 = jnp.broadcast_to(U0[:1], U0.shape)
        xs = _check_avail(program, avail, T_GD, L)
        D, V = vt.n_dev, vt.block

        def make_update(eng):
            mix = all_sum = None
            if program.mixer in ("plain", "neighbor"):
                # the neighbor rule's virtual lowering is structurally a
                # single round, matching its mesh/simulator forms
                mix = eng.make_virtual_mixer(vt, axis_name, T_con,
                                             rule=program.combine)
            elif program.mixer == "central":
                def all_sum(G):
                    # block-local sum, then the cross-device psum — the
                    # exact global gradient on every device
                    return jax.lax.psum(jnp.sum(G, axis=0), axis_name)
            elif program.mixer == "state":
                mix = eng.make_virtual_state_mixer(vt, axis_name, T_con,
                                                   rule=program.combine,
                                                   **rule_kw)
            elif program.mixer == "masked":
                mix = eng.make_virtual_masked_mixer(vt, axis_name, T_con,
                                                    rule=program.combine)
            elif program.mixer == "masked_state":
                mix = eng.make_virtual_masked_state_mixer(
                    vt, axis_name, T_con, rule=program.combine)

            def where_live(m, a, b):
                rows = m.reshape(D, V)[jax.lax.axis_index(axis_name)]
                return jnp.where(rows[:, None, None], a, b)

            qr = jax.vmap(lambda u: _qr_pos(u)[0])

            def update(U, aux, mg, xt=None):
                ctx = ProgramCtx(min_grad=lambda U_, fold: mg(U_),
                                 mix=mix, qr=qr, eta=eta, eta_L=eta_L,
                                 local_steps=local_steps, all_sum=all_sum,
                                 where_live=where_live, send_fraction=None)
                if program.takes_avail:
                    U_new, aux_new, _ = program.update(ctx, U, aux, 0, xt)
                else:
                    U_new, aux_new, _ = program.update(ctx, U, aux, 0)
                return U_new, aux_new
            return update

        if program.aux == "iterate":
            def init_aux(Ub):
                return Ub
        elif program.aux == "state":
            # the simulator's stacked state, per block slab (zero
            # public copies; the stochastic round counter stays a
            # per-device scalar with identical per-round values)
            def init_aux(Ub):
                return rule.init_state(Ub, **rule_kw)
        else:
            init_aux = None

        return _altgdmin_virtual_mesh(U0, Xg, yg, mesh, axis_name, vt=vt,
                                      eta=eta, T_GD=T_GD,
                                      make_update=make_update,
                                      engine=engine, backend=backend,
                                      U_star=U_star, init_aux=init_aux,
                                      xs=xs)

    run.__name__ = run.__qualname__ = f"{program.name}__virtual_mesh"
    run.__doc__ = (f"Virtual-mesh lowering of the {program.name!r} solver "
                   f"program (combine rule {program.combine!r}).")
    return run


# ----------------------------------------------------------------------
# program registry — the 12 solvers as data
# ----------------------------------------------------------------------

PROGRAMS: dict[str, SolverProgram] = {}


def register_program(program: SolverProgram) -> SolverProgram:
    if program.name in PROGRAMS:
        raise ValueError(f"solver program {program.name!r} already "
                         f"registered")
    PROGRAMS[program.name] = program
    return program


def get_program(name: str) -> SolverProgram:
    try:
        return PROGRAMS[name]
    except KeyError:
        raise ValueError(f"unknown solver program {name!r}; registered: "
                         f"{sorted(PROGRAMS)}") from None


def program_names() -> tuple[str, ...]:
    return tuple(sorted(PROGRAMS))


# Budget shorthand: the adapt-then-combine family shares one shape —
# simulator fuses min-grad + the hoisted W^{T_con} combine (2 dispatches,
# round-independent); mesh keeps the combine per round (1 + R); the
# virtual tier's combine is segment-sum/ppermute only (1).
_BUDGET_DIFFUSION = DispatchBudget(
    simulator=(2, 0, 0, 0), mesh=(1, 1, 0, 0), virtual=(1, 0, 0, 0))

# Masked / event rules: one masked-combine dispatch per round on both
# stacked tiers, none on the virtual tier.
_BUDGET_MASKED = DispatchBudget(
    simulator=(1, 1, 0, 0), mesh=(1, 1, 0, 0), virtual=(1, 0, 0, 0))

register_program(SolverProgram(
    name="dif_altgdmin", combine="gossip", update=_upd_dif,
    dispatch_budget=_BUDGET_DIFFUSION))

register_program(SolverProgram(
    name="dec_altgdmin", combine="gossip", update=_upd_dec,
    dispatch_budget=_BUDGET_DIFFUSION))

register_program(SolverProgram(
    name="centralized_altgdmin", combine="central", update=_upd_central,
    mixer="central", stacked=False, topology="none", decentralized=False,
    refit=_refit_first,
    dispatch_budget=DispatchBudget(
        simulator=(1, 0, 0, 0), mesh=(1, 0, 0, 0), virtual=(1, 0, 0, 0),
        wire_mesh=0, wire_virtual=0)))   # fusion center: psum, no gossip

register_program(SolverProgram(
    name="dgd_altgdmin", combine="neighbor", update=_upd_dgd,
    mixer="neighbor", topology="adj",
    dispatch_budget=DispatchBudget(      # single self-excluding round
        simulator=(1, 1, 0, 0), mesh=(1, 1, 0, 0), virtual=(1, 0, 0, 0))))

register_program(SolverProgram(
    name="exact_diffusion", combine="exact_diffusion",
    update=_upd_exact_diffusion, aux="iterate",
    dispatch_budget=_BUDGET_DIFFUSION))

register_program(SolverProgram(
    name="beyond_central", combine="beyond_central",
    update=_upd_beyond_central, spec_kwargs=("local_steps",),
    defaults=(("local_steps", 1),), refit=_refit_last_local,
    dispatch_budget=DispatchBudget(      # one min-grad per LOCAL step,
        simulator=(0, 1, 0, 1),          # one combine round per iter
        mesh=(0, 1, 0, 1), virtual=(0, 0, 0, 1))))

register_program(SolverProgram(
    name="dif_topk", combine="topk_gossip", update=_upd_compressed,
    mixer="state", aux="state",
    spec_kwargs=("compression_k", "consensus_gamma"),
    rule_kwargs=("compression_k", "consensus_gamma"),
    defaults=(("compression_k", 0), ("consensus_gamma", 1.0)),
    dispatch_budget=DispatchBudget(      # encode + combine per round;
        simulator=(1, 2, 0, 0),          # indices ride the wire (w=2)
        mesh=(1, 2, 0, 0), virtual=(1, 1, 0, 0), wire_mesh=2)))

register_program(SolverProgram(
    name="dif_quantized", combine="quantized_gossip",
    update=_upd_compressed, mixer="state", aux="state",
    spec_kwargs=("compression", "consensus_gamma"),
    rule_kwargs=("compression", "consensus_gamma"),
    defaults=(("compression", None), ("consensus_gamma", 1.0)),
    dispatch_budget=DispatchBudget(      # per-shift dequant on mesh;
        simulator=(1, 2, 0, 0),          # scales ride the wire (w=2)
        mesh=(1, 2, 1, 0), virtual=(1, 1, 0, 0), wire_mesh=2)))

register_program(SolverProgram(
    name="dif_event", combine="event_gossip", update=_upd_compressed,
    mixer="state", aux="state", records_send_frac=True,
    spec_kwargs=("event_threshold", "consensus_gamma"),
    rule_kwargs=("event_threshold", "consensus_gamma"),
    defaults=(("event_threshold", 0.0), ("consensus_gamma", 1.0)),
    dispatch_budget=_BUDGET_MASKED))

register_program(SolverProgram(
    name="dif_partial", combine="partial_gossip", update=_upd_masked,
    mixer="masked", takes_avail=True,
    dispatch_budget=_BUDGET_MASKED))

register_program(SolverProgram(
    name="dif_stale", combine="stale_gossip", update=_upd_masked_state,
    mixer="masked_state", aux="state", takes_avail=True,
    dispatch_budget=_BUDGET_MASKED))

register_program(SolverProgram(
    name="dif_pushsum", combine="push_sum_gossip", update=_upd_masked,
    mixer="masked", takes_avail=True,
    dispatch_budget=DispatchBudget(      # ratio consensus: weight row
        simulator=(1, 2, 0, 0),          # rides with every message
        mesh=(1, 1, 0, 0), virtual=(1, 0, 0, 0),
        wire_mesh=2, wire_virtual=2)))
