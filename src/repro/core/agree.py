"""AGREE — the agreement (gossip) protocol, Algorithm 1.

Simulator form: all node variables are stacked on a leading axis
``Z: (L, ...)`` and one gossip round is the exact mixing product
``Z ← W @ Z`` (the paper's line 4,
``Z_g ← Z_g + Σ_{j∈N_g} (1/deg_g)(Z_j − Z_g)``, is precisely this product
with the equal-neighbor W of repro.distributed.mixing).

Proposition 1: after T_con rounds on a connected graph,
max_g |z_g − z̄| ≤ γ(W)^{T_con} · max_g |z_g^{(in)} − z̄|.

Both entry points are thin views of the unified consensus layer
(:mod:`repro.distributed.consensus`): :func:`agree` is the gossip rule's
exact sequential simulator lowering, :func:`agree_power` its precomputed
single-product form (the fused backends' hoist target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.consensus import stacked_dense_mix, stacked_product


def agree(Z: jax.Array, W: jax.Array, T_con: int) -> jax.Array:
    """Run T_con gossip rounds. Z: (L, ...), W: (L, L). Static unroll is
    avoided via lax.scan so T_GD-deep outer loops stay compile-cheap."""
    return stacked_product(Z, W, T_con)


def agree_power(Z: jax.Array, W: jax.Array, T_con: int) -> jax.Array:
    """Equivalent single-product form using W^{T_con}; useful when the same
    (W, T_con) is reused many times (the matrix power is precomputable)."""
    Wp = jnp.linalg.matrix_power(W, T_con)
    return stacked_dense_mix(Z, Wp, backend="xla-ref")
