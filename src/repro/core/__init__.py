# The paper's primary contribution: Dif-AltGDmin (diffusion-based
# decentralized federated multi-task representation learning), plus the
# baselines it compares against, as a faithful single-host simulator.
# The production mesh runtime lives in repro.distributed / repro.launch.
from repro.core.problem import MTRLProblem, generate_problem, split_samples, node_view
from repro.core.metrics import (
    subspace_distance, subspace_distance_F, task_error, consensus_spread,
)
from repro.core.agree import agree
from repro.core.spectral import decentralized_spectral_init, SpectralInit
from repro.core.altgdmin import (
    dif_altgdmin, dec_altgdmin, centralized_altgdmin, dgd_altgdmin,
    exact_diffusion_altgdmin, beyond_central_altgdmin,
    dif_topk_altgdmin, dif_quantized_altgdmin, dif_event_altgdmin,
    dif_partial_altgdmin, dif_stale_altgdmin, dif_pushsum_altgdmin,
    minimize_B, grad_U, RunResult, resolve_eta,
)
from repro.core.engine import AltgdminEngine, resolve_engine
from repro.core import theory
from repro.core import comm_model
from repro.core import system_clock
from repro.core.program import (
    SolverProgram, get_program, program_names, register_program,
    lower_simulator, lower_mesh, lower_virtual_mesh,
)

# Mesh entry points, derived from the solver programs (the historical
# hand-written *_mesh closures are gone from repro.core.runtime).
dif_altgdmin_mesh = lower_mesh(get_program("dif_altgdmin"))
dec_altgdmin_mesh = lower_mesh(get_program("dec_altgdmin"))
dgd_altgdmin_mesh = lower_mesh(get_program("dgd_altgdmin"))
centralized_altgdmin_mesh = lower_mesh(get_program("centralized_altgdmin"))
exact_diffusion_mesh = lower_mesh(get_program("exact_diffusion"))
beyond_central_mesh = lower_mesh(get_program("beyond_central"))
dif_topk_mesh = lower_mesh(get_program("dif_topk"))
dif_quantized_mesh = lower_mesh(get_program("dif_quantized"))
dif_event_mesh = lower_mesh(get_program("dif_event"))
dif_partial_mesh = lower_mesh(get_program("dif_partial"))
dif_stale_mesh = lower_mesh(get_program("dif_stale"))
dif_pushsum_mesh = lower_mesh(get_program("dif_pushsum"))
dif_altgdmin_virtual_mesh = lower_virtual_mesh(get_program("dif_altgdmin"))
