"""Subspace-distance and recovery metrics (paper Sec. II, Notations)."""
from __future__ import annotations

import jax.numpy as jnp


def subspace_distance(U1, U2):
    """SD₂(U1, U2) := ||(I − U1 U1ᵀ) U2||₂ (spectral norm).

    U1 and U2 must have orthonormal columns. Computed without forming the
    d×d projector: ||(I − P)U2||₂ = ||U2 − U1 (U1ᵀU2)||₂.
    """
    M = U2 - U1 @ (U1.T @ U2)
    return jnp.linalg.norm(M, ord=2)


def subspace_distance_F(U1, U2):
    """Frobenius-norm variant."""
    M = U2 - U1 @ (U1.T @ U2)
    return jnp.linalg.norm(M)


def task_error(theta_hat, theta_star):
    """Relative per-task error max_t ||θ̂_t − θ*_t|| / ||θ*_t|| (Theorem 1.1).
    theta_*: (d, T)."""
    num = jnp.linalg.norm(theta_hat - theta_star, axis=0)
    den = jnp.linalg.norm(theta_star, axis=0)
    return jnp.max(num / den)


# Above this node count the exact pairwise diameter is replaced by the
# O(L·d·r) consensus radius (max deviation from the node mean).  The
# exact form's fused reduction still materializes an (L, L) norm buffer
# — 40 GB at L=100k — which would defeat the sparse consensus path.
SPREAD_EXACT_MAX = 4096


def consensus_spread(U_nodes):
    """max_{g,g'} ||U_g − U_g'||_F over the node axis (UconsErr of Sec. IV).
    U_nodes: (L, d, r).

    Above ``SPREAD_EXACT_MAX`` nodes this returns the consensus *radius*
    ``max_g ||U_g − Ū||_F`` instead of the pairwise diameter — the same
    quantity within a factor of 2 (radius ≤ diameter ≤ 2·radius) at
    O(L·d·r) memory instead of O(L²)."""
    if U_nodes.shape[0] <= SPREAD_EXACT_MAX:
        diff = U_nodes[:, None] - U_nodes[None, :]
        return jnp.max(jnp.sqrt(jnp.sum(diff ** 2, axis=(-2, -1))))
    dev = U_nodes - jnp.mean(U_nodes, axis=0, keepdims=True)
    return jnp.max(jnp.sqrt(jnp.sum(dev ** 2, axis=(-2, -1))))
