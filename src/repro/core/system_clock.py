"""Event-driven simulated wall-clock for the system-realism layer.

The closed-form pricing of :mod:`repro.core.comm_model` assumes every
node computes in lockstep and every gossip round costs one max-over-
neighbours message time — adequate for the paper's Sec. V figures, but
unable to express stragglers, heterogeneous compute, or nodes that drop
out mid-run.  This module replaces it (whenever an
:class:`~repro.api.spec.SystemSpec` is present on the experiment) with a
genuine discrete-event simulation: a priority queue of compute-completion
and message-delivery events, advanced per outer iteration.

Semantics, mirroring what the solvers actually execute:

  * the OUTER iteration is a barrier (the drivers are synchronous
    ``lax.scan`` steps): iteration τ+1 starts when the slowest LIVE node
    finishes iteration τ;
  * within an iteration, a live node first computes (base
    ``compute_s_per_iter`` × its speed multiplier × an optional
    straggler factor), then runs ``rounds_per_iter`` gossip rounds; its
    round-ρ sends leave when it has BOTH finished round ρ−1 and received
    every round-(ρ−1) message from its live neighbours (per-link wire
    times, jittered individually — the event-driven part: one slow link
    delays exactly its receivers, not the whole fleet);
  * nodes that are down this iteration send nothing, receive nothing,
    and do not gate the barrier (an all-down iteration prices one bare
    compute tick);
  * ``send_fraction`` (the event rule's measured per-iteration trigger
    rate) makes each message pay its wire time only with that
    probability — a skipped re-broadcast still gates round progression
    (gossip is synchronous) but crosses no wire.

Degenerate anchor: with availability ≡ 1, unit speeds, no stragglers
and zero jitter, every round costs exactly ``latency + bytes/bandwidth``
and the axis equals ``comm_model.decentralized_time_axis`` to the last
bit; with jitter the two agree within the jitter scale (both draw from
the same model, in different orders).
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.comm_model import NetworkModel


def _iteration_seconds(live, neighbors, rounds: int, compute, model,
                       n_entries: int, bytes_per_entry, rng,
                       send_fraction) -> float:
    """One outer iteration's simulated duration (the barrier: max over
    live nodes' finish times).  ``live``: node ids up this iteration;
    ``compute``: {node: seconds until its sends can start};
    ``neighbors``: {node: live neighbour ids}."""
    if not live:
        # every node down: the barrier still ticks one bare compute unit
        return float(max(compute.values(), default=0.0))
    if rounds == 0:
        return float(max(compute[g] for g in live))

    need = {g: len(neighbors[g]) for g in live}
    got = {(g, rd): 0 for g in live for rd in range(rounds)}
    latest = {(g, rd): 0.0 for g in live for rd in range(rounds)}
    ready = {}                  # (g, rd) -> time node g entered round rd
    done = {}
    heap = []
    seq = 0
    for g in live:
        heapq.heappush(heap, (compute[g], seq, "ready", g, 0))
        seq += 1

    def advance(g, rd):
        """Node g leaves round rd once it entered it AND heard every
        live neighbour's round-rd message."""
        nonlocal seq
        if (g, rd) in ready and got[(g, rd)] == need[g]:
            t = max(ready[(g, rd)], latest[(g, rd)])
            heapq.heappush(heap, (t, seq, "ready", g, rd + 1))
            seq += 1
            got[(g, rd)] = -1               # fire once

    while heap:
        t, _, _, g, rd = heapq.heappop(heap)
        if rd == rounds:
            done.setdefault(g, t)
            continue
        if (g, rd) in ready:
            continue
        ready[(g, rd)] = t
        for j in neighbors[g]:
            wire = 0.0
            if send_fraction is None or rng is None \
                    or rng.random() < send_fraction:
                wire = model.message_time(n_entries, rng,
                                          bytes_per_entry=bytes_per_entry)
            arr = t + wire
            got[(j, rd)] += 1
            latest[(j, rd)] = max(latest[(j, rd)], arr)
            advance(j, rd)
        advance(g, rd)

    return float(max(done[g] for g in live))


def simulated_time_axis(*, avail: np.ndarray, rounds_per_iter: int,
                        adj: np.ndarray | None = None,
                        model: NetworkModel,
                        compute_s_per_iter: float,
                        speeds: np.ndarray | None = None,
                        straggler_prob: float = 0.0,
                        straggler_factor: float = 1.0,
                        n_entries: int, bytes_per_entry: int | None = None,
                        rng: np.random.Generator | None = None,
                        send_fraction: np.ndarray | None = None,
                        neighbors=None) -> np.ndarray:
    """Cumulative simulated seconds after each outer iteration.

    ``avail``: (T_GD, L) bool availability mask (the SAME array the
    dropout-tolerant solvers consume, so time and trajectory see one
    fault schedule); ``adj``: (L, L) 0/1 adjacency, or pass ``neighbors``
    (per-node neighbour-id lists, e.g. ``SparseGraph.neighbor_lists()``)
    to avoid densifying a large sparse topology; ``speeds``: per-node
    compute multipliers; ``send_fraction``: optional (T_GD,) measured
    per-iteration send rate (the event rule's telemetry) replacing the
    static always-send pricing.  ``rng`` drives jitter, stragglers and
    send coin-flips — pass a seeded generator for reproducible axes.
    """
    avail = np.asarray(avail, dtype=bool)
    n_iters, L = avail.shape
    if neighbors is not None:
        if len(neighbors) != L:
            raise ValueError(f"neighbor lists cover {len(neighbors)} nodes "
                             f"but the mask has {L}")
        all_nbrs = [list(map(int, ns)) for ns in neighbors]
    else:
        if adj is None:
            raise ValueError("simulated_time_axis needs either adj or "
                             "neighbors")
        adj = np.asarray(adj)
        if adj.shape != (L, L):
            raise ValueError(f"adjacency {adj.shape} does not match the "
                             f"mask's {L} nodes")
        all_nbrs = [np.nonzero(adj[g])[0].tolist() for g in range(L)]
    speeds = np.ones(L) if speeds is None else np.asarray(speeds, float)

    out = np.empty(n_iters)
    total = 0.0
    for t in range(n_iters):
        live = [g for g in range(L) if avail[t, g]]
        live_set = set(live)
        nbrs = {g: [j for j in all_nbrs[g] if j in live_set] for g in live}
        compute = {}
        for g in live:
            c = compute_s_per_iter * speeds[g]
            if straggler_prob > 0 and rng is not None \
                    and rng.random() < straggler_prob:
                c *= straggler_factor
            compute[g] = c
        if not live:
            compute = {0: compute_s_per_iter}
            nbrs = {}
            live_for_iter = []
        else:
            live_for_iter = live
        sf = None if send_fraction is None else float(send_fraction[t])
        total += _iteration_seconds(live_for_iter, nbrs, rounds_per_iter,
                                    compute, model, n_entries,
                                    bytes_per_entry, rng, sf)
        out[t] = total
    return out
