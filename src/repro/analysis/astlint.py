"""Level-2 analyzers: flake8-plugin-style AST rules over ``src/``.

  * **RL001** — bare ``assert`` in ``src/repro/kernels/``: kernel-shape
    contracts die silently under ``python -O``; raise instead (PR 4
    converted five by hand — this keeps them converted).
  * **RL002** — ``.to_dense()`` / ``.adj`` access outside the
    ``DENSE_MATERIALIZE_MAX``-guarded allowlist: every dense
    materialization site must carry an inline justification naming why
    it cannot exceed the guard.
  * **RL003** — ``REPRO_*`` env vars: reads must go through the
    :mod:`repro.utils.env` registry, and every ``REPRO_*`` string
    literal in ``src/`` must be a declared registry key (the PR-3
    typo'd-override bug class).
  * **RL004** — unseeded ``np.random`` in ``src/``: the legacy global
    RNG (``np.random.rand`` etc., or an argless ``default_rng()``)
    makes runs irreproducible; thread an explicit seed.
  * **RL005** — ``SolverProgram.update`` bodies (the ``_upd_*``
    functions) must be pure: no attribute mutation, no free variables
    beyond ``ctx``/arguments/builtins/the declared-pure allowlist, and
    no Python ``if`` on tracer arguments (host branching on traced
    values either fails under jit or silently specializes).
  * **RL006** — ``repro.core.runtime`` holds ONLY the two substrate
    skeletons (folds in the old ``tools/check_runtime_clean.py``; that
    script now delegates here).

Suppression is inline, never invisible::

    x = g.to_dense()   # reprolint: allow=RL002 — spectral-init tier, L <= DENSE_MATERIALIZE_MAX

The marker must name the rule AND carry a justification after the dash;
a bare ``allow=RL002`` is itself a finding.  Markers are honored on the
flagged line or the line immediately above it.
"""
from __future__ import annotations

import ast
import builtins
import pathlib
import re

from repro.analysis.findings import Finding

KERNELS_DIR = "src/repro/kernels/"
ENV_REGISTRY_PATH = "src/repro/utils/env.py"
RUNTIME_PATH = "src/repro/core/runtime.py"
RUNTIME_ALLOWED = {"_altgdmin_mesh", "_altgdmin_virtual_mesh"}

# RL002: files whose job IS the dense/sparse boundary — graphs.py
# defines Graph.adj and the SparseGraph.adj property that itself raises
# above DENSE_MATERIALIZE_MAX, so flagging it would be circular.
RL002_EXEMPT_FILES = ("src/repro/distributed/graphs.py",)

# RL005: module-level names an update body may capture besides builtins
# — each must be a pure, stateless callable.
RL005_PURE_CAPTURES = {"ExactDiffusionCombine"}

_ENV_LITERAL = re.compile(r"^REPRO_[A-Z0-9_]+$")
_MARKER = re.compile(
    r"#\s*reprolint:\s*allow=(?P<rules>[A-Z0-9,]+)"
    r"(?:\s*[—–-]+\s*(?P<why>\S.*))?")

ALL_RULES = ("RL001", "RL002", "RL003", "RL004", "RL005", "RL006")


def _declared_env_vars() -> set:
    from repro.utils.env import ENV_VARS
    return set(ENV_VARS)


class _Markers:
    """Inline ``# reprolint: allow=`` markers of one source file."""

    def __init__(self, src: str, path: str):
        self.by_line: dict[int, set] = {}
        self.findings: list[Finding] = []
        for i, text in enumerate(src.splitlines(), start=1):
            m = _MARKER.search(text)
            if not m:
                continue
            rules = set(m.group("rules").split(","))
            if not m.group("why"):
                self.findings.append(Finding(
                    rule="RL000", path=path, line=i, symbol="",
                    detail=f"marker:{i}",
                    message="suppression marker without a justification "
                            "— write `# reprolint: allow=<rule> — <why>`"))
                continue
            self.by_line[i] = rules

    def allows(self, rule: str, line: int) -> bool:
        return (rule in self.by_line.get(line, ())
                or rule in self.by_line.get(line - 1, ()))


def _finding(markers, rule, path, line, symbol, message, detail):
    if markers.allows(rule, line):
        return []
    return [Finding(rule=rule, path=path, line=line, symbol=symbol,
                    message=message, detail=detail)]


def _enclosing_names(tree):
    """node -> name of the nearest enclosing function/class, for
    symbols in fingerprints."""
    names = {}

    def walk(node, current):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            current = node.name
        for child in ast.iter_child_nodes(node):
            names[child] = current
            walk(child, current)

    walk(tree, "")
    return names


# ----------------------------------------------------------------------
# per-rule visitors
# ----------------------------------------------------------------------

def _rl001(tree, names, markers, path):
    if KERNELS_DIR not in path:
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            out += _finding(
                markers, "RL001", path, node.lineno,
                names.get(node, ""), detail=f"assert:{names.get(node, '')}",
                message="bare `assert` in a kernel module — stripped "
                        "under python -O; raise ValueError instead")
    return out


def _rl002(tree, names, markers, path):
    if any(path.endswith(p) or p in path for p in RL002_EXEMPT_FILES):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Attribute):
            continue
        if node.attr == "to_dense" and isinstance(node.ctx, ast.Load):
            what = ".to_dense()"
        elif node.attr == "adj" and isinstance(node.ctx, ast.Load):
            what = ".adj"
        else:
            continue
        out += _finding(
            markers, "RL002", path, node.lineno, names.get(node, ""),
            detail=f"{node.attr}:{names.get(node, '')}",
            message=f"{what} materializes a dense (L, L) topology — "
                    f"justify the size guard with an inline "
                    f"`# reprolint: allow=RL002 — ...` or take the "
                    f"sparse path")
    return out


def _env_read_arg(node):
    """The REPRO_* literal of an env read call/subscript, if any."""
    target = None
    if isinstance(node, ast.Call):
        f = node.func
        # os.environ.get(...) / os.getenv(...)
        if (isinstance(f, ast.Attribute) and f.attr in ("get", "getenv")
                and node.args):
            target = node.args[0]
    elif isinstance(node, ast.Subscript):     # os.environ[...]
        target = node.slice
    if (isinstance(target, ast.Constant) and isinstance(target.value, str)
            and _ENV_LITERAL.match(target.value)):
        return target.value
    return None


def _rl003(tree, names, markers, path):
    out = []
    declared = _declared_env_vars()
    in_registry = path.endswith(ENV_REGISTRY_PATH.rsplit("/", 1)[-1]) and \
        "utils" in path
    for node in ast.walk(tree):
        var = _env_read_arg(node)
        if var is not None and not in_registry:
            out += _finding(
                markers, "RL003", path, node.lineno, names.get(node, ""),
                detail=f"read:{var}",
                message=f"direct environ read of {var} — go through the "
                        f"repro.utils.env registry accessors")
        if isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and _ENV_LITERAL.match(node.value) \
                and node.value not in declared:
            out += _finding(
                markers, "RL003", path, node.lineno, names.get(node, ""),
                detail=f"undeclared:{node.value}",
                message=f"{node.value} is not declared in "
                        f"repro.utils.env.ENV_VARS — declare it (or fix "
                        f"the typo; undeclared names read nothing)")
    return out


_NP_RANDOM_SEEDED = {"default_rng", "Generator", "SeedSequence",
                     "PCG64", "Philox"}


def _rl004(tree, names, markers, path):
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not (isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Attribute)
                and f.value.attr == "random"
                and isinstance(f.value.value, ast.Name)
                and f.value.value.id in ("np", "numpy")):
            continue
        if f.attr in _NP_RANDOM_SEEDED:
            if node.args or node.keywords:
                continue          # seeded constructor
            msg = (f"np.random.{f.attr}() without a seed — thread an "
                   f"explicit seed for reproducibility")
        else:
            msg = (f"np.random.{f.attr} uses the global unseeded RNG — "
                   f"use np.random.default_rng(seed)")
        out += _finding(markers, "RL004", path, node.lineno,
                        names.get(node, ""),
                        detail=f"{f.attr}:{names.get(node, '')}",
                        message=msg)
    return out


def _rl005(tree, names, markers, path):
    out = []
    builtin_names = set(dir(builtins))
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef) or \
                not fn.name.startswith("_upd_"):
            continue
        params = {a.arg for a in (fn.args.args + fn.args.kwonlyargs
                                  + fn.args.posonlyargs)}
        tracer_params = params - {"ctx"}
        local = set(params)
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Attribute):
                            out += _finding(
                                markers, "RL005", path, node.lineno,
                                fn.name, detail=f"mutation:{fn.name}",
                                message=f"attribute mutation in update "
                                        f"body {fn.name}() — update "
                                        f"bodies must be pure (lowerings "
                                        f"re-trace them per substrate)")
                        elif isinstance(leaf, ast.Name):
                            local.add(leaf.id)
            if isinstance(node, (ast.For,)) and \
                    isinstance(node.target, ast.Name):
                local.add(node.target.id)
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                name = node.id
                if name in local or name in builtin_names \
                        or name in RL005_PURE_CAPTURES:
                    continue
                out += _finding(
                    markers, "RL005", path, node.lineno, fn.name,
                    detail=f"capture:{fn.name}:{name}",
                    message=f"update body {fn.name}() captures free "
                            f"variable {name!r} — updates may only touch "
                            f"ctx, their arguments, and declared-pure "
                            f"helpers (RL005_PURE_CAPTURES)")
            if isinstance(node, ast.If):
                used = {leaf.id for leaf in ast.walk(node.test)
                        if isinstance(leaf, ast.Name)}
                if used & tracer_params:
                    out += _finding(
                        markers, "RL005", path, node.lineno, fn.name,
                        detail=f"tracer-if:{fn.name}",
                        message=f"Python `if` on a tracer argument in "
                                f"{fn.name}() — use jnp.where / "
                                f"lax.cond; host branching on traced "
                                f"values fails under jit")
    return out


def _rl006(tree, names, markers, path):
    if not path.endswith("runtime.py") or "core" not in path:
        return []
    top_level = [n.name for n in tree.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    out = []
    for name in top_level:
        if name not in RUNTIME_ALLOWED:
            out.append(Finding(
                rule="RL006", path=path, line=0, symbol=name,
                detail=f"rogue:{name}",
                message=f"solver-specific function {name}() in the "
                        f"runtime module — register a SolverProgram in "
                        f"repro.core.program instead; the lowerings "
                        f"derive every substrate"))
    for name in sorted(RUNTIME_ALLOWED - set(top_level)):
        out.append(Finding(
            rule="RL006", path=path, line=0, symbol=name,
            detail=f"missing:{name}",
            message=f"expected substrate skeleton {name}() missing from "
                    f"the runtime module"))
    return out


_RULES = {"RL001": _rl001, "RL002": _rl002, "RL003": _rl003,
          "RL004": _rl004, "RL005": _rl005, "RL006": _rl006}


# ----------------------------------------------------------------------
# entry points
# ----------------------------------------------------------------------

def check_source(src: str, path: str, rules=ALL_RULES) -> list[Finding]:
    """Run the AST rules over one source string — the testable core
    (tests feed known-bad fixture snippets through this)."""
    tree = ast.parse(src, filename=path)
    names = _enclosing_names(tree)
    markers = _Markers(src, path)
    findings = list(markers.findings)
    for rule in rules:
        findings += _RULES[rule](tree, names, markers, path)
    return findings


def run_ast_rules(repo_root, rules=ALL_RULES) -> list[Finding]:
    """All rules over every ``src/repro/**.py`` file."""
    root = pathlib.Path(repo_root)
    findings = []
    for p in sorted((root / "src" / "repro").rglob("*.py")):
        rel = p.relative_to(root).as_posix()
        findings += check_source(p.read_text(), rel, rules)
    return findings
