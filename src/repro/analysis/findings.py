"""Findings, fingerprints, and the baseline/suppression file.

Every analyzer (jaxpr or AST level) reports :class:`Finding`s.  A
finding's *fingerprint* is deliberately line-insensitive —
``rule:path:symbol:detail`` — so a baseline entry survives unrelated
edits to the file and dies exactly when the flagged construct moves or
changes.  The baseline file (``tools/reprolint/baseline.json``) is the
escape hatch for findings that are accepted-for-now: each entry must
carry a one-line justification, and ``python -m tools.reprolint
--write-baseline`` emits a skeleton to fill in.  A clean tree ships an
EMPTY baseline.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit.

    ``rule`` is the rule id (``RL001``…``RL006`` for the AST level,
    ``JX001``…``JX004`` for the jaxpr level); ``path`` is repo-relative;
    ``line`` is 0 for whole-trace findings (jaxpr rules attach the
    traced source location in ``detail`` instead); ``symbol`` names the
    enclosing function/solver/substrate so the fingerprint survives
    line drift."""
    rule: str
    path: str
    line: int
    symbol: str
    message: str
    detail: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}:{self.detail}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.rule} {loc}{sym}: {self.message}"


def load_baseline(path) -> dict[str, str]:
    """fingerprint -> justification.  A missing file is an empty
    baseline; a present file must parse and every entry must carry a
    non-empty justification (an empty one defeats the point of a
    suppression file)."""
    p = pathlib.Path(path)
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    out = {}
    for entry in data.get("suppressions", []):
        fp = entry.get("fingerprint", "")
        why = entry.get("justification", "")
        if not fp:
            raise ValueError(f"baseline entry without fingerprint: {entry}")
        if not why.strip() or why.strip().upper().startswith("TODO"):
            raise ValueError(
                f"baseline entry for {fp!r} has no justification — every "
                f"suppression must say why it is acceptable (the "
                f"--write-baseline skeleton's TODO placeholders do not "
                f"count)")
        out[fp] = why
    return out


def write_baseline(path, findings) -> None:
    """Emit a baseline skeleton for the given findings.  Justifications
    are left as TODO placeholders on purpose: the file will not LOAD
    until each is filled in, so a baseline can never silently accrete."""
    entries = [{"fingerprint": f.fingerprint,
                "justification": "TODO: justify or fix",
                "message": f.message}
               for f in sorted(findings, key=lambda f: f.fingerprint)]
    pathlib.Path(path).write_text(
        json.dumps({"suppressions": entries}, indent=2) + "\n")


def split_by_baseline(findings, baseline: dict[str, str]):
    """(new, suppressed, stale_fingerprints).  Stale entries — baseline
    fingerprints no finding matched — are reported so a fixed bug also
    removes its suppression."""
    new, suppressed = [], []
    seen = set()
    for f in findings:
        if f.fingerprint in baseline:
            suppressed.append(f)
            seen.add(f.fingerprint)
        else:
            new.append(f)
    stale = sorted(set(baseline) - seen)
    return new, suppressed, stale
