"""Level-1 analyzers: invariants of the traced lowerings.

Four rules, each a checked property of ``jax.make_jaxpr`` output — no
solver ever executes:

  * **JX001 dispatch budget** — the fused backends promise "one kernel
    per phase": the number of ``pallas_call`` eqns per outer iteration
    must equal the program's registered
    :class:`~repro.core.program.DispatchBudget` exactly (and exactly
    one dispatch — the final B refit — may live outside the outer
    scan).  This replaces the runtime call-count mocks.
  * **JX002 no dense node axis** — no eqn may CREATE a buffer carrying
    two dims equal to the node count L (the 40 GB ``consensus_spread``
    bug class).  Pass-throughs of an existing (L, L) operand — the
    small-L dense mixing tier below ``SPARSE_MIN_NODES`` — are fine;
    the rule fires only where the quadratic buffer is born, and those
    birth sites must be on the explicit allowlist below, each with a
    one-line justification naming its size guard.
  * **JX003 precision flow** — traced at f64, no eqn may narrow an f64
    aval to f32/bf16/f16 outside ``src/repro/kernels/`` (the sanctioned
    f32-accumulator kernels).  This makes the ``_fused_wanted``
    f64-stays-exact gate statically verifiable.
  * **JX004 comm pricing** — the ppermute structure of every mesh /
    virtual-mesh lowering must match its ``CommSignature``: eqn-counted
    ppermutes per outer iteration == rounds_per_iter × shift classes ×
    the rule's registered wire factor.  A lowering that gossips more
    (or less) than its signature prices is lying to the system clock —
    the PR-9 topk/quantized aggregation bug class.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.analysis.harness import (SUBSTRATES, Trace, count_primitive,
                                    eqn_location, iter_eqns, trace_program)

# JX002 allowlist: (repo-relative path, function) -> why the (L, L)
# buffer is acceptable.  Every entry must name the size guard that caps
# it below the sparse tier.
DENSE_NODE_AXIS_ALLOWLIST = {
    ("src/repro/core/metrics.py", "consensus_spread"):
        "exact pairwise diameter; consensus_spread switches to the "
        "O(L·d·r) radius above SPREAD_EXACT_MAX=4096 nodes",
    ("src/repro/distributed/consensus.py", "masked_mixing_matrix"):
        "per-iteration masked dense W; dense tier only — sparse "
        "topologies take the _sparse_masked_fold edge path above "
        "SPARSE_MIN_NODES=512",
    ("src/repro/distributed/consensus.py", "push_sum_matrix"):
        "per-iteration column-stochastic dense W; dense tier only, "
        "same SPARSE_MIN_NODES=512 gate as masked_mixing_matrix",
}

# JX003: directories whose f64→f32 narrowings are sanctioned (the
# mixed-precision accumulator kernels).
SANCTIONED_NARROWING_DIRS = ("src/repro/kernels/",)

_NARROW = {jnp.dtype(t) for t in ("float32", "bfloat16", "float16")}


def _sym(trace: Trace) -> str:
    return f"{trace.program.name}/{trace.substrate}"


def _lowering_path(trace: Trace) -> str:
    return "src/repro/core/program.py"


# ----------------------------------------------------------------------
# JX001 — dispatch budget
# ----------------------------------------------------------------------

def check_dispatch_budget(trace: Trace) -> list[Finding]:
    budget = trace.program.dispatch_budget
    if budget is None:
        return [Finding(
            rule="JX001", path=_lowering_path(trace), line=0,
            symbol=_sym(trace), detail="missing-budget",
            message=f"program {trace.program.name!r} registered without "
                    f"a DispatchBudget — every program must declare its "
                    f"per-iteration pallas_call count")]
    expected = budget.per_iter(trace.substrate, trace.rounds,
                               trace.n_shifts, trace.local_steps)
    got, outside = count_primitive(trace, "pallas_call")
    out = []
    if got != expected:
        out.append(Finding(
            rule="JX001", path=_lowering_path(trace), line=0,
            symbol=_sym(trace), detail="per-iter",
            message=f"{got} pallas_call eqns per outer iteration, budget "
                    f"says {expected} (R={trace.rounds}, "
                    f"K={trace.n_shifts}, local_steps={trace.local_steps})"))
    if outside != 1:
        out.append(Finding(
            rule="JX001", path=_lowering_path(trace), line=0,
            symbol=_sym(trace), detail="outside-scan",
            message=f"{outside} pallas_call eqns outside the outer scan; "
                    f"exactly 1 (the final B refit) is budgeted"))
    return out


# ----------------------------------------------------------------------
# JX002 — no dense node axis
# ----------------------------------------------------------------------

def _ndims_equal(aval, L: int) -> int:
    shape = getattr(aval, "shape", ())
    return sum(1 for dim in shape if dim == L)


def check_dense_node_axis(trace: Trace) -> list[Finding]:
    L = trace.L
    out = []
    seen = set()
    for eqn, _, _ in iter_eqns(trace.jaxpr):
        creates = any(_ndims_equal(v.aval, L) >= 2 for v in eqn.outvars)
        if not creates:
            continue
        inherits = any(_ndims_equal(v.aval, L) >= 2 for v in eqn.invars
                       if hasattr(v, "aval"))
        if inherits:
            continue            # pass-through of an existing (L, L) operand
        path, func, line = eqn_location(eqn)
        key = (path, func)
        if key in DENSE_NODE_AXIS_ALLOWLIST or key in seen:
            continue
        seen.add(key)
        shape = next(tuple(v.aval.shape) for v in eqn.outvars
                     if _ndims_equal(v.aval, L) >= 2)
        out.append(Finding(
            rule="JX002", path=path or _lowering_path(trace), line=line,
            symbol=_sym(trace), detail=f"{func}:{eqn.primitive.name}",
            message=f"eqn {eqn.primitive.name!r} in {func}() creates a "
                    f"dense node-axis buffer {shape} (two dims == L={L}) "
                    f"— O(L²) memory; use the sparse path or allowlist "
                    f"with its size guard"))
    return out


# ----------------------------------------------------------------------
# JX003 — precision flow (run on the f64 trace)
# ----------------------------------------------------------------------

def check_precision_flow(trace: Trace) -> list[Finding]:
    out = []
    seen = set()
    for eqn, _, _ in iter_eqns(trace.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = [v for v in eqn.invars
               if getattr(getattr(v, "aval", None), "dtype", None)
               == jnp.dtype("float64")]
        if not src:
            continue
        dst = eqn.params.get("new_dtype")
        if dst is None or jnp.dtype(dst) not in _NARROW:
            continue
        path, func, line = eqn_location(eqn)
        if any(path.startswith(d) for d in SANCTIONED_NARROWING_DIRS):
            continue
        key = (path, func, str(dst))
        if key in seen:
            continue
        seen.add(key)
        out.append(Finding(
            rule="JX003", path=path or _lowering_path(trace), line=line,
            symbol=_sym(trace), detail=f"{func}:{jnp.dtype(dst).name}",
            message=f"f64 value narrowed to {jnp.dtype(dst).name} in "
                    f"{func}() — outside the sanctioned kernels/ "
                    f"accumulators, f64 runs must stay exact "
                    f"(the _fused_wanted gate)"))
    return out


# ----------------------------------------------------------------------
# JX004 — comm-pricing completeness
# ----------------------------------------------------------------------

def check_comm_pricing(trace: Trace) -> list[Finding]:
    if trace.substrate == "simulator":
        # the simulator's wire is the hoisted W^{T_con} combine — rounds
        # legitimately collapse into one matmul, so eqn counting is
        # meaningless there; pricing is checked on the wire substrates
        return []
    budget = trace.program.dispatch_budget
    if budget is None:
        return []                # JX001 already reports the missing budget
    wire = (budget.wire_mesh if trace.substrate == "mesh"
            else budget.wire_virtual)
    expected = trace.rounds * trace.n_shifts * wire
    got, outside = count_primitive(trace, "ppermute")
    out = []
    if got != expected:
        out.append(Finding(
            rule="JX004", path=_lowering_path(trace), line=0,
            symbol=_sym(trace), detail="rounds",
            message=f"{got} ppermute eqns per outer iteration, but the "
                    f"CommSignature prices {expected} "
                    f"(rounds={trace.rounds} × shifts={trace.n_shifts} × "
                    f"wire={wire}) — the lowering's gossip structure and "
                    f"its wire pricing disagree"))
    if outside != 0:
        out.append(Finding(
            rule="JX004", path=_lowering_path(trace), line=0,
            symbol=_sym(trace), detail="outside-scan",
            message=f"{outside} ppermute eqns outside the outer scan — "
                    f"unpriced communication"))
    return out


# ----------------------------------------------------------------------
# driver entry
# ----------------------------------------------------------------------

def analyze_program(name: str, substrates=SUBSTRATES) -> list[Finding]:
    """All four jaxpr rules for one program: f32 traces price the
    dispatch/dense/comm structure, an f64 trace checks precision flow."""
    findings = []
    for substrate in substrates:
        t32 = trace_program(name, substrate, jnp.float32)
        findings += check_dispatch_budget(t32)
        findings += check_dense_node_axis(t32)
        findings += check_comm_pricing(t32)
        t64 = trace_program(name, substrate, jnp.float64)
        findings += check_precision_flow(t64)
    return findings
