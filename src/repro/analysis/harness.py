"""Trace harness: every registered program × every lowering, as jaxprs.

The jaxpr analyzers never EXECUTE a solver — they ``jax.make_jaxpr`` the
lowering at a tiny shape and walk the closed jaxpr.  Tracing is enough:
dispatch counts, aval shapes, dtype narrowings, and ppermute structure
are all properties of the trace, and random normal data is as good as a
real problem instance.

Shape choices (why these numbers):

  * sim/mesh use L = 8 — one node per fake host device, matching the
    parity tests in tests/test_programs.py; virtual uses L = 24 on 8
    devices (block 3) so L, the device count, and the block size are
    three DISTINCT numbers and a dim equal to L is unambiguous.
  * d = 16, r = 2, tpn = 3, n = 12 — no dim collides with L on either
    tier, so the no-dense-node-axis rule (JX002) cannot false-positive
    on a data axis.
  * T_GD = 3, T_con = 2, local_steps = 2 — all distinct, so the outer
    scan is identified by ``length == T_GD`` alone.

The walker (:func:`iter_eqns`) recurses into scan / pjit / shard_map /
custom-call sub-jaxprs and yields ``(eqn, mult, in_outer)`` where
``mult`` is the number of times the eqn runs per outer iteration
(inner-scan lengths multiply — a statically-single ppermute inside a
``length=T_con`` round scan runs T_con times) and ``in_outer`` says
whether the eqn is under the outer T_GD scan at all.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Iterator

import numpy as np

import jax
import jax.numpy as jnp

# Trace-time constants — see module docstring for why each value.
D, R, TPN, N = 16, 2, 3, 12
T_GD, T_CON, LOCAL_STEPS = 3, 2, 2
L_SIM = 8            # simulator + mesh node count (== device count)
L_VIRT = 24          # virtual tier: 8 devices × block 3
N_DEV = 8

SUBSTRATES = ("simulator", "mesh", "virtual")

# The non-default spec knobs per program (mirrors the parity tests —
# exercises the compressed / local-epoch paths the defaults skip).
SPEC_KW = {
    "beyond_central": dict(local_steps=LOCAL_STEPS),
    "dif_topk": dict(compression_k=3),
    "dif_quantized": dict(compression="int8_stochastic"),
    "dif_event": dict(event_threshold=0.05),
}


@dataclasses.dataclass(frozen=True)
class Trace:
    """One traced (program, substrate) pair plus the structural facts
    the analyzers price against."""
    program: Any              # the SolverProgram
    substrate: str            # "simulator" | "mesh" | "virtual"
    dtype: Any                # trace input dtype (jnp.float32/float64)
    jaxpr: Any                # ClosedJaxpr
    L: int                    # global node count of this trace
    rounds: int               # R — CommSignature.rounds_per_iter at T_con
    n_shifts: int             # K — shift classes (0 on the simulator)
    local_steps: int


def _orthonormal(rng, shape, dtype):
    *lead, d, r = shape
    q = np.linalg.qr(rng.standard_normal(shape))[0]
    return jnp.asarray(q.astype(dtype))


@functools.lru_cache(maxsize=4)
def _setup(L: int, dtype_name: str):
    """Concrete trace inputs for node count L.  Cached: the two node
    counts × two dtypes cover every trace."""
    from repro.distributed import graphs, mixing
    from repro.distributed.consensus import neighbor_average_matrix

    dtype = np.dtype(dtype_name)
    rng = np.random.default_rng(7)
    g = (graphs.erdos_renyi(L, 0.6, seed=2) if L == L_SIM
         else graphs.erdos_renyi(L, 0.4, seed=3))
    adj = jnp.asarray(np.asarray(  # reprolint: allow=RL002 — trace-time toy graph, L <= 24
        g.adj, dtype=dtype))
    W = jnp.asarray(np.asarray(mixing.metropolis_weights(g), dtype=dtype))
    Madj = jnp.asarray(np.asarray(neighbor_average_matrix(adj),
                                  dtype=dtype))
    U0 = _orthonormal(rng, (L, D, R), dtype)
    Xg = jnp.asarray(rng.standard_normal((L, TPN, N, D)).astype(dtype))
    yg = jnp.asarray(rng.standard_normal((L, TPN, N)).astype(dtype))
    avail = jnp.asarray(rng.random((T_GD, L)) > 0.3)
    return dict(adj=adj, W=W, Madj=Madj, U0=U0, Xg=Xg, yg=yg, avail=avail)


def _mesh8():
    from repro.utils.compat import make_mesh
    if len(jax.devices()) < N_DEV:
        raise RuntimeError(
            f"the mesh/virtual traces need {N_DEV} devices (have "
            f"{len(jax.devices())}); run via `python -m tools.reprolint`, "
            f"which sets XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{N_DEV} before importing jax")
    return make_mesh((N_DEV,), ("nodes",))


def trace_program(name: str, substrate: str, dtype=jnp.float32) -> Trace:
    """Trace one program through one lowering; returns the closed jaxpr
    plus the R/K context its budgets are priced against."""
    from repro.core.program import (get_program, lower_mesh,
                                    lower_simulator, lower_virtual_mesh)
    from repro.distributed.consensus import (VirtualTopology, get_rule,
                                             mesh_weights_from_matrix)
    from repro.distributed.mixing import SparseWeights

    program = get_program(name)
    rule = get_rule(program.combine)
    spec_kw = SPEC_KW.get(name, {})
    local_steps = int(spec_kw.get("local_steps", 1))
    L = L_VIRT if substrate == "virtual" else L_SIM
    pb = _setup(L, np.dtype(dtype).name)
    kw = dict(eta=0.01, T_GD=T_GD, U_star=pb["U0"][0],
              backend="pallas-interpret", **spec_kw)
    if program.takes_avail:
        kw["avail"] = pb["avail"]
    rounds = int(rule.signature(T_CON).rounds_per_iter)

    if substrate == "simulator":
        run = lower_simulator(program)
        if program.topology == "none":
            fn = lambda U0, Xg, yg: run(U0[0], Xg, yg, **kw)
        elif program.topology == "adj":
            fn = lambda U0, Xg, yg: run(U0, Xg, yg, pb["adj"], **kw)
        else:
            fn = lambda U0, Xg, yg: run(U0, Xg, yg, pb["W"], T_con=T_CON,
                                        **kw)
        n_shifts = 0
    elif substrate == "mesh":
        run = lower_mesh(program)
        mesh = _mesh8()
        W = pb["Madj"] if program.topology == "adj" else pb["W"]
        shifts, _ = mesh_weights_from_matrix(np.asarray(W))
        n_shifts = len(shifts)
        fn = lambda U0, Xg, yg: run(U0, Xg, yg, mesh, "nodes",
                                    T_con=T_CON, W=np.asarray(W), **kw)
    elif substrate == "virtual":
        run = lower_virtual_mesh(program)
        mesh = _mesh8()
        W = pb["Madj"] if program.topology == "adj" else pb["W"]
        vt = VirtualTopology.from_weights(
            SparseWeights.from_dense(np.asarray(W)), N_DEV)
        n_shifts = len(vt.dev_shifts)
        fn = lambda U0, Xg, yg: run(U0, Xg, yg, mesh, "nodes", vt=vt,
                                    T_con=T_CON, **kw)
    else:
        raise ValueError(f"unknown substrate {substrate!r}; expected one "
                         f"of {SUBSTRATES}")

    jaxpr = jax.make_jaxpr(fn)(pb["U0"], pb["Xg"], pb["yg"])
    return Trace(program=program, substrate=substrate, dtype=dtype,
                 jaxpr=jaxpr, L=L, rounds=rounds, n_shifts=n_shifts,
                 local_steps=local_steps)


# ----------------------------------------------------------------------
# jaxpr walking
# ----------------------------------------------------------------------

def _sub_jaxprs(eqn):
    """Every sub-jaxpr reachable from an eqn's params, as bare Jaxprs.
    Covers scan/while (jaxpr), pjit/shard_map/custom_* (jaxpr /
    call_jaxpr / branches) without enumerating primitive names."""
    for val in eqn.params.values():
        for item in (val if isinstance(val, (tuple, list)) else (val,)):
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                yield item.jaxpr          # ClosedJaxpr
            elif hasattr(item, "eqns"):
                yield item                # bare Jaxpr


def iter_eqns(closed_jaxpr, outer_len: int = T_GD
              ) -> Iterator[tuple[Any, int, bool]]:
    """Yield ``(eqn, mult, in_outer)`` over the whole jaxpr tree.

    ``mult`` is how many times the eqn executes per outer iteration
    (once ``in_outer``) or per run (outside it): scans that are not the
    outer T_GD loop multiply by their ``length``; the outer scan itself
    flips ``in_outer`` without multiplying, which is exactly the
    "per outer iteration" accounting the dispatch budget is written in.
    """
    def walk(jaxpr, mult, in_outer):
        for eqn in jaxpr.eqns:
            yield eqn, mult, in_outer
            sub_mult, sub_outer = mult, in_outer
            if eqn.primitive.name == "scan":
                length = eqn.params.get("length")
                if length == outer_len and not in_outer:
                    sub_outer = True
                elif length is not None:
                    sub_mult = mult * int(length)
            for sub in _sub_jaxprs(eqn):
                yield from walk(sub, sub_mult, sub_outer)

    yield from walk(closed_jaxpr.jaxpr, 1, False)


def eqn_location(eqn):
    """(repo-relative path, function name, line) of the user frame that
    traced this eqn, or ('', '', 0) when jax has no source info."""
    try:
        from jax._src import source_info_util
        fr = source_info_util.user_frame(eqn.source_info)
        if fr is None:
            return "", "", 0
        path = fr.file_name
        marker = "/src/repro/"
        if marker in path:
            path = "src/repro/" + path.split(marker, 1)[1]
        return path, fr.function_name, fr.start_line
    except Exception:
        return "", "", 0


def count_primitive(trace: Trace, prim: str) -> tuple[int, int]:
    """(per-outer-iteration count, outside-outer count) of a primitive,
    dynamic — inner-scan lengths included."""
    inner = outer = 0
    for eqn, mult, in_outer in iter_eqns(trace.jaxpr):
        if eqn.primitive.name == prim:
            if in_outer:
                inner += mult
            else:
                outer += mult
    return inner, outer
