"""reprolint — the repo's static-analysis suite (``repro.analysis``).

Two levels, one driver:

  * jaxpr analyzers (:mod:`repro.analysis.jaxlint`) trace every
    registered :class:`~repro.core.program.SolverProgram` through its
    three lowerings at a tiny shape and enforce the dispatch budget
    (JX001), the no-dense-node-axis invariant (JX002), f64 precision
    flow (JX003), and CommSignature wire pricing (JX004);
  * AST rules (:mod:`repro.analysis.astlint`) enforce the source-level
    hygiene rules RL001–RL006.

Run everything: ``python -m tools.reprolint --all`` (the CLI sets up
the 8 fake host devices the mesh traces need).  Programmatic use::

    from repro.analysis import run_all
    findings = run_all(repo_root=".")
"""
from repro.analysis.astlint import check_source, run_ast_rules
from repro.analysis.driver import main, run_all
from repro.analysis.findings import (Finding, load_baseline,
                                     split_by_baseline, write_baseline)
from repro.analysis.jaxlint import analyze_program

__all__ = ["Finding", "analyze_program", "check_source", "load_baseline",
           "main", "run_all", "run_ast_rules", "split_by_baseline",
           "write_baseline"]
