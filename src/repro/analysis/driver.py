"""The reprolint driver — both analyzer levels, the baseline, the CLI.

Exit codes: 0 clean (or every finding baselined), 1 findings, 2 usage.
The CLI front-end is ``tools/reprolint`` (``python -m tools.reprolint``),
which prepares the 8 fake host devices before jax loads; this module
assumes that environment already exists when the jaxpr level runs.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from repro.analysis.findings import (Finding, load_baseline,
                                     split_by_baseline, write_baseline)

DEFAULT_BASELINE = "tools/reprolint/baseline.json"


def run_all(repo_root=".", *, ast_level=True, jaxpr_level=True,
            programs=None, substrates=None) -> list[Finding]:
    """Every finding on the tree (pre-baseline)."""
    from repro.analysis import astlint, jaxlint
    from repro.analysis.harness import SUBSTRATES

    findings: list[Finding] = []
    if ast_level:
        findings += astlint.run_ast_rules(repo_root)
    if jaxpr_level:
        from repro.core.program import program_names
        for name in programs or program_names():
            findings += jaxlint.analyze_program(
                name, substrates or SUBSTRATES)
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="jaxpr + AST static analysis for the repro codebase "
                    "(see README 'Static analysis' for the rule table)")
    level = ap.add_mutually_exclusive_group()
    level.add_argument("--all", action="store_true",
                       help="both analyzer levels (the CI entry point)")
    level.add_argument("--ast", action="store_true",
                       help="AST rules RL001–RL006 only (fast, no jax "
                            "tracing)")
    level.add_argument("--jaxpr", action="store_true",
                       help="jaxpr rules JX001–JX004 only")
    ap.add_argument("--program", action="append", default=None,
                    metavar="NAME",
                    help="restrict the jaxpr level to this program "
                         "(repeatable; default: all registered)")
    ap.add_argument("--substrate", action="append", default=None,
                    choices=("simulator", "mesh", "virtual"),
                    help="restrict the jaxpr level to this substrate "
                         "(repeatable; default: all three)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"suppression file (default {DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline as "
                         "TODO-justified suppressions and exit")
    args = ap.parse_args(argv)
    if not (args.all or args.ast or args.jaxpr):
        ap.error("pick a level: --all, --ast, or --jaxpr")

    root = pathlib.Path.cwd()
    if not (root / "src" / "repro").is_dir():
        print("reprolint: run from the repo root (src/repro not found)",
              file=sys.stderr)
        return 2

    findings = run_all(
        root, ast_level=args.all or args.ast,
        jaxpr_level=args.all or args.jaxpr,
        programs=args.program, substrates=args.substrate)

    if args.write_baseline:
        write_baseline(root / args.baseline, findings)
        print(f"wrote {len(findings)} suppression skeleton(s) to "
              f"{args.baseline}; fill in every justification")
        return 0

    baseline = load_baseline(root / args.baseline)
    new, suppressed, stale = split_by_baseline(findings, baseline)

    for f in sorted(new, key=lambda f: (f.path, f.line, f.rule)):
        print(f.render())
    if suppressed:
        print(f"({len(suppressed)} finding(s) suppressed by "
              f"{args.baseline})")
    for fp in stale:
        print(f"stale baseline entry (fix landed — remove it): {fp}")

    if new or stale:
        print(f"reprolint: {len(new)} finding(s), {len(stale)} stale "
              f"baseline entr(ies)")
        return 1
    print("reprolint: clean")
    return 0
