"""Network topologies for the decentralized runtime.

Topology is static host-side metadata (numpy, never traced).  The native
representation is :class:`SparseGraph` — CSR neighbour lists — because at
the node scales the roadmap targets (L ≈ 10⁵–10⁶) an (L, L) adjacency is
pure overhead: real relatedness graphs are sparse and skewed.  Every
generator emits a SparseGraph built from an edge list; no generator ever
allocates an (L, L) matrix (``erdos_renyi`` keeps its historical dense
draw only below ``ER_DENSE_MAX`` nodes, where it is both cheap and the
seed-compatibility anchor — the same numpy RNG stream produces the same
graph as every previous release).

The dense :class:`Graph` wrapper remains for small-L call sites (mixing-
matrix builders, parity tests): ``SparseGraph.adj`` materializes a dense
adjacency on demand but refuses above ``DENSE_MATERIALIZE_MAX`` nodes so
an accidental densification of a 100k-node graph fails loudly instead of
allocating 10 GB.

The paper's experiments use Erdős–Rényi graphs; the TPU runtime prefers
ring/torus/hypercube because those embed in the ICI fabric with
nearest-neighbour collective-permutes.  The scale families —
:func:`barabasi_albert` (scale-free preferential attachment),
:func:`hierarchical` (b-ary aggregation tree), and
:func:`cluster_of_cliques` (dense pods bridged in a ring) — model the
skewed real-world relatedness graphs the sparse consensus path exists
for.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Largest L for which SparseGraph.adj will materialize a dense matrix
# (4096² int8 = 16 MB; beyond that a dense adjacency is a bug).
DENSE_MATERIALIZE_MAX = 4096

# erdos_renyi keeps the historical dense (L, L) uniform draw below this
# many nodes: identical RNG consumption → bit-identical graphs for every
# existing seeded test/benchmark.  Above it the G(L, M) edge-count
# sampler runs (no (L, L) allocation).
ER_DENSE_MAX = 2048


def _ranges(counts: np.ndarray) -> np.ndarray:
    """[0..c₀-1, 0..c₁-1, ...] — vectorized per-segment aranges."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    out = np.arange(total, dtype=np.int64)
    offs = np.repeat(np.cumsum(counts) - counts, counts)
    return out - offs


@dataclasses.dataclass(frozen=True)
class SparseGraph:
    """Undirected graph on L nodes in CSR form (host numpy).

    ``indptr``: (L+1,) int64 row pointers; ``col_idx``: (nnz,) int32
    neighbour indices, sorted within each row.  Both directions of every
    edge are stored (nnz = 2·|E|), the diagonal never is.  Exposes the
    same read interface as the dense :class:`Graph` (``n_nodes`` /
    ``degrees`` / ``max_degree`` / ``n_edges`` / ``neighbors`` /
    ``is_connected`` / ``adj``) so small-L call sites work unchanged.
    """
    indptr: np.ndarray
    col_idx: np.ndarray

    def __post_init__(self):
        indptr = np.asarray(self.indptr, dtype=np.int64)
        col = np.asarray(self.col_idx, dtype=np.int32)
        object.__setattr__(self, "indptr", indptr)
        object.__setattr__(self, "col_idx", col)
        if indptr.ndim != 1 or indptr.size < 1:
            raise ValueError(f"indptr must be (L+1,), got {indptr.shape}")
        L = indptr.size - 1
        if indptr[0] != 0 or indptr[-1] != col.size:
            raise ValueError("indptr must start at 0 and end at nnz")
        if np.any(np.diff(indptr) < 0):
            raise ValueError("indptr must be nondecreasing")
        if col.size:
            if col.min() < 0 or col.max() >= L:
                raise ValueError("col_idx out of range")
        rows = self._row_idx()
        if np.any(rows == col.astype(np.int64)):
            raise ValueError("no self loops allowed")
        # symmetry: the (row, col) key multiset must equal its transpose
        fwd = np.sort(rows * L + col)
        rev = np.sort(col.astype(np.int64) * L + rows)
        if not np.array_equal(fwd, rev):
            raise ValueError("adjacency must be symmetric (undirected graph)")

    def _row_idx(self) -> np.ndarray:
        """(nnz,) row index of every stored entry (COO expansion)."""
        return np.repeat(np.arange(self.n_nodes, dtype=np.int64),
                         np.diff(self.indptr))

    # ------------------------------------------------------ construction

    @classmethod
    def from_edges(cls, L: int, u, v) -> "SparseGraph":
        """Build from a (directed or undirected) edge list: self loops
        dropped, duplicates merged, both directions stored."""
        u = np.asarray(u, dtype=np.int64).ravel()
        v = np.asarray(v, dtype=np.int64).ravel()
        if u.size and (min(u.min(), v.min()) < 0
                       or max(u.max(), v.max()) >= L):
            raise ValueError(f"edge endpoints out of range for L={L}")
        keep = u != v
        u, v = u[keep], v[keep]
        key = np.unique(np.concatenate([u * L + v, v * L + u]))
        rows = key // L
        cols = (key % L).astype(np.int32)
        counts = np.bincount(rows, minlength=L)
        indptr = np.zeros(L + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return cls(indptr=indptr, col_idx=cols)

    @classmethod
    def from_dense(cls, adj) -> "SparseGraph":
        a = np.asarray(adj)
        rows, cols = np.nonzero(a)          # row-major → CSR-sorted
        L = a.shape[0]
        counts = np.bincount(rows, minlength=L)
        indptr = np.zeros(L + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return cls(indptr=indptr, col_idx=cols.astype(np.int32))

    # -------------------------------------------------------- interface

    @property
    def n_nodes(self) -> int:
        return self.indptr.size - 1

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.n_nodes else 0

    @property
    def n_edges(self) -> int:
        return self.col_idx.size // 2

    @property
    def density(self) -> float:
        """Fraction of possible (off-diagonal) entries present."""
        L = self.n_nodes
        return self.col_idx.size / (L * (L - 1)) if L > 1 else 0.0

    def neighbors(self, g: int) -> np.ndarray:
        return self.col_idx[self.indptr[g]:self.indptr[g + 1]]

    def neighbor_lists(self) -> list:
        """Per-node neighbour arrays (the event-clock's input — no dense
        adjacency needed)."""
        return [self.neighbors(g) for g in range(self.n_nodes)]

    def edges(self) -> tuple[np.ndarray, np.ndarray]:
        """Canonical (u, v) with u < v, one row per undirected edge."""
        rows = self._row_idx()
        keep = rows < self.col_idx
        return rows[keep], self.col_idx[keep].astype(np.int64)

    def is_connected(self) -> bool:
        L = self.n_nodes
        if L <= 1:
            return True
        seen = np.zeros(L, dtype=bool)
        seen[0] = True
        frontier = np.array([0], dtype=np.int64)
        while frontier.size:
            starts = self.indptr[frontier]
            counts = np.diff(self.indptr)[frontier]
            nbrs = self.col_idx[np.repeat(starts, counts) + _ranges(counts)]
            new = np.unique(nbrs[~seen[nbrs]])
            seen[new] = True
            frontier = new
        return bool(seen.all())

    @property
    def adj(self) -> np.ndarray:
        """Dense (L, L) int8 adjacency — small graphs only (guarded)."""
        L = self.n_nodes
        if L > DENSE_MATERIALIZE_MAX:
            raise ValueError(
                f"refusing to densify a {L}-node graph "
                f"(> DENSE_MATERIALIZE_MAX={DENSE_MATERIALIZE_MAX}); the "
                f"sparse consensus path never needs the dense adjacency")
        a = np.zeros((L, L), dtype=np.int8)
        a[self._row_idx(), self.col_idx] = 1
        return a

    def to_dense(self) -> "Graph":
        return Graph(self.adj)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph on L nodes. ``adj`` is a symmetric 0/1 matrix with
    zero diagonal.  Small-L view; generators emit :class:`SparseGraph`."""
    adj: np.ndarray  # (L, L) int8

    def __post_init__(self):
        a = np.asarray(self.adj)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if not np.array_equal(a, a.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if np.any(np.diag(a) != 0):
            raise ValueError("no self loops allowed")

    @property
    def n_nodes(self) -> int:
        return self.adj.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def n_edges(self) -> int:
        return int(self.adj.sum()) // 2

    @property
    def density(self) -> float:
        L = self.n_nodes
        return int(self.adj.sum()) / (L * (L - 1)) if L > 1 else 0.0

    def neighbors(self, g: int) -> np.ndarray:
        return np.nonzero(self.adj[g])[0]

    def neighbor_lists(self) -> list:
        return [self.neighbors(g) for g in range(self.n_nodes)]

    def to_sparse(self) -> SparseGraph:
        return SparseGraph.from_dense(self.adj)

    def is_connected(self) -> bool:
        L = self.n_nodes
        seen = np.zeros(L, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            u = stack.pop()
            for v in np.nonzero(self.adj[u])[0]:
                if not seen[v]:
                    seen[v] = True
                    stack.append(int(v))
        return bool(seen.all())


def _sample_pair_set(rng: np.random.Generator, L: int, M: int,
                     forbid_key=None) -> tuple[np.ndarray, np.ndarray]:
    """M distinct unordered node pairs, uniform over the C(L, 2) set —
    the G(L, M) sampler.  Draws (u, v) uniformly (every unordered pair
    has equal mass 2/L²), canonicalizes, dedupes, and tops up until M
    distinct pairs exist; never touches an (L, L) array."""
    n_pairs = L * (L - 1) // 2
    if M > n_pairs:
        raise ValueError(f"cannot sample {M} distinct pairs from {n_pairs}")
    keys = np.zeros(0, dtype=np.int64)
    while keys.size < M:
        chunk = max(1024, int(1.2 * (M - keys.size)))
        u = rng.integers(0, L, size=chunk)
        v = rng.integers(0, L, size=chunk)
        ok = u != v
        lo, hi = np.minimum(u[ok], v[ok]), np.maximum(u[ok], v[ok])
        keys = np.unique(np.concatenate([keys, lo * L + hi]))
    if keys.size > M:
        keys = keys[rng.choice(keys.size, M, replace=False)]
    return keys // L, keys % L


def erdos_renyi(L: int, p: float, seed: int = 0,
                ensure_connected: bool = True,
                max_tries: int = 1000) -> SparseGraph:
    """G(L, p) as in the paper's simulations.  If ``ensure_connected``,
    resample until connected (the paper's Assumption 3), falling back to
    overlaying a ring if p is too small to connect within ``max_tries``.

    Below ``ER_DENSE_MAX`` nodes the historical dense (L, L) uniform
    draw runs (bit-identical graphs for existing seeds); above it the
    edge COUNT is drawn Binomial(C(L,2), p) and that many distinct edges
    are sampled uniformly — the G(L, M) variant, equal in distribution,
    with O(E) memory instead of O(L²)."""
    rng = np.random.default_rng(seed)
    g = None
    for _ in range(max_tries):
        if L <= ER_DENSE_MAX:
            u = rng.random((L, L))
            upper = np.triu(np.ones((L, L), dtype=bool), 1)
            a = ((u < p) & upper).astype(np.int8)
            g = SparseGraph.from_dense(a + a.T)
        else:
            M = int(rng.binomial(L * (L - 1) // 2, p))
            g = SparseGraph.from_edges(L, *_sample_pair_set(rng, L, M))
        if not ensure_connected or g.is_connected():
            return g
    # fall back: overlay a ring to force connectivity
    u, v = g.edges()
    ru = np.arange(L, dtype=np.int64)
    return SparseGraph.from_edges(L, np.concatenate([u, ru]),
                                  np.concatenate([v, (ru + 1) % L]))


def circulant(L: int, shifts: tuple[int, ...] = (-1, 1)) -> SparseGraph:
    """Circulant graph: node i adjacent to i+s (mod L) for each shift —
    the topology a circulant mixing matrix actually gossips over (each
    shift = one collective-permute on the mesh runtime)."""
    i = np.arange(L, dtype=np.int64)
    u = np.concatenate([i for _ in shifts]) if shifts else i[:0]
    v = np.concatenate([(i + s) % L for s in shifts]) if shifts else i[:0]
    return SparseGraph.from_edges(L, u, v)


def ring(L: int) -> SparseGraph:
    if L == 1:
        return SparseGraph.from_edges(1, [], [])
    i = np.arange(L, dtype=np.int64)
    return SparseGraph.from_edges(L, i, (i + 1) % L)


def path_graph(L: int) -> SparseGraph:
    i = np.arange(L - 1, dtype=np.int64)
    return SparseGraph.from_edges(L, i, i + 1)


def torus2d(rows: int, cols: int) -> SparseGraph:
    """2-D torus — the natural embedding of a TPU ICI mesh slice."""
    L = rows * cols
    r, c = np.divmod(np.arange(L, dtype=np.int64), cols)
    down = ((r + 1) % rows) * cols + c
    right = r * cols + (c + 1) % cols
    i = np.arange(L, dtype=np.int64)
    return SparseGraph.from_edges(L, np.concatenate([i, i]),
                                  np.concatenate([down, right]))


def hypercube(dim: int) -> SparseGraph:
    L = 1 << dim
    i = np.arange(L, dtype=np.int64)
    u = np.concatenate([i for _ in range(dim)])
    v = np.concatenate([i ^ (1 << b) for b in range(dim)])
    return SparseGraph.from_edges(L, u, v)


def complete(L: int) -> SparseGraph:
    u, v = np.triu_indices(L, 1)
    return SparseGraph.from_edges(L, u, v)


def star(L: int) -> SparseGraph:
    v = np.arange(1, L, dtype=np.int64)
    return SparseGraph.from_edges(L, np.zeros_like(v), v)


# ----------------------------------------------------------------------
# scale families (sparse-born: no (L, L) allocation ever)
# ----------------------------------------------------------------------

def barabasi_albert(L: int, m: int = 2, seed: int = 0) -> SparseGraph:
    """Scale-free preferential attachment (Barabási–Albert): start from
    an (m+1)-clique, then each new node attaches to m distinct existing
    nodes drawn proportionally to degree (the repeated-endpoints trick).
    Connected by construction; degree distribution is the skewed
    power-law real relatedness graphs show."""
    if m < 1:
        raise ValueError(f"barabasi_albert needs m >= 1, got {m}")
    if L < m + 1:
        raise ValueError(f"barabasi_albert needs L >= m+1={m + 1}, "
                         f"got L={L}")
    rng = np.random.default_rng(seed)
    seed_u, seed_v = np.triu_indices(m + 1, 1)
    us = [seed_u.astype(np.int64)]
    vs = [seed_v.astype(np.int64)]
    # every edge endpoint appears once → sampling the list IS sampling
    # proportionally to degree
    repeated = list(np.concatenate([seed_u, seed_v]))
    for new in range(m + 1, L):
        targets: set = set()
        while len(targets) < m:
            targets.add(repeated[rng.integers(0, len(repeated))])
        t = np.fromiter(targets, dtype=np.int64, count=m)
        us.append(np.full(m, new, dtype=np.int64))
        vs.append(t)
        repeated.extend(t)
        repeated.extend([new] * m)
    return SparseGraph.from_edges(L, np.concatenate(us), np.concatenate(vs))


def hierarchical(L: int, branching: int = 4) -> SparseGraph:
    """Hierarchical aggregation tree: node i > 0 links to its parent
    ⌊(i−1)/b⌋ — the b-ary tree overlay of datacenter/edge aggregation
    tiers.  L−1 edges, diameter O(log_b L), connected by construction."""
    if branching < 1:
        raise ValueError(f"hierarchical needs branching >= 1, got "
                         f"{branching}")
    i = np.arange(1, L, dtype=np.int64)
    return SparseGraph.from_edges(L, i, (i - 1) // branching)


def cluster_of_cliques(L: int, clique: int = 8, seed: int = 0) -> SparseGraph:
    """Cluster-of-cliques: dense pods of ``clique`` nodes (the last pod
    takes the remainder), bridged in a ring by one seeded representative
    pair per adjacent pod — the "tight teams, thin backbone" shape of
    federated silos.  Connected whenever L ≥ 1."""
    if clique < 2:
        raise ValueError(f"cluster_of_cliques needs clique >= 2, got "
                         f"{clique}")
    rng = np.random.default_rng(seed)
    n_pods = max(1, -(-L // clique))
    us, vs = [], []
    cu, cv = np.triu_indices(clique, 1)
    for k in range(n_pods):
        lo, hi = k * clique, min((k + 1) * clique, L)
        size = hi - lo
        if size >= 2:
            keep = (cu < size) & (cv < size)
            us.append(lo + cu[keep].astype(np.int64))
            vs.append(lo + cv[keep].astype(np.int64))
    if n_pods > 1:
        for k in range(n_pods):
            k2 = (k + 1) % n_pods
            lo, hi = k * clique, min((k + 1) * clique, L)
            lo2, hi2 = k2 * clique, min((k2 + 1) * clique, L)
            us.append(np.array([rng.integers(lo, hi)], dtype=np.int64))
            vs.append(np.array([rng.integers(lo2, hi2)], dtype=np.int64))
    if not us:
        return SparseGraph.from_edges(L, [], [])
    return SparseGraph.from_edges(L, np.concatenate(us), np.concatenate(vs))


# ----------------------------------------------------------------------
# bandwidth-reducing relabeling (mesh shift-count pruning)
# ----------------------------------------------------------------------

def reverse_cuthill_mckee(graph) -> np.ndarray:
    """Reverse Cuthill–McKee node permutation ``perm`` (new→old): BFS
    from a minimum-degree node, visiting each frontier's neighbours in
    increasing-degree order, then reversed.  Relabeling an irregular
    graph by ``perm`` concentrates its adjacency near the diagonal, so
    :func:`repro.distributed.consensus.mesh_weights_from_matrix` sees
    far fewer distinct cyclic shifts — each shift is one
    collective-permute on the mesh runtime, making this the shift-count
    pruning knob.  Handles disconnected graphs (each component appended
    in turn).  Works on :class:`SparseGraph` and dense :class:`Graph`.
    """
    sg = graph if isinstance(graph, SparseGraph) else graph.to_sparse()
    L = sg.n_nodes
    deg = sg.degrees
    visited = np.zeros(L, dtype=bool)
    order = np.empty(L, dtype=np.int64)
    pos = 0
    for start in np.argsort(deg, kind="stable"):
        if visited[start]:
            continue
        visited[start] = True
        queue = [int(start)]
        while queue:
            u = queue.pop(0)
            order[pos] = u
            pos += 1
            nbrs = sg.neighbors(u)
            nbrs = nbrs[~visited[nbrs]]
            nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
            visited[nbrs] = True
            queue.extend(int(x) for x in nbrs)
    return order[::-1].copy()
