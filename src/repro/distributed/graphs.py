"""Network topologies for the decentralized runtime.

A :class:`Graph` is a plain adjacency-matrix wrapper (numpy, host side —
topology is static metadata, never traced).  The paper's experiments use
Erdős–Rényi graphs; the TPU runtime prefers ring/torus/hypercube because
those embed in the ICI fabric with nearest-neighbour collective-permutes
(DESIGN.md §3, hardware adaptation #1).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph on L nodes. ``adj`` is a symmetric 0/1 matrix with
    zero diagonal."""
    adj: np.ndarray  # (L, L) int8

    def __post_init__(self):
        a = np.asarray(self.adj)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if not np.array_equal(a, a.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if np.any(np.diag(a) != 0):
            raise ValueError("no self loops allowed")

    @property
    def n_nodes(self) -> int:
        return self.adj.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def n_edges(self) -> int:
        return int(self.adj.sum()) // 2

    def neighbors(self, g: int) -> np.ndarray:
        return np.nonzero(self.adj[g])[0]

    def is_connected(self) -> bool:
        L = self.n_nodes
        seen = np.zeros(L, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            u = stack.pop()
            for v in np.nonzero(self.adj[u])[0]:
                if not seen[v]:
                    seen[v] = True
                    stack.append(int(v))
        return bool(seen.all())


def erdos_renyi(L: int, p: float, seed: int = 0,
                ensure_connected: bool = True, max_tries: int = 1000) -> Graph:
    """G(L, p) as in the paper's simulations. If ``ensure_connected``,
    resample until connected (the paper's Assumption 3), falling back to
    adding a ring if p is too small to connect within ``max_tries``."""
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        u = rng.random((L, L))
        upper = np.triu(np.ones((L, L), dtype=bool), 1)
        a = ((u < p) & upper).astype(np.int8)
        a = a + a.T
        g = Graph(a)
        if not ensure_connected or g.is_connected():
            return g
    # fall back: overlay a ring to force connectivity
    a = a | ring(L).adj
    return Graph(a.astype(np.int8))


def circulant(L: int, shifts: tuple[int, ...] = (-1, 1)) -> Graph:
    """Circulant graph: node i adjacent to i+s (mod L) for each shift —
    the topology a circulant mixing matrix actually gossips over (each
    shift = one collective-permute on the mesh runtime)."""
    a = np.zeros((L, L), dtype=np.int8)
    for i in range(L):
        for s in shifts:
            j = (i + s) % L
            if i != j:
                a[i, j] = 1
                a[j, i] = 1
    return Graph(a)


def ring(L: int) -> Graph:
    a = np.zeros((L, L), dtype=np.int8)
    if L == 1:
        return Graph(a)
    for i in range(L):
        a[i, (i + 1) % L] = 1
        a[(i + 1) % L, i] = 1
    return Graph(a)


def path_graph(L: int) -> Graph:
    a = np.zeros((L, L), dtype=np.int8)
    for i in range(L - 1):
        a[i, i + 1] = 1
        a[i + 1, i] = 1
    return Graph(a)


def torus2d(rows: int, cols: int) -> Graph:
    """2-D torus — the natural embedding of a TPU ICI mesh slice."""
    L = rows * cols
    a = np.zeros((L, L), dtype=np.int8)

    def idx(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = idx(r, c)
            for j in (idx(r + 1, c), idx(r, c + 1)):
                if i != j:
                    a[i, j] = 1
                    a[j, i] = 1
    return Graph(a)


def hypercube(dim: int) -> Graph:
    L = 1 << dim
    a = np.zeros((L, L), dtype=np.int8)
    for i in range(L):
        for b in range(dim):
            j = i ^ (1 << b)
            a[i, j] = 1
    return Graph(a)


def complete(L: int) -> Graph:
    a = np.ones((L, L), dtype=np.int8) - np.eye(L, dtype=np.int8)
    return Graph(a)


def star(L: int) -> Graph:
    a = np.zeros((L, L), dtype=np.int8)
    a[0, 1:] = 1
    a[1:, 0] = 1
    return Graph(a)
