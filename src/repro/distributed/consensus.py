"""Unified pluggable consensus layer — every ``Z ← W Z`` in one place.

The AGREE protocol is the communication heart of the AltGDmin family,
but before this module each execution surface re-derived the mixing
product independently: the simulator's stacked scan (core/agree.py), the
mesh runtime's inline ppermute chain (core/runtime.py), the trainer's
roll form (distributed/gossip.py / aggregation.py), and the engine's
fused ``W^{T_con}`` combine (core/engine.py).  A :class:`CombineRule`
now owns all of them, with three lowered forms per rule:

  * **simulator** — stacked node axis, ``Z: (L, ...)``.  The unfused
    lowering is the exact sequential product (dtype-preserving, the
    numerics anchor); fused backends hoist onto a precomputed dense
    mixer executed by ``kernels/gossip_axpy.mix_rows`` (one weighted
    combine instead of T_con HBM sweeps).
  * **mesh** — one node per device inside ``shard_map``.  Each gossip
    round exchanges blocks by ``lax.ppermute`` and then combines them:
    the unfused lowering is the sequential weighted-sum chain, the fused
    lowering is ONE (K+1)-way ``kernels/gossip_axpy.gossip_combine``
    dispatch per round.  Any weighted graph lowers this way
    (:func:`mesh_weights_from_matrix`): one permute per distinct cyclic
    shift of W's sparsity pattern, each device combining with its own W
    row — circulant matrices collapse to shared scalar weights.
  * **comm signature** — a :class:`CommSignature` consumed by
    :mod:`repro.core.comm_model` and the API's wall-clock pricing, so a
    rule's communication cost is declared next to its math.

Precision policy (shared by every lowering): the fused combine kernels
accumulate in f32, so float64 operands always take the exact unfused
path — x64 simulations are never silently truncated in the consensus
phase.  Lower-precision operands (bf16 wire dtypes) accumulate in the
promoted f32 dtype on the unfused path too, matching the kernels.

Rules registered here: ``gossip`` (the paper's T_con-round AGREE),
``neighbor`` (DGD's single self-excluding exchange), ``central`` (fusion
center), ``none`` (no communication), plus the related-work combines —
``exact_diffusion`` (the projection-corrected combine of *Exact Subspace
Diffusion for Decentralized Multitask Learning*, arXiv:2304.07358) and
``beyond_central`` (the communication-efficient single-round combine of
*Beyond Centralization*, arXiv:2512.22675) — and the compressed wire
rules ``topk_gossip`` / ``quantized_gossip`` / ``event_gossip`` (see
:class:`CompressedGossipCombine`: stateful encode, compact payloads,
error feedback).  The dropout-tolerant rules ``partial_gossip`` /
``stale_gossip`` / ``push_sum_gossip`` (see
:class:`MaskedGossipCombine`) take a per-iteration availability mask:
masked weight renormalization, last-delivered stale copies, and
bias-corrected push-sum weight carry respectively — with availability
≡ 1 the first two reproduce dense gossip bit-for-bit.
``register_rule`` is open.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CommSignature:
    """What a combine rule costs on the wire, per outer iteration.

    ``pattern`` prices the exchange shape: ``"gossip"`` /``"neighbor"``
    send the iterate to every graph neighbour ``rounds_per_iter`` times;
    ``"central"`` is one gather + one broadcast; ``"none"`` is silent.

    ``entries_per_round`` / ``bytes_per_entry`` describe the PAYLOAD of
    one message: ``None`` means the dense d×r iterate at the network
    model's native precision (every uncompressed rule), while the
    compressed rules fill both so the pricing layer
    (:func:`repro.core.comm_model.time_axis_from_signature`) sees the
    smaller wire format instead of silently assuming a dense exchange.
    """
    pattern: str                 # "gossip" | "neighbor" | "central" | "none"
    rounds_per_iter: int
    entries_per_round: Optional[int] = None   # None → dense d·r
    bytes_per_entry: Optional[int] = None     # None → the model's native

    def bytes_per_iter(self, n_entries: int, itemsize: int, n_nodes: int,
                       degree: int) -> int:
        """Bytes sent per node per outer iteration (benchmark tables).
        The signature's own payload fields override the dense
        ``n_entries`` / ``itemsize`` arguments when set."""
        n = (self.entries_per_round if self.entries_per_round is not None
             else n_entries)
        bpe = (self.bytes_per_entry if self.bytes_per_entry is not None
               else itemsize)
        if self.pattern == "central":
            # ring all-reduce equivalent: 2·(L−1)/L · size
            return int(2 * (n_nodes - 1) / n_nodes * n * bpe)
        return int(self.rounds_per_iter * degree * n * bpe)

    def network_bytes_per_iter(self, n_entries: int, itemsize: int, *,
                               n_nodes: int, n_edges: int) -> int:
        """TOTAL bytes the whole network moves per outer iteration,
        derived from the graph's edge set (degree-weighted: one message
        per directed edge per round, Σ_g deg_g = 2·|E|) — NOT from an
        L² all-pairs assumption.  Dense and sparse representations of
        the same graph report the same ``n_edges``, so they price
        identically (the consistency regression); the scale benchmark
        reports this next to per-node :meth:`bytes_per_iter`."""
        n = (self.entries_per_round if self.entries_per_round is not None
             else n_entries)
        bpe = (self.bytes_per_entry if self.bytes_per_entry is not None
               else itemsize)
        if self.pattern == "none" or self.rounds_per_iter == 0:
            return 0
        if self.pattern == "central":
            # L uploads + L downloads of the iterate
            return int(2 * n_nodes * n * bpe)
        return int(self.rounds_per_iter * 2 * n_edges * n * bpe)


# ----------------------------------------------------------------------
# the combine primitives every lowering bottoms out in
# ----------------------------------------------------------------------

def _acc_dtype(dtype):
    return jnp.promote_types(dtype, jnp.float32)


def _fused_wanted(backend: str, dtype) -> bool:
    """Fused Pallas combines accumulate in f32: take them only on the
    pallas backends and never for float64 operands (x64 policy)."""
    return backend != "xla-ref" and jnp.dtype(dtype) != jnp.float64


def combine_blocks(z, neighbors: Sequence[jax.Array], weights, *,
                   backend: str = "xla-ref"):
    """ONE (K+1)-way weighted combine ``z ← w₀·z + Σ_k w_{k+1}·nbr_k`` —
    the primitive under every mesh lowering (ppermute rounds, trainer
    roll rounds).  ``weights`` is a length-K+1 sequence: Python floats
    for uniform circulant weights, or a (K+1,) array slice of the
    device's own W row for arbitrary weighted topologies.  Unfused: the
    sequential chain in the promoted accumulator dtype; fused: a single
    ``gossip_combine`` dispatch."""
    from repro.kernels import ops
    neighbors = list(neighbors)
    if neighbors and _fused_wanted(backend, z.dtype):
        return ops.gossip_combine(z, jnp.stack(neighbors), weights,
                                  backend=backend)
    acc_dt = _acc_dtype(z.dtype)
    w = (list(weights) if isinstance(weights, (tuple, list))
         else list(jnp.asarray(weights).astype(acc_dt)))
    acc = w[0] * z.astype(acc_dt)
    for k, nbr in enumerate(neighbors):
        acc = acc + w[k + 1] * nbr.astype(acc_dt)
    return acc.astype(z.dtype)


def stacked_product(Z: jax.Array, W, T_con: int) -> jax.Array:
    """The exact sequential simulator product: T_con rounds of ``W @ Z``
    over the leading node axis, dtype-preserving (the seed's ``agree``
    math — every other lowering is validated against this).  ``W`` may
    be a :class:`~repro.distributed.mixing.SparseWeights`, in which case
    each round is the padded-COO segment-sum of
    :func:`stacked_sparse_product` instead of a dense matmul."""
    from repro.distributed.mixing import SparseWeights
    if isinstance(W, SparseWeights):
        return stacked_sparse_product(Z, W, T_con)
    if T_con == 0:
        return Z
    W = W.astype(Z.dtype)
    flat = Z.reshape(Z.shape[0], -1)

    def body(carry, _):
        return W @ carry, None

    out, _ = jax.lax.scan(body, flat, None, length=T_con)
    return out.reshape(Z.shape)


def stacked_dense_mix(Z: jax.Array, M, *, backend: str):
    """Single combine ``Z ← M Z`` for a precomputed mixer (e.g.
    ``W^{T_con}``): fused ``mix_rows`` on the pallas backends, einsum on
    xla-ref/f64.  A :class:`SparseWeights` mixer takes the segment-sum
    lowering instead (one sparse round, any backend)."""
    from repro.distributed.mixing import SparseWeights
    from repro.kernels import ops
    if isinstance(M, SparseWeights):
        return stacked_sparse_product(Z, M, 1)
    if _fused_wanted(backend, Z.dtype):
        return ops.mix_nodes(Z, M.astype(jnp.float32),
                             backend=backend).astype(Z.dtype)
    return jnp.einsum("gh,h...->g...", M.astype(Z.dtype), Z)


# ----------------------------------------------------------------------
# sparse simulator lowering
# ----------------------------------------------------------------------
#
# Above a node-count/density cutoff the (L, L) mixing matrix is pure
# overhead: every combine rule can lower to "gather sender rows by
# col_idx, weight, segment-sum into receivers" on the padded edge list a
# SparseWeights carries.  The edge arrays are padded to a multiple of
# _SPARSE_PAD entries so nearby sizes share compiled executables; the
# padding entries point at dummy segment L with weight exactly 0.0, so
# they are arithmetically invisible (the padding-neutrality test pins
# this).  Edges are CSR-sorted by receiver row with the padding at the
# end, so ``segment_sum(..., indices_are_sorted=True)`` is valid.

SPARSE_MIN_NODES = 512
SPARSE_DENSITY_THRESHOLD = 0.25
_SPARSE_PAD = 1024


def maybe_sparsify(W):
    """Auto-select the sparse simulator lowering for a concrete dense
    mixing matrix: above :data:`SPARSE_MIN_NODES` nodes AND at or below
    :data:`SPARSE_DENSITY_THRESHOLD` off-diagonal density, return the
    equivalent :class:`~repro.distributed.mixing.SparseWeights`;
    otherwise (small L, dense graph, traced operand, or anything that
    is not a square matrix) return ``W`` unchanged.  An explicit
    ``SparseWeights`` input passes straight through — a caller that
    built one has already chosen the representation."""
    from repro.distributed.mixing import SparseWeights
    if isinstance(W, SparseWeights) or W is None:
        return W
    if isinstance(W, jax.core.Tracer):
        return W
    try:
        Wn = np.asarray(W)
    except Exception:
        return W
    if Wn.ndim != 2 or Wn.shape[0] != Wn.shape[1]:
        return W
    L = Wn.shape[0]
    if L < SPARSE_MIN_NODES or L < 2:
        return W
    off = np.count_nonzero(Wn) - np.count_nonzero(np.diag(Wn))
    if off / (L * (L - 1)) > SPARSE_DENSITY_THRESHOLD:
        return W
    return SparseWeights.from_dense(Wn)


def _padded_coo(rows, cols, vals, n: int):
    """Pad host COO arrays to a multiple of :data:`_SPARSE_PAD` entries:
    padding rows point at dummy segment ``n``, padding cols at 0, and
    padding weights are exactly 0.0."""
    nnz = int(vals.size)
    total = max(_SPARSE_PAD,
                -(-nnz // _SPARSE_PAD) * _SPARSE_PAD)
    pad = total - nnz
    return (np.concatenate([rows, np.full(pad, n, np.int32)]),
            np.concatenate([cols, np.zeros(pad, np.int32)]),
            np.concatenate([vals, np.zeros(pad)]))


def _sparse_arrays(sw):
    """(rows, cols, vals, diag) padded host arrays of a SparseWeights —
    the static operands every sparse mixer closes over."""
    rows, cols, vals = _padded_coo(sw.rows, sw.cols, sw.vals, sw.n)
    return rows, cols, vals, sw.diag


def sparse_round(Zf, rows, cols, vals, diag, L: int):
    """ONE ``Z ← W Z`` on the padded edge list, ``Zf: (L, F)``: gather
    sender rows by ``cols``, weight, ``segment_sum`` into receiver rows
    (dummy segment L absorbs the padding), then add the separate
    diagonal term.  ``vals``/``diag`` must already be in ``Zf.dtype``
    (the caller casts once, mirroring ``stacked_product``'s
    ``W.astype``)."""
    gathered = vals[:, None] * Zf[cols]
    acc = jax.ops.segment_sum(gathered, rows, num_segments=L + 1,
                              indices_are_sorted=True)
    return acc[:L] + diag[:, None] * Zf


def sparse_offdiag_apply(Zf, rows, cols, vals, L: int):
    """The off-diagonal half of :func:`sparse_round` — ``(W − diag) Z``
    — for combines that treat the self term specially (the compressed
    rules' exact-self correction)."""
    gathered = vals[:, None] * Zf[cols]
    acc = jax.ops.segment_sum(gathered, rows, num_segments=L + 1,
                              indices_are_sorted=True)
    return acc[:L]


def stacked_sparse_product(Z: jax.Array, sw, T_con: int) -> jax.Array:
    """T_con sequential rounds of the sparse ``Z ← W Z`` — the sparse
    twin of :func:`stacked_product`, dtype-preserving (weights cast to
    ``Z.dtype`` exactly like the dense path's ``W.astype``)."""
    if T_con == 0:
        return Z
    L = sw.n
    rows, cols, vals, diag = _sparse_arrays(sw)
    rows, cols = jnp.asarray(rows), jnp.asarray(cols)
    vals = jnp.asarray(vals, Z.dtype)
    diag = jnp.asarray(diag, Z.dtype)
    flat = Z.reshape(L, -1)

    def body(carry, _):
        return sparse_round(carry, rows, cols, vals, diag, L), None

    out, _ = jax.lax.scan(body, flat, None, length=T_con)
    return out.reshape(Z.shape)


def node_mean(Z: jax.Array) -> jax.Array:
    """Fusion-center combine: exact mean over the node axis, broadcast
    back (lowers to one all-reduce under pjit)."""
    acc_dt = _acc_dtype(Z.dtype)
    m = jnp.mean(Z.astype(acc_dt), axis=0, keepdims=True)
    return jnp.broadcast_to(m, Z.shape).astype(Z.dtype)


def neighbor_average_matrix(adj):
    """DGD's row-stochastic neighbour average M = D⁻¹A (zero diagonal,
    isolated nodes guarded to degree 1).  ONE derivation shared by the
    simulator driver and the mesh lowering — their ≤1e-7 parity depends
    on both sides using the same matrix.  A
    :class:`~repro.distributed.graphs.SparseGraph` adjacency yields the
    equivalent :class:`SparseWeights` (same per-edge 1/deg values,
    never densified)."""
    from repro.distributed.graphs import Graph, SparseGraph
    from repro.distributed.mixing import neighbor_average_weights_sparse
    if isinstance(adj, SparseGraph):
        return neighbor_average_weights_sparse(adj)
    if isinstance(adj, Graph):
        adj = jnp.asarray(adj.adj, jnp.float64)  # reprolint: allow=RL002 — dense-Graph input tier; SparseGraph returns sparse above
    deg = jnp.maximum(jnp.sum(adj, axis=1), 1.0)
    return adj / deg[:, None]


def mesh_weights_from_matrix(W) -> tuple[tuple[int, ...], np.ndarray]:
    """Decompose a concrete (L, L) mixing matrix into cyclic-shift form:
    ``(shifts, table)`` with ``table[i] = [W_ii, W_{i,(i+s1)%L}, ...]``.

    Every entry of W lies on exactly one cyclic diagonal (edge (i, j) on
    shift ``(j−i) mod L``), so ANY weighted graph lowers to one
    ``lax.ppermute`` per distinct shift plus one (K+1)-way weighted
    combine — a circulant matrix needs exactly its own |shifts|, an
    irregular graph up to L−1.  Shifts are reported as signed
    representatives in (−L/2, L/2] and sorted, so a symmetric ring
    decomposes to the runtime's historical (−1, 1) order.

    W must be host-concrete (topology is static metadata, never traced).
    A :class:`SparseWeights` densifies first (the per-device mesh tier
    is small-L by construction; the large-L mesh form is
    :class:`VirtualTopology`).
    """
    from repro.distributed.mixing import SparseWeights
    if isinstance(W, SparseWeights):
        W = W.to_dense()  # reprolint: allow=RL002 — per-device mesh tier is small-L by construction; large-L uses VirtualTopology
    try:
        Wn = np.asarray(W)
    except Exception as e:                       # jax TracerConversionError
        raise ValueError(
            "mesh_weights_from_matrix needs a concrete mixing matrix — "
            "topology is static metadata and cannot be traced") from e
    if Wn.ndim != 2 or Wn.shape[0] != Wn.shape[1]:
        raise ValueError(f"mixing matrix must be square, got {Wn.shape}")
    L = Wn.shape[0]
    idx = np.arange(L)
    shifts = sorted(
        (s if s <= L // 2 else s - L)
        for s in range(1, L) if np.any(Wn[idx, (idx + s) % L] != 0))
    table = np.empty((L, len(shifts) + 1), dtype=Wn.dtype)
    table[:, 0] = np.diag(Wn)
    for k, s in enumerate(shifts):
        table[:, k + 1] = Wn[idx, (idx + s) % L]
    return tuple(shifts), table


@dataclasses.dataclass(frozen=True)
class RelabeledMeshWeights:
    """:func:`mesh_weights_from_matrix` after RCM shift-count pruning.

    ``perm`` (new→old) relabels the node axis; ``shifts``/``table``
    decompose the RELABELED matrix ``W[perm][:, perm]``.  A mesh run
    permutes its node-major inputs by ``perm`` (device k hosts old node
    ``perm[k]``), gossips with the pruned shift set, and un-permutes the
    outputs — the mixing arithmetic is identical (a relabeling is a
    similarity transform by a permutation matrix).  ``shifts_before`` /
    ``shifts_after`` report the pruning: each shift is one
    collective-permute per gossip round on the mesh runtime.
    """
    perm: np.ndarray
    shifts: tuple
    table: np.ndarray
    shifts_before: int
    shifts_after: int


def mesh_weights_relabeled(W, *, verify: bool = True
                           ) -> RelabeledMeshWeights:
    """Shift-count pruning for :func:`mesh_weights_from_matrix` via
    bandwidth-reducing node relabeling (reverse Cuthill–McKee on the
    mixing matrix's support).  An irregular graph's raw decomposition
    can need up to L−1 distinct cyclic shifts; RCM concentrates the
    support near the diagonal, so the relabeled matrix decomposes into
    the few shifts spanned by its bandwidth.  Falls back to the identity
    relabeling when RCM does not strictly reduce the shift count (e.g.
    a circulant is already optimal).  ``verify`` asserts round-trip
    equivalence: the shift table rebuilt densely must equal the
    relabeled matrix entry for entry, and un-permuting recovers W.
    """
    from repro.distributed.graphs import SparseGraph, reverse_cuthill_mckee
    from repro.distributed.mixing import SparseWeights
    if isinstance(W, SparseWeights):
        W = W.to_dense()  # reprolint: allow=RL002 — per-device mesh tier is small-L by construction; large-L uses VirtualTopology
    Wn = np.asarray(W)
    L = Wn.shape[0]
    shifts0, table0 = mesh_weights_from_matrix(Wn)
    off = (Wn != 0) | (Wn != 0).T
    np.fill_diagonal(off, False)
    rows, cols = np.nonzero(off)
    perm = reverse_cuthill_mckee(SparseGraph.from_edges(L, rows, cols))
    Wp = Wn[np.ix_(perm, perm)]
    shifts, table = mesh_weights_from_matrix(Wp)
    if len(shifts) >= len(shifts0):           # pruning didn't help
        perm, Wp = np.arange(L, dtype=np.int64), Wn
        shifts, table = shifts0, table0
    if verify:
        idx = np.arange(L)
        R = np.zeros_like(Wp)
        R[idx, idx] = table[:, 0]
        for k, s in enumerate(shifts):
            R[idx, (idx + s) % L] = table[:, k + 1]
        if not np.array_equal(R, Wp):
            raise AssertionError("RCM decomposition round-trip failed")
        inv = np.empty(L, dtype=np.int64)
        inv[perm] = np.arange(L)
        if not np.array_equal(Wp[np.ix_(inv, inv)], Wn):
            raise AssertionError("RCM relabeling round-trip failed")
    return RelabeledMeshWeights(perm=perm, shifts=tuple(shifts),
                                table=table, shifts_before=len(shifts0),
                                shifts_after=len(shifts))


# ----------------------------------------------------------------------
# virtual-node mesh tier
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VirtualTopology:
    """Device × local-block decomposition of a sparse mixing matrix —
    the mesh form of the node axis past one-node-per-device.

    Node ``i`` lives on device ``i // block`` as local virtual node
    ``i % block`` (contiguous blocks).  Every stored edge falls in
    exactly one DEVICE-shift class ``s = (dev_j − dev_i) mod D`` — the
    per-edge-class generalization of :func:`mesh_weights_from_matrix`'s
    per-entry cyclic shifts:

      * class 0 (``local_*``): both endpoints co-located — gossip is a
        free on-device segment-sum shuffle, no wire traffic;
      * each nonzero class (``cross_*``, one slot per entry of
        ``dev_shifts``): ONE ``lax.ppermute`` of the whole local block
        per round, then a sparse apply at the receiver — only these
        classes pay priced bytes.

    Edge arrays are padded per device (dummy segment ``block``, weight
    exactly 0) and sorted by receiver row, so the on-device
    ``segment_sum`` jits with static shapes; ``diag`` is the separate
    (D, block) self-weight plane.  Topology is static metadata: all
    arrays are host numpy.
    """
    n_dev: int
    block: int
    dev_shifts: tuple[int, ...]
    local_rows: np.ndarray   # (D, E0) int32 — receiver local row
    local_cols: np.ndarray   # (D, E0) int32 — sender local row
    local_vals: np.ndarray   # (D, E0) float64
    cross_rows: np.ndarray   # (S, D, E1) int32
    cross_cols: np.ndarray   # (S, D, E1) int32 — sender-local, in the
    cross_vals: np.ndarray   # (S, D, E1)        permuted block
    diag: np.ndarray         # (D, block) float64

    @staticmethod
    def _group(dev, lr, lc, v, D: int, V: int):
        """Per-device padded (rows, cols, vals) — entries sorted by
        (device, local row) so segment ids are sorted, padding (row V,
        weight 0) at the end."""
        order = np.lexsort((lc, lr, dev))
        dev, lr, lc, v = dev[order], lr[order], lc[order], v[order]
        counts = np.bincount(dev, minlength=D)
        E = max(int(counts.max()) if counts.size else 0, 1)
        rows = np.full((D, E), V, np.int32)
        cols = np.zeros((D, E), np.int32)
        vals = np.zeros((D, E))
        starts = np.cumsum(counts) - counts
        pos = np.arange(dev.size) - np.repeat(starts, counts)
        rows[dev, pos] = lr
        cols[dev, pos] = lc
        vals[dev, pos] = v
        return rows, cols, vals

    @classmethod
    def from_weights(cls, W, n_dev: int) -> "VirtualTopology":
        from repro.distributed.mixing import SparseWeights
        sw = W if isinstance(W, SparseWeights) \
            else SparseWeights.from_dense(W)
        L, D = sw.n, int(n_dev)
        if D < 1 or L % D:
            raise ValueError(f"virtual-node tier needs n_dev to divide "
                             f"L, got L={L}, n_dev={D}")
        V = L // D
        di = (sw.rows // V).astype(np.int64)
        dj = (sw.cols // V).astype(np.int64)
        s = (dj - di) % D
        ss = np.where(s <= D // 2, s, s - D)
        lr = (sw.rows % V).astype(np.int64)
        lc = (sw.cols % V).astype(np.int64)
        loc = s == 0
        l_rows, l_cols, l_vals = cls._group(di[loc], lr[loc], lc[loc],
                                            sw.vals[loc], D, V)
        shifts = tuple(int(x) for x in np.unique(ss[~loc]))
        c_rows, c_cols, c_vals = [], [], []
        for sk in shifts:
            sel = ss == sk
            rk, ck, vk = cls._group(di[sel], lr[sel], lc[sel],
                                    sw.vals[sel], D, V)
            c_rows.append(rk)
            c_cols.append(ck)
            c_vals.append(vk)
        E1 = max((a.shape[1] for a in c_rows), default=1)

        def stack(arrs, fill, dtype):
            out = np.full((len(shifts), D, E1), fill, dtype)
            for k, a in enumerate(arrs):
                out[k, :, :a.shape[1]] = a
            return out
        return cls(
            n_dev=D, block=V, dev_shifts=shifts,
            local_rows=l_rows, local_cols=l_cols, local_vals=l_vals,
            cross_rows=stack(c_rows, V, np.int32),
            cross_cols=stack(c_cols, 0, np.int32),
            cross_vals=stack(c_vals, 0.0, np.float64),
            diag=np.asarray(sw.diag, np.float64).reshape(D, V).copy())

    # -------------------------------------------------------- accounting

    @property
    def n_nodes(self) -> int:
        return self.n_dev * self.block

    @property
    def n_local_entries(self) -> int:
        return int(np.count_nonzero(self.local_rows != self.block))

    @property
    def n_cross_entries(self) -> int:
        return int(np.count_nonzero(self.cross_rows != self.block))

    @property
    def block_sends_per_round(self) -> int:
        """ppermutes (whole-block sends) one round costs per device —
        the priced wire traffic; co-located gossip is free."""
        return len(self.dev_shifts)


def _device_slice(arrays, g):
    """This device's slice of :func:`virtual_arrays`'s stacked operands:
    ``(lr, lc, lv, [cr_k...], [cc_k...], [cv_k...], dg)`` — the selected
    form every virtual round variant (dense / masked / state) consumes,
    so the per-rule lowerings never re-derive the gather."""
    lr, lc, lv, cr, cc, cv, dg = arrays
    S = cr.shape[0]
    return (lr[g], lc[g], lv[g],
            [cr[k][g] for k in range(S)],
            [cc[k][g] for k in range(S)],
            [cv[k][g] for k in range(S)],
            dg[g])


def _virtual_selected_round(zf, vt: VirtualTopology, axis_name: str,
                            sel, *, z_diag=None):
    """One combine round on the virtual-node tier with PRE-SELECTED
    (possibly mask-folded or column-normalized) per-device edge arrays
    ``sel`` (:func:`_device_slice` layout).  ``zf: (V, F)`` is this
    device's flattened block — it is both the ppermute payload and the
    off-diagonal operand; ``z_diag`` (default ``zf``) is the operand of
    the diagonal term, split out for the compressed rules' exact-self
    correction (off-diagonal mass on the refreshed public copies, the
    self weight on the true iterate)."""
    lr, lc, lv, crs, ccs, cvs, dg = sel
    V, D = vt.block, vt.n_dev
    acc = dg[:, None] * (zf if z_diag is None else z_diag)
    acc = acc + jax.ops.segment_sum(
        lv[:, None] * zf[lc], lr, num_segments=V + 1,
        indices_are_sorted=True)[:V]
    for k, s in enumerate(vt.dev_shifts):
        perm = [(i, (i - s) % D) for i in range(D)]   # receive from i+s
        zs = jax.lax.ppermute(zf, axis_name, perm)
        acc = acc + jax.ops.segment_sum(
            cvs[k][:, None] * zs[ccs[k]], crs[k],
            num_segments=V + 1, indices_are_sorted=True)[:V]
    return acc


def virtual_mesh_round(zf, g, vt: VirtualTopology, axis_name: str,
                       arrays):
    """One gossip round on the virtual-node tier, ``zf: (V, F)`` this
    device's flattened block.  ``arrays`` are the device-side copies of
    vt's edge arrays in ``zf.dtype`` (built once per trace by
    :func:`virtual_arrays`)."""
    return _virtual_selected_round(zf, vt, axis_name,
                                   _device_slice(arrays, g))


def virtual_arrays(vt: VirtualTopology, dtype):
    """Device-side operands of :func:`virtual_mesh_round` (weights cast
    once to the iterate dtype)."""
    return (jnp.asarray(vt.local_rows), jnp.asarray(vt.local_cols),
            jnp.asarray(vt.local_vals, dtype),
            jnp.asarray(vt.cross_rows), jnp.asarray(vt.cross_cols),
            jnp.asarray(vt.cross_vals, dtype),
            jnp.asarray(vt.diag, dtype))


def _virtual_masked_fold(vt: VirtualTopology, sel, g, mf, *,
                         fold_diag: bool = True):
    """Edge-level availability fold on a device's selected arrays — the
    virtual-tier twin of :func:`_sparse_masked_fold`: a link is live iff
    BOTH endpoints are (receiver mask rows ``mf[g]``, sender mask rows
    ``mf[(g+s) mod D]`` per cross class), dead links' weight folds into
    the receiver's diagonal (``fold_diag=False`` keeps the original
    diagonal for push-sum, which renormalizes instead).  ``mf`` is the
    (D, V) per-device mask in the value dtype.  Padding entries carry
    weight exactly 0, so their clamped gathers contribute nothing."""
    lr, lc, lv, crs, ccs, cvs, dg = sel
    V, D = vt.block, vt.n_dev
    mg = mf[g]
    keep = mg[lr] * mg[lc]
    lv_m = lv * keep
    lost = jax.ops.segment_sum(lv * (1.0 - keep), lr,
                               num_segments=V + 1,
                               indices_are_sorted=True)[:V]
    cvs_m = []
    for k, s in enumerate(vt.dev_shifts):
        ms = mf[(g + s) % D]                    # the class's sender block
        keep_k = mg[crs[k]] * ms[ccs[k]]
        cvs_m.append(cvs[k] * keep_k)
        lost = lost + jax.ops.segment_sum(
            cvs[k] * (1.0 - keep_k), crs[k], num_segments=V + 1,
            indices_are_sorted=True)[:V]
    dg_eff = dg + lost if fold_diag else dg
    return (lr, lc, lv_m, crs, ccs, cvs_m, dg_eff)


def _vt_edges(vt: VirtualTopology):
    """Reconstruct the GLOBAL off-diagonal COO (rows, cols, vals) a
    VirtualTopology encodes, padding excluded — host-side metadata for
    structural checks (push-sum's symmetry validation)."""
    D, V = vt.n_dev, vt.block
    rows, cols, vals = [], [], []
    for g in range(D):
        live = vt.local_rows[g] != V
        rows.append(g * V + vt.local_rows[g][live])
        cols.append(g * V + vt.local_cols[g][live])
        vals.append(vt.local_vals[g][live])
    for k, s in enumerate(vt.dev_shifts):
        for g in range(D):
            live = vt.cross_rows[k, g] != V
            rows.append(g * V + vt.cross_rows[k, g][live])
            cols.append(((g + s) % D) * V + vt.cross_cols[k, g][live])
            vals.append(vt.cross_vals[k, g][live])
    return (np.concatenate(rows).astype(np.int64),
            np.concatenate(cols).astype(np.int64),
            np.concatenate(vals))


def _vt_is_symmetric(vt: VirtualTopology) -> bool:
    """Whether the encoded mixing matrix is symmetric: the sorted edge
    list equals the sorted transposed edge list (values to float
    tolerance) — O(E log E), never densified."""
    r, c, v = _vt_edges(vt)
    o1 = np.lexsort((c, r))       # (r, c) order of the edge list
    o2 = np.lexsort((r, c))       # (c, r) order = (r, c) of the transpose
    return (np.array_equal(r[o1], c[o2])
            and np.array_equal(c[o1], r[o2])
            and np.allclose(v[o1], v[o2]))


# ----------------------------------------------------------------------
# CombineRule
# ----------------------------------------------------------------------

class CombineRule:
    """One consensus/combine scheme, lowered three ways.

    ``make_sim_mixer(W, T_con, backend=...)`` returns the simulator
    closure ``Z (L, ...) ↦ combined Z``; ``make_mesh_mixer(...)`` the
    per-device closure used inside ``shard_map`` — pass ``W=`` for an
    arbitrary weighted topology (each distinct cyclic shift of W's
    sparsity pattern becomes one collective-permute, each device combines
    with its own W row), or ``shifts``/``self_weight`` for the uniform
    circulant form; ``signature(T_con)`` the comm cost.  Subclasses
    override the pieces that differ.
    """

    name: str = "base"

    # ------------------------------------------------------- simulator

    def make_sim_mixer(self, W, T_con: int, *,
                       backend: str = "xla-ref") -> Callable:
        raise NotImplementedError

    # ------------------------------------------------------------ mesh

    def make_mesh_mixer(self, axis_name: str, L: int, T_con: int,
                        shifts: Sequence[int] = (-1, 1),
                        self_weight: float | None = None, *,
                        W=None, backend: str = "xla-ref") -> Callable:
        raise NotImplementedError

    # ---------------------------------------------------- virtual mesh

    def make_virtual_mesh_mixer(self, axis_name: str,
                                vt: VirtualTopology, T_con: int, *,
                                backend: str = "xla-ref") -> Callable:
        raise NotImplementedError(
            f"combine rule {self.name!r} has no virtual-mesh lowering")

    # ------------------------------------------------------- signature

    def signature(self, T_con: int, **params) -> CommSignature:
        """The rule's per-iteration comm cost.  ``params`` carries the
        optional payload context (problem dims ``d``/``r`` and the
        compression knobs) — base rules ignore it; compressed rules use
        it to fill ``entries_per_round``/``bytes_per_entry``."""
        raise NotImplementedError

    # ---------------------------------------------------------- shared

    @staticmethod
    def _ring_weights(shifts: Sequence[int], self_weight: float | None):
        k = len(shifts)
        sw = self_weight if self_weight is not None else 1.0 / (k + 1)
        return sw, (1.0 - sw) / k

    @classmethod
    def _mesh_weights(cls, L: int, shifts: Sequence[int],
                      self_weight: float | None, W):
        """Resolve the mesh lowering's (shifts, weights) pair.

        With ``W``: decompose the actual mixing matrix — identical rows
        collapse to shared Python-float weights (the circulant fast
        path, no per-device gather), otherwise the full (L, K+1) table
        is kept and each device selects its row inside the round.
        Without ``W``: the historical uniform circulant weights of
        ``shifts``/``self_weight``."""
        if W is None:
            sw, wn = cls._ring_weights(shifts, self_weight)
            return tuple(shifts), (sw,) + (wn,) * len(shifts)
        shifts_, table = mesh_weights_from_matrix(W)
        if table.shape[0] != L:
            raise ValueError(f"mixing matrix is {table.shape[0]}×"
                             f"{table.shape[0]} but the mesh axis has "
                             f"{L} devices")
        if np.all(table == table[0]):
            return shifts_, tuple(float(x) for x in table[0])
        return shifts_, jnp.asarray(table)

    @classmethod
    def _mesh_round(cls, z, axis_name: str, L: int,
                    shifts: Sequence[int], weights, backend: str):
        """One gossip round on hardware: K collective-permutes to fetch
        neighbour blocks, then ONE (K+1)-way combine (fused on pallas
        backends).  ``weights`` is a shared scalar tuple (uniform /
        circulant) or an (L, K+1) table — then each device picks its own
        row by ``axis_index`` (arbitrary weighted topology)."""
        w = (weights if isinstance(weights, tuple)
             else weights[jax.lax.axis_index(axis_name)])
        nbrs = []
        for s in shifts:
            perm = [(i, (i - s) % L) for i in range(L)]   # receive from i+s
            nbrs.append(jax.lax.ppermute(z, axis_name, perm))
        return combine_blocks(z, nbrs, w, backend=backend)

    @classmethod
    def roll_round(cls, x, shifts: Sequence[int], weights, *,
                   backend: str = "xla-ref"):
        """One gossip round in the pjit/trainer form: neighbour blocks
        come from ``jnp.roll`` over the leading node axis (XLA lowers the
        sharded roll to the same collective-permute).  ``weights``:
        length-K+1 ``(w_self, w_shift1, ...)`` shared by every node, or a
        per-node ``(L, K+1)`` table (column k+1 = each node's weight on
        its shift-``shifts[k]`` neighbour — the
        :func:`mesh_weights_from_matrix` layout) for non-uniform /
        non-circulant mixing matrices."""
        nbrs = [jnp.roll(x, -s, axis=0) for s in shifts]
        w = jnp.asarray(weights) if not isinstance(weights, (tuple, list)) \
            else None
        if w is not None and w.ndim == 2:
            if w.shape[0] != x.shape[0]:
                raise ValueError(
                    f"per-node weight table has {w.shape[0]} rows but the "
                    f"leading node axis is {x.shape[0]} — roll_round mixes "
                    f"over the leading axis, one table row per node")
            # every node is a real row of the leading axis here, so the
            # table broadcasts directly; unfused chain in the promoted
            # accumulator dtype (the fused combine kernel takes only
            # per-shift scalars, not per-node tables)
            acc_dt = _acc_dtype(x.dtype)
            col = (slice(None),) + (None,) * (x.ndim - 1)
            wt = w.astype(acc_dt)
            acc = wt[:, 0][col] * x.astype(acc_dt)
            for k, nbr in enumerate(nbrs):
                acc = acc + wt[:, k + 1][col] * nbr.astype(acc_dt)
            return acc.astype(x.dtype)
        return combine_blocks(x, nbrs, weights, backend=backend)


class GossipCombine(CombineRule):
    """The paper's AGREE combine: T_con rounds of the mixing product
    ``Z ← W Z`` (Algorithm 1)."""

    name = "gossip"

    def make_sim_mixer(self, W, T_con: int, *, backend: str = "xla-ref"):
        from repro.distributed.mixing import SparseWeights
        W = maybe_sparsify(W)
        if T_con == 0:
            return lambda Z: Z
        if isinstance(W, SparseWeights):
            return self._make_sparse_sim_mixer(W, T_con, backend)
        if backend == "xla-ref" or W.dtype == jnp.float64:
            # sequential exact product: the unfused reference backend,
            # and x64 operands on any backend (deciding on W's dtype at
            # build time also keeps the dead f32 W^{T_con} hoist out of
            # x64 traces — reprolint rule JX003)
            return lambda Z: stacked_product(Z, W, T_con)
        Wp = jnp.linalg.matrix_power(W.astype(jnp.float32), T_con)

        def mix(Z):
            if Z.dtype == jnp.float64:
                # f32-accumulating fused kernel: keep x64 runs exact
                return stacked_product(Z, W, T_con)
            return stacked_dense_mix(Z, Wp, backend=backend)
        return mix

    @staticmethod
    def _make_sparse_sim_mixer(sw, T_con: int, backend: str):
        """Sparse twin of the hoist policy: fused backends precompute
        ``W^{T_con}`` host-side (scipy CSR power) and apply it in ONE
        segment-sum round — but only while the power's fill-in stays
        within :meth:`SparseWeights.power`'s budget; past it (or on
        xla-ref / f64 operands, which stay sequential-exact) the mixer
        degrades gracefully to the per-round sparse product."""
        hoisted = None
        if backend != "xla-ref" and T_con > 1:
            hoisted = sw.power(T_con)     # None → fill-in over budget

        def mix(Z):
            if (hoisted is None or backend == "xla-ref"
                    or Z.dtype == jnp.float64):
                return stacked_sparse_product(Z, sw, T_con)
            return stacked_sparse_product(Z, hoisted, 1)
        return mix

    def make_mesh_mixer(self, axis_name, L, T_con, shifts=(-1, 1),
                        self_weight=None, *, W=None, backend="xla-ref"):
        shifts_, weights = self._mesh_weights(L, shifts, self_weight, W)
        if T_con == 0:
            return lambda z: z

        def gossip(z):
            def round_(carry, _):
                return self._mesh_round(carry, axis_name, L, shifts_,
                                        weights, backend), None
            out, _ = jax.lax.scan(round_, z, None, length=T_con)
            return out
        return gossip

    def make_virtual_mesh_mixer(self, axis_name: str,
                                vt: VirtualTopology, T_con: int, *,
                                backend: str = "xla-ref") -> Callable:
        """Per-device closure ``z (V, ...) ↦ z'`` on the virtual-node
        tier: T_con rounds, each one on-device segment-sum shuffle for
        the co-located edges plus one ppermute + sparse apply per
        cross-device shift class.  Always per-round (a ``W^{T_con}``
        hoist would create new cross-device classes, defeating the
        decomposition)."""
        if T_con == 0:
            return lambda z: z

        def gossip(z):
            g = jax.lax.axis_index(axis_name)
            arrays = virtual_arrays(vt, z.dtype)
            shape = z.shape

            def round_(carry, _):
                out = virtual_mesh_round(carry, g, vt, axis_name, arrays)
                return out, None
            out, _ = jax.lax.scan(round_, z.reshape(vt.block, -1), None,
                                  length=T_con)
            return out.reshape(shape)
        return gossip

    def signature(self, T_con: int, **params) -> CommSignature:
        return CommSignature("gossip", T_con)


class NeighborCombine(CombineRule):
    """DGD's combine: ONE row-stochastic neighbour average that excludes
    the node itself (Experiment 1's ``(1/deg_g) Σ_{g'∈N_g} U_g'``).  The
    simulator form takes the precomputed neighbour-average matrix M."""

    name = "neighbor"

    def make_sim_mixer(self, M, T_con: int = 1, *, backend: str = "xla-ref"):
        M = maybe_sparsify(M)
        return lambda Z: stacked_dense_mix(Z, M, backend=backend)

    def make_mesh_mixer(self, axis_name, L, T_con=1, shifts=(-1, 1),
                        self_weight=None, *, W=None, backend="xla-ref"):
        """ONE neighbour-average round.  Without ``W`` the circulant
        graph of ``shifts`` is K-regular, so the average is the
        equal-weight shift combine with structurally zero self weight;
        with ``W`` (the precomputed row-stochastic neighbour matrix,
        zero diagonal) each device combines with its own row — the
        irregular-graph form."""
        if W is None:
            shifts_ = tuple(shifts)
            weights = (0.0,) + (1.0 / len(shifts),) * len(shifts)
        else:
            shifts_, weights = self._mesh_weights(L, shifts, self_weight, W)
        return lambda z: self._mesh_round(z, axis_name, L, shifts_,
                                          weights, backend)

    def make_virtual_mesh_mixer(self, axis_name: str,
                                vt: VirtualTopology, T_con: int = 1, *,
                                backend: str = "xla-ref") -> Callable:
        """ONE neighbour-average round on the virtual tier, whatever
        ``T_con`` says (the rule IS a single self-excluding exchange).
        ``vt`` decomposes the precomputed row-stochastic neighbour
        matrix — its zero diagonal survives the decomposition as a zero
        ``diag`` plane, so the round is exactly ``M Z``."""
        def mix(z):
            g = jax.lax.axis_index(axis_name)
            arrays = virtual_arrays(vt, z.dtype)
            shape = z.shape
            out = virtual_mesh_round(z.reshape(vt.block, -1), g, vt,
                                     axis_name, arrays)
            return out.reshape(shape)
        return mix

    def signature(self, T_con: int, **params) -> CommSignature:
        return CommSignature("neighbor", 1)


class CentralCombine(CombineRule):
    """Fusion-center combine: the exact node mean (AltGDmin [10])."""

    name = "central"

    def make_sim_mixer(self, W=None, T_con: int = 0, *,
                       backend: str = "xla-ref"):
        return node_mean

    def make_mesh_mixer(self, axis_name, L, T_con=0, shifts=(),
                        self_weight=None, *, W=None, backend="xla-ref"):
        return lambda z: jax.lax.pmean(z, axis_name)

    def signature(self, T_con: int, **params) -> CommSignature:
        return CommSignature("central", 1)


class NoCombine(CombineRule):
    """Local training: no communication (identity combine)."""

    name = "none"

    def make_sim_mixer(self, W=None, T_con: int = 0, *,
                       backend: str = "xla-ref"):
        return lambda Z: Z

    def make_mesh_mixer(self, axis_name, L, T_con=0, shifts=(),
                        self_weight=None, *, W=None, backend="xla-ref"):
        return lambda z: z

    def signature(self, T_con: int, **params) -> CommSignature:
        return CommSignature("none", 0)


class ExactDiffusionCombine(GossipCombine):
    """The projection-corrected combine of Exact Subspace Diffusion
    (arXiv:2304.07358).  The mixing product is standard AGREE, but each
    application first bias-corrects the adapt iterate with the previous
    correction state:

        φ_g^τ = ψ_g^τ + U_g^{τ-1} − ψ_g^{τ-1}        (correction)
        Ũ_g^τ = Σ_j W_gj φ_j^τ  (T_con rounds)        (combine)

    so the combine tracks the exact (bias-free) fixed point instead of
    the diffusion limit point; the driver carries ``(ψ_prev, U_prev)``
    through its scan and retracts Ũ onto the Grassmannian afterwards
    (the subspace projection step).
    """

    name = "exact_diffusion"

    @staticmethod
    def correct(psi, psi_prev, U_prev):
        """φ = ψ + U_prev − ψ_prev (vanishes at τ=0 when ψ_prev=U_prev)."""
        return psi + U_prev - psi_prev


class BeyondCentralCombine(GossipCombine):
    """The communication-efficient combine of Beyond Centralization
    (arXiv:2512.22675): nodes take several *local* adapt steps between
    consensus exchanges and then combine with ONE gossip round — per
    outer iteration the wire carries a single d×r exchange instead of
    the T_con-round AGREE chain."""

    name = "beyond_central"

    def make_sim_mixer(self, W, T_con: int = 1, *, backend: str = "xla-ref"):
        # a single mixing round regardless of T_con — that IS the rule
        return super().make_sim_mixer(W, 1, backend=backend)

    def make_mesh_mixer(self, axis_name, L, T_con=1, shifts=(-1, 1),
                        self_weight=None, *, W=None, backend="xla-ref"):
        return super().make_mesh_mixer(axis_name, L, 1, shifts,
                                       self_weight, W=W, backend=backend)

    def make_virtual_mesh_mixer(self, axis_name, vt, T_con=1, *,
                                backend="xla-ref"):
        return super().make_virtual_mesh_mixer(axis_name, vt, 1,
                                               backend=backend)

    def signature(self, T_con: int, **params) -> CommSignature:
        return CommSignature("gossip", 1)


# ----------------------------------------------------------------------
# compressed / event-triggered wire rules
# ----------------------------------------------------------------------

def _scatter_replace_rows(xhat, vals, idx):
    """Replace rows ``idx`` of each (d, r) block with ``vals`` (top-k
    refresh).  Indices from top-k are unique, so the scatter is
    order-independent and a FULL index set makes the result exactly
    ``vals``'s source — the bit-identity anchor of ``k = d``."""
    def one(x, v, i):
        return x.at[i].set(v)
    return jax.vmap(one)(xhat, vals, idx)


class CompressedGossipCombine(GossipCombine):
    """Base of the compressed-communication gossip rules.

    These rules shrink what one gossip round puts on the wire.  Naive
    compression of the d×r iterate itself stalls far from the dense
    trajectory (an orthonormal-ish basis has no dominant rows to keep),
    so the rules use the reference-copy error-feedback scheme of
    CHOCO-SGD / EF21: every node maintains a PUBLIC COPY ``x̂_g`` of its
    iterate — the value the network believes — replicated at its
    neighbours, and each round refreshes the copy's stalest content with
    a compact payload:

        payload, x̂_g' = refresh(Z_g, x̂_g)      # what crosses the wire
        x̂_j'          = apply(payload_j, x̂_j)  # neighbours' copies
        Z_g'           = W_gg·Z_g + Σ_{j≠g} W_gj·x̂_j'

    The copy state IS the error-feedback state: ``Z − x̂`` is exactly
    the accumulated compression error, re-injected into every
    subsequent payload, and it contracts as consensus tightens — so
    compressed Dif-AltGDmin still converges to the paper's error floor.
    The drivers thread the state through their ``lax.scan`` carry (the
    mesh runtime's aux-carry slot).

    The SELF term never crosses a wire, so the combine keeps it exact:
    the simulator computes ``W @ X̂' + diag(W)·(Z − X̂')`` (one dense
    combine on the refreshed copies — fused ``mix_rows`` on pallas
    backends — plus the exact-self correction); the mesh ppermutes the
    COMPACT payload per shift, applies it to the stored neighbour
    copies, and merges the K+1 blocks in ONE fused ``gossip_combine``
    dispatch per round.  A lossless refresh (k = d, θ = 0) makes
    ``X̂' = Z`` bit-exact and the round IS the dense ``W @ Z`` product
    bit-for-bit on the exact (unfused / x64) lowering — the numerics
    anchor the tests pin.  Fused backends agree with the dense rule to
    f32 round-off only: dense gossip hoists all T_con rounds into ONE
    precomputed ``W^{T_con}`` combine, while a compressed rule must mix
    round by round (the refresh is data-dependent).

    Precision policy (the shared ``_fused_wanted`` gate): float64
    operands take the exact reference encoder AND the unfused combine
    chain — compression *semantics* are dtype-independent, only the
    f32-accumulating kernels are avoided, so x64 runs stay exact.

    The stateless ``make_sim_mixer``/``make_mesh_mixer`` entry points
    are forbidden (they would silently drop the state); drivers use
    ``make_sim_state_mixer``/``make_mesh_state_mixer`` and seed the
    state with ``init_state`` (simulator) / ``init_mesh_state`` (one
    copy of every neighbour's x̂ per device, zero-initialized on both
    substrates so the copies agree without a setup exchange).
    """

    # ------------------------------------------------- rule interface

    def resolve_params(self, d: int, r: int, **kw) -> dict:
        """Static per-run parameters from the spec knobs + problem dims."""
        raise NotImplementedError

    def refresh(self, Z, xhat, node_ids, count, *, backend, **params):
        """One round's wire encode for stacked blocks ``Z: (N, d, r)``:
        returns ``(payload, xhat_new)`` — the compact payload that
        crosses the wire and the node's refreshed public copy."""
        raise NotImplementedError

    def apply(self, payload, xhat, *, backend, **params):
        """A receiver's side of ``refresh``: update a stored neighbour
        copy ``xhat: (N, d, r)`` from a received payload.  Must
        reproduce ``refresh``'s ``xhat_new`` bit-for-bit given the same
        payload and copy (simulator ≡ mesh parity rests on it)."""
        raise NotImplementedError

    # ------------------------------------------------------- state

    def init_state(self, Z_nodes, **kw):
        """Simulator state: the stacked public copies ``x̂`` (zero — the
        network starts with no beliefs), plus the round counter for
        stochastic rules."""
        xhat = jnp.zeros_like(Z_nodes)
        if self._stochastic(**kw):
            return (xhat, jnp.zeros((), jnp.int32))
        return xhat

    def init_mesh_state(self, z_local, n_shifts: int, **kw):
        """Per-device mesh state: ``(x̂_self (1, d, r), x̂_nbrs
        (n_shifts, 1, d, r))`` — this device's public copy plus its copy
        of each shift-neighbour's x̂ (what the neighbour's payloads have
        built up), all zero-initialized."""
        own = jnp.zeros_like(z_local[None])
        nbrs = jnp.zeros((n_shifts,) + own.shape, own.dtype)
        if self._stochastic(**kw):
            return (own, nbrs, jnp.zeros((), jnp.int32))
        return own, nbrs

    def _stochastic(self, **kw) -> bool:
        return False

    # ----------------------------------------------------- lowerings

    def make_sim_mixer(self, W, T_con, *, backend="xla-ref"):
        raise TypeError(f"combine rule {self.name!r} is stateful; use "
                        f"make_sim_state_mixer / init_state")

    def make_mesh_mixer(self, axis_name, L, T_con, shifts=(-1, 1),
                        self_weight=None, *, W=None, backend="xla-ref"):
        raise TypeError(f"combine rule {self.name!r} is stateful; use "
                        f"make_mesh_state_mixer / init_mesh_state")

    def make_virtual_mesh_mixer(self, axis_name, vt, T_con, *,
                                backend="xla-ref"):
        raise TypeError(f"combine rule {self.name!r} is stateful; use "
                        f"make_virtual_mesh_state_mixer / init_state")

    def make_sim_state_mixer(self, W, T_con: int, *,
                             backend: str = "xla-ref", **kw) -> Callable:
        """Simulator closure ``(Z (L, d, r), state) ↦ (Z', state')``:
        T_con rounds of refresh + dense combine on the public copies +
        exact-self correction.  ``consensus_gamma`` (CHOCO step size,
        default 1) relaxes each round toward the combined value,
        ``Z ← Z + γ(combined − Z)`` — the damping that keeps aggressive
        compression (k ≪ d/4) stable; γ = 1 is a Python-level no-op so
        default trajectories stay bit-identical."""
        from repro.distributed.mixing import SparseWeights
        gamma = float(kw.pop("consensus_gamma", 1.0))
        W = maybe_sparsify(W)
        sparse = isinstance(W, SparseWeights)
        if T_con == 0:
            return lambda Z, state: (Z, state)

        def mix(Z, state):
            N = Z.shape[0]
            params = self.resolve_params(Z.shape[1], Z.shape[2], **kw)
            ids = jnp.arange(N)
            if sparse:
                rows, cols, vals, diag = _sparse_arrays(W)
                rows, cols = jnp.asarray(rows), jnp.asarray(cols)
                vals = jnp.asarray(vals, Z.dtype)
                w_diag = jnp.asarray(diag, Z.dtype)[:, None, None]
            else:
                w_diag = jnp.diag(jnp.asarray(W)) \
                    .astype(Z.dtype)[:, None, None]

            def round_(carry, _):
                Zc, st = carry
                xhat, count = st if self._stochastic(**kw) else (st, None)
                _, xhat2 = self.refresh(Zc, xhat, ids, count,
                                        backend=backend, **params)
                if sparse:
                    # exact-self built in: (W − diag) x̂' + diag·Z equals
                    # the dense W x̂' + diag·(Z − x̂') without the
                    # add-and-subtract round trip
                    off = sparse_offdiag_apply(xhat2.reshape(N, -1),
                                               rows, cols, vals, N)
                    Z2 = off.reshape(Zc.shape) + w_diag * Zc
                    if gamma != 1.0:
                        Z2 = Zc + gamma * (Z2 - Zc)
                    st2 = ((xhat2, count + 1) if self._stochastic(**kw)
                           else xhat2)
                    return (Z2, st2), None
                if _fused_wanted(backend, Zc.dtype):
                    Z2 = stacked_dense_mix(xhat2, W, backend=backend)
                else:
                    # dense product on the refreshed copies, arithmetic-
                    # identical to stacked_product's round
                    Z2 = (W.astype(Zc.dtype)
                          @ xhat2.reshape(N, -1)).reshape(Zc.shape)
                # exact-self correction: the node's own block never
                # crosses a wire.  A lossless refresh (k = d, θ = 0)
                # makes Zc − xhat2 exactly zero, so the round stays the
                # dense W @ Z product bit-for-bit.
                Z2 = Z2 + w_diag * (Zc - xhat2)
                if gamma != 1.0:
                    Z2 = Zc + gamma * (Z2 - Zc)      # CHOCO relaxation
                st2 = ((xhat2, count + 1) if self._stochastic(**kw)
                       else xhat2)
                return (Z2, st2), None

            (Z_fin, st_fin), _ = jax.lax.scan(round_, (Z, state), None,
                                              length=T_con)
            return Z_fin, st_fin
        return mix

    def make_mesh_state_mixer(self, axis_name: str, L: int, T_con: int,
                              shifts: Sequence[int] = (-1, 1),
                              self_weight: float | None = None, *,
                              W=None, backend: str = "xla-ref",
                              **kw) -> Callable:
        """Per-device closure ``(z (d, r), state) ↦ (z', state')`` with
        ``state = (x̂_self, x̂_nbrs[, count])`` from ``init_mesh_state``:
        per round the COMPACT payload is exchanged by collective-permute
        (one per distinct cyclic shift), applied to the stored neighbour
        copies, and the K+1 blocks — exact self + refreshed copies —
        merge in ONE fused ``gossip_combine`` dispatch.
        ``consensus_gamma``: the CHOCO relaxation, as on the simulator
        lowering (γ = 1 → bit-identical no-op)."""
        gamma = float(kw.pop("consensus_gamma", 1.0))
        shifts_, weights = self._mesh_weights(L, shifts, self_weight, W)
        if T_con == 0:
            return lambda z, state: (z, state)

        def mix(z, state):
            d, r = z.shape
            params = self.resolve_params(d, r, **kw)
            ids = jax.lax.axis_index(axis_name)[None]
            w = (weights if isinstance(weights, tuple)
                 else weights[jax.lax.axis_index(axis_name)])

            def round_(carry, _):
                zc, st = carry
                if self._stochastic(**kw):
                    own, nbr_copies, count = st
                else:
                    (own, nbr_copies), count = st, None
                payload, own2 = self.refresh(zc[None], own, ids, count,
                                             backend=backend, **params)
                nbrs2 = []
                for i, s in enumerate(shifts_):
                    perm = [(g, (g - s) % L) for g in range(L)]
                    p = jax.tree.map(
                        lambda x: jax.lax.ppermute(x, axis_name, perm),
                        payload)
                    nbrs2.append(self.apply(p, nbr_copies[i],
                                            backend=backend, **params))
                # exact-self combine: the device's own block goes in
                # exact, neighbours as their refreshed public copies
                z2 = combine_blocks(zc, [n[0] for n in nbrs2], w,
                                    backend=backend)
                if gamma != 1.0:
                    z2 = zc + gamma * (z2 - zc)      # CHOCO relaxation
                nbr2 = (jnp.stack(nbrs2) if nbrs2
                        else jnp.zeros_like(nbr_copies))
                st2 = ((own2, nbr2, count + 1)
                       if self._stochastic(**kw) else (own2, nbr2))
                return (z2, st2), None

            (z_fin, st_fin), _ = jax.lax.scan(round_, (z, state), None,
                                              length=T_con)
            return z_fin, st_fin
        return mix

    def make_virtual_mesh_state_mixer(self, axis_name: str, vt, T_con: int,
                                      *, backend: str = "xla-ref",
                                      **kw) -> Callable:
        """Per-device virtual-tier closure ``(z (V, d, r), state) ↦
        (z', state')`` with ``state`` the block's stacked public copies
        from ``init_state`` (zero, per virtual node).  Each round
        refreshes the block's copies — GLOBAL node ids ``g·V + [0, V)``
        keep the stochastic quantizer's per-node fold_in identical to
        the simulator's ``arange(L)`` — then runs one sparse segment-sum
        round on the refreshed copies with the diagonal applied to the
        EXACT iterate (the simulator's exact-self identity ``(W − diag)
        x̂' + diag·Z``).  The wire note: a cross-device shift class
        ships the whole refreshed block; the per-edge payload is still
        the compact refresh semantically, the block transport just
        batches it.  ``consensus_gamma`` relaxes as on the other
        lowerings (γ = 1 → no-op)."""
        gamma = float(kw.pop("consensus_gamma", 1.0))
        if T_con == 0:
            return lambda z, state: (z, state)

        def mix(z, state):
            V = vt.block
            params = self.resolve_params(z.shape[1], z.shape[2], **kw)
            g = jax.lax.axis_index(axis_name)
            ids = g * V + jnp.arange(V)
            arrays = virtual_arrays(vt, z.dtype)
            sel = _device_slice(arrays, g)

            def round_(carry, _):
                zc, st = carry
                xhat, count = st if self._stochastic(**kw) else (st, None)
                _, xhat2 = self.refresh(zc, xhat, ids, count,
                                        backend=backend, **params)
                acc = _virtual_selected_round(
                    xhat2.reshape(V, -1), vt, axis_name, sel,
                    z_diag=zc.reshape(V, -1))
                Z2 = acc.reshape(zc.shape)
                if gamma != 1.0:
                    Z2 = zc + gamma * (Z2 - zc)      # CHOCO relaxation
                st2 = ((xhat2, count + 1) if self._stochastic(**kw)
                       else xhat2)
                return (Z2, st2), None

            (z_fin, st_fin), _ = jax.lax.scan(round_, (z, state), None,
                                              length=T_con)
            return z_fin, st_fin
        return mix


class TopkGossipCombine(CompressedGossipCombine):
    """``topk_gossip`` — rank-preserving top-k ROW refresh: per round
    each node re-broadcasts the ``compression_k`` rows of its iterate
    whose public copy drifted the most (largest ``‖Z − x̂‖`` row norms —
    the ``compress_topk`` kernel selects, the wire carries the ABSOLUTE
    ``Z`` rows + int32 indices, receivers replace those copy rows).
    Keeping whole rows keeps the payload a valid factor slice;
    ``compression_k = 0`` defaults to d/4 (a 4× value-entry reduction);
    ``compression_k = d`` refreshes every row and recovers dense gossip
    bit-identically on the exact path (see the base-class note on fused
    backends).

    Wire-format pricing: the signature prices k·r payload values at
    4 bytes (f32 — a sparsified payload does not carry the simulation's
    f64, since the production combine accumulates in f32 anyway) plus k
    int32 row indices, against the dense baseline at the network
    model's native precision.  At k = d/4 under the paper's f64 model
    the 6.4× bytes reduction therefore decomposes as 3.2× from sending
    fewer entries × 2× from the f32 wire; ``bench_compression`` reports
    both factors separately."""

    name = "topk_gossip"

    def resolve_params(self, d, r, compression_k: int = 0, **_):
        k = int(compression_k) or max(1, d // 4)
        if not 1 <= k <= d:
            raise ValueError(f"topk_gossip needs 1 <= compression_k <= d, "
                             f"got k={k} for d={d}")
        return {"k": k}

    def refresh(self, Z, xhat, node_ids, count, *, backend, k):
        from repro.kernels import ops
        delta = Z - xhat                     # accumulated compression error
        cb = backend if _fused_wanted(backend, Z.dtype) else "xla-ref"
        _, idx = ops.compress_topk(delta, k, backend=cb)   # stalest rows
        vals = jnp.take_along_axis(Z, idx[..., None], axis=1)
        return (vals, idx), _scatter_replace_rows(xhat, vals, idx)

    def apply(self, payload, xhat, *, backend, k):
        vals, idx = payload
        return _scatter_replace_rows(xhat, vals, idx)

    def signature(self, T_con: int, *, d=None, r=None, compression_k=0,
                  **_) -> CommSignature:
        if d is None or r is None:
            return CommSignature("gossip", T_con)
        k = self.resolve_params(d, r, compression_k)["k"]
        # f32 wire values (k·r) + int32 row indices (k): 4 bytes each
        return CommSignature("gossip", T_con,
                             entries_per_round=k * (r + 1),
                             bytes_per_entry=4)


class QuantizedGossipCombine(CompressedGossipCombine):
    """``quantized_gossip`` — low-precision wire dtype with f32
    accumulation: the DIFFERENCE ``Z − x̂`` is quantized and accumulated
    onto the public copies, so the quantization error contracts with
    consensus (exact convergence, no bf16-resolution floor on the
    iterate itself).  Wire formats (``compression``):

      * ``"bf16"`` (default) — round-to-nearest-even bfloat16 cast;
        2 bytes/entry, no side information;
      * ``"int8"`` — per-message max-abs scale, round-to-nearest int8;
        1 byte/entry + one f32 scale per message;
      * ``"int8_stochastic"`` — int8 with stochastic rounding
        (deterministic counter-based keys: the same per-node draws on
        both substrates, so simulator ≡ mesh parity holds bit-wise).

    The combine itself always accumulates in f32 (or f64 on the exact
    x64 path) — only the wire carries the low-precision payload.
    """

    name = "quantized_gossip"

    WIRES = ("bf16", "int8", "int8_stochastic")

    def resolve_params(self, d, r, compression=None, **_):
        wire = compression or "bf16"
        if wire not in self.WIRES:
            raise ValueError(f"unknown quantized_gossip wire format "
                             f"{wire!r}; expected one of {self.WIRES}")
        return {"wire": wire}

    def _stochastic(self, compression=None, **_):
        return (compression or "bf16") == "int8_stochastic"

    @staticmethod
    def _int8_scale(delta):
        scale = jnp.max(jnp.abs(delta), axis=(-2, -1), keepdims=True) / 127.0
        return jnp.maximum(scale, jnp.finfo(delta.dtype).tiny)

    def _dequant(self, q, scale, dtype, *, backend):
        from repro.kernels import ops
        cb = backend if _fused_wanted(backend, dtype) else "xla-ref"
        return ops.dequant(q, scale, backend=cb)

    def refresh(self, Z, xhat, node_ids, count, *, backend, wire):
        delta = Z - xhat                     # accumulated compression error
        if wire == "bf16":
            q = delta.astype(jnp.bfloat16)
            payload = (q,)
            inc = q.astype(Z.dtype)
        else:
            scale = self._int8_scale(delta)
            if wire == "int8_stochastic":
                key = jax.random.fold_in(jax.random.PRNGKey(0), count)
                keys = jax.vmap(jax.random.fold_in, (None, 0))(key, node_ids)
                # dither in the operand precision: drawing at f32 and
                # upcasting would narrow x64 runs (reprolint JX003)
                u = jax.vmap(lambda kk: jax.random.uniform(
                    kk, Z.shape[1:], Z.dtype))(keys)
                qf = jnp.floor(delta / scale + u)
            else:
                qf = jnp.rint(delta / scale)
            q = jnp.clip(qf, -127, 127).astype(jnp.int8)
            payload = (q, scale)
            inc = self._dequant(q, scale, Z.dtype, backend=backend)
        return payload, xhat + inc

    def apply(self, payload, xhat, *, backend, wire):
        if wire == "bf16":
            return xhat + payload[0].astype(xhat.dtype)
        q, scale = payload
        return xhat + self._dequant(q, scale, xhat.dtype, backend=backend)

    def signature(self, T_con: int, *, d=None, r=None, compression=None,
                  **_) -> CommSignature:
        if d is None or r is None:
            return CommSignature("gossip", T_con)
        wire = self.resolve_params(d, r, compression)["wire"]
        if wire == "bf16":
            return CommSignature("gossip", T_con, entries_per_round=d * r,
                                 bytes_per_entry=2)
        # int8 payload + one f32 scale (4 one-byte entries)
        return CommSignature("gossip", T_con, entries_per_round=d * r + 4,
                             bytes_per_entry=1)


class EventGossipCombine(CompressedGossipCombine):
    """``event_gossip`` — event-triggered exchange: a node re-broadcasts
    its full iterate only when its public copy went stale,
    ``‖Z_g − x̂_g‖_F > θ·‖Z_g‖_F`` (θ = ``event_threshold``); otherwise
    neighbours keep combining with the last-sent copy.  θ = 0 always
    triggers and recovers dense gossip bit-identically on the exact
    path (see the base-class note on fused backends).

    The SPMD lowerings still execute the exchange every round (a static
    program cannot elide a send), so the saving is a *message-count*
    one on real event-driven networks; the static signature therefore
    prices the θ = 0 worst case, and ``benchmarks.kernel_bench.
    bench_compression`` reports the measured send fraction."""

    name = "event_gossip"

    def resolve_params(self, d, r, event_threshold: float = 0.0, **_):
        if event_threshold < 0:
            raise ValueError(f"event_threshold must be >= 0, got "
                             f"{event_threshold}")
        return {"threshold": float(event_threshold)}

    @staticmethod
    def _trigger(Z, xhat, threshold):
        """Per-node send decision: ``‖Z − x̂‖_F > θ·‖Z‖_F`` — ONE
        definition shared by the round encode and the benchmark
        telemetry, so the reported send fraction always measures the
        condition the rule actually uses."""
        moved = jnp.sqrt(jnp.sum((Z - xhat) ** 2, axis=(-2, -1)))
        scale = jnp.sqrt(jnp.sum(Z ** 2, axis=(-2, -1)))
        return moved > threshold * scale

    def refresh(self, Z, xhat, node_ids, count, *, backend, threshold):
        trig = self._trigger(Z, xhat, threshold)
        S = jnp.where(trig[:, None, None], Z, xhat)    # absolute resend
        return (S,), S

    def apply(self, payload, xhat, *, backend, threshold):
        return payload[0]

    def send_fraction(self, Z, xhat, threshold: float):
        """Measured trigger rate of one round (benchmark telemetry —
        the static signature prices the worst case instead)."""
        return jnp.mean(self._trigger(Z, xhat, threshold)
                        .astype(jnp.float32))

    def signature(self, T_con: int, **_) -> CommSignature:
        # static pricing cannot see the trigger rate: θ = 0 worst case
        return CommSignature("gossip", T_con)

# ----------------------------------------------------------------------
# dropout-tolerant rules (availability-masked gossip)
# ----------------------------------------------------------------------

def masked_mixing_matrix(W, mask):
    """Per-round effective mixing matrix under a participation mask
    ``mask: (L,)`` (truthy = live).  A link is live iff BOTH endpoints
    are; a dead link's weight folds back into the SELF weight (mass
    redistribution over the live neighbourhood rather than row division),
    which (a) keeps W(m) doubly stochastic whenever W is — so partial
    gossip stays an unbiased averaging operator in expectation — and
    (b) makes the full mask return W bit-for-bit (multiply by exact
    ones, add exact zeros): the degenerate regression anchor.  A fully
    isolated down node's row degenerates to ``e_g`` (its lost weight is
    its whole off-diagonal mass), freezing its iterate."""
    m = mask.astype(W.dtype)
    eye = jnp.eye(W.shape[0], dtype=W.dtype)
    keep = m[:, None] * m[None, :] * (1.0 - eye) + eye   # self link stays
    lost = jnp.sum(W * (1.0 - keep), axis=1)
    return W * keep + jnp.diag(lost)


def push_sum_matrix(W, mask):
    """Column-stochastic masked mixing matrix for push-sum: live sender
    j distributes its mass over its LIVE out-neighbours + itself, each
    column renormalized by its live mass ``c_j = W_jj + Σ_{i≠j} m_i m_j
    W_ij`` — exactly column-stochastic by construction, whatever the
    mask does to the graph (the directed, non-doubly-stochastic regime
    push-sum's weight carry corrects)."""
    m = mask.astype(W.dtype)
    eye = jnp.eye(W.shape[0], dtype=W.dtype)
    keep = m[:, None] * m[None, :] * (1.0 - eye) + eye
    Wm = W * keep
    c = jnp.sum(Wm, axis=0)                              # live column mass
    return Wm / jnp.where(c > 0, c, 1.0)[None, :]


def _sparse_masked_fold(rows, cols, vals, diag, m, L: int):
    """Edge-level :func:`masked_mixing_matrix`: a link is live iff BOTH
    endpoints are (``keep = m_i · m_j`` per stored edge), and a dead
    link's weight folds into the receiver's diagonal.  Padding entries
    carry weight exactly 0, so their out-of-bounds row-L gathers (jnp
    clamps them) contribute nothing to either term."""
    keep = m[rows] * m[cols]
    lost = jax.ops.segment_sum(vals * (1.0 - keep), rows,
                               num_segments=L + 1,
                               indices_are_sorted=True)[:L]
    return vals * keep, diag + lost


def _sparse_masked_gossip_mixer(sw, T_con: int):
    """Sparse lowering of ``partial_gossip``'s simulator mixer: fold the
    mask once per iteration, then T_con segment-sum rounds.  The fold is
    data-dependent, so there is no ``W^{T_con}`` hoist on any backend
    (exactly like the dense lowering)."""
    rows_h, cols_h, vals_h, diag_h = _sparse_arrays(sw)
    L = sw.n

    def mix(Z, m):
        rows, cols = jnp.asarray(rows_h), jnp.asarray(cols_h)
        vals = jnp.asarray(vals_h, Z.dtype)
        diag = jnp.asarray(diag_h, Z.dtype)
        vals_eff, diag_eff = _sparse_masked_fold(
            rows, cols, vals, diag, m.astype(Z.dtype), L)
        flat = Z.reshape(L, -1)

        def round_(carry, _):
            return sparse_round(carry, rows, cols, vals_eff, diag_eff,
                                L), None
        out, _ = jax.lax.scan(round_, flat, None, length=T_con)
        return out.reshape(Z.shape)
    return mix


class MaskedGossipCombine(GossipCombine):
    """Base of the dropout-tolerant gossip rules: per-iteration
    availability masks enter the combine, so the stateless
    ``make_sim_mixer``/``make_mesh_mixer`` entry points are forbidden
    (they would silently drop the mask) — drivers use the
    ``*_masked_*`` forms and pass the (L,) mask of the CURRENT outer
    iteration (all T_con rounds of one iteration share it; node churn
    is an outer-iteration phenomenon, not a per-round one)."""

    def make_sim_mixer(self, W, T_con, *, backend="xla-ref"):
        raise TypeError(f"combine rule {self.name!r} is availability-"
                        f"masked; use make_sim_masked_mixer")

    def make_mesh_mixer(self, axis_name, L, T_con, shifts=(-1, 1),
                        self_weight=None, *, W=None, backend="xla-ref"):
        raise TypeError(f"combine rule {self.name!r} is availability-"
                        f"masked; use make_mesh_masked_mixer")

    def make_virtual_mesh_mixer(self, axis_name, vt, T_con, *,
                                backend="xla-ref"):
        raise TypeError(f"combine rule {self.name!r} is availability-"
                        f"masked; use make_virtual_mesh_masked_mixer")

    def signature(self, T_con: int, **params) -> CommSignature:
        # static pricing cannot see the mask: full-participation worst
        # case (the event-driven clock measures the real cost)
        return CommSignature("gossip", T_con)

    # ---------------------------------------------------- mesh shared

    @staticmethod
    def _mask_keep(m, g, shifts_, L, dtype):
        """Per-device liveness of each shift link: keep_k = m_g ·
        m_{(g+s_k) mod L}."""
        mf = m.astype(dtype)
        return jnp.stack([mf[g] * mf[(g + s) % L] for s in shifts_])

    @classmethod
    def _masked_mesh_round(cls, z, m, axis_name, L, shifts_, weights,
                           backend):
        """One masked gossip round on hardware: the dense
        :meth:`_mesh_round` permutes, but the (K+1,) combine weights are
        re-derived from the mask — dead links zeroed, their weight
        folded into the self weight (the row of
        :func:`masked_mixing_matrix` this device owns).  Full mask:
        ``w·1`` and ``w₀+0`` keep the dense weights bit-for-bit."""
        g = jax.lax.axis_index(axis_name)
        w = jnp.asarray(weights if isinstance(weights, tuple)
                        else weights[g])
        keep = cls._mask_keep(m, g, shifts_, L, w.dtype)
        w_eff = jnp.concatenate([
            (w[0] + jnp.sum(w[1:] * (1.0 - keep)))[None],
            w[1:] * keep])
        nbrs = []
        for s in shifts_:
            perm = [(i, (i - s) % L) for i in range(L)]
            nbrs.append(jax.lax.ppermute(z, axis_name, perm))
        return combine_blocks(z, nbrs, w_eff, backend=backend)


class PartialGossipCombine(MaskedGossipCombine):
    """``partial_gossip`` — per-round participation masking: only links
    whose BOTH endpoints are live carry weight, the dead weight folds
    into the self weight (see :func:`masked_mixing_matrix`), and down
    nodes' rows collapse toward identity (the driver freezes their
    iterate anyway).  With availability ≡ 1 the effective matrix IS W
    bit-for-bit, so trajectories reproduce dense ``dif_altgdmin``
    exactly — the regression anchor of the fault layer."""

    name = "partial_gossip"

    def make_sim_masked_mixer(self, W, T_con: int, *,
                              backend: str = "xla-ref") -> Callable:
        """Simulator closure ``(Z (L, ...), m (L,)) ↦ Z'``.  The masked
        matrix is data-dependent, so fused backends mix round by round
        (no ``W^{T_con}`` hoist); the exact path repeats
        ``stacked_product``'s flattened matmul arithmetic so the full
        mask is bit-identical to dense gossip."""
        from repro.distributed.mixing import SparseWeights
        W = maybe_sparsify(W)
        if T_con == 0:
            return lambda Z, m: Z
        if isinstance(W, SparseWeights):
            return _sparse_masked_gossip_mixer(W, T_con)

        def mix(Z, m):
            Wd = jnp.asarray(W).astype(Z.dtype)
            Weff = masked_mixing_matrix(Wd, m)
            if _fused_wanted(backend, Z.dtype):
                def round_(carry, _):
                    return stacked_dense_mix(carry, Weff,
                                             backend=backend), None
                out, _ = jax.lax.scan(round_, Z, None, length=T_con)
                return out
            flat = Z.reshape(Z.shape[0], -1)

            def round_(carry, _):
                return Weff @ carry, None
            out, _ = jax.lax.scan(round_, flat, None, length=T_con)
            return out.reshape(Z.shape)
        return mix

    def make_mesh_masked_mixer(self, axis_name: str, L: int, T_con: int,
                               shifts: Sequence[int] = (-1, 1),
                               self_weight: float | None = None, *,
                               W=None, backend: str = "xla-ref") -> Callable:
        """Per-device closure ``(z (d, r), m (L,)) ↦ z'`` — the masked
        ppermute round T_con times (the mask rides the scan xs of the
        shared mesh skeleton, replicated on every device)."""
        shifts_, weights = self._mesh_weights(L, shifts, self_weight, W)
        if T_con == 0:
            return lambda z, m: z

        def mix(z, m):
            def round_(carry, _):
                return self._masked_mesh_round(carry, m, axis_name, L,
                                               shifts_, weights,
                                               backend), None
            out, _ = jax.lax.scan(round_, z, None, length=T_con)
            return out
        return mix

    def make_virtual_mesh_masked_mixer(self, axis_name: str, vt,
                                       T_con: int, *,
                                       backend: str = "xla-ref") -> Callable:
        """Per-device virtual-tier closure ``(z (V, d, r), m (L,)) ↦
        z'``: the per-edge masked fold zeroes every edge with a dead
        endpoint and moves the lost mass onto the receiver's diagonal
        (the COO form of :func:`masked_mixing_matrix`'s fold), then runs
        T_con plain segment-sum rounds on the folded slice.  Full mask:
        every keep is 1, the fold is the identity, and the rounds ARE
        the dense virtual lowering's rounds bit-for-bit."""
        if T_con == 0:
            return lambda z, m: z

        def mix(z, m):
            g = jax.lax.axis_index(axis_name)
            arrays = virtual_arrays(vt, z.dtype)
            mf = m.astype(z.dtype).reshape(vt.n_dev, vt.block)
            sel_eff = _virtual_masked_fold(
                vt, _device_slice(arrays, g), g, mf)
            flat = z.reshape(vt.block, -1)

            def round_(carry, _):
                return _virtual_selected_round(carry, vt, axis_name,
                                               sel_eff), None
            out, _ = jax.lax.scan(round_, flat, None, length=T_con)
            return out.reshape(z.shape)
        return mix


class StaleGossipCombine(MaskedGossipCombine):
    """``stale_gossip`` — dropout tolerance on the
    :class:`CompressedGossipCombine` reference-copy machinery: every
    node's PUBLIC COPY x̂ persists across iterations (the state rides
    the drivers' scan carry); a LIVE node re-publishes its iterate each
    round (x̂ ← Z), a DOWN node sends nothing new — its last-delivered
    copy sits in the neighbours' receive queue and mixes in ONCE, in
    the iteration's first AGREE round (the late arrival lands at its
    stale value instead of tearing a hole in the weights).  Rounds
    2..T_con have no fresh packet from a down node to deliver, so the
    down link's weight folds to the receiver's diagonal exactly like
    ``partial_gossip`` — re-mixing the same stale anchor every round
    would compound its weight and halve the contraction rate.  Down
    nodes neither combine (the driver freezes them).  Full mask: every
    copy refreshes to Z, the fold is a no-op, and every round IS dense
    ``W @ Z`` bit-for-bit (the exact-self term never crosses a wire,
    and a live refresh makes the copy exact)."""

    name = "stale_gossip"

    # ------------------------------------------------------- state

    def init_state(self, Z_nodes, **kw):
        """Stacked public copies x̂ (L, d, r), zero — the network starts
        with no beliefs, exactly like the compressed rules (no setup
        exchange)."""
        return jnp.zeros_like(Z_nodes)

    def init_mesh_state(self, z_local, n_shifts: int = 0, **kw):
        """Per-device state: this device's own public copy (1, d, r).
        Unlike the compressed rules no neighbour-copy buffers are
        needed — a round's payload IS the sender's current copy, so
        receivers never hold a fresher belief than what arrives."""
        return jnp.zeros_like(z_local[None])

    # --------------------------------------------------- lowerings

    def make_sim_masked_state_mixer(self, W, T_con: int, *,
                                    backend: str = "xla-ref",
                                    **kw) -> Callable:
        """Simulator closure ``(Z, x̂, m) ↦ (Z', x̂')``."""
        from repro.distributed.mixing import SparseWeights
        W = maybe_sparsify(W)
        if T_con == 0:
            return lambda Z, state, m: (Z, state)
        if isinstance(W, SparseWeights):
            return self._make_sparse_masked_state_mixer(W, T_con)

        def mix(Z, state, m):
            N = Z.shape[0]
            Wd = jnp.asarray(W).astype(Z.dtype)
            Weff = masked_mixing_matrix(Wd, m.astype(Wd.dtype))
            mrow = m.astype(bool)[:, None, None]

            def round_(carry, rd):
                Zc, xhat = carry
                xhat2 = jnp.where(mrow, Zc, xhat)    # live nodes publish
                # the queued stale packet delivers once (round 0, dense
                # W); later rounds fold the dead link to the diagonal
                Wr = jnp.where(rd == 0, Wd, Weff)
                if _fused_wanted(backend, Zc.dtype):
                    Z2 = stacked_dense_mix(xhat2, Wr, backend=backend)
                else:
                    Z2 = (Wr @ xhat2.reshape(N, -1)).reshape(Zc.shape)
                # live g's own copy is exact (x̂₂_g = Z_g), so no self
                # correction is needed; down nodes freeze outright
                Z2 = jnp.where(mrow, Z2, Zc)
                return (Z2, xhat2), None

            (Zf, xf), _ = jax.lax.scan(round_, (Z, state),
                                       jnp.arange(T_con))
            return Zf, xf
        return mix

    @staticmethod
    def _make_sparse_masked_state_mixer(sw, T_con: int):
        """Sparse stale-gossip rounds: round 0 applies the DENSE weights
        to the published copies (the queued stale packet delivers once),
        later rounds the per-edge masked fold — per-round ``where`` on
        the edge values instead of the (L, L) ``jnp.where`` of the dense
        lowering."""
        rows_h, cols_h, vals_h, diag_h = _sparse_arrays(sw)
        L = sw.n

        def mix(Z, state, m):
            rows, cols = jnp.asarray(rows_h), jnp.asarray(cols_h)
            vals = jnp.asarray(vals_h, Z.dtype)
            diag = jnp.asarray(diag_h, Z.dtype)
            vals_eff, diag_eff = _sparse_masked_fold(
                rows, cols, vals, diag, m.astype(Z.dtype), L)
            mrow = m.astype(bool)[:, None, None]

            def round_(carry, rd):
                Zc, xhat = carry
                xhat2 = jnp.where(mrow, Zc, xhat)   # live nodes publish
                vals_rd = jnp.where(rd == 0, vals, vals_eff)
                diag_rd = jnp.where(rd == 0, diag, diag_eff)
                Z2 = sparse_round(xhat2.reshape(L, -1), rows, cols,
                                  vals_rd, diag_rd, L).reshape(Zc.shape)
                Z2 = jnp.where(mrow, Z2, Zc)        # down: freeze
                return (Z2, xhat2), None

            (Zf, xf), _ = jax.lax.scan(round_, (Z, state),
                                       jnp.arange(T_con))
            return Zf, xf
        return mix

    def make_mesh_masked_state_mixer(self, axis_name: str, L: int,
                                     T_con: int,
                                     shifts: Sequence[int] = (-1, 1),
                                     self_weight: float | None = None, *,
                                     W=None, backend: str = "xla-ref",
                                     **kw) -> Callable:
        """Per-device closure ``(z, x̂_own, m) ↦ (z', x̂_own')``: in the
        FIRST round a live device publishes z into its copy, every
        device permutes its copy (a down sender's wire carries its
        queued last-published value), and live devices combine
        self-exact with the K delivered copies under the DENSE weights;
        later rounds have nothing new from down senders, so their link
        weight folds to the receiver's diagonal (``partial_gossip``
        style) instead of re-mixing the same stale packet."""
        shifts_, weights = self._mesh_weights(L, shifts, self_weight, W)
        if T_con == 0:
            return lambda z, state, m: (z, state)

        cls = type(self)

        def mix(z, state, m):
            g = jax.lax.axis_index(axis_name)
            w = (weights if isinstance(weights, tuple) else weights[g])
            w_arr = jnp.asarray(w, dtype=z.dtype)
            keep = cls._mask_keep(m, g, shifts_, L, z.dtype)
            w_fold = jnp.concatenate(
                [(w_arr[0] + jnp.sum(w_arr[1:] * (1.0 - keep)))[None],
                 w_arr[1:] * keep])
            live = m.astype(bool)[g]

            def round_(carry, rd):
                zc, own = carry
                own2 = jnp.where(live, zc[None], own)   # publish if live
                nbrs = []
                for s in shifts_:
                    perm = [(i, (i - s) % L) for i in range(L)]
                    nbrs.append(jax.lax.ppermute(own2, axis_name, perm))
                # queued stale packet mixes once (round 0, dense w);
                # afterwards the dead link's weight folds to self
                w_rd = jnp.where(rd == 0, w_arr, w_fold)
                z2 = combine_blocks(zc, [n[0] for n in nbrs], w_rd,
                                    backend=backend)
                z2 = jnp.where(live, z2, zc)            # down: freeze
                return (z2, own2), None

            (zf, of), _ = jax.lax.scan(round_, (z, state),
                                       jnp.arange(T_con))
            return zf, of
        return mix

    def make_virtual_mesh_masked_state_mixer(self, axis_name: str, vt,
                                             T_con: int, *,
                                             backend: str = "xla-ref",
                                             **kw) -> Callable:
        """Per-device virtual-tier closure ``(z (V, d, r), x̂ (V, d, r),
        m (L,)) ↦ (z', x̂')`` — the simulator's sparse stale rounds on
        the device's block slice: live virtual nodes publish into their
        copies, round 0 mixes the published copies under the UNMASKED
        edge values (the queued stale packet delivers once), later
        rounds under the per-edge masked fold; down nodes freeze."""
        if T_con == 0:
            return lambda z, state, m: (z, state)

        def mix(z, state, m):
            V = vt.block
            g = jax.lax.axis_index(axis_name)
            arrays = virtual_arrays(vt, z.dtype)
            sel = _device_slice(arrays, g)
            mf = m.astype(z.dtype).reshape(vt.n_dev, V)
            sel_m = _virtual_masked_fold(vt, sel, g, mf)
            lr, lc, lv, crs, ccs, cvs, dg = sel
            _, _, lv_m, _, _, cvs_m, dg_m = sel_m
            mrow = m.astype(bool).reshape(vt.n_dev, V)[g][:, None, None]

            def round_(carry, rd):
                zc, xhat = carry
                xhat2 = jnp.where(mrow, zc, xhat)   # live nodes publish
                sel_rd = (lr, lc, jnp.where(rd == 0, lv, lv_m),
                          crs, ccs,
                          [jnp.where(rd == 0, a, b)
                           for a, b in zip(cvs, cvs_m)],
                          jnp.where(rd == 0, dg, dg_m))
                acc = _virtual_selected_round(xhat2.reshape(V, -1), vt,
                                              axis_name, sel_rd)
                Z2 = jnp.where(mrow, acc.reshape(zc.shape), zc)
                return (Z2, xhat2), None

            (zf, xf), _ = jax.lax.scan(round_, (z, state),
                                       jnp.arange(T_con))
            return zf, xf
        return mix


class PushSumGossipCombine(MaskedGossipCombine):
    """``push_sum_gossip`` — ratio-consensus for the DIRECTED mixing
    matrices dropout induces: the masked matrix
    (:func:`push_sum_matrix`) is only column-stochastic, so plain
    gossip would drift toward a weighted (biased) average; push-sum
    carries a companion weight scalar w through the same matrix
    (z ← Cz, w ← Cw, w₀ = 1) and reads out the bias-corrected ratio
    z/w after the T_con rounds.  The weight vector stays a probability
    vector up to scale (Σ_g w_g = L — columns sum to one), the
    invariant the tests pin.  The weight resets to 1 each outer
    iteration (each AGREE phase is its own push-sum episode), so no
    cross-iteration state is carried.  Full mask on a doubly stochastic
    W: C ≈ W and w ≈ 1 up to the row sums' float round-off — the
    degenerate case matches dense gossip to machine precision (not
    bit-for-bit: the ratio correction is genuinely different
    arithmetic)."""

    name = "push_sum_gossip"

    def make_sim_masked_mixer(self, W, T_con: int, *,
                              backend: str = "xla-ref") -> Callable:
        from repro.distributed.mixing import SparseWeights
        W = maybe_sparsify(W)
        if T_con == 0:
            return lambda Z, m: Z
        if isinstance(W, SparseWeights):
            return self._make_sparse_masked_mixer(W, T_con)

        def mix(Z, m):
            N = Z.shape[0]
            Wd = jnp.asarray(W).astype(Z.dtype)
            C = push_sum_matrix(Wd, m)
            flat = Z.reshape(N, -1)
            w0 = jnp.ones((N, 1), Z.dtype)

            def round_(carry, _):
                zf, wv = carry
                if _fused_wanted(backend, Z.dtype):
                    zf = stacked_dense_mix(zf, C, backend=backend)
                    wv = stacked_dense_mix(wv, C, backend=backend)
                else:
                    zf, wv = C @ zf, C @ wv
                return (zf, wv), None

            (zf, wv), _ = jax.lax.scan(round_, (flat, w0), None,
                                       length=T_con)
            out = zf / jnp.where(wv > 0, wv, 1.0)    # bias correction
            return out.reshape(Z.shape)
        return mix

    @staticmethod
    def _make_sparse_masked_mixer(sw, T_con: int):
        """Sparse push-sum: the column normalizer is a segment-sum over
        SENDER columns of the masked edge values (``c_j = W_jj +
        Σ_{i≠j} m_i m_j W_ij`` — the self link always stays, exactly
        like :func:`push_sum_matrix`), the column-stochastic edge
        values are ``vals_m / c[col]``, and the companion weight vector
        rides the same rounds."""
        rows_h, cols_h, vals_h, diag_h = _sparse_arrays(sw)
        L = sw.n

        def mix(Z, m):
            rows, cols = jnp.asarray(rows_h), jnp.asarray(cols_h)
            vals = jnp.asarray(vals_h, Z.dtype)
            diag = jnp.asarray(diag_h, Z.dtype)
            mf = m.astype(Z.dtype)
            vals_m = vals * mf[rows] * mf[cols]
            # live column mass: padding cols point at 0 but carry
            # weight 0, so the unsorted sender-side segment_sum is safe
            c = diag + jax.ops.segment_sum(vals_m, cols, num_segments=L)
            c = jnp.where(c > 0, c, 1.0)
            vals_C = vals_m / c[cols]
            diag_C = diag / c
            flat = Z.reshape(L, -1)
            w0 = jnp.ones((L, 1), Z.dtype)

            def round_(carry, _):
                zf, wv = carry
                zf = sparse_round(zf, rows, cols, vals_C, diag_C, L)
                wv = sparse_round(wv, rows, cols, vals_C, diag_C, L)
                return (zf, wv), None

            (zf, wv), _ = jax.lax.scan(round_, (flat, w0), None,
                                       length=T_con)
            out = zf / jnp.where(wv > 0, wv, 1.0)    # bias correction
            return out.reshape(Z.shape)
        return mix

    def make_mesh_masked_mixer(self, axis_name: str, L: int, T_con: int,
                               shifts: Sequence[int] = (-1, 1),
                               self_weight: float | None = None, *,
                               W=None, backend: str = "xla-ref") -> Callable:
        """Per-device push-sum round: the sender normalizes its OWN
        column locally (w_eff over its live links — exact because W must
        be symmetric, validated below, so its row IS its column),
        pre-scales the payload (z/c, w/c), and receivers combine with
        their masked row weights.  Requires a symmetric mixing matrix;
        asymmetric topologies need a sender-side column exchange the
        mesh lowering does not implement."""
        if W is not None:
            Wn = np.asarray(W)
            if not np.allclose(Wn, Wn.T):
                raise ValueError(
                    "push_sum_gossip's mesh lowering computes each "
                    "sender's column normalizer from its own row, which "
                    "requires a symmetric mixing matrix")
        elif set(shifts) != {-s for s in shifts}:
            raise ValueError(
                f"push_sum_gossip's mesh lowering needs symmetric "
                f"circulant shifts (closed under negation), got "
                f"{tuple(shifts)}")
        shifts_, weights = self._mesh_weights(L, shifts, self_weight, W)
        if T_con == 0:
            return lambda z, m: z

        def mix(z, m):
            g = jax.lax.axis_index(axis_name)
            w = jnp.asarray(weights if isinstance(weights, tuple)
                            else weights[g])
            keep = self._mask_keep(m, g, shifts_, L, w.dtype)
            # own column's live mass (symmetric W: row slice = column)
            c = w[0] + jnp.sum(w[1:] * keep)
            c = jnp.where(c > 0, c, 1.0)
            w_eff = jnp.concatenate([w[:1], w[1:] * keep])
            wv0 = jnp.ones((), z.dtype)

            def round_(carry, _):
                zc, wv = carry
                zs = zc / c.astype(zc.dtype)         # pre-scaled payload
                ws = wv / c.astype(zc.dtype)
                nbrs_z, nbrs_w = [], []
                for s in shifts_:
                    perm = [(i, (i - s) % L) for i in range(L)]
                    nbrs_z.append(jax.lax.ppermute(zs, axis_name, perm))
                    nbrs_w.append(jax.lax.ppermute(ws, axis_name, perm))
                z2 = combine_blocks(zs, nbrs_z, w_eff, backend=backend)
                acc_dt = _acc_dtype(zc.dtype)
                w2 = w_eff.astype(acc_dt)[0] * ws.astype(acc_dt)
                for k, nw in enumerate(nbrs_w):
                    w2 = w2 + w_eff.astype(acc_dt)[k + 1] \
                        * nw.astype(acc_dt)
                return (z2, w2.astype(zc.dtype)), None

            (zf, wv), _ = jax.lax.scan(round_, (z, wv0), None,
                                       length=T_con)
            return zf / jnp.where(wv > 0, wv, 1.0)
        return mix

    def make_virtual_mesh_masked_mixer(self, axis_name: str, vt,
                                       T_con: int, *,
                                       backend: str = "xla-ref") -> Callable:
        """Per-device virtual-tier push-sum ``(z (V, d, r), m (L,)) ↦
        z'``: each virtual node's column normalizer is its own RECEIVER-
        side live mass (row slice = column slice under the symmetry
        requirement, checked at make time), payloads are pre-scaled
        (z/c, w/c) and pushed through the MASKED edge values with the
        ORIGINAL diagonal — arithmetic-identical to the simulator's
        column-stochastic ``vals_m / c[col]`` rounds because
        ``vals_C·z[col] = vals_m·(z/c)[col]`` and ``diag_C·z =
        diag·(z/c)`` — with the companion weight riding the same
        rounds."""
        if not _vt_is_symmetric(vt):
            raise ValueError(
                "push_sum_gossip's virtual-mesh lowering computes each "
                "sender's column normalizer from its own receiver-side "
                "mass, which requires a symmetric mixing matrix")
        if T_con == 0:
            return lambda z, m: z

        def mix(z, m):
            V = vt.block
            g = jax.lax.axis_index(axis_name)
            arrays = virtual_arrays(vt, z.dtype)
            sel = _device_slice(arrays, g)
            mf = m.astype(z.dtype).reshape(vt.n_dev, V)
            # masked edges, ORIGINAL diagonal: the self link always
            # stays live, exactly like push_sum_matrix
            sel_m = _virtual_masked_fold(vt, sel, g, mf, fold_diag=False)
            lr, _, lv_m, crs, _, cvs_m, dg = sel_m
            # own column's live mass, receiver side (symmetric W)
            c = dg + jax.ops.segment_sum(lv_m, lr, num_segments=V + 1,
                                         indices_are_sorted=True)[:V]
            for k in range(len(vt.dev_shifts)):
                c = c + jax.ops.segment_sum(cvs_m[k], crs[k],
                                            num_segments=V + 1,
                                            indices_are_sorted=True)[:V]
            c = jnp.where(c > 0, c, 1.0)
            flat = z.reshape(V, -1)
            w0 = jnp.ones((V, 1), z.dtype)

            def round_(carry, _):
                zf, wv = carry
                zs = zf / c[:, None]                 # pre-scaled payload
                ws = wv / c[:, None]
                zf2 = _virtual_selected_round(zs, vt, axis_name, sel_m)
                wv2 = _virtual_selected_round(ws, vt, axis_name, sel_m)
                return (zf2, wv2), None

            (zf, wv), _ = jax.lax.scan(round_, (flat, w0), None,
                                       length=T_con)
            out = zf / jnp.where(wv > 0, wv, 1.0)    # bias correction
            return out.reshape(z.shape)
        return mix


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

COMBINE_RULES: dict[str, CombineRule] = {}


def register_rule(rule: CombineRule) -> CombineRule:
    if rule.name in COMBINE_RULES:
        raise ValueError(f"combine rule {rule.name!r} already registered")
    COMBINE_RULES[rule.name] = rule
    return rule


def get_rule(name: str) -> CombineRule:
    try:
        return COMBINE_RULES[name]
    except KeyError:
        raise ValueError(f"unknown combine rule {name!r}; registered: "
                         f"{sorted(COMBINE_RULES)}") from None


def rule_names() -> tuple[str, ...]:
    return tuple(sorted(COMBINE_RULES))


for _rule in (GossipCombine(), NeighborCombine(), CentralCombine(),
              NoCombine(), ExactDiffusionCombine(), BeyondCentralCombine(),
              TopkGossipCombine(), QuantizedGossipCombine(),
              EventGossipCombine(), PartialGossipCombine(),
              StaleGossipCombine(), PushSumGossipCombine()):
    register_rule(_rule)
