"""Unified pluggable consensus layer — every ``Z ← W Z`` in one place.

The AGREE protocol is the communication heart of the AltGDmin family,
but before this module each execution surface re-derived the mixing
product independently: the simulator's stacked scan (core/agree.py), the
mesh runtime's inline ppermute chain (core/runtime.py), the trainer's
roll form (distributed/gossip.py / aggregation.py), and the engine's
fused ``W^{T_con}`` combine (core/engine.py).  A :class:`CombineRule`
now owns all of them, with three lowered forms per rule:

  * **simulator** — stacked node axis, ``Z: (L, ...)``.  The unfused
    lowering is the exact sequential product (dtype-preserving, the
    numerics anchor); fused backends hoist onto a precomputed dense
    mixer executed by ``kernels/gossip_axpy.mix_rows`` (one weighted
    combine instead of T_con HBM sweeps).
  * **mesh** — one node per device inside ``shard_map``.  Each gossip
    round exchanges blocks by ``lax.ppermute`` and then combines them:
    the unfused lowering is the sequential weighted-sum chain, the fused
    lowering is ONE (K+1)-way ``kernels/gossip_axpy.gossip_combine``
    dispatch per round.  Any weighted graph lowers this way
    (:func:`mesh_weights_from_matrix`): one permute per distinct cyclic
    shift of W's sparsity pattern, each device combining with its own W
    row — circulant matrices collapse to shared scalar weights.
  * **comm signature** — a :class:`CommSignature` consumed by
    :mod:`repro.core.comm_model` and the API's wall-clock pricing, so a
    rule's communication cost is declared next to its math.

Precision policy (shared by every lowering): the fused combine kernels
accumulate in f32, so float64 operands always take the exact unfused
path — x64 simulations are never silently truncated in the consensus
phase.  Lower-precision operands (bf16 wire dtypes) accumulate in the
promoted f32 dtype on the unfused path too, matching the kernels.

Rules registered here: ``gossip`` (the paper's T_con-round AGREE),
``neighbor`` (DGD's single self-excluding exchange), ``central`` (fusion
center), ``none`` (no communication), plus the related-work combines —
``exact_diffusion`` (the projection-corrected combine of *Exact Subspace
Diffusion for Decentralized Multitask Learning*, arXiv:2304.07358) and
``beyond_central`` (the communication-efficient single-round combine of
*Beyond Centralization*, arXiv:2512.22675).  ``register_rule`` is open.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CommSignature:
    """What a combine rule costs on the wire, per outer iteration.

    ``pattern`` prices the exchange shape: ``"gossip"`` /``"neighbor"``
    send the iterate to every graph neighbour ``rounds_per_iter`` times;
    ``"central"`` is one gather + one broadcast; ``"none"`` is silent.
    """
    pattern: str                 # "gossip" | "neighbor" | "central" | "none"
    rounds_per_iter: int

    def bytes_per_iter(self, n_entries: int, itemsize: int, n_nodes: int,
                       degree: int) -> int:
        """Bytes sent per node per outer iteration (benchmark tables)."""
        if self.pattern == "central":
            # ring all-reduce equivalent: 2·(L−1)/L · size
            return int(2 * (n_nodes - 1) / n_nodes * n_entries * itemsize)
        return int(self.rounds_per_iter * degree * n_entries * itemsize)


# ----------------------------------------------------------------------
# the combine primitives every lowering bottoms out in
# ----------------------------------------------------------------------

def _acc_dtype(dtype):
    return jnp.promote_types(dtype, jnp.float32)


def _fused_wanted(backend: str, dtype) -> bool:
    """Fused Pallas combines accumulate in f32: take them only on the
    pallas backends and never for float64 operands (x64 policy)."""
    return backend != "xla-ref" and jnp.dtype(dtype) != jnp.float64


def combine_blocks(z, neighbors: Sequence[jax.Array], weights, *,
                   backend: str = "xla-ref"):
    """ONE (K+1)-way weighted combine ``z ← w₀·z + Σ_k w_{k+1}·nbr_k`` —
    the primitive under every mesh lowering (ppermute rounds, trainer
    roll rounds).  ``weights`` is a length-K+1 sequence: Python floats
    for uniform circulant weights, or a (K+1,) array slice of the
    device's own W row for arbitrary weighted topologies.  Unfused: the
    sequential chain in the promoted accumulator dtype; fused: a single
    ``gossip_combine`` dispatch."""
    from repro.kernels import ops
    neighbors = list(neighbors)
    if neighbors and _fused_wanted(backend, z.dtype):
        return ops.gossip_combine(z, jnp.stack(neighbors), weights,
                                  backend=backend)
    acc_dt = _acc_dtype(z.dtype)
    w = (list(weights) if isinstance(weights, (tuple, list))
         else list(jnp.asarray(weights).astype(acc_dt)))
    acc = w[0] * z.astype(acc_dt)
    for k, nbr in enumerate(neighbors):
        acc = acc + w[k + 1] * nbr.astype(acc_dt)
    return acc.astype(z.dtype)


def stacked_product(Z: jax.Array, W: jax.Array, T_con: int) -> jax.Array:
    """The exact sequential simulator product: T_con rounds of ``W @ Z``
    over the leading node axis, dtype-preserving (the seed's ``agree``
    math — every other lowering is validated against this)."""
    if T_con == 0:
        return Z
    W = W.astype(Z.dtype)
    flat = Z.reshape(Z.shape[0], -1)

    def body(carry, _):
        return W @ carry, None

    out, _ = jax.lax.scan(body, flat, None, length=T_con)
    return out.reshape(Z.shape)


def stacked_dense_mix(Z: jax.Array, M: jax.Array, *, backend: str):
    """Single dense combine ``Z ← M Z`` for a precomputed mixer (e.g.
    ``W^{T_con}``): fused ``mix_rows`` on the pallas backends, einsum on
    xla-ref/f64."""
    from repro.kernels import ops
    if _fused_wanted(backend, Z.dtype):
        return ops.mix_nodes(Z, M.astype(jnp.float32),
                             backend=backend).astype(Z.dtype)
    return jnp.einsum("gh,h...->g...", M.astype(Z.dtype), Z)


def node_mean(Z: jax.Array) -> jax.Array:
    """Fusion-center combine: exact mean over the node axis, broadcast
    back (lowers to one all-reduce under pjit)."""
    acc_dt = _acc_dtype(Z.dtype)
    m = jnp.mean(Z.astype(acc_dt), axis=0, keepdims=True)
    return jnp.broadcast_to(m, Z.shape).astype(Z.dtype)


def neighbor_average_matrix(adj):
    """DGD's row-stochastic neighbour average M = D⁻¹A (zero diagonal,
    isolated nodes guarded to degree 1).  ONE derivation shared by the
    simulator driver and the mesh lowering — their ≤1e-7 parity depends
    on both sides using the same matrix."""
    deg = jnp.maximum(jnp.sum(adj, axis=1), 1.0)
    return adj / deg[:, None]


def mesh_weights_from_matrix(W) -> tuple[tuple[int, ...], np.ndarray]:
    """Decompose a concrete (L, L) mixing matrix into cyclic-shift form:
    ``(shifts, table)`` with ``table[i] = [W_ii, W_{i,(i+s1)%L}, ...]``.

    Every entry of W lies on exactly one cyclic diagonal (edge (i, j) on
    shift ``(j−i) mod L``), so ANY weighted graph lowers to one
    ``lax.ppermute`` per distinct shift plus one (K+1)-way weighted
    combine — a circulant matrix needs exactly its own |shifts|, an
    irregular graph up to L−1.  Shifts are reported as signed
    representatives in (−L/2, L/2] and sorted, so a symmetric ring
    decomposes to the runtime's historical (−1, 1) order.

    W must be host-concrete (topology is static metadata, never traced).
    """
    try:
        Wn = np.asarray(W)
    except Exception as e:                       # jax TracerConversionError
        raise ValueError(
            "mesh_weights_from_matrix needs a concrete mixing matrix — "
            "topology is static metadata and cannot be traced") from e
    if Wn.ndim != 2 or Wn.shape[0] != Wn.shape[1]:
        raise ValueError(f"mixing matrix must be square, got {Wn.shape}")
    L = Wn.shape[0]
    idx = np.arange(L)
    shifts = sorted(
        (s if s <= L // 2 else s - L)
        for s in range(1, L) if np.any(Wn[idx, (idx + s) % L] != 0))
    table = np.empty((L, len(shifts) + 1), dtype=Wn.dtype)
    table[:, 0] = np.diag(Wn)
    for k, s in enumerate(shifts):
        table[:, k + 1] = Wn[idx, (idx + s) % L]
    return tuple(shifts), table


# ----------------------------------------------------------------------
# CombineRule
# ----------------------------------------------------------------------

class CombineRule:
    """One consensus/combine scheme, lowered three ways.

    ``make_sim_mixer(W, T_con, backend=...)`` returns the simulator
    closure ``Z (L, ...) ↦ combined Z``; ``make_mesh_mixer(...)`` the
    per-device closure used inside ``shard_map`` — pass ``W=`` for an
    arbitrary weighted topology (each distinct cyclic shift of W's
    sparsity pattern becomes one collective-permute, each device combines
    with its own W row), or ``shifts``/``self_weight`` for the uniform
    circulant form; ``signature(T_con)`` the comm cost.  Subclasses
    override the pieces that differ.
    """

    name: str = "base"

    # ------------------------------------------------------- simulator

    def make_sim_mixer(self, W, T_con: int, *,
                       backend: str = "xla-ref") -> Callable:
        raise NotImplementedError

    # ------------------------------------------------------------ mesh

    def make_mesh_mixer(self, axis_name: str, L: int, T_con: int,
                        shifts: Sequence[int] = (-1, 1),
                        self_weight: float | None = None, *,
                        W=None, backend: str = "xla-ref") -> Callable:
        raise NotImplementedError

    # ------------------------------------------------------- signature

    def signature(self, T_con: int) -> CommSignature:
        raise NotImplementedError

    # ---------------------------------------------------------- shared

    @staticmethod
    def _ring_weights(shifts: Sequence[int], self_weight: float | None):
        k = len(shifts)
        sw = self_weight if self_weight is not None else 1.0 / (k + 1)
        return sw, (1.0 - sw) / k

    @classmethod
    def _mesh_weights(cls, L: int, shifts: Sequence[int],
                      self_weight: float | None, W):
        """Resolve the mesh lowering's (shifts, weights) pair.

        With ``W``: decompose the actual mixing matrix — identical rows
        collapse to shared Python-float weights (the circulant fast
        path, no per-device gather), otherwise the full (L, K+1) table
        is kept and each device selects its row inside the round.
        Without ``W``: the historical uniform circulant weights of
        ``shifts``/``self_weight``."""
        if W is None:
            sw, wn = cls._ring_weights(shifts, self_weight)
            return tuple(shifts), (sw,) + (wn,) * len(shifts)
        shifts_, table = mesh_weights_from_matrix(W)
        if table.shape[0] != L:
            raise ValueError(f"mixing matrix is {table.shape[0]}×"
                             f"{table.shape[0]} but the mesh axis has "
                             f"{L} devices")
        if np.all(table == table[0]):
            return shifts_, tuple(float(x) for x in table[0])
        return shifts_, jnp.asarray(table)

    @classmethod
    def _mesh_round(cls, z, axis_name: str, L: int,
                    shifts: Sequence[int], weights, backend: str):
        """One gossip round on hardware: K collective-permutes to fetch
        neighbour blocks, then ONE (K+1)-way combine (fused on pallas
        backends).  ``weights`` is a shared scalar tuple (uniform /
        circulant) or an (L, K+1) table — then each device picks its own
        row by ``axis_index`` (arbitrary weighted topology)."""
        w = (weights if isinstance(weights, tuple)
             else weights[jax.lax.axis_index(axis_name)])
        nbrs = []
        for s in shifts:
            perm = [(i, (i - s) % L) for i in range(L)]   # receive from i+s
            nbrs.append(jax.lax.ppermute(z, axis_name, perm))
        return combine_blocks(z, nbrs, w, backend=backend)

    @classmethod
    def roll_round(cls, x, shifts: Sequence[int], weights, *,
                   backend: str = "xla-ref"):
        """One gossip round in the pjit/trainer form: neighbour blocks
        come from ``jnp.roll`` over the leading node axis (XLA lowers the
        sharded roll to the same collective-permute).  ``weights``:
        length-K+1 ``(w_self, w_shift1, ...)``."""
        nbrs = [jnp.roll(x, -s, axis=0) for s in shifts]
        return combine_blocks(x, nbrs, weights, backend=backend)


class GossipCombine(CombineRule):
    """The paper's AGREE combine: T_con rounds of the mixing product
    ``Z ← W Z`` (Algorithm 1)."""

    name = "gossip"

    def make_sim_mixer(self, W, T_con: int, *, backend: str = "xla-ref"):
        if T_con == 0:
            return lambda Z: Z
        if backend == "xla-ref":
            return lambda Z: stacked_product(Z, W, T_con)
        Wp = jnp.linalg.matrix_power(W.astype(jnp.float32), T_con)

        def mix(Z):
            if Z.dtype == jnp.float64:
                # f32-accumulating fused kernel: keep x64 runs exact
                return stacked_product(Z, W, T_con)
            return stacked_dense_mix(Z, Wp, backend=backend)
        return mix

    def make_mesh_mixer(self, axis_name, L, T_con, shifts=(-1, 1),
                        self_weight=None, *, W=None, backend="xla-ref"):
        shifts_, weights = self._mesh_weights(L, shifts, self_weight, W)
        if T_con == 0:
            return lambda z: z

        def gossip(z):
            def round_(carry, _):
                return self._mesh_round(carry, axis_name, L, shifts_,
                                        weights, backend), None
            out, _ = jax.lax.scan(round_, z, None, length=T_con)
            return out
        return gossip

    def signature(self, T_con: int) -> CommSignature:
        return CommSignature("gossip", T_con)


class NeighborCombine(CombineRule):
    """DGD's combine: ONE row-stochastic neighbour average that excludes
    the node itself (Experiment 1's ``(1/deg_g) Σ_{g'∈N_g} U_g'``).  The
    simulator form takes the precomputed neighbour-average matrix M."""

    name = "neighbor"

    def make_sim_mixer(self, M, T_con: int = 1, *, backend: str = "xla-ref"):
        return lambda Z: stacked_dense_mix(Z, M, backend=backend)

    def make_mesh_mixer(self, axis_name, L, T_con=1, shifts=(-1, 1),
                        self_weight=None, *, W=None, backend="xla-ref"):
        """ONE neighbour-average round.  Without ``W`` the circulant
        graph of ``shifts`` is K-regular, so the average is the
        equal-weight shift combine with structurally zero self weight;
        with ``W`` (the precomputed row-stochastic neighbour matrix,
        zero diagonal) each device combines with its own row — the
        irregular-graph form."""
        if W is None:
            shifts_ = tuple(shifts)
            weights = (0.0,) + (1.0 / len(shifts),) * len(shifts)
        else:
            shifts_, weights = self._mesh_weights(L, shifts, self_weight, W)
        return lambda z: self._mesh_round(z, axis_name, L, shifts_,
                                          weights, backend)

    def signature(self, T_con: int) -> CommSignature:
        return CommSignature("neighbor", 1)


class CentralCombine(CombineRule):
    """Fusion-center combine: the exact node mean (AltGDmin [10])."""

    name = "central"

    def make_sim_mixer(self, W=None, T_con: int = 0, *,
                       backend: str = "xla-ref"):
        return node_mean

    def make_mesh_mixer(self, axis_name, L, T_con=0, shifts=(),
                        self_weight=None, *, W=None, backend="xla-ref"):
        return lambda z: jax.lax.pmean(z, axis_name)

    def signature(self, T_con: int) -> CommSignature:
        return CommSignature("central", 1)


class NoCombine(CombineRule):
    """Local training: no communication (identity combine)."""

    name = "none"

    def make_sim_mixer(self, W=None, T_con: int = 0, *,
                       backend: str = "xla-ref"):
        return lambda Z: Z

    def make_mesh_mixer(self, axis_name, L, T_con=0, shifts=(),
                        self_weight=None, *, W=None, backend="xla-ref"):
        return lambda z: z

    def signature(self, T_con: int) -> CommSignature:
        return CommSignature("none", 0)


class ExactDiffusionCombine(GossipCombine):
    """The projection-corrected combine of Exact Subspace Diffusion
    (arXiv:2304.07358).  The mixing product is standard AGREE, but each
    application first bias-corrects the adapt iterate with the previous
    correction state:

        φ_g^τ = ψ_g^τ + U_g^{τ-1} − ψ_g^{τ-1}        (correction)
        Ũ_g^τ = Σ_j W_gj φ_j^τ  (T_con rounds)        (combine)

    so the combine tracks the exact (bias-free) fixed point instead of
    the diffusion limit point; the driver carries ``(ψ_prev, U_prev)``
    through its scan and retracts Ũ onto the Grassmannian afterwards
    (the subspace projection step).
    """

    name = "exact_diffusion"

    @staticmethod
    def correct(psi, psi_prev, U_prev):
        """φ = ψ + U_prev − ψ_prev (vanishes at τ=0 when ψ_prev=U_prev)."""
        return psi + U_prev - psi_prev


class BeyondCentralCombine(GossipCombine):
    """The communication-efficient combine of Beyond Centralization
    (arXiv:2512.22675): nodes take several *local* adapt steps between
    consensus exchanges and then combine with ONE gossip round — per
    outer iteration the wire carries a single d×r exchange instead of
    the T_con-round AGREE chain."""

    name = "beyond_central"

    def make_sim_mixer(self, W, T_con: int = 1, *, backend: str = "xla-ref"):
        # a single mixing round regardless of T_con — that IS the rule
        return super().make_sim_mixer(W, 1, backend=backend)

    def make_mesh_mixer(self, axis_name, L, T_con=1, shifts=(-1, 1),
                        self_weight=None, *, W=None, backend="xla-ref"):
        return super().make_mesh_mixer(axis_name, L, 1, shifts,
                                       self_weight, W=W, backend=backend)

    def signature(self, T_con: int) -> CommSignature:
        return CommSignature("gossip", 1)


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

COMBINE_RULES: dict[str, CombineRule] = {}


def register_rule(rule: CombineRule) -> CombineRule:
    if rule.name in COMBINE_RULES:
        raise ValueError(f"combine rule {rule.name!r} already registered")
    COMBINE_RULES[rule.name] = rule
    return rule


def get_rule(name: str) -> CombineRule:
    try:
        return COMBINE_RULES[name]
    except KeyError:
        raise ValueError(f"unknown combine rule {name!r}; registered: "
                         f"{sorted(COMBINE_RULES)}") from None


def rule_names() -> tuple[str, ...]:
    return tuple(sorted(COMBINE_RULES))


for _rule in (GossipCombine(), NeighborCombine(), CentralCombine(),
              NoCombine(), ExactDiffusionCombine(), BeyondCentralCombine()):
    register_rule(_rule)
