from repro.distributed.graphs import (
    Graph, erdos_renyi, ring, torus2d, hypercube, complete, star,
    path_graph, circulant,
)
from repro.distributed.mixing import (
    metropolis_weights, equal_neighbor_weights, lazy_weights, gamma,
    circulant_weights,
)
from repro.distributed.consensus import (
    CombineRule, CommSignature, COMBINE_RULES, register_rule, get_rule,
    rule_names, combine_blocks, mesh_weights_from_matrix,
    neighbor_average_matrix,
)
from repro.distributed.gossip import (
    roll_gossip, shard_map_gossip, ring_weights, torus_shifts, axis_mean,
)
from repro.distributed.aggregation import (
    AggregationConfig, aggregate_gradients, aggregate_params,
    comm_bytes_per_step, STRATEGIES,
)
