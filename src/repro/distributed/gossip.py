"""Hardware gossip — the AGREE protocol on a TPU mesh.

Two numerically-identical implementations of one circulant gossip round
    Z_g ← w_self · Z_g + Σ_k w_k · Z_{g+s_k  (mod L)}
(= ``Z ← W Z`` for the circulant W of repro.distributed.mixing):

  * :func:`shard_map_gossip` — nodes are devices along a mesh axis; each
    shift is ONE ``lax.ppermute`` (nearest-neighbour collective-permute on
    the ICI torus).  This is the paper's communication pattern lowered to
    TPU-native collectives; used by the linear-MTRL distributed runtime.
  * :func:`roll_gossip` — nodes are the leading array axis; each shift is
    a ``jnp.roll``.  Under pjit with that axis sharded over the mesh, XLA
    lowers the roll to the same collective-permute — but the function
    composes freely with vmap/grad/scan, so the deep-learning trainer
    (repro.distributed.aggregation) uses this form.

Both bottom out in the unified consensus layer's K+1-way combine
(:func:`repro.distributed.consensus.combine_blocks`) — the same primitive
the AltGDmin mesh runtime fuses into one ``gossip_combine`` dispatch per
round on the pallas backends.

DESIGN.md §3 hardware adaptation: production topologies are rings/tori
(fabric-native); arbitrary Erdős–Rényi graphs stay in the simulator.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import numpy as np

from repro.distributed.consensus import GossipCombine, get_rule
from repro.utils.compat import shard_map as _shard_map


def ring_weights(shifts: Sequence[int] = (-1, 1),
                 self_weight: float | None = None):
    """(self_weight, per-shift weight) for a symmetric circulant mixer.
    Defaults to equal weights 1/(k+1) — the paper's equal-neighbour rule on
    a regular ring."""
    return GossipCombine._ring_weights(shifts, self_weight)


def torus_shifts(rows: int, cols: int):
    """Neighbour shifts of a rows×cols torus flattened row-major: ±1 (same
    row, wrap handled by flat modular shift) and ±cols."""
    return (-1, 1, -cols, cols)


# ---------------------------------------------------------------- pjit form

def roll_gossip(tree, T_con: int, shifts: Sequence[int] = (-1, 1),
                self_weight: float | None = None, *, W=None,
                backend: str = "xla-ref"):
    """T_con gossip rounds over the leading (node) axis of every leaf.

    Without ``W`` this is the uniform circulant mixer of ``shifts`` /
    ``self_weight`` (the historical trainer form).  Pass ``W=`` — ANY
    concrete (L, L) mixing matrix — to gossip with the matrix's actual
    weights: the consensus layer decomposes it into cyclic shifts plus
    per-node weight rows (circulant matrices collapse to the shared
    scalar fast path, bit-compatible with the legacy form; irregular
    Metropolis/ER matrices roll with an (L, K+1) table each node indexes
    by its row).  Leaves whose leading axis disagrees with W's size
    raise a ``ValueError`` instead of silently mixing with wrong
    weights."""
    if T_con == 0:
        return tree
    rule = get_rule("gossip")
    if W is not None:
        # one source of truth with the shard_map mesh lowering:
        # _mesh_weights collapses a circulant W to shared scalars and
        # keeps an (L, K+1) per-node table otherwise
        L = np.asarray(W).shape[0]
        shifts, weights = GossipCombine._mesh_weights(L, (), None, W)
        bad = [x.shape for x in jax.tree.leaves(tree)
               if x.shape[:1] != (L,)]
        if bad:
            raise ValueError(
                f"roll_gossip W= is {L}×{L} but leaves have leading "
                f"(node) axes {sorted({s[0] for s in bad})} — every leaf "
                f"must carry one row per node")
    else:
        sw, wn = ring_weights(shifts, self_weight)
        weights = (sw,) + (wn,) * len(shifts)

    def one_round(t):
        return jax.tree.map(
            lambda x: rule.roll_round(x, shifts, weights, backend=backend),
            t)

    for _ in range(T_con):
        tree = one_round(tree)
    return tree


# ---------------------------------------------------------- shard_map form

def shard_map_gossip(Z, mesh, axis_name: str, T_con: int,
                     shifts: Sequence[int] = (-1, 1),
                     self_weight: float | None = None, *, W=None,
                     backend: str = "xla-ref"):
    """AGREE on hardware: Z's leading axis (length = mesh axis size) is
    sharded over ``axis_name``; every round each device exchanges its block
    with its graph neighbours via collective-permute, then combines them
    (one fused K+1-way dispatch per round on the pallas backends).
    Pass ``W=`` (a concrete mixing matrix) to gossip over an arbitrary
    weighted topology instead of the uniform circulant of ``shifts``."""
    L = mesh.shape[axis_name]
    if Z.shape[0] != L:
        raise ValueError(f"leading axis {Z.shape[0]} != mesh axis {L}")
    mixer = get_rule("gossip").make_mesh_mixer(
        axis_name, L, T_con, shifts, self_weight, W=W, backend=backend)
    spec = jax.sharding.PartitionSpec(axis_name)

    @functools.partial(_shard_map, mesh=mesh, in_specs=spec,
                       out_specs=spec, axis_names={axis_name},
                       check_rep=backend == "xla-ref")
    def run(z):
        return mixer(z)

    return run(Z)


def axis_mean(tree, axis_name: str):
    """Fusion-center baseline inside shard_map: exact pmean."""
    mix = get_rule("central").make_mesh_mixer(axis_name, 0)
    return jax.tree.map(mix, tree)
