"""Hardware gossip — the AGREE protocol on a TPU mesh.

Two numerically-identical implementations of one circulant gossip round
    Z_g ← w_self · Z_g + Σ_k w_k · Z_{g+s_k  (mod L)}
(= ``Z ← W Z`` for the circulant W of repro.distributed.mixing):

  * :func:`shard_map_gossip` — nodes are devices along a mesh axis; each
    shift is ONE ``lax.ppermute`` (nearest-neighbour collective-permute on
    the ICI torus).  This is the paper's communication pattern lowered to
    TPU-native collectives; used by the linear-MTRL distributed runtime.
  * :func:`roll_gossip` — nodes are the leading array axis; each shift is
    a ``jnp.roll``.  Under pjit with that axis sharded over the mesh, XLA
    lowers the roll to the same collective-permute — but the function
    composes freely with vmap/grad/scan, so the deep-learning trainer
    (repro.distributed.aggregation) uses this form.

DESIGN.md §3 hardware adaptation: production topologies are rings/tori
(fabric-native); arbitrary Erdős–Rényi graphs stay in the simulator.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.utils.compat import shard_map as _shard_map


def ring_weights(shifts: Sequence[int] = (-1, 1),
                 self_weight: float | None = None):
    """(self_weight, per-shift weight) for a symmetric circulant mixer.
    Defaults to equal weights 1/(k+1) — the paper's equal-neighbour rule on
    a regular ring."""
    k = len(shifts)
    sw = self_weight if self_weight is not None else 1.0 / (k + 1)
    return sw, (1.0 - sw) / k


def torus_shifts(rows: int, cols: int):
    """Neighbour shifts of a rows×cols torus flattened row-major: ±1 (same
    row, wrap handled by flat modular shift) and ±cols."""
    return (-1, 1, -cols, cols)


# ---------------------------------------------------------------- pjit form

def roll_gossip(tree, T_con: int, shifts: Sequence[int] = (-1, 1),
                self_weight: float | None = None):
    """T_con gossip rounds over the leading (node) axis of every leaf."""
    if T_con == 0:
        return tree
    sw, wn = ring_weights(shifts, self_weight)

    def one_round(t):
        def mix(x):
            acc_dt = jnp.promote_types(x.dtype, jnp.float32)
            acc = sw * x.astype(acc_dt)
            for s in shifts:
                acc = acc + wn * jnp.roll(x, -s, axis=0).astype(acc_dt)
            return acc.astype(x.dtype)
        return jax.tree.map(mix, t)

    for _ in range(T_con):
        tree = one_round(tree)
    return tree


# ---------------------------------------------------------- shard_map form

def _ppermute_round(z, axis_name, L, shifts, sw, wn):
    acc_dt = jnp.promote_types(z.dtype, jnp.float32)
    acc = sw * z.astype(acc_dt)
    for s in shifts:
        perm = [(i, (i - s) % L) for i in range(L)]   # receive from i+s
        acc = acc + wn * jax.lax.ppermute(z, axis_name, perm).astype(acc_dt)
    return acc.astype(z.dtype)


def shard_map_gossip(Z, mesh, axis_name: str, T_con: int,
                     shifts: Sequence[int] = (-1, 1),
                     self_weight: float | None = None):
    """AGREE on hardware: Z's leading axis (length = mesh axis size) is
    sharded over ``axis_name``; every round each device exchanges its block
    with its ring neighbours via collective-permute."""
    L = mesh.shape[axis_name]
    if Z.shape[0] != L:
        raise ValueError(f"leading axis {Z.shape[0]} != mesh axis {L}")
    sw, wn = ring_weights(shifts, self_weight)
    spec = jax.sharding.PartitionSpec(axis_name)

    @functools.partial(_shard_map, mesh=mesh, in_specs=spec,
                       out_specs=spec, axis_names={axis_name})
    def run(z):
        def body(carry, _):
            return _ppermute_round(carry, axis_name, L, shifts, sw, wn), None
        out, _ = jax.lax.scan(body, z, None, length=T_con)
        return out

    return run(Z)


def axis_mean(tree, axis_name: str):
    """Fusion-center baseline inside shard_map: exact pmean."""
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), tree)
