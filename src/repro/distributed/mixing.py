"""Doubly-stochastic mixing (weight) matrices W for the AGREE protocol.

The paper (Sec. III) uses the equal-neighbor rule
``W_gj = 1/deg_g`` for j ∈ N_g — note this is doubly stochastic only for
regular graphs, and the paper's AGREE line 4

    Z_g ← Z_g + Σ_j (1/deg_g) (Z_j − Z_g)

is exactly ``Z ← W Z`` with W = I − D^{-1}(D − A) = D^{-1}A ... plus the
retained self term; we implement that exact update as
:func:`equal_neighbor_weights` and additionally provide Metropolis–Hastings
weights, which are doubly stochastic on *any* graph (used when Proposition 1
requires double stochasticity on irregular graphs).

``gamma(W) = max(|λ₂|, |λ_L|)`` is the consensus contraction factor of
Proposition 1.
"""
from __future__ import annotations

import numpy as np

from repro.distributed.graphs import Graph


def equal_neighbor_weights(graph: Graph) -> np.ndarray:
    """The paper's AGREE update written as a mixing matrix:
    W = I - D^{-1} L_graph  (row-stochastic always; doubly stochastic iff the
    graph is regular)."""
    a = graph.adj.astype(np.float64)
    deg = np.maximum(a.sum(axis=1), 1.0)
    w = a / deg[:, None]
    w[np.diag_indices_from(w)] = 1.0 - w.sum(axis=1)
    return w


def metropolis_weights(graph: Graph) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric & doubly stochastic on any
    connected graph.  W_ij = 1/(1+max(d_i,d_j)) for edges."""
    a = graph.adj.astype(np.float64)
    deg = a.sum(axis=1)
    L = graph.n_nodes
    w = np.zeros((L, L))
    ii, jj = np.nonzero(np.triu(a, 1))
    for i, j in zip(ii, jj):
        w[i, j] = w[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    w[np.diag_indices_from(w)] = 1.0 - w.sum(axis=1)
    return w


def lazy_weights(graph: Graph, beta: float = 0.5) -> np.ndarray:
    """Lazy variant W_lazy = (1-beta) I + beta W_metropolis — guarantees
    gamma < 1 even on bipartite graphs."""
    w = metropolis_weights(graph)
    return (1.0 - beta) * np.eye(graph.n_nodes) + beta * w


def circulant_weights(L: int, shifts: tuple[int, ...] = (-1, 1),
                      self_weight: float | None = None) -> np.ndarray:
    """Circulant mixing matrix on a ring-like topology: node i averages with
    i+s for s in shifts.  This is the form the TPU runtime implements with
    ``lax.ppermute`` (each shift = one collective-permute); keeping the
    simulator and the runtime numerically identical.

    Default: symmetric ring with weights (1-sw)/len(shifts) per neighbour.
    """
    k = len(shifts)
    sw = self_weight if self_weight is not None else 1.0 / (k + 1)
    wn = (1.0 - sw) / k
    w = np.eye(L) * sw
    for s in shifts:
        idx = (np.arange(L) + s) % L
        w[np.arange(L), idx] += wn
    return w


def gamma(w: np.ndarray) -> float:
    """gamma(W) := max(|λ₂|, |λ_L|) — the consensus contraction factor."""
    ev = np.linalg.eigvals(w)
    ev = np.sort(np.abs(ev))[::-1]
    if len(ev) == 1:
        return 0.0
    return float(ev[1])


def is_doubly_stochastic(w: np.ndarray, tol: float = 1e-9) -> bool:
    return (np.all(w >= -tol)
            and np.allclose(w.sum(axis=0), 1.0, atol=1e-8)
            and np.allclose(w.sum(axis=1), 1.0, atol=1e-8))
