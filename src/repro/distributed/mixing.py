"""Doubly-stochastic mixing (weight) matrices W for the AGREE protocol.

The paper (Sec. III) uses the equal-neighbor rule
``W_gj = 1/deg_g`` for j ∈ N_g — note this is doubly stochastic only for
regular graphs, and the paper's AGREE line 4

    Z_g ← Z_g + Σ_j (1/deg_g) (Z_j − Z_g)

is exactly ``Z ← W Z`` with W = I − D^{-1}(D − A) = D^{-1}A ... plus the
retained self term; we implement that exact update as
:func:`equal_neighbor_weights` and additionally provide Metropolis–Hastings
weights, which are doubly stochastic on *any* graph (used when Proposition 1
requires double stochasticity on irregular graphs).

``gamma(W) = max(|λ₂|, |λ_L|)`` is the consensus contraction factor of
Proposition 1.

Scale path: the dense builders above return (L, L) numpy matrices and are
the small-L anchor; their sparse counterparts
(:func:`metropolis_weights_sparse` / :func:`equal_neighbor_weights_sparse`
/ :func:`lazy_weights_sparse` / :func:`circulant_weights_sparse`) build a
:class:`SparseWeights` — COO off-diagonal edges + a separate diagonal —
straight from a :class:`~repro.distributed.graphs.SparseGraph`'s CSR
arrays, never allocating O(L²).  ``SparseWeights.to_dense()`` equals the
dense builder's matrix to float round-off (summation order differs), the
parity the tests pin.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.distributed.graphs import (DENSE_MATERIALIZE_MAX, Graph,
                                      SparseGraph)


@dataclasses.dataclass(frozen=True)
class SparseWeights:
    """A mixing matrix in sparse form (host numpy, static metadata).

    ``rows``/``cols``/``vals`` are the off-diagonal entries in COO
    layout, sorted by (row, col) — CSR order, so ``segment_sum`` over
    ``rows`` sees sorted segment ids; ``diag`` is the (L,) diagonal kept
    separate (the self weight never crosses a wire, and every combine
    rule treats it specially).  The sparsity PATTERN must be symmetric
    (undirected graphs); the values need not be (push-sum's
    column-normalized matrices are directed).
    """
    n: int
    rows: np.ndarray   # (nnz,) int32 — receiver
    cols: np.ndarray   # (nnz,) int32 — sender
    vals: np.ndarray   # (nnz,) float64
    diag: np.ndarray   # (L,)  float64

    def __post_init__(self):
        rows = np.asarray(self.rows, dtype=np.int32)
        cols = np.asarray(self.cols, dtype=np.int32)
        vals = np.asarray(self.vals, dtype=np.float64)
        diag = np.asarray(self.diag, dtype=np.float64)
        order = np.lexsort((cols, rows))
        if not np.array_equal(order, np.arange(order.size)):
            rows, cols, vals = rows[order], cols[order], vals[order]
        for name, arr in (("rows", rows), ("cols", cols), ("vals", vals)):
            object.__setattr__(self, name, arr)
        object.__setattr__(self, "diag", diag)
        if not (rows.shape == cols.shape == vals.shape):
            raise ValueError("rows/cols/vals must share a shape")
        if diag.shape != (self.n,):
            raise ValueError(f"diag must be ({self.n},), got {diag.shape}")
        if rows.size:
            if rows.min() < 0 or rows.max() >= self.n \
                    or cols.min() < 0 or cols.max() >= self.n:
                raise ValueError("edge index out of range")
            if np.any(rows == cols):
                raise ValueError("diagonal entries belong in .diag")

    # ------------------------------------------------------ construction

    @classmethod
    def from_dense(cls, W) -> "SparseWeights":
        Wn = np.asarray(W, dtype=np.float64)
        if Wn.ndim != 2 or Wn.shape[0] != Wn.shape[1]:
            raise ValueError(f"mixing matrix must be square, got {Wn.shape}")
        off = Wn - np.diag(np.diag(Wn))
        rows, cols = np.nonzero(off)
        return cls(n=Wn.shape[0], rows=rows.astype(np.int32),
                   cols=cols.astype(np.int32), vals=off[rows, cols],
                   diag=np.diag(Wn).copy())

    # -------------------------------------------------------- interface

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n, self.n)

    @property
    def nnz(self) -> int:
        return self.vals.size

    @property
    def n_edges(self) -> int:
        """Undirected edge count of the sparsity pattern."""
        return self.nnz // 2

    @property
    def density(self) -> float:
        return self.nnz / (self.n * (self.n - 1)) if self.n > 1 else 0.0

    def row_sums(self) -> np.ndarray:
        return self.diag + np.bincount(self.rows, weights=self.vals,
                                       minlength=self.n)

    def col_sums(self) -> np.ndarray:
        return self.diag + np.bincount(self.cols, weights=self.vals,
                                       minlength=self.n)

    def to_dense(self) -> np.ndarray:
        if self.n > DENSE_MATERIALIZE_MAX:
            raise ValueError(
                f"refusing to densify a {self.n}×{self.n} mixing matrix "
                f"(> DENSE_MATERIALIZE_MAX={DENSE_MATERIALIZE_MAX})")
        W = np.zeros((self.n, self.n))
        W[self.rows, self.cols] = self.vals
        W[np.diag_indices(self.n)] = self.diag
        return W

    def scipy_csr(self):
        """scipy.sparse CSR view (diagonal included) — the host-side
        form the ``W^{T_con}`` power hoist multiplies in."""
        import scipy.sparse as sp
        idx = np.arange(self.n, dtype=np.int32)
        return sp.csr_matrix(
            (np.concatenate([self.vals, self.diag]),
             (np.concatenate([self.rows, idx]),
              np.concatenate([self.cols, idx]))), shape=self.shape)

    def power(self, T: int, max_fill_factor: float = 8.0):
        """``W^T`` as a SparseWeights, or ``None`` when the power's
        fill-in exceeds ``max_fill_factor × max(nnz, L)`` stored entries
        — the budget at which hoisting T_con rounds into one product
        stops paying and the caller should keep the per-round sparse
        product instead (graceful degradation)."""
        if T < 1:
            raise ValueError(f"power needs T >= 1, got {T}")
        budget = max_fill_factor * max(self.nnz, self.n)
        A = self.scipy_csr()
        P = A
        for _ in range(T - 1):
            P = (P @ A).tocsr()
            P.eliminate_zeros()
            if P.nnz > budget:
                return None
        P = P.tocoo()
        off = P.row != P.col
        diag = np.zeros(self.n)
        diag[P.row[~off]] = P.data[~off]
        return SparseWeights(n=self.n, rows=P.row[off].astype(np.int32),
                             cols=P.col[off].astype(np.int32),
                             vals=P.data[off].astype(np.float64), diag=diag)


def _graph_csr(graph) -> tuple[SparseGraph, np.ndarray, np.ndarray]:
    """(sparse graph, COO rows, COO cols) for either graph flavour."""
    sg = graph if isinstance(graph, SparseGraph) else graph.to_sparse()
    return sg, sg._row_idx().astype(np.int32), sg.col_idx


def equal_neighbor_weights_sparse(graph) -> SparseWeights:
    """Sparse :func:`equal_neighbor_weights`: W_gj = 1/deg_g on edges,
    diagonal 1 − rowsum (zero except isolated nodes)."""
    sg, rows, cols = _graph_csr(graph)
    deg = np.maximum(sg.degrees.astype(np.float64), 1.0)
    vals = 1.0 / deg[rows]
    diag = 1.0 - np.bincount(rows, weights=vals, minlength=sg.n_nodes)
    return SparseWeights(n=sg.n_nodes, rows=rows, cols=cols, vals=vals,
                         diag=diag)


def metropolis_weights_sparse(graph) -> SparseWeights:
    """Sparse :func:`metropolis_weights`: W_ij = 1/(1+max(d_i, d_j)) on
    edges — computed per edge from the CSR degrees, O(E)."""
    sg, rows, cols = _graph_csr(graph)
    deg = sg.degrees.astype(np.float64)
    vals = 1.0 / (1.0 + np.maximum(deg[rows], deg[cols]))
    diag = 1.0 - np.bincount(rows, weights=vals, minlength=sg.n_nodes)
    return SparseWeights(n=sg.n_nodes, rows=rows, cols=cols, vals=vals,
                         diag=diag)


def lazy_weights_sparse(graph, beta: float = 0.5) -> SparseWeights:
    """Sparse :func:`lazy_weights`: (1−β)I + β·W_metropolis."""
    w = metropolis_weights_sparse(graph)
    return SparseWeights(n=w.n, rows=w.rows, cols=w.cols,
                         vals=beta * w.vals,
                         diag=(1.0 - beta) + beta * w.diag)


def circulant_weights_sparse(L: int, shifts: tuple[int, ...] = (-1, 1),
                             self_weight: float | None = None
                             ) -> SparseWeights:
    """Sparse :func:`circulant_weights`: per-shift uniform weights,
    colliding shifts accumulated exactly like the dense builder (shifts
    that are ≡ 0 mod L fold into the diagonal)."""
    k = len(shifts)
    sw = self_weight if self_weight is not None else 1.0 / (k + 1)
    wn = (1.0 - sw) / k if k else 0.0
    i = np.arange(L, dtype=np.int64)
    rows = np.concatenate([i for _ in shifts]) if k else i[:0]
    cols = np.concatenate([(i + s) % L for s in shifts]) if k else i[:0]
    diag = np.full(L, float(sw))
    off = rows != cols
    diag += np.bincount(rows[~off], minlength=L) * wn
    key, inv = np.unique(rows[off] * L + cols[off], return_inverse=True)
    vals = np.bincount(inv, minlength=key.size) * wn
    return SparseWeights(n=L, rows=(key // L).astype(np.int32),
                         cols=(key % L).astype(np.int32), vals=vals,
                         diag=diag)


def neighbor_average_weights_sparse(graph) -> SparseWeights:
    """Sparse DGD neighbour average M = D⁻¹A (zero diagonal) — the
    sparse counterpart of
    :func:`repro.distributed.consensus.neighbor_average_matrix`."""
    sg, rows, cols = _graph_csr(graph)
    deg = np.maximum(sg.degrees.astype(np.float64), 1.0)
    return SparseWeights(n=sg.n_nodes, rows=rows, cols=cols,
                         vals=1.0 / deg[rows],
                         diag=np.zeros(sg.n_nodes))


def equal_neighbor_weights(graph: Graph) -> np.ndarray:
    """The paper's AGREE update written as a mixing matrix:
    W = I - D^{-1} L_graph  (row-stochastic always; doubly stochastic iff the
    graph is regular)."""
    # reprolint: allow=RL002 — dense-Graph weights builder; sparse graphs use neighbor_average_weights_sparse
    a = graph.adj.astype(np.float64)
    deg = np.maximum(a.sum(axis=1), 1.0)
    w = a / deg[:, None]
    w[np.diag_indices_from(w)] = 1.0 - w.sum(axis=1)
    return w


def metropolis_weights(graph: Graph) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric & doubly stochastic on any
    connected graph.  W_ij = 1/(1+max(d_i,d_j)) for edges."""
    # reprolint: allow=RL002 — dense-Graph weights builder; sparse graphs use metropolis_weights_sparse
    a = graph.adj.astype(np.float64)
    deg = a.sum(axis=1)
    L = graph.n_nodes
    w = np.zeros((L, L))
    ii, jj = np.nonzero(np.triu(a, 1))
    for i, j in zip(ii, jj):
        w[i, j] = w[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    w[np.diag_indices_from(w)] = 1.0 - w.sum(axis=1)
    return w


def lazy_weights(graph: Graph, beta: float = 0.5) -> np.ndarray:
    """Lazy variant W_lazy = (1-beta) I + beta W_metropolis — guarantees
    gamma < 1 even on bipartite graphs."""
    w = metropolis_weights(graph)
    return (1.0 - beta) * np.eye(graph.n_nodes) + beta * w


def circulant_weights(L: int, shifts: tuple[int, ...] = (-1, 1),
                      self_weight: float | None = None) -> np.ndarray:
    """Circulant mixing matrix on a ring-like topology: node i averages with
    i+s for s in shifts.  This is the form the TPU runtime implements with
    ``lax.ppermute`` (each shift = one collective-permute); keeping the
    simulator and the runtime numerically identical.

    Default: symmetric ring with weights (1-sw)/len(shifts) per neighbour.
    """
    k = len(shifts)
    sw = self_weight if self_weight is not None else 1.0 / (k + 1)
    wn = (1.0 - sw) / k
    w = np.eye(L) * sw
    for s in shifts:
        idx = (np.arange(L) + s) % L
        w[np.arange(L), idx] += wn
    return w


def gamma(w: np.ndarray) -> float:
    """gamma(W) := max(|λ₂|, |λ_L|) — the consensus contraction factor."""
    ev = np.linalg.eigvals(w)
    ev = np.sort(np.abs(ev))[::-1]
    if len(ev) == 1:
        return 0.0
    return float(ev[1])


def is_doubly_stochastic(w: np.ndarray, tol: float = 1e-9) -> bool:
    return (np.all(w >= -tol)
            and np.allclose(w.sum(axis=0), 1.0, atol=1e-8)
            and np.allclose(w.sum(axis=1), 1.0, atol=1e-8))
