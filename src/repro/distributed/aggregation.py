"""Aggregation strategies — the paper's technique as a first-class feature
of the deep-net trainer.

Every strategy operates on pytrees whose leaves carry a leading **node
axis** (n_nodes, ...).  In the production mesh that axis is sharded over
'data' (single-pod: 16 nodes) or ('pod',) (multi-pod: pods-as-nodes, the
setting where inter-node links — DCN — really are the expensive resource,
exactly the paper's premise).  Gossip rounds lower to collective-permute
chains; 'allreduce' lowers to one all-reduce (the fusion-center baseline).

| strategy    | paper algorithm        | comm per step              |
|-------------|------------------------|----------------------------|
| allreduce   | AltGDmin [10]          | 1 all-reduce (exact mean)  |
| consensus   | Dec-AltGDmin [9]       | T_con permutes of *grads*  |
| diffusion   | Dif-AltGDmin (paper)   | T_con permutes of *params* |
| dgd         | DGD-variant (Exp. 1)   | 1 permute of params        |
| topk        | Dif-AltGDmin + top-k   | T_con permutes, k entries  |
| quantized   | Dif-AltGDmin + quant   | T_con permutes, low-bit    |
| local       | no communication       | —                          |

The compressed strategies are the trainer-side counterparts of the
``topk_gossip`` / ``quantized_gossip`` CombineRules: the exchange runs
the stateless form of the compressor (top-k magnitude sparsification of
the sent copy; bfloat16 wire cast), and :func:`comm_bytes_per_step`
prices the step from the rule's actual :class:`CommSignature` — the
compact payload, not the dense ``wire_dtype`` scalar count.  (The
error-feedback state the consensus-layer rules carry lives in the
solver scan; the trainer hooks are stateless by design.)

The *federated carve-out*: parameter groups matching ``local_patterns``
(task heads, embeddings) are never communicated — they remain node-local,
mirroring the paper's B_g that never leaves the node.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp

from repro.distributed import consensus as _consensus
from repro.distributed.gossip import roll_gossip

STRATEGIES = ("allreduce", "diffusion", "consensus", "dgd", "topk",
              "quantized", "local")

# every strategy is one CombineRule applied to grads or params; the rule's
# CommSignature prices the wire cost (comm_bytes_per_step below)
RULE_FOR_STRATEGY = {"allreduce": "central", "diffusion": "gossip",
                     "consensus": "gossip", "dgd": "neighbor",
                     "topk": "topk_gossip", "quantized": "quantized_gossip",
                     "local": "none"}


@dataclasses.dataclass(frozen=True)
class AggregationConfig:
    strategy: str = "diffusion"
    t_con: int = 1                   # gossip rounds per step
    shifts: tuple = (-1, 1)          # ring topology by default
    self_weight: float | None = None
    local_patterns: tuple = ()       # param path regexes kept node-local
    wire_dtype: str | None = None    # cast to this dtype for the exchange
    #   (e.g. "bfloat16": halves gossip bytes; mixing still in f32 —
    #   a beyond-paper §Perf knob)
    compression_k: int = 0           # topk: entries kept per leaf (0 → ¼)
    compression: str | None = None   # quantized: wire format (None → bf16)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"choose from {STRATEGIES}")
        if self.compression_k and self.strategy != "topk":
            raise ValueError("compression_k only applies to the 'topk' "
                             f"strategy, not {self.strategy!r}")
        if self.compression is not None and self.strategy != "quantized":
            raise ValueError("compression only applies to the 'quantized' "
                             f"strategy, not {self.strategy!r}")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _split_local(tree, patterns):
    """Mask: True leaves are communicated, False stay local."""
    if not patterns:
        return jax.tree.map(lambda _: True, tree)
    regs = [re.compile(p) for p in patterns]
    return jax.tree_util.tree_map_with_path(
        lambda path, _: not any(r.search(_path_str(path)) for r in regs),
        tree)


def _mix(tree, mask, mix_fn, wire_dtype=None):
    if wire_dtype is not None:
        wd = jnp.dtype(wire_dtype)
        send = jax.tree.map(lambda x: x.astype(wd), tree)
        mixed = mix_fn(send)
        mixed = jax.tree.map(lambda m, x: m.astype(x.dtype), mixed, tree)
    else:
        mixed = mix_fn(tree)
    return jax.tree.map(lambda m, a, b: a if m else b, mask, mixed, tree)


def _topk_sparsify(tree, k: int):
    """Stateless top-k compressor for the trainer exchange: every node
    keeps only its ``k`` largest-magnitude entries per leaf (0 → a
    quarter of the leaf, the ``topk_gossip`` rule's ``d // 4`` default)
    and sends zeros elsewhere.  The consensus-layer rule additionally
    carries error feedback in the solver scan state; the trainer hook is
    its memoryless form."""
    def spars(x):
        flat = x.reshape(x.shape[0], -1)          # (nodes, m)
        m = flat.shape[1]
        kk = min(int(k) or max(1, m // 4), m)
        if kk == m:
            return x
        kth = jax.lax.top_k(jnp.abs(flat), kk)[0][:, -1:]
        return jnp.where(jnp.abs(flat) >= kth, flat, 0.0).reshape(x.shape)
    return jax.tree.map(spars, tree)


def _node_mean(tree):
    """Exact mean over the node axis, broadcast back (→ all-reduce)."""
    return jax.tree.map(_consensus.node_mean, tree)


def aggregate_gradients(grads, agg: AggregationConfig):
    """Pre-optimizer gradient communication (allreduce / consensus)."""
    mask = _split_local(grads, agg.local_patterns)
    if agg.strategy == "allreduce":
        return _mix(grads, mask, _node_mean, agg.wire_dtype)
    if agg.strategy == "consensus":
        return _mix(grads, mask,
                    lambda t: roll_gossip(t, agg.t_con, agg.shifts,
                                          agg.self_weight),
                    agg.wire_dtype)
    return grads          # diffusion / dgd / local: no grad communication


def aggregate_params(params, agg: AggregationConfig):
    """Post-optimizer parameter communication (diffusion / dgd)."""
    mask = _split_local(params, agg.local_patterns)
    if agg.strategy in ("diffusion", "topk", "quantized"):
        wire = agg.wire_dtype
        if agg.strategy == "quantized" and wire is None:
            wire = "bfloat16"        # the rule's default bf16 wire format
        def mix_fn(t):
            if agg.strategy == "topk":
                t = _topk_sparsify(t, agg.compression_k)
            return roll_gossip(t, agg.t_con, agg.shifts, agg.self_weight)
        return _mix(params, mask, mix_fn, wire)
    if agg.strategy == "dgd":
        # neighbour average EXCLUDING self (paper Experiment 1 formula)
        return _mix(params, mask,
                    lambda t: roll_gossip(t, 1, agg.shifts,
                                          self_weight=0.0),
                    agg.wire_dtype)
    return params         # allreduce / consensus / local


def pre_update(grads, agg: AggregationConfig):
    return aggregate_gradients(grads, agg)


def post_update(params, agg: AggregationConfig):
    return aggregate_params(params, agg)


def comm_bytes_per_step(n_params_communicated: int, itemsize: int,
                        agg: AggregationConfig, n_nodes: int) -> int:
    """Analytic per-step communication volume (for the benchmark tables):
    bytes sent per node per step, from the strategy's CombineRule
    signature (gossip: t_con rounds × deg messages; neighbor: one
    exchange; central: the ring all-reduce volume).

    The payload context (the communicated entry count plus the config's
    compression knobs) is forwarded to the rule's ``signature``, so the
    compressed strategies price their actual wire format — top-k: k
    values + k indices per round; quantized: bf16/int8 entries — instead
    of the dense ``n_params × itemsize`` product.  Base rules ignore the
    context (see :meth:`CombineRule.signature`)."""
    sig = _consensus.get_rule(RULE_FOR_STRATEGY[agg.strategy]).signature(
        agg.t_con, d=n_params_communicated, r=1,
        compression_k=agg.compression_k, compression=agg.compression)
    return sig.bytes_per_iter(n_params_communicated, itemsize, n_nodes,
                              degree=len(agg.shifts))
