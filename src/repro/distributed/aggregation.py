"""Aggregation strategies — the paper's technique as a first-class feature
of the deep-net trainer.

Every strategy operates on pytrees whose leaves carry a leading **node
axis** (n_nodes, ...).  In the production mesh that axis is sharded over
'data' (single-pod: 16 nodes) or ('pod',) (multi-pod: pods-as-nodes, the
setting where inter-node links — DCN — really are the expensive resource,
exactly the paper's premise).  Gossip rounds lower to collective-permute
chains; 'allreduce' lowers to one all-reduce (the fusion-center baseline).

| strategy    | paper algorithm        | comm per step              |
|-------------|------------------------|----------------------------|
| allreduce   | AltGDmin [10]          | 1 all-reduce (exact mean)  |
| consensus   | Dec-AltGDmin [9]       | T_con permutes of *grads*  |
| diffusion   | Dif-AltGDmin (paper)   | T_con permutes of *params* |
| dgd         | DGD-variant (Exp. 1)   | 1 permute of params        |
| local       | no communication       | —                          |

The *federated carve-out*: parameter groups matching ``local_patterns``
(task heads, embeddings) are never communicated — they remain node-local,
mirroring the paper's B_g that never leaves the node.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp

from repro.distributed import consensus as _consensus
from repro.distributed.gossip import roll_gossip

STRATEGIES = ("allreduce", "diffusion", "consensus", "dgd", "local")

# every strategy is one CombineRule applied to grads or params; the rule's
# CommSignature prices the wire cost (comm_bytes_per_step below)
RULE_FOR_STRATEGY = {"allreduce": "central", "diffusion": "gossip",
                     "consensus": "gossip", "dgd": "neighbor",
                     "local": "none"}


@dataclasses.dataclass(frozen=True)
class AggregationConfig:
    strategy: str = "diffusion"
    t_con: int = 1                   # gossip rounds per step
    shifts: tuple = (-1, 1)          # ring topology by default
    self_weight: float | None = None
    local_patterns: tuple = ()       # param path regexes kept node-local
    wire_dtype: str | None = None    # cast to this dtype for the exchange
    #   (e.g. "bfloat16": halves gossip bytes; mixing still in f32 —
    #   a beyond-paper §Perf knob)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"choose from {STRATEGIES}")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _split_local(tree, patterns):
    """Mask: True leaves are communicated, False stay local."""
    if not patterns:
        return jax.tree.map(lambda _: True, tree)
    regs = [re.compile(p) for p in patterns]
    return jax.tree_util.tree_map_with_path(
        lambda path, _: not any(r.search(_path_str(path)) for r in regs),
        tree)


def _mix(tree, mask, mix_fn, wire_dtype=None):
    if wire_dtype is not None:
        wd = jnp.dtype(wire_dtype)
        send = jax.tree.map(lambda x: x.astype(wd), tree)
        mixed = mix_fn(send)
        mixed = jax.tree.map(lambda m, x: m.astype(x.dtype), mixed, tree)
    else:
        mixed = mix_fn(tree)
    return jax.tree.map(lambda m, a, b: a if m else b, mask, mixed, tree)


def _node_mean(tree):
    """Exact mean over the node axis, broadcast back (→ all-reduce)."""
    return jax.tree.map(_consensus.node_mean, tree)


def aggregate_gradients(grads, agg: AggregationConfig):
    """Pre-optimizer gradient communication (allreduce / consensus)."""
    mask = _split_local(grads, agg.local_patterns)
    if agg.strategy == "allreduce":
        return _mix(grads, mask, _node_mean, agg.wire_dtype)
    if agg.strategy == "consensus":
        return _mix(grads, mask,
                    lambda t: roll_gossip(t, agg.t_con, agg.shifts,
                                          agg.self_weight),
                    agg.wire_dtype)
    return grads          # diffusion / dgd / local: no grad communication


def aggregate_params(params, agg: AggregationConfig):
    """Post-optimizer parameter communication (diffusion / dgd)."""
    mask = _split_local(params, agg.local_patterns)
    if agg.strategy == "diffusion":
        return _mix(params, mask,
                    lambda t: roll_gossip(t, agg.t_con, agg.shifts,
                                          agg.self_weight),
                    agg.wire_dtype)
    if agg.strategy == "dgd":
        # neighbour average EXCLUDING self (paper Experiment 1 formula)
        return _mix(params, mask,
                    lambda t: roll_gossip(t, 1, agg.shifts,
                                          self_weight=0.0),
                    agg.wire_dtype)
    return params         # allreduce / consensus / local


def pre_update(grads, agg: AggregationConfig):
    return aggregate_gradients(grads, agg)


def post_update(params, agg: AggregationConfig):
    return aggregate_params(params, agg)


def comm_bytes_per_step(n_params_communicated: int, itemsize: int,
                        agg: AggregationConfig, n_nodes: int) -> int:
    """Analytic per-step communication volume (for the benchmark tables):
    bytes sent per node per step, from the strategy's CombineRule
    signature (gossip: t_con rounds × deg messages; neighbor: one
    exchange; central: the ring all-reduce volume)."""
    sig = _consensus.get_rule(RULE_FOR_STRATEGY[agg.strategy]
                              ).signature(agg.t_con)
    return sig.bytes_per_iter(n_params_communicated, itemsize, n_nodes,
                              degree=len(agg.shifts))
