"""Representation lifecycle: publish U snapshots during training, hot-swap
them into the serving engine.

The training side (``run_experiment(..., checkpoint_every=k,
checkpoint_dir=...)``) publishes the node bases every k outer iterations
through :func:`publish_representation`: the deployable single basis
U = QR(mean_g U_g) — the consensus representative all nodes are
contracting toward — lands next to the raw (L, d, r) stack in one
crash-safe checkpoint (see :mod:`repro.checkpoint.store`).

The serving side polls :class:`HotSwapSource` between batches: it
re-reads ``latest_step`` (cheap — one listdir) and restores only when a
NEWER complete step appeared, so the server tracks a drifting U while
consensus keeps refining it — the continual-learning mode where
b_new recovery error falls as fresher U's publish.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.spectral import _qr_pos


def deployable_basis(U_nodes):
    """The single served basis from a stack of node bases: orthonormalize
    the node mean (sign-fixed QR).  A (d, r) input passes through the
    same retraction, so both layouts publish an orthonormal U."""
    U = jnp.asarray(U_nodes)
    if U.ndim == 3:
        U = jnp.mean(U, axis=0)
    return _qr_pos(U)[0]


def publish_representation(directory: str, step: int, U_nodes) -> str:
    """Write checkpoint ``step``: {"U": deployable (d, r), "U_nodes":
    raw stack}.  Crash-safe via the store's stage-then-rename."""
    U_nodes = jnp.asarray(U_nodes)
    tree = {"U": deployable_basis(U_nodes),
            "U_nodes": U_nodes if U_nodes.ndim == 3 else U_nodes[None]}
    return save_checkpoint(directory, step, tree)


def load_representation(directory: str, step: int, *, d: int, r: int,
                        dtype=jnp.float32):
    """Restore just the deployable U of checkpoint ``step``."""
    like = {"U": jnp.zeros((d, r), dtype)}
    return restore_checkpoint(directory, step, like)["U"]


class RepresentationPublisher:
    """Cadenced publisher: ``maybe(step, U_nodes)`` writes every
    ``every`` steps (and always at step 0); ``published`` records the
    steps written, in order."""

    def __init__(self, directory: str, *, every: int = 1):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.directory = directory
        self.every = int(every)
        self.published: list = []

    def maybe(self, step: int, U_nodes) -> bool:
        if step % self.every and step != 0:
            return False
        self.publish(step, U_nodes)
        return True

    def publish(self, step: int, U_nodes) -> str:
        path = publish_representation(self.directory, step, U_nodes)
        self.published.append(int(step))
        return path


class HotSwapSource:
    """Poll-based reader for the serving loop.

    ``poll()`` returns ``(step, U)`` when a complete checkpoint newer
    than the last one served exists, else None.  A partially written
    save is invisible (``latest_step`` requires the manifest, which
    lands atomically), so the server can poll mid-training safely."""

    def __init__(self, directory: str, *, d: int, r: int,
                 dtype=jnp.float32):
        self.directory = directory
        self.d, self.r = int(d), int(r)
        self.dtype = dtype
        self.last_step: int | None = None

    def poll(self):
        step = latest_step(self.directory)
        if step is None or (self.last_step is not None
                            and step <= self.last_step):
            return None
        U = load_representation(self.directory, step, d=self.d, r=self.r,
                                dtype=self.dtype)
        self.last_step = step
        return step, U
