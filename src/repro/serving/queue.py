"""Admission control + deadline batching for the serving engine.

The serving loop is a discrete-event simulation of a single-server
batching frontend, the standard datacenter shape (max_batch × max_wait
deadline batcher over a bounded FIFO):

  * requests arrive on a (seeded) Poisson process, carrying few-shot
    personalization data (X_new, y_new) drawn from the paper's model
    y = X U* b* + noise — the closed-loop generator below;
  * a batch launches when ``max_batch`` requests are queued OR the
    oldest queued request has waited ``max_wait_s``, whichever first
    (never before the server is free — one outstanding batch);
  * the queue is bounded: an arrival that lands on a full queue is
    SHED (counted, never silently dropped);
  * between batches the loop polls an optional hot-swap source for a
    fresher representation (the drifting-U continual mode).

Time is virtual for arrivals/queueing (deterministic, seeded) while the
service time of each batch is either MEASURED wall-clock of the actual
packed solve (the benchmark mode) or a supplied model (the deterministic
test mode).  Per-request telemetry — queue wait, end-to-end latency,
batch size, the U version that served it, and the recovery error when
ground truth is attached — comes back as :class:`ServeRecord` rows.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import numpy as np


@dataclasses.dataclass
class ServeRequest:
    """One few-shot personalization request."""
    rid: int
    X: np.ndarray                    # (T_new, d) user design
    y: np.ndarray                    # (T_new,) responses
    t_arrival: float                 # seconds on the virtual clock
    theta_star: Optional[np.ndarray] = None   # (d,) ground truth, if known


@dataclasses.dataclass
class ServeRecord:
    """Per-request telemetry emitted by :func:`run_closed_loop`."""
    rid: int
    t_arrival: float
    t_launch: float
    t_done: float
    batch_size: int
    version: int                     # U checkpoint step that served it
    err: Optional[float] = None      # ||Ub̂ − θ*|| / ||θ*||

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def queue_wait(self) -> float:
        return self.t_launch - self.t_arrival


@dataclasses.dataclass
class ServeReport:
    """One closed-loop run: telemetry + counters."""
    records: list
    n_shed: int
    depth_trace: list                # queue depth sampled at each launch
    batch_sizes: list

    def latency_percentiles(self, qs=(50, 99)):
        lat = np.array([r.latency for r in self.records])
        return {f"p{q}": float(np.percentile(lat, q)) for q in qs}

    @property
    def mean_err(self) -> float:
        errs = [r.err for r in self.records if r.err is not None]
        return float(np.mean(errs)) if errs else float("nan")


class RequestGenerator:
    """Seeded closed-loop load: new users drawn from the paper's model.

    Each request is a fresh task θ* = U* b* with b* ~ N(0, I_r), a
    Gaussian design X ∈ R^{T_new × d}, and y = X θ* + noise.  ``t_new``
    may be an int (uniform) or a sequence to sample from (heterogeneous
    few-shot budgets — the ragged-batch path).  Arrivals are Poisson at
    ``rate_hz`` on the virtual clock."""

    def __init__(self, U_star, *, t_new=16, rate_hz: float = 200.0,
                 noise_std: float = 0.0, seed: int = 0):
        self.U_star = np.asarray(U_star)
        self.t_new = (t_new,) if isinstance(t_new, int) else tuple(t_new)
        self.rate_hz = float(rate_hz)
        self.noise_std = float(noise_std)
        self.rng = np.random.default_rng(seed)
        self._clock = 0.0
        self._next_rid = 0

    def generate(self, n: int) -> list:
        d, r = self.U_star.shape
        out = []
        for _ in range(n):
            self._clock += self.rng.exponential(1.0 / self.rate_hz)
            t_i = int(self.rng.choice(self.t_new))
            b_star = self.rng.standard_normal(r)
            theta = self.U_star @ b_star
            X = self.rng.standard_normal((t_i, d))
            y = X @ theta
            if self.noise_std > 0:
                y = y + self.noise_std * self.rng.standard_normal(t_i)
            out.append(ServeRequest(rid=self._next_rid, X=X, y=y,
                                    t_arrival=self._clock,
                                    theta_star=theta))
            self._next_rid += 1
        return out


def run_closed_loop(engine, requests, *, max_batch: int | None = None,
                    max_wait_s: float = 2e-3, queue_capacity: int = 256,
                    swap_source=None,
                    service_time: Optional[Callable[[int], float]] = None
                    ) -> ServeReport:
    """Drive ``engine`` through the deadline batcher over ``requests``.

    ``service_time``: None → measure the wall-clock of each packed solve
    (benchmark mode); a callable ``batch_size -> seconds`` makes the
    whole simulation deterministic (test mode; the solve still runs so
    recovery errors are real).  ``swap_source`` (an object with
    ``poll() -> (step, U) | None``, e.g.
    :class:`repro.serving.publisher.HotSwapSource`) is consulted before
    each batch launch — the drifting-U mode."""
    max_batch = engine.max_batch if max_batch is None else max_batch
    if max_batch > engine.max_batch:
        raise ValueError(f"max_batch={max_batch} exceeds the engine's "
                         f"packed capacity {engine.max_batch}")
    if queue_capacity < max_batch:
        raise ValueError(f"queue_capacity={queue_capacity} cannot hold "
                         f"one full batch of {max_batch}")
    arr = sorted(requests, key=lambda q: q.t_arrival)
    q: deque = deque()
    i = 0                       # next arrival index
    t_free = 0.0                # server free time
    n_shed = 0
    records, depth_trace, batch_sizes = [], [], []

    def admit_until(t, shed_overflow=True):
        nonlocal i, n_shed
        while i < len(arr) and arr[i].t_arrival <= t:
            if len(q) < queue_capacity:
                q.append(arr[i])
            elif shed_overflow:
                n_shed += 1
            i += 1

    while i < len(arr) or q:
        if not q:                       # idle: jump to the next arrival
            admit_until(arr[i].t_arrival)
        # batching window: launch at max_batch or the head's deadline
        if len(q) < max_batch:
            deadline = max(t_free, q[0].t_arrival + max_wait_s)
            while len(q) < max_batch and i < len(arr) \
                    and arr[i].t_arrival <= deadline:
                q.append(arr[i])
                i += 1
        # full → launch the moment the max_batch-th request landed (or
        # the server freed); short → launch at the head's deadline
        t_launch = (max(t_free, q[max_batch - 1].t_arrival)
                    if len(q) >= max_batch else deadline)
        batch = [q.popleft() for _ in range(min(max_batch, len(q)))]
        depth_trace.append(len(q))
        batch_sizes.append(len(batch))

        if swap_source is not None:     # drifting U: between batches only
            fresh = swap_source.poll()
            if fresh is not None:
                step, U = fresh
                engine.update_representation(U, version=step)

        t0 = time.perf_counter()
        B, theta, version = engine.solve([b.X for b in batch],
                                         [b.y for b in batch])
        jax.block_until_ready(B)
        measured = time.perf_counter() - t0
        service = measured if service_time is None \
            else float(service_time(len(batch)))
        t_done = t_launch + service
        t_free = t_done
        theta = np.asarray(theta)
        for j, req in enumerate(batch):
            err = None
            if req.theta_star is not None:
                err = float(np.linalg.norm(theta[j] - req.theta_star)
                            / max(np.linalg.norm(req.theta_star), 1e-30))
            records.append(ServeRecord(
                rid=req.rid, t_arrival=req.t_arrival, t_launch=t_launch,
                t_done=t_done, batch_size=len(batch), version=version,
                err=err))
        # arrivals that landed while the batch was in flight
        admit_until(t_done)

    records.sort(key=lambda rec: rec.rid)
    return ServeReport(records=records, n_shed=n_shed,
                       depth_trace=depth_trace, batch_sizes=batch_sizes)
