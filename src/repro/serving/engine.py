"""Batched min-B inference: the serving-side solve.

The product story of the paper is that the learned low-rank basis U
turns a brand-new user's d-dimensional regression into a cheap
r-dimensional one: given the user's few-shot data (X_new, y_new), the
personalized head is b_new = (X_new U)† y_new — exactly the min-B step
of Algorithm 3, with one shared U instead of per-node bases.

:class:`ServingEngine` treats that solve as a request workload.  R
in-flight requests are padded/packed into ONE dispatch of the training
engine's min-B path (:meth:`repro.core.engine.AltgdminEngine.minimize_B`
— the streamed-A ``node_task_gram`` kernel with in-batch Cholesky on the
pallas backends, the ``ref_minimize_B`` oracle on xla-ref), so serving
is bit-consistent with the training-side fold solve by construction.

Packing is exact, not approximate:

  * ragged sample counts (heterogeneous T_new) are right-padded with
    ZERO rows of X and y — a zero row contributes nothing to the Gram
    AᵀA or to Aᵀy, so the padded solve is bit-identical to the unpadded
    one (pinned in tests/test_serving.py);
  * a short batch (R < max_batch) is padded with dummy slots that
    replicate request 0's design and carry y = 0 — their solution is
    exactly 0 and the Gram stays SPD (no NaN lanes), while the real
    slots are untouched bit-for-bit.

Fixed padded shapes (``max_batch`` slots × bucketed T_new) mean the jit
cache holds one executable per (batch-capacity, sample-bucket) pair, not
one per ragged request mix.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import AltgdminEngine


def pack_requests(X_list, y_list, *, max_batch: int, pad_n_to: int = 8,
                  dtype=None):
    """Pad/pack R ragged requests into fixed-shape arrays.

    X_list[i]: (T_i, d); y_list[i]: (T_i,).  Returns
    (X (max_batch, n_pad, d), y (max_batch, n_pad), R) where n_pad is
    the max T_i rounded up to a multiple of ``pad_n_to``.  Slots ≥ R
    replicate request 0's design with zero responses (solution exactly
    0, Gram SPD)."""
    R = len(X_list)
    if R == 0:
        raise ValueError("pack_requests needs at least one request")
    if R > max_batch:
        raise ValueError(f"got {R} requests but max_batch={max_batch}; "
                         f"the admission queue must cap batches")
    d = np.shape(X_list[0])[-1]
    n_pad = -(-max(np.shape(x)[0] for x in X_list) // pad_n_to) * pad_n_to
    dt = dtype or jnp.asarray(X_list[0]).dtype
    X = np.zeros((max_batch, n_pad, d), dt)
    y = np.zeros((max_batch, n_pad), dt)
    for i, (Xi, yi) in enumerate(zip(X_list, y_list)):
        t = np.shape(Xi)[0]
        if np.shape(yi)[0] != t:
            raise ValueError(f"request {i}: X has {t} rows but y has "
                             f"{np.shape(yi)[0]}")
        X[i, :t] = np.asarray(Xi, dt)
        y[i, :t] = np.asarray(yi, dt)
    for i in range(R, max_batch):          # dummy slots: SPD Gram, b = 0
        X[i] = X[0]
    return jnp.asarray(X), jnp.asarray(y), R


class ServingEngine:
    """The frozen-or-drifting-U request solver.

    One instance holds the current representation U (d, r) plus a
    :class:`AltgdminEngine` backend binding; :meth:`solve` is the
    request-facing entry (ragged list in, per-request b_new out) and
    :meth:`solve_packed` the fixed-shape hot path the benchmark drives
    directly.  ``update_representation`` hot-swaps U between batches
    (the drifting-U continual mode); the swap is lock-guarded so a
    publisher thread can push while the serving loop drains.
    """

    def __init__(self, U, *, max_batch: int = 32, backend: str | None = None,
                 blk_d: int = 256, pad_n_to: int = 8, version: int = 0):
        self.engine = AltgdminEngine(backend, blk_d=blk_d)
        self.max_batch = int(max_batch)
        self.pad_n_to = int(pad_n_to)
        self._lock = threading.Lock()
        self.n_dispatches = 0
        self.n_requests = 0
        self.update_representation(U, version=version)
        # one jitted closure; U rides as an argument so hot swaps hit
        # the same executable (shapes/dtype unchanged)
        self._solve = jax.jit(self._solve_impl)

    # ------------------------------------------------------------ U life

    def update_representation(self, U, *, version: int | None = None):
        """Hot-swap the representation (e.g. a fresher checkpoint)."""
        U = jnp.asarray(U)
        if U.ndim != 2:
            raise ValueError(f"serving wants a single (d, r) basis, got "
                             f"shape {U.shape}")
        with self._lock:
            self.U = U
            if version is not None:
                self.version = int(version)

    @property
    def d(self) -> int:
        return self.U.shape[0]

    @property
    def r(self) -> int:
        return self.U.shape[1]

    # ------------------------------------------------------------ solve

    def _solve_impl(self, U, X, y):
        # the training-side min-B path verbatim: one node, R tasks
        return self.engine.minimize_B(U[None], X[None], y[None])[0]

    def solve_packed(self, X, y):
        """Fixed-shape hot path.  X: (R, n, d); y: (R, n) → b (R, r).
        Rows beyond a request's true T_new must be zero (exact padding);
        bit-consistent with the training engine's fold solve."""
        with self._lock:
            U, version = self.U, self.version
        B = self._solve(U, X, y)
        self.n_dispatches += 1
        self.n_requests += X.shape[0]
        return B, version

    def solve(self, X_list, y_list):
        """Ragged request list in, per-request solutions out.

        Returns (B (R, r), theta (R, d), version): b_new per request and
        the personalized regressors θ̂ = U b_new (the basis-invariant
        quantity a drifting U is scored on)."""
        for i, Xi in enumerate(X_list):
            if np.shape(Xi)[0] < self.r:
                raise ValueError(
                    f"request {i} has T_new={np.shape(Xi)[0]} < r={self.r} "
                    f"samples; the r-dimensional system is underdetermined")
        X, y, R = pack_requests(X_list, y_list, max_batch=self.max_batch,
                                pad_n_to=self.pad_n_to, dtype=self.U.dtype)
        B_full, version = self.solve_packed(X, y)
        B = B_full[:R]
        theta = B @ self.U.T
        self.n_requests -= self.max_batch - R      # count real ones only
        return B, theta, version
