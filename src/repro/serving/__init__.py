"""Few-shot personalization serving — the paper's min-B step as an
online inference workload over a checkpointed, drifting representation.

Layers (bottom up):

  * :mod:`repro.serving.engine`    — :class:`ServingEngine`, the packed
    batched min-B solve (one training-engine dispatch per batch);
  * :mod:`repro.serving.queue`     — deadline batcher + bounded queue +
    seeded closed-loop load (:func:`run_closed_loop`);
  * :mod:`repro.serving.publisher` — U snapshots on the crash-safe
    checkpoint store, and the server's hot-swap reader.
"""
from repro.serving.engine import ServingEngine, pack_requests
from repro.serving.publisher import (HotSwapSource, RepresentationPublisher,
                                     deployable_basis, load_representation,
                                     publish_representation)
from repro.serving.queue import (RequestGenerator, ServeRecord, ServeReport,
                                 ServeRequest, run_closed_loop)

__all__ = [
    "ServingEngine", "pack_requests",
    "RequestGenerator", "ServeRequest", "ServeRecord", "ServeReport",
    "run_closed_loop",
    "RepresentationPublisher", "HotSwapSource", "publish_representation",
    "load_representation", "deployable_basis",
]
