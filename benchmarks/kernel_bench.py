"""Kernel micro-benchmarks: wall-time of the jnp production paths on CPU
(the Pallas kernels run in interpret mode here, so CPU timings of them are
meaningless — on-TPU projections come from the roofline instead; this
table tracks the *reference* path and validates kernel-vs-ref agreement
as a benchmark-time canary)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    fn(*args)            # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6    # µs


def bench_kernels():
    rows = []
    key = jax.random.PRNGKey(0)

    # chunked attention (production jnp path) at a prefill-ish shape
    B, S, H, D = 1, 1024, 8, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    from repro.models.attention import chunked_attention
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    f = jax.jit(lambda q: chunked_attention(q, q, q, pos, pos, chunk=256))
    rows.append({"kernel": "chunked_attention", "shape": f"{B}x{S}x{H}x{D}",
                 "us_per_call": round(_time(f, q), 1)})

    # SSD chunked scan
    from repro.models.ssm import ssd_chunked
    Bs, Ss, Hs, P, N = 2, 512, 8, 64, 64
    x = jax.random.normal(key, (Bs, Ss, Hs, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (Bs, Ss, Hs))) * 0.1
    A = -jnp.exp(jax.random.normal(key, (Hs,)) * 0.3)
    Bm = jax.random.normal(key, (Bs, Ss, N), jnp.float32)
    D = jnp.ones((Hs,), jnp.float32)
    g = jax.jit(lambda x, dt, Bm: ssd_chunked(x, dt, A, Bm, Bm, D, 128)[0])
    rows.append({"kernel": "ssd_chunked", "shape": f"{Bs}x{Ss}x{Hs}x{P}",
                 "us_per_call": round(_time(g, x, dt, Bm), 1)})

    # paper's LS hot loop: kernel-vs-simulator agreement + timing
    T, n, d, r = 32, 30, 600, 4
    X = jax.random.normal(key, (T, n, d), jnp.float32)
    U = jnp.linalg.qr(jax.random.normal(key, (d, r), jnp.float32))[0]
    y = jax.random.normal(key, (T, n), jnp.float32)
    Bk = ops.altgdmin_minimize_B(X, U, y, blk_d=200)
    G, c = ref.ref_task_gram(X, U, y)
    Bref = jnp.stack([jnp.linalg.solve(G[t], c[t]) for t in range(T)])
    agree = bool(jnp.allclose(Bk, Bref, rtol=1e-3, atol=1e-4))
    h = jax.jit(lambda X, U, y: jnp.einsum("tnr,tns->trs",
                                           jnp.einsum("tnd,dr->tnr", X, U),
                                           jnp.einsum("tnd,dr->tnr", X, U)))
    rows.append({"kernel": "altgdmin_ls(ref path)",
                 "shape": f"T{T}xn{n}xd{d}xr{r}",
                 "us_per_call": round(_time(h, X, U, y), 1),
                 "kernel_matches_ref": agree})
    return rows
