"""Kernel micro-benchmarks: wall-time of the jnp production paths on CPU
(the Pallas kernels run in interpret mode here, so CPU timings of them are
meaningless — on-TPU projections come from the roofline instead; this
table tracks the *reference* path and validates kernel-vs-ref agreement
as a benchmark-time canary)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    fn(*args)            # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6    # µs


# ------------------------------------------------- AltGDmin engine

def altgdmin_iter_flops(L, tpn, n, d, r, *, fused: bool) -> int:
    """Model FLOPs of one outer AltGDmin iteration (min-B + gradient)
    across all L·tpn tasks.  The unfused path builds the streamed
    A = X_t U twice (Gram pass + gradient pass 0); the fused engine once —
    the 2ndr A-build dominates: dropping one of three X-sized streams is
    one fewer HBM sweep over X (~33% of X traffic) and an
    r/(2r+1) ≈ 40–44% model-FLOP cut at the paper's r=4–10 shapes."""
    T = L * tpn
    a_build = 2 * n * d * r
    gram = 2 * n * r * r + 2 * n * r          # G = AᵀA, c = Aᵀy
    solve = (2 * r ** 3) // 3                 # r×r Cholesky
    resid = 2 * n * r + n                     # A b − y
    grad = 2 * n * d + d * r                  # Xᵀresid, outer with b
    per_task = a_build * (1 if fused else 2) + gram + solve + resid + grad
    return T * per_task


def _engine_instance(L, tpn, n, d, r, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(ks[0], (L, tpn, n, d), jnp.float32)
    U = jnp.stack([
        jnp.linalg.qr(jax.random.normal(jax.random.fold_in(ks[1], g),
                                        (d, r), jnp.float32))[0]
        for g in range(L)])
    y = jax.random.normal(ks[2], (L, tpn, n), jnp.float32)
    return X, U, y


# Paper Experiment-1 regime: the CI-scale variant, its 10× scaling, and
# (full runs only) the exact paper shape L=20, T=600, d=600, n=30, r=4.
ENGINE_SHAPES = (
    dict(shape="exp1_small", L=10, tpn=15, n=30, d=150, r=4),
    dict(shape="exp1_small_10x", L=10, tpn=30, n=30, d=750, r=4),
)
ENGINE_SHAPES_FULL = ENGINE_SHAPES + (
    dict(shape="exp1_paper", L=20, tpn=30, n=30, d=600, r=4),
)


def bench_altgdmin_engine(quick: bool = False):
    """µs/outer-iteration of the AltGDmin hot loop: fused engine vs the
    unfused two-dispatch kernel pair vs the xla-ref einsum path.  On this
    CPU container the Pallas backends run in interpret mode, so their
    absolute timings are not TPU projections — the model-FLOP column is
    the hardware-independent trajectory metric; xla-ref timings track the
    simulator's real CPU cost."""
    shapes = ENGINE_SHAPES if quick else ENGINE_SHAPES_FULL
    rows = []
    for cfg in shapes:
        L, tpn, n, d, r = (cfg[k] for k in ("L", "tpn", "n", "d", "r"))
        X, U, y = _engine_instance(L, tpn, n, d, r)
        big = L * tpn * n * d >= 5_000_000    # interpret mode is slow here
        reps_interp = 1 if (big or quick) else 3

        def fused(backend, reps):
            def f(X, U, y):
                return ops.altgdmin_fused_step(X, U, y, blk_d=256,
                                               backend=backend)
            return _time(f, X, U, y, reps=reps)

        def unfused(backend, reps):
            def f(X, U, y):
                B = ops.altgdmin_node_minimize_B(X, U, y, blk_d=256,
                                                 backend=backend)
                return ops.altgdmin_node_gradient(X, U, B, y, blk_d=256,
                                                  backend=backend)
            return _time(f, X, U, y, reps=reps)

        variants = [
            # the fused engine kernel (single dispatch, one A build)
            ("fused", "pallas-interpret", True,
             lambda: fused("pallas-interpret", reps_interp)),
            # the same kernels unfused (gram dispatch + grad dispatch,
            # A rebuilt in the gradient's pass 0)
            ("unfused", "pallas-interpret", False,
             lambda: unfused("pallas-interpret", reps_interp)),
            # the seed simulator's einsum path (XLA schedules; A also
            # materialized twice)
            ("reference", "xla-ref", False, lambda: unfused("xla-ref", 5)),
        ]
        for engine_path, backend, is_fused, run in variants:
            rows.append(dict(
                cfg, engine=engine_path, backend=backend,
                us_per_iteration=round(run(), 1),
                model_flops_per_iteration=altgdmin_iter_flops(
                    L, tpn, n, d, r, fused=is_fused)))
    return rows


# ------------------------------------------------- consensus combine

# Per-node gossip operand is the d×r subspace iterate; K = ring degree.
CONSENSUS_SHAPES = (
    dict(shape="paper_dxr", d=600, r=4, K=2),       # paper Experiment 1
    dict(shape="large_dxr", d=4096, r=16, K=4),     # production-ish torus
)


def bench_consensus(quick: bool = False, t_con: int = 3):
    """µs per gossip round of the mesh runtime's combine phase: the
    fused (K+1)-way ``gossip_combine`` kernel (ONE dispatch per round,
    uniform ring weights AND the per-shift weighted form that arbitrary
    topologies — Metropolis rows — lower to) vs the unfused weighted-sum
    chain (K separate axpy sweeps — the pre-consensus-layer runtime
    path).  Neighbour blocks are held fixed (the ppermute cost is
    identical for all variants and excluded); interpret-mode timings are
    CPU validations, not TPU projections — the dispatch count (1 vs K)
    is the trajectory metric."""
    rows = []
    key = jax.random.PRNGKey(0)
    shapes = CONSENSUS_SHAPES[:1] if quick else CONSENSUS_SHAPES
    for cfg in shapes:
        d, r, K = cfg["d"], cfg["r"], cfg["K"]
        z = jax.random.normal(key, (d, r), jnp.float32)
        nbrs = jax.random.normal(jax.random.fold_in(key, 1), (K, d, r),
                                 jnp.float32)
        sw = 1.0 / (K + 1)
        wn = (1.0 - sw) / K
        w_uniform = jnp.asarray((sw,) + (wn,) * K, jnp.float32)
        # a non-uniform Metropolis-style row (what an irregular-graph
        # device actually feeds the kernel)
        w_row = jax.nn.softmax(jax.random.normal(
            jax.random.fold_in(key, 2), (K + 1,))).astype(jnp.float32)

        def make_fused(w):
            @jax.jit
            def fused_rounds(z, nbrs):
                def body(carry, _):
                    return ops.gossip_combine(
                        carry, nbrs, w, backend="pallas-interpret"), None
                return jax.lax.scan(body, z, None, length=t_con)[0]
            return fused_rounds

        @jax.jit
        def chain_rounds(z, nbrs):
            def body(carry, _):
                acc = sw * carry
                for k in range(K):
                    acc = acc + wn * nbrs[k]
                return acc, None
            return jax.lax.scan(body, z, None, length=t_con)[0]

        for variant, fn, dispatches in (
                ("fused_gossip_combine", make_fused(w_uniform), 1),
                ("fused_weighted_combine", make_fused(w_row), 1),
                ("unfused_chain", chain_rounds, K)):
            us = _time(fn, z, nbrs, reps=2 if quick else 5) / t_con
            rows.append(dict(cfg, variant=variant, t_con=t_con,
                             combine_dispatches_per_round=dispatches,
                             us_per_round=round(us, 1)))
    return rows


def bench_kernels():
    rows = []
    key = jax.random.PRNGKey(0)

    # chunked attention (production jnp path) at a prefill-ish shape
    B, S, H, D = 1, 1024, 8, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    from repro.models.attention import chunked_attention
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    f = jax.jit(lambda q: chunked_attention(q, q, q, pos, pos, chunk=256))
    rows.append({"kernel": "chunked_attention", "shape": f"{B}x{S}x{H}x{D}",
                 "us_per_call": round(_time(f, q), 1)})

    # SSD chunked scan
    from repro.models.ssm import ssd_chunked
    Bs, Ss, Hs, P, N = 2, 512, 8, 64, 64
    x = jax.random.normal(key, (Bs, Ss, Hs, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (Bs, Ss, Hs))) * 0.1
    A = -jnp.exp(jax.random.normal(key, (Hs,)) * 0.3)
    Bm = jax.random.normal(key, (Bs, Ss, N), jnp.float32)
    D = jnp.ones((Hs,), jnp.float32)
    g = jax.jit(lambda x, dt, Bm: ssd_chunked(x, dt, A, Bm, Bm, D, 128)[0])
    rows.append({"kernel": "ssd_chunked", "shape": f"{Bs}x{Ss}x{Hs}x{P}",
                 "us_per_call": round(_time(g, x, dt, Bm), 1)})

    # paper's LS hot loop: kernel-vs-simulator agreement + timing
    T, n, d, r = 32, 30, 600, 4
    X = jax.random.normal(key, (T, n, d), jnp.float32)
    U = jnp.linalg.qr(jax.random.normal(key, (d, r), jnp.float32))[0]
    y = jax.random.normal(key, (T, n), jnp.float32)
    Bk = ops.altgdmin_minimize_B(X, U, y, blk_d=200)
    G, c = ref.ref_task_gram(X, U, y)
    Bref = jnp.stack([jnp.linalg.solve(G[t], c[t]) for t in range(T)])
    agree = bool(jnp.allclose(Bk, Bref, rtol=1e-3, atol=1e-4))
    h = jax.jit(lambda X, U, y: jnp.einsum("tnr,tns->trs",
                                           jnp.einsum("tnd,dr->tnr", X, U),
                                           jnp.einsum("tnd,dr->tnr", X, U)))
    rows.append({"kernel": "altgdmin_ls(ref path)",
                 "shape": f"T{T}xn{n}xd{d}xr{r}",
                 "us_per_call": round(_time(h, X, U, y), 1),
                 "kernel_matches_ref": agree})
    return rows


# ------------------------------------------------- compressed combine

# The acceptance shape of the compressed rules: the paper's d×r iterate
# at (d=100, r=4, L=16) on the degree-2 ring.
COMPRESSION_SHAPE = dict(shape="paper_d100", d=100, r=4, L=16, K=2)


def bench_compression(quick: bool = False, t_con: int = 3):
    """Wire volume + µs/round of the compressed consensus rules vs dense
    gossip: per variant the declared CommSignature payload
    (entries/round, bytes/iter at the paper's f64 network model and the
    reduction factor vs dense) and the measured time of one simulator
    round — the fused lowering (pallas-interpret: mix_rows on the
    refreshed copies + compress/dequant kernels) vs the exact xla-ref
    chain.  Interpret-mode timings are CPU validations, not TPU
    projections; the bytes columns are the trajectory metric.  The
    event rule also reports its measured send fraction on a
    near-consensus iterate (the static signature prices the θ=0 worst
    case).

    ``reduction_vs_dense`` is the full wire-format factor (the dense
    baseline ships f64 under the paper's model); ``entries_reduction``
    isolates the pure entry-count factor so the sparsification and the
    lower-precision-wire contributions are not conflated (top-k at
    k=d/4: 6.4× = 3.2× fewer entries × 2× f32 wire)."""
    import numpy as np

    from repro.distributed.consensus import CommSignature, get_rule

    cfg = COMPRESSION_SHAPE
    d, r, L, K = cfg["d"], cfg["r"], cfg["L"], cfg["K"]
    key = jax.random.PRNGKey(0)
    Z = jax.random.normal(key, (L, d, r), jnp.float32)
    W = jnp.asarray(np.eye(L) / 3
                    + np.roll(np.eye(L), 1, 1) / 3
                    + np.roll(np.eye(L), -1, 1) / 3, jnp.float32)
    dense_bytes = CommSignature("gossip", t_con).bytes_per_iter(
        d * r, 8, L, K)

    variants = [
        ("dense_gossip", "gossip", {}),
        ("topk_quarter_d", "topk_gossip", {"compression_k": d // 4}),
        ("quantized_bf16", "quantized_gossip", {}),
        ("quantized_int8", "quantized_gossip", {"compression": "int8"}),
        ("event_theta_0.05", "event_gossip", {"event_threshold": 0.05}),
    ]
    rows = []
    for variant, rule_name, kw in variants:
        rule = get_rule(rule_name)
        sig = rule.signature(t_con, d=d, r=r, **kw)
        bytes_iter = sig.bytes_per_iter(d * r, 8, L, K)

        def timed_round(backend, rule=rule, rule_name=rule_name, kw=kw):
            if rule_name == "gossip":
                mixer = rule.make_sim_mixer(W, t_con, backend=backend)
                fn = jax.jit(mixer)
                return _time(fn, Z, reps=3 if quick else 10) / t_con
            mixer = rule.make_sim_state_mixer(W, t_con, backend=backend,
                                              **kw)
            state = rule.init_state(Z, **kw)
            fn = jax.jit(lambda z, s: mixer(z, s)[0])
            return _time(fn, Z, state, reps=3 if quick else 10) / t_con

        entries = (sig.entries_per_round
                   if sig.entries_per_round is not None else d * r)
        row = dict(cfg, variant=variant, t_con=t_con,
                   entries_per_round=entries,
                   bytes_per_iter=bytes_iter,
                   reduction_vs_dense=round(dense_bytes / bytes_iter, 2),
                   entries_reduction=round(d * r / entries, 2),
                   us_per_round_fused=round(
                       timed_round("pallas-interpret"), 1),
                   us_per_round_ref=round(timed_round("xla-ref"), 1))
        if rule_name == "event_gossip":
            # measured trigger rate: cold copies always send; a
            # near-consensus iterate with warm copies almost never does
            rule_ev = get_rule("event_gossip")
            row["send_frac_cold"] = float(rule_ev.send_fraction(
                Z, jnp.zeros_like(Z), kw["event_threshold"]))
            row["send_frac_warm"] = float(rule_ev.send_fraction(
                Z, Z * (1 + 1e-4), kw["event_threshold"]))
        rows.append(row)
    return rows
