"""Roofline-table builder: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline markdown table and a machine-readable CSV.

  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return [r for r in recs if r.get("status") == "ok"]


def fmt_s(x):
    return f"{x:.2e}"


def what_would_help(rec) -> str:
    dom = rec["roofline"]["dominant"]
    kind = rec["kind"]
    if dom == "collective":
        return ("fewer/smaller collectives: larger per-node shards, "
                "gossip instead of all-reduce, or overlap with compute")
    if dom == "memory":
        if kind == "decode":
            return ("decode is weight/cache-streaming bound: quantize "
                    "weights/KV or batch more tokens per weight read")
        return ("raise arithmetic intensity: fuse ops, larger blocks, "
                "bf16 activations, avoid re-materialization")
    return "compute-bound — already near the useful roofline; check MFU"


def build_rows(recs, mesh_filter="16x16"):
    rows = []
    for r in recs:
        if r["mesh"] != mesh_filter:
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "kind": r["kind"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"],
            "model_flops": rf["model_flops_total"],
            "hlo_flops": rf["hlo_flops_total"],
            "useful_ratio": rf["useful_flops_ratio"],
            "peak_gib_per_dev": r["memory"]["peak_bytes"] / 2**30,
            "dominant_collective": r.get("dominant_collective", ""),
            "note": r.get("note", ""),
        })
    rows.sort(key=lambda x: (x["arch"], SHAPE_ORDER.index(x["shape"])))
    return rows


def markdown_table(rows):
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful FLOPs | peak GiB/dev |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        ur = (f"{r['useful_ratio']:.2f}" if r["useful_ratio"]
              else "n/a")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {ur} | "
            f"{r['peak_gib_per_dev']:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--csv", default="experiments/bench/roofline.csv")
    args = ap.parse_args()
    recs = load(args.dir)
    rows = build_rows(recs, args.mesh)
    print(markdown_table(rows))
    print(f"\n{len(rows)} rows ({args.mesh}); "
          f"{len(recs)} ok records total")
    # dominant-term census + hillclimb candidates
    from collections import Counter
    print("bottleneck census:", Counter(r["dominant"] for r in rows))
    worst = sorted(rows, key=lambda r: -(r["useful_ratio"] or 0))
    coll = sorted(rows, key=lambda r: -r["collective_s"] /
                  max(r["compute_s"] + r["memory_s"], 1e-12))
    print("most collective-bound:",
          [(r['arch'], r['shape']) for r in coll[:3]])
    os.makedirs(os.path.dirname(args.csv), exist_ok=True)
    import csv as _csv
    with open(args.csv, "w", newline="") as f:
        w = _csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
