"""§Perf hillclimb driver: runs named variants of the three chosen
(arch × shape) pairs through the dry-run pipeline and prints
before/after roofline terms per hypothesis.

MUST run in its own process (512 fake devices):

  PYTHONPATH=src python -m benchmarks.hillclimb --pair deepseek-train
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import json            # noqa: E402

from repro.launch.dryrun import run_one      # noqa: E402


# Each experiment: (tag, kwargs for run_one).  The first entry is the
# paper-faithful BASELINE; later entries are the hypothesis ladder.
PAIRS = {
    # most collective-bound + MoE (expert-parallel a2a) — drive the
    # collective term down
    "deepseek-train": [
        ("baseline_diffusion_tcon1",
         dict(arch="deepseek-v3-671b", shape_name="train_4k",
              multi_pod=False, aggregation="diffusion", t_con=1)),
        ("H1_allreduce_fusion_center",
         dict(arch="deepseek-v3-671b", shape_name="train_4k",
              multi_pod=False, aggregation="allreduce")),
        ("H2_wire_bf16",
         dict(arch="deepseek-v3-671b", shape_name="train_4k",
              multi_pod=False, aggregation="diffusion", t_con=1,
              wire_dtype="bfloat16")),
        ("H3_wire_bf16_remat_dots",
         dict(arch="deepseek-v3-671b", shape_name="train_4k",
              multi_pod=False, aggregation="diffusion", t_con=1,
              wire_dtype="bfloat16", remat_policy="dots")),
    ],
    # worst decode memory (MHA 32k KV cache, 77 GiB/dev) — drive the
    # memory term / peak bytes down
    "musicgen-decode": [
        ("baseline",
         dict(arch="musicgen-medium", shape_name="decode_32k",
              multi_pod=False)),
        ("H1_shard_cache_slots",
         dict(arch="musicgen-medium", shape_name="decode_32k",
              multi_pod=False, shard_cache_slots=True)),
    ],
    # the paper's own technique at LM scale: aggregation strategy ladder
    "qwen3-train": [
        ("baseline_diffusion_tcon1",
         dict(arch="qwen3-1.7b", shape_name="train_4k", multi_pod=False,
              aggregation="diffusion", t_con=1)),
        ("A_consensus_tcon10_decAltGDmin",
         dict(arch="qwen3-1.7b", shape_name="train_4k", multi_pod=False,
              aggregation="consensus", t_con=10)),
        ("B_allreduce_fusion_center",
         dict(arch="qwen3-1.7b", shape_name="train_4k", multi_pod=False,
              aggregation="allreduce")),
        ("H1_wire_bf16",
         dict(arch="qwen3-1.7b", shape_name="train_4k", multi_pod=False,
              aggregation="diffusion", t_con=1, wire_dtype="bfloat16")),
        ("H2_remat_dots",
         dict(arch="qwen3-1.7b", shape_name="train_4k", multi_pod=False,
              aggregation="diffusion", t_con=1, wire_dtype="bfloat16",
              remat_policy="dots")),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True,
                    choices=list(PAIRS) + ["all"])
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    pairs = list(PAIRS) if args.pair == "all" else [args.pair]
    for pair in pairs:
        print(f"\n===== {pair} =====")
        for tag, kw in PAIRS[pair]:
            try:
                rec = run_one(**kw)
            except Exception as e:
                print(f"{tag}: FAILED {e!r}")
                continue
            path = os.path.join(args.out, f"{pair}_{tag}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            r = rec["roofline"]
            print(f"{tag}: compute={r['compute_s']:.3e} "
                  f"mem={r['memory_s']:.3e} coll={r['collective_s']:.3e} "
                  f"dom={r['dominant']} "
                  f"peak={rec['memory']['peak_bytes']/2**30:.1f}GiB "
                  f"useful={r['useful_flops_ratio']:.2f}", flush=True)


if __name__ == "__main__":
    main()
