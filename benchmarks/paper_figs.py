"""Benchmarks reproducing the paper's two figures (scaled for CPU):

  fig1 — Experiment 1: subspace distance vs iteration AND vs emulated
         wall-clock (1 Gbps / 5 ms network model) for Dif-AltGDmin,
         Dec-AltGDmin, centralized AltGDmin, DGD; T_con ∈ {2, 5, 10}.
  fig2 — Experiment 2: robustness to connectivity, p ∈ {0.2, 0.5, 0.8}.

Each returns rows of CSV records; benchmarks.run prints them and writes
experiments/bench/*.csv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    generate_problem, node_view, decentralized_spectral_init,
    dif_altgdmin, dec_altgdmin, centralized_altgdmin, dgd_altgdmin,
)
from repro.core.altgdmin import resolve_eta
from repro.core.comm_model import (
    decentralized_time_axis, centralized_time_axis, ETHERNET_1GBPS,
)
from repro.distributed import erdos_renyi, metropolis_weights, gamma


def _setup(cfg, trial: int):
    prob = generate_problem(jax.random.PRNGKey(cfg.seed + trial),
                            d=cfg.d, T=cfg.T, r=cfg.r, n=cfg.n, L=cfg.L,
                            kappa=2.0)
    Xg, yg = node_view(prob)
    graph = erdos_renyi(cfg.L, cfg.p, seed=cfg.seed + 100 + trial)
    W = jnp.asarray(metropolis_weights(graph))
    init = decentralized_spectral_init(
        jax.random.PRNGKey(cfg.seed + 200 + trial), Xg, yg, W,
        kappa=prob.kappa, mu=prob.mu, r=cfg.r, T_pm=cfg.T_pm,
        T_con=cfg.T_con)
    eta = resolve_eta(None, cfg.n, R_diag=init.R_diag, L=cfg.L)
    return prob, Xg, yg, graph, W, init, eta


def _algorithms(cfg, prob, Xg, yg, graph, W, init, eta):
    kw = dict(eta=eta, T_GD=cfg.T_GD, U_star=prob.U_star)
    return {
        "dif_altgdmin": lambda: dif_altgdmin(init.U0, Xg, yg, W,
                                             T_con=cfg.T_con, **kw),
        "dec_altgdmin": lambda: dec_altgdmin(init.U0, Xg, yg, W,
                                             T_con=cfg.T_con, **kw),
        "altgdmin_central": lambda: centralized_altgdmin(init.U0[0], Xg,
                                                         yg, **kw),
        "dgd_variant": lambda: dgd_altgdmin(
            init.U0, Xg, yg, jnp.asarray(graph.adj, jnp.float64), **kw),
    }


def _time_axis(alg: str, cfg, graph, n_iters: int):
    if alg == "altgdmin_central":
        return centralized_time_axis(n_iters, cfg.d, cfg.r, cfg.L, 1e-3)
    t_con = 1 if alg == "dgd_variant" else cfg.T_con
    return decentralized_time_axis(n_iters, t_con, cfg.d, cfg.r,
                                   graph.max_degree, 1e-3)


def run_experiment(configs, n_trials: int, checkpoints=(0, 0.25, 0.5,
                                                        0.75, 1.0)):
    rows = []
    for cfg in configs:
        acc = {}
        for trial in range(n_trials):
            prob, Xg, yg, graph, W, init, eta = _setup(cfg, trial)
            for alg, fn in _algorithms(cfg, prob, Xg, yg, graph, W, init,
                                       eta).items():
                sd = np.asarray(fn().sd_max)
                acc.setdefault(alg, []).append((sd, graph))
        for alg, runs in acc.items():
            sds = np.stack([sd for sd, _ in runs])
            mean_sd = sds.mean(axis=0)
            t_axis = _time_axis(alg, cfg, runs[0][1], len(mean_sd))
            for frac in checkpoints:
                i = min(int(frac * (len(mean_sd) - 1)), len(mean_sd) - 1)
                rows.append({
                    "config": cfg.name, "algorithm": alg,
                    "T_con": cfg.T_con, "p": cfg.p, "iteration": i,
                    "subspace_distance": float(mean_sd[i]),
                    "emulated_time_s": float(t_axis[i]),
                    "n_trials": n_trials,
                })
    return rows


def bench_fig1(n_trials: int = 2):
    """Experiment 1: vary T_con (uses the scaled-down preset)."""
    from repro.configs.paper import EXPERIMENT1_SMALL
    return run_experiment(EXPERIMENT1_SMALL, n_trials)


def bench_fig2(n_trials: int = 2):
    """Experiment 2: vary edge probability p."""
    from repro.configs.paper import EXPERIMENT2_SMALL
    return run_experiment(EXPERIMENT2_SMALL, n_trials)
