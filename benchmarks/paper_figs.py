"""Benchmarks reproducing the paper's two figures (scaled for CPU):

  fig1 — Experiment 1: subspace distance vs iteration AND vs emulated
         wall-clock (1 Gbps / 5 ms network model) for Dif-AltGDmin,
         Dec-AltGDmin, centralized AltGDmin, DGD; T_con ∈ {2, 5, 10}.
  fig2 — Experiment 2: robustness to connectivity, p ∈ {0.2, 0.5, 0.8}.

Each figure is a sweep of :class:`ExperimentSpec` cells — algorithms ×
presets × trials — driven entirely through ``run_experiment``; the Trace
carries the comm-model wall-clock axis, so nothing is recomputed here.
Each bench returns rows of CSV records; benchmarks.run prints them and
writes experiments/bench/*.csv.
"""
from __future__ import annotations

import numpy as np

from repro.api import (ExperimentSpec, InitSpec, ProblemSpec, SolverSpec,
                       TopologySpec, materialize, run_experiment)

ALGORITHMS = {
    "dif_altgdmin": "dif_altgdmin",
    "dec_altgdmin": "dec_altgdmin",
    "altgdmin_central": "centralized_altgdmin",
    "dgd_variant": "dgd_altgdmin",
}


def _spec(cfg, trial: int, solver: str) -> ExperimentSpec:
    """One sweep cell.  Problem/topology/init sub-specs depend only on
    (cfg, trial), so the four algorithms of a cell share identical data,
    graph, starting bases, and η (the keys derive from the spec-level
    run key plus these seeds)."""
    return ExperimentSpec(
        name=cfg.name,
        problem=ProblemSpec(d=cfg.d, T=cfg.T, r=cfg.r, n=cfg.n, L=cfg.L,
                            kappa=2.0),
        topology=TopologySpec(family="erdos_renyi", p=cfg.p,
                              seed=cfg.seed + 100 + trial,
                              weights="metropolis"),
        init=InitSpec(T_pm=cfg.T_pm, T_con=cfg.T_con),
        solver=SolverSpec(name=solver, T_GD=cfg.T_GD, T_con=cfg.T_con),
    )


def run_experiment_grid(configs, n_trials: int,
                        checkpoints=(0, 0.25, 0.5, 0.75, 1.0)):
    rows = []
    for cfg in configs:
        acc = {}          # alg -> list of (sd_max, time_axis); keep only
        for trial in range(n_trials):             # what the rows need
            # the four solvers of one cell share the materialization
            # (identical problem/topology/init sub-specs and key)
            mat = materialize(_spec(cfg, trial, "dif_altgdmin"),
                              key=cfg.seed + trial)
            for alg, solver in ALGORITHMS.items():
                spec = _spec(cfg, trial, solver)
                trace = run_experiment(spec, key=cfg.seed + trial,
                                       materialized=mat)
                acc.setdefault(alg, []).append((trace.sd_max,
                                                trace.time_axis))
        for alg, results in acc.items():
            mean_sd = np.stack([sd for sd, _ in results]).mean(axis=0)
            t_axis = results[0][1]
            for frac in checkpoints:
                i = min(int(frac * (len(mean_sd) - 1)), len(mean_sd) - 1)
                rows.append({
                    "config": cfg.name, "algorithm": alg,
                    "T_con": cfg.T_con, "p": cfg.p, "iteration": i,
                    "subspace_distance": float(mean_sd[i]),
                    "emulated_time_s": float(t_axis[i]),
                    "n_trials": n_trials,
                })
    return rows


def bench_fig1(n_trials: int = 2):
    """Experiment 1: vary T_con (uses the scaled-down preset)."""
    from repro.configs.paper import EXPERIMENT1_SMALL
    return run_experiment_grid(EXPERIMENT1_SMALL, n_trials)


def bench_fig2(n_trials: int = 2):
    """Experiment 2: vary edge probability p."""
    from repro.configs.paper import EXPERIMENT2_SMALL
    return run_experiment_grid(EXPERIMENT2_SMALL, n_trials)


def specs_for_figure(configs, solvers=tuple(ALGORITHMS.values()),
                     trial: int = 0):
    """The sweep grid as serializable specs (JSON round-trip safe) — for
    external drivers that want to shard cells across workers."""
    return [_spec(cfg, trial, solver)
            for cfg in configs for solver in solvers]
