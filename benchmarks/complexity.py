"""Benchmark for the paper's Sec. III complexity table: Dif-AltGDmin vs
Dec-AltGDmin time/communication complexity, both the analytic formulas
(theory.py) and the MEASURED communication volume of the runtime
aggregation strategies — the claimed κ²-vs-κ⁴ and ε-(in)dependence
improvements made concrete.
"""
from __future__ import annotations


from repro.core import theory
from repro.distributed import AggregationConfig, comm_bytes_per_step


def bench_complexity_table():
    """Analytic τ_time / τ_comm for the paper's Experiment-1 setting at
    several target accuracies ε and condition numbers κ."""
    rows = []
    base = dict(n=30, d=600, T=600, r=4, L=20, gamma_W=0.8, max_deg=10)
    for kappa in (1.5, 2.0, 4.0):
        for eps in (1e-2, 1e-4, 1e-8):
            dif = theory.dif_complexity(kappa=kappa, eps=eps, **base)
            dec = theory.dec_complexity(kappa=kappa, eps=eps, **base)
            rows.append({
                "kappa": kappa, "eps": eps,
                "dif_T_con_GD": dif.T_con_GD, "dec_T_con_GD": dec.T_con_GD,
                "dif_tau_time": dif.tau_time, "dec_tau_time": dec.tau_time,
                "dif_tau_comm": dif.tau_comm, "dec_tau_comm": dec.tau_comm,
                "time_speedup": dec.tau_time / dif.tau_time,
                "comm_reduction": dec.tau_comm / dif.tau_comm,
            })
    return rows


def bench_trainer_comm():
    """Per-step communication volume of each trainer aggregation strategy
    for a 1B-param backbone over 16 nodes (bf16) — the deep-net analogue
    of the paper's communication-complexity comparison."""
    n_params, itemsize, L = 1_000_000_000, 2, 16
    rows = []
    for strategy, t_con in [("allreduce", 0), ("diffusion", 1),
                            ("diffusion", 3), ("consensus", 10),
                            ("consensus", 30), ("dgd", 1), ("local", 0)]:
        agg = AggregationConfig(strategy=strategy, t_con=max(t_con, 1))
        b = comm_bytes_per_step(n_params, itemsize, agg, L)
        rows.append({"strategy": strategy, "t_con": t_con,
                     "bytes_per_node_per_step": b,
                     "gbytes": round(b / 1e9, 3)})
    return rows
