"""Scale benchmark — the sparse consensus path at large L
(``BENCH_altgdmin.json["scale"]``):

  * section="large_L": a full dif_altgdmin run through the runner on the
    sparse simulator substrate at L=100k (quick: L=10k) — Barabási–Albert
    relatedness graph, O(E) SparseWeights mixing, no (L, L) allocation
    anywhere.  Reports µs per outer GD iteration, peak RSS, and the edge
    count the comm model prices.
  * section="sparse_vs_dense": µs per T_con-round AGREE mix of the
    sparse segment-sum lowering vs the dense stacked ``W @ Z`` at
    moderate L — the crossover behind the auto-sparsify density/size
    cutoff.
  * section="rcm": shift-count pruning of the mesh cyclic-shift
    decomposition under RCM relabeling — irregular ER (an expander:
    bandwidth, hence shift count, is irreducible) vs a
    scrambled-labeling cluster-of-cliques graph where RCM recovers the
    banded structure.
  * section="virtual_mesh": the virtual-node mesh tier at the same
    L=100k (quick: 10k) on 8 fake host devices — three NON-gossip
    programs (exact_diffusion's ψ-corrected combine, dif_topk's
    compressed wire, dif_partial's masked dropout combine) through
    the one program lowering, via the runner's mesh dispatch.  Runs in
    a subprocess because the fake device count is fixed at process
    start.
"""
from __future__ import annotations

import json
import resource
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def bench_large_L(quick: bool = False):
    from repro.api.runner import materialize, run_experiment
    from repro.api.spec import (ExperimentSpec, InitSpec, ProblemSpec,
                                SolverSpec, TopologySpec)

    L = 10_000 if quick else 100_000
    spec = ExperimentSpec(
        problem=ProblemSpec(d=16, T=L, r=2, n=8, L=L, kappa=1.2),
        topology=TopologySpec(family="barabasi_albert", ba_m=3, seed=0,
                              weights="metropolis",
                              representation="sparse"),
        init=InitSpec(T_pm=3, T_con=2),
        solver=SolverSpec(name="dif_altgdmin", T_GD=3, T_con=3, eta=1e-4),
        substrate="simulator",
    )
    rss0 = _peak_rss_mb()
    mat = materialize(spec)
    graph = mat.graph
    t0 = time.perf_counter()
    trace = run_experiment(spec, materialized=mat)
    jax.block_until_ready(trace.U_nodes)
    total_s = time.perf_counter() - t0
    # separate the steady-state iteration cost from jit compilation:
    # second run on the SAME materialization reuses every compiled fn
    t1 = time.perf_counter()
    trace = run_experiment(spec, materialized=mat)
    jax.block_until_ready(trace.U_nodes)
    warm_s = time.perf_counter() - t1
    return [{
        "section": "large_L",
        "L": L,
        "family": "barabasi_albert",
        "n_edges": int(graph.n_edges),
        "density": float(graph.density),
        "us_per_iter": warm_s / spec.solver.T_GD * 1e6,
        "first_run_s": round(total_s, 3),
        "peak_rss_mb": round(_peak_rss_mb(), 1),
        "rss_before_mb": round(rss0, 1),
        "sd_max_final": float(trace.sd_max[-1]),
    }]


def bench_sparse_vs_dense(quick: bool = False):
    from repro.distributed import graphs, mixing
    from repro.distributed.consensus import stacked_product

    rows = []
    t_con = 3
    Ls = (512, 1024) if quick else (512, 1024, 4096)
    for L in Ls:
        g = graphs.erdos_renyi(L, p=min(10.0 / L, 1.0), seed=0)
        sw = mixing.metropolis_weights_sparse(g)
        Wd = jnp.asarray(sw.to_dense())
        Z = jax.random.normal(jax.random.PRNGKey(1), (L, 64))

        def dense_mix(z):
            return stacked_product(z, Wd, t_con)

        def sparse_mix(z):
            return stacked_product(z, sw, t_con)

        for name, fn in (("dense", jax.jit(dense_mix)),
                         ("sparse", jax.jit(sparse_mix))):
            fn(Z).block_until_ready()
            t0 = time.perf_counter()
            reps = 10
            for _ in range(reps):
                out = fn(Z)
            jax.block_until_ready(out)
            rows.append({
                "section": "sparse_vs_dense",
                "L": L,
                "path": name,
                "n_edges": int(sw.n_edges),
                "T_con": t_con,
                "us_per_mix": (time.perf_counter() - t0) / reps * 1e6,
            })
    return rows


def bench_rcm(quick: bool = False):
    from repro.distributed import graphs, mixing
    from repro.distributed.consensus import mesh_weights_relabeled

    rows = []
    L = 128 if quick else 256
    cases = {
        "erdos_renyi": np.asarray(mixing.metropolis_weights(
            graphs.erdos_renyi(L, p=4.0 / L, seed=5).to_dense())),
    }
    rng = np.random.default_rng(0)
    Wc = np.asarray(mixing.metropolis_weights(
        graphs.cluster_of_cliques(L, clique=8, seed=2).to_dense()))
    p = rng.permutation(L)
    cases["cluster_cliques_scrambled"] = Wc[np.ix_(p, p)]
    for name, W in cases.items():
        t0 = time.perf_counter()
        rw = mesh_weights_relabeled(W)     # includes round-trip verify
        rows.append({
            "section": "rcm",
            "L": L,
            "graph": name,
            "shifts_before": rw.shifts_before,
            "shifts_after": rw.shifts_after,
            "prune_factor": round(rw.shifts_before
                                  / max(rw.shifts_after, 1), 2),
            "ms": (time.perf_counter() - t0) * 1e3,
        })
    return rows


_VIRTUAL_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, resource, sys, time
    import jax
    jax.config.update("jax_enable_x64", True)
    import dataclasses
    from repro.api.runner import materialize, run_experiment
    from repro.api.spec import (ExperimentSpec, InitSpec, ProblemSpec,
                                SolverSpec, TopologySpec)

    L = int(sys.argv[1])
    base = ExperimentSpec(
        problem=ProblemSpec(d=16, T=L, r=2, n=8, L=L, kappa=1.2),
        topology=TopologySpec(family="barabasi_albert", ba_m=3, seed=0,
                              weights="metropolis",
                              representation="sparse"),
        init=InitSpec(T_pm=3, T_con=2),
        solver=SolverSpec(name="dif_altgdmin", T_GD=3, T_con=3, eta=1e-4),
        substrate="mesh",
    )
    mat = materialize(base)          # one graph/init for all solvers
    n_dev = jax.device_count()
    rows = []
    for name, kw in (("exact_diffusion", {}),
                     ("dif_topk", {"compression_k": 4}),
                     ("dif_partial", {})):
        spec = dataclasses.replace(
            base, solver=dataclasses.replace(base.solver, name=name, **kw))
        t0 = time.perf_counter()
        trace = run_experiment(spec, materialized=mat)
        jax.block_until_ready(trace.U_nodes)
        total_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        trace = run_experiment(spec, materialized=mat)
        jax.block_until_ready(trace.U_nodes)
        warm_s = time.perf_counter() - t1
        rows.append({
            "section": "virtual_mesh",
            "solver": name,
            "L": L,
            "n_dev": n_dev,
            "block": L // n_dev,
            "n_edges": int(mat.graph.n_edges),
            "us_per_iter": warm_s / spec.solver.T_GD * 1e6,
            "first_run_s": round(total_s, 3),
            "peak_rss_mb": round(
                resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                / 1024.0, 1),
            "sd_max_final": float(trace.sd_max[-1]),
        })
    print("ROWS=" + json.dumps(rows))
""")


def bench_virtual_mesh(quick: bool = False):
    """Virtual-node mesh tier rows — non-gossip programs at large L on
    8 fake host devices (subprocess: device count is process-fixed)."""
    import os

    L = 10_000 if quick else 100_000
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    r = subprocess.run([sys.executable, "-c", _VIRTUAL_SCRIPT, str(L)],
                       capture_output=True, text=True, cwd=repo_root,
                       env={**env, "PYTHONPATH": "src"}, timeout=5400)
    if r.returncode != 0:
        raise RuntimeError(f"virtual-mesh bench failed:\n{r.stderr[-4000:]}")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("ROWS=")][-1]
    return json.loads(line[len("ROWS="):])


def bench_scale(quick: bool = False):
    return (bench_large_L(quick=quick)
            + bench_sparse_vs_dense(quick=quick)
            + bench_rcm(quick=quick)
            + bench_virtual_mesh(quick=quick))
