"""System-realism benchmark — convergence vs SIMULATED seconds under
seeded node dropout.

One problem cell (the paper's Experiment-1 shape, scaled down in
``--quick``), four solvers: dense ``dif_altgdmin`` under an always-on
SystemSpec (the baseline — its simulated axis must match the
closed-form model up to jitter), and the three dropout-tolerant
variants (``dif_partial`` / ``dif_stale`` / ``dif_pushsum``) under a
seeded 30%-dropout Bernoulli schedule.  Every run shares problem /
topology / init (one materialization), so the rows isolate what the
fault layer changes: the trajectory (subspace distance per iteration)
and the event-driven clock's pricing of the time the dropped sends
save.  Consumed by ``benchmarks.run`` into
``BENCH_altgdmin.json["system"]``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import (ExperimentSpec, InitSpec, ProblemSpec, SolverSpec,
                       SystemSpec, TopologySpec, materialize,
                       run_experiment)

CHECKPOINTS = (0.0, 0.25, 0.5, 0.75, 1.0)

ALWAYS_ON = SystemSpec()                                  # degenerate
DROPOUT_30 = SystemSpec(availability="bernoulli", p_on=0.7, seed=7)

SOLVERS = (("dif_altgdmin", ALWAYS_ON),
           ("dif_partial", DROPOUT_30),
           ("dif_stale", DROPOUT_30),
           ("dif_pushsum", DROPOUT_30))


def _base_spec(quick: bool) -> ExperimentSpec:
    if quick:
        problem = ProblemSpec(d=60, T=30, r=4, n=24, L=10, kappa=2.0)
        T_GD = 60
    else:
        problem = ProblemSpec(d=150, T=150, r=4, n=30, L=10, kappa=2.0)
        # 300 outer iterations: the stale-copy rule pays a genuine rate
        # cost for mixing one-iteration-old packets, and needs the extra
        # headroom to clear the 1e-2 acceptance bar the dense/partial/
        # push-sum runs clear by ~iteration 220
        T_GD = 300
    return ExperimentSpec(
        name="system_dropout",
        problem=problem,
        topology=TopologySpec(family="erdos_renyi", p=0.5, seed=11,
                              weights="metropolis"),
        init=InitSpec(T_pm=10, T_con=5),
        solver=SolverSpec(name="dif_altgdmin", T_GD=T_GD, T_con=5),
    )


def bench_system(quick: bool = False) -> list[dict]:
    """Rows: solver × checkpoint, with subspace distance and SIMULATED
    seconds (the event-driven clock) at that iteration."""
    base = _base_spec(quick)
    mat = materialize(base, key=17)
    rows = []
    for solver, system in SOLVERS:
        spec = dataclasses.replace(
            base, solver=dataclasses.replace(base.solver, name=solver),
            system=system)
        trace = run_experiment(spec, key=17, materialized=mat)
        n = len(trace.sd_max)
        live_frac = (1.0 if system.is_always_on
                     else float(system.availability_mask(
                         spec.solver.T_GD, spec.problem.L).mean()))
        for frac in CHECKPOINTS:
            i = min(int(frac * (n - 1)), n - 1)
            rows.append({
                "solver": solver,
                "availability": system.availability,
                "p_on": system.p_on,
                "live_fraction": round(live_frac, 4),
                "iteration": i,
                "subspace_distance": float(trace.sd_max[i]),
                "simulated_s": float(trace.time_axis[i]),
                "time_axis_source": trace.time_axis_source,
            })
        assert np.all(np.isfinite(trace.sd_max)), solver
        assert np.all(np.diff(trace.time_axis) > 0), solver
    return rows
